"""Synthetic token pipeline with checkpointable state + AQP-planned mixture.

The pipeline is organized in *blocks* (shard slabs), matching the paper's
storage model: a corpus is a set of domains, each a sequence of fixed-size
token blocks.  Mixture weights can be computed by an approximate query over
the corpus-metadata table through PilotDB (`plan_mixture_weights`) — the
paper's technique running inside the training framework's data layer:
"what fraction of high-quality tokens does each domain hold?" is a grouped
AVG with an a-priori error bound, answered from a block sample instead of a
full metadata scan.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core import CompositeAgg, ErrorSpec, PilotDB, Query
from repro.engine import logical as L
from repro.engine.executor import Executor
from repro.engine.expr import Col
from repro.engine.table import BlockTable


@dataclasses.dataclass
class DataState:
    """Checkpointable cursor: rng state + per-domain block cursors."""

    seed: int
    step: int
    cursors: Dict[str, int]

    def to_json(self):
        return {"seed": self.seed, "step": self.step, "cursors": dict(self.cursors)}

    @staticmethod
    def from_json(d):
        return DataState(seed=int(d["seed"]), step=int(d["step"]),
                         cursors=dict(d["cursors"]))


class TokenPipeline:
    """Deterministic, resumable synthetic LM batches."""

    def __init__(self, vocab_size: int, batch: int, seq: int, *,
                 domains: Optional[Dict[str, float]] = None, seed: int = 0):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq
        self.domains = domains or {"default": 1.0}
        total = sum(self.domains.values())
        self.weights = {k: v / total for k, v in self.domains.items()}
        self.state = DataState(seed=seed, step=0,
                               cursors={k: 0 for k in self.domains})

    def next_batch(self) -> Dict[str, np.ndarray]:
        # stateless-per-step RNG: resume-exact after checkpoint restore
        rng = np.random.default_rng((self.state.seed, self.state.step))
        names = sorted(self.weights)
        probs = np.array([self.weights[k] for k in names])
        doms = rng.choice(len(names), size=self.batch, p=probs)
        tokens = rng.integers(0, self.vocab, size=(self.batch, self.seq + 1),
                              dtype=np.int32)
        # domain imprint: offsets make batches domain-distinguishable
        tokens = (tokens + doms[:, None] * 17) % self.vocab
        for i, d in enumerate(doms):
            self.state.cursors[names[d]] += 1
        self.state.step += 1
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


def make_domain_metadata(num_blocks_per_domain: Dict[str, int], *,
                         block_rows: int = 128, seed: int = 0) -> BlockTable:
    """Corpus-metadata table: one row per token block with a quality score.
    Domains are integer-coded in sorted-name order."""
    rng = np.random.default_rng(seed)
    rows_dom, rows_q, rows_tok = [], [], []
    for code, name in enumerate(sorted(num_blocks_per_domain)):
        n = num_blocks_per_domain[name] * block_rows
        rows_dom.append(np.full(n, code, np.int32))
        # per-domain quality distributions differ -> mixture weights differ
        rows_q.append(rng.beta(2.0 + code, 2.0, n).astype(np.float32))
        rows_tok.append(rng.integers(512, 2048, n).astype(np.float32))
    dom = np.concatenate(rows_dom)
    # interleave domains across blocks (ingest order in real corpora mixes
    # shards); contiguous layout would be Lemma 4.1's homogeneous-block
    # worst case and force the planner to exact execution
    perm = rng.permutation(len(dom))
    return BlockTable.from_numpy(
        "corpus_meta",
        {"domain": dom[perm],
         "quality": np.concatenate(rows_q)[perm],
         "tokens": np.concatenate(rows_tok)[perm]},
        block_rows)


def plan_mixture_weights(meta: BlockTable, num_domains: int, *,
                         error: float = 0.1, confidence: float = 0.9,
                         seed: int = 0) -> Tuple[Dict[int, float], object]:
    """AQP-planned mixture: per-domain mean quality with (e, p) guarantees,
    normalized into sampling weights.  Returns (weights, TaqaReport)."""
    db = PilotDB(Executor({"corpus_meta": meta}), large_table_rows=10_000)
    q = Query(child=L.Scan("corpus_meta"),
              aggs=(CompositeAgg("q", "avg", Col("quality")),),
              group_by="domain", max_groups=num_domains)
    ans = db.query(q, ErrorSpec(error=error, confidence=confidence), seed=seed)
    vals = ans.values[0]
    present = ans.group_present
    w = {g: float(max(vals[g], 0.0)) for g in range(num_domains) if present[g]}
    total = sum(w.values()) or 1.0
    return {g: v / total for g, v in w.items()}, ans.report
