"""Sharding policy: FSDP over the data axes × TP over the model axis.

Rules (path-name driven, uniform across all 10 architectures):

* 2-D projections: input-feature dim → FSDP axes, output-feature dim → TP
  (`wq/wk/wv/w1/w3/router`, and the SSM projections); reversed for the
  output projections (`wo/w2/s_wo`).  With scan-over-layers the leading L
  axis is unsharded.
* MoE experts: expert dim → TP (expert parallelism); D dim → FSDP.
* Embedding/head: vocab → TP (padded to 128 so it always divides), d_model
  unsharded (tables are small relative to the FSDP savings and lookups stay
  local); the head's contraction runs TP-sharded into a vocab-sharded logits
  tensor.
* Norm scales and biases: replicated.
* Optimizer state mirrors parameter sharding leaf-for-leaf.

Activations: batch → data axes.  Decode KV caches: batch → data, seq → TP
(sequence parallelism; the baseline lets XLA resolve attention over the
sharded seq axis — see EXPERIMENTS.md §Perf for the shard_map upgrade).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

FSDP_MIN_SIZE = 2**16  # leave tiny tensors replicated


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def tp_axis(mesh: Mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


def _divisible(dim: int, mesh: Mesh, axes) -> bool:
    if not axes:
        return False
    total = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        total *= mesh.shape[a]
    return dim % total == 0 and dim >= total


def param_pspec(path: str, shape: Tuple[int, ...], mesh: Mesh,
                scan_layers: bool = True) -> P:
    fsdp = data_axes(mesh)
    tp = tp_axis(mesh)
    name = path.split("/")[-1]

    if name in ("embed",):
        # shard d_model, NOT vocab: token gathers stay shard-local (a
        # vocab-sharded table turns every lookup into a permute chain)
        return P(None, tp) if _divisible(shape[1], mesh, tp) else P(None, None)
    if name in ("head",):
        return P(None, tp) if _divisible(shape[1], mesh, tp) else P(None, None)
    if name in ("final_norm", "enc_norm") or name.startswith("ln") or name == "s_gbias":
        return P(*([None] * len(shape)))

    # stacked layer arrays: strip the leading L axis from the rule
    lead: Tuple[Any, ...] = (None,) if scan_layers else ()
    core = shape[1:] if scan_layers else shape

    def spec(*parts):
        out = lead + tuple(parts)
        return P(*out)

    if name in ("e_w1", "e_w3"):           # (E, D, F): EP x FSDP
        ep = tp if _divisible(core[0], mesh, tp) else None
        fs = fsdp if _divisible(core[1], mesh, fsdp) else None
        return spec(ep, fs, None)
    if name == "e_w2":                      # (E, F, D)
        ep = tp if _divisible(core[0], mesh, tp) else None
        fs = fsdp if _divisible(core[2], mesh, fsdp) else None
        return spec(ep, None, fs)
    if len(core) == 2:
        d_in, d_out = core
        if name in ("wo", "w2", "s_wo", "xwo"):
            a = tp if _divisible(d_in, mesh, tp) else None
            b = fsdp if _divisible(d_out, mesh, fsdp) else None
            return spec(a, b)
        # default: in → FSDP, out → TP
        a = fsdp if _divisible(d_in, mesh, fsdp) else None
        b = tp if _divisible(d_out, mesh, tp) else None
        return spec(a, b)
    return P(*([None] * len(shape)))


def params_pspecs(abstract_params, mesh: Mesh, scan_layers: bool = True):
    """PartitionSpec tree matching the abstract parameter tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    specs = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        specs.append(param_pspec(name, leaf.shape, mesh, scan_layers))
    return jax.tree_util.tree_unflatten(treedef, specs)


def params_shardings(abstract_params, mesh: Mesh, scan_layers: bool = True):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        params_pspecs(abstract_params, mesh, scan_layers))


# -- activations / batches ----------------------------------------------------

def batch_pspec(mesh: Mesh, batch_size: int) -> P:
    dp = data_axes(mesh)
    if _divisible(batch_size, mesh, dp):
        return P(dp)
    # small batches (e.g. long_500k's batch=1): replicate over data
    return P(None)


def batch_pspecs(batch_abstract, mesh: Mesh):
    def leaf_spec(leaf):
        bp = batch_pspec(mesh, leaf.shape[0])
        return P(*(bp + tuple([None] * (len(leaf.shape) - 1))))

    return jax.tree.map(leaf_spec, batch_abstract)


def cache_pspecs(cache_abstract, mesh: Mesh):
    """Decode-cache sharding: (L, B, kvH, S, hd) — batch→data, seq→TP."""
    dp = data_axes(mesh)
    tp = tp_axis(mesh)

    def leaf_spec(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        if name == "pos":
            return P()
        if name in ("k", "v", "cross_k", "cross_v"):
            L, b, kvh, s, hd = leaf.shape
            bspec = dp if _divisible(b, mesh, dp) else None
            sspec = tp if _divisible(s, mesh, tp) else None
            return P(None, bspec, None, sspec, None)
        if name == "ssm":
            L, b, nh, dk, dv = leaf.shape
            bspec = dp if _divisible(b, mesh, dp) else None
            hspec = tp if _divisible(nh, mesh, tp) else None
            return P(None, bspec, hspec, None, None)
        return P(*([None] * len(leaf.shape)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_abstract)
    return jax.tree_util.tree_unflatten(
        treedef, [leaf_spec(p, l) for p, l in flat])
