"""AdamW in pure JAX, sharding-transparent (state mirrors param sharding).

Moments are kept in float32 regardless of param dtype (bf16-safe); the
update is fused into one tree_map pass so XLA can overlap it with the
gradient all-reduces/reduce-scatters that FSDP sharding induces.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init_opt_state(params) -> OptState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(f32, params),
                    nu=jax.tree.map(f32, params))


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, new_mu, new_nu), metrics
