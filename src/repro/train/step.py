"""Loss + train_step factory: remat'd forward, microbatch accumulation,
optional error-feedback gradient compression before the optimizer."""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import Model
from repro.train import compression
from repro.train.optimizer import AdamWConfig, OptState, adamw_update, init_opt_state


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    residual: Any  # error-feedback buffer (None leaves when compression off)


def cross_entropy(logits, labels, vocab_size: int):
    """Sharded-vocab-safe CE over the padded vocab.

    The vocab axis is TP-sharded at scale, so this avoids any op that would
    force an all-gather of the (B, S, V) logits: padding is masked with an
    iota compare (local), the label logit is extracted with a masked local
    reduction (psum of (B, S) — tiny), and logsumexp reduces over the
    sharded axis (all-reduce of (B, S)).  take_along_axis / concatenate
    formulations materialize or gather the full-vocab tensor (≈24 GB/device
    at train_4k scale) — measured, not hypothetical."""
    logits = logits.astype(jnp.float32)
    vpad = logits.shape[-1]
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          len(logits.shape) - 1)
    if vpad > vocab_size:
        logits = jnp.where(vocab_iota < vocab_size, logits, -1e30)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    label_logit = jnp.sum(
        jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1)
    return (lse - label_logit).mean()


def init_train_state(model: Model, rng, *, compress: bool = False) -> TrainState:
    params = model.init(rng)
    residual = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params) \
        if compress else None
    return TrainState(params=params, opt=init_opt_state(params), residual=residual)


def make_train_step(model: Model, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1, aux_weight: float = 0.01,
                    compress: bool = False):
    """Builds train_step(state, batch) -> (state, metrics).

    microbatches > 1 splits the batch on axis 0 and accumulates gradients
    with a lax.scan — activation memory drops by the microbatch factor while
    keeping one optimizer step per global batch.
    """
    vocab = model.cfg.vocab_size

    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch)
        return cross_entropy(logits, batch["labels"], vocab) + aux_weight * aux

    def compute_grads(params, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            return loss, grads

        def split(x):
            # strided split: microbatch m takes elements m::microbatches, so
            # each microbatch stays evenly spread across the sharded batch
            # axis (a batch-major reshape would put microbatch 0 entirely on
            # the first half of the data shards — XLA then replicates)
            b = x.shape[0]
            return x.reshape(b // microbatches, microbatches,
                             *x.shape[1:]).swapaxes(0, 1)

        mb = jax.tree.map(split, batch)

        def acc_step(carry, mbatch):
            loss_acc, g_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mbatch)
            g_acc = jax.tree.map(jnp.add, g_acc, grads)
            return (loss_acc + loss, g_acc), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, g_sum), _ = jax.lax.scan(acc_step, (jnp.float32(0.0), zero), mb)
        scale = 1.0 / microbatches
        return loss_sum * scale, jax.tree.map(lambda g: g * scale, g_sum)

    def train_step(state: TrainState, batch):
        loss, grads = compute_grads(state.params, batch)
        residual = state.residual
        comp_err = jnp.float32(0.0)
        if compress:
            grads, residual, comp_err = compression.compress_tree(grads, residual)
        params, opt, metrics = adamw_update(opt_cfg, state.params, grads, state.opt)
        metrics = dict(metrics, loss=loss, compression_err=comp_err)
        return TrainState(params, opt, residual), metrics

    return train_step
