"""Error-feedback int8 gradient compression (1-bit-Adam/EF-SGD family).

At 1000+-node scale the DP gradient reduction is the dominant collective;
int8 quantization cuts its bytes 4× versus f32 (2× versus bf16).  Plain
quantization biases the update; *error feedback* (carrying the quantization
residual into the next step) restores convergence (Stich et al., Seide et
al.).  The quantizer is per-tensor symmetric int8 with a max-abs scale —
cheap enough to fuse before the reduce-scatter.

On this container the collective itself is XLA's job; this module provides
the (de)quantization + residual algebra, unit-tested for the contraction
property and for end-to-end convergence in tests/test_train.py.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


def quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8.  Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / INT8_MAX
    scale = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_leaf(g: jax.Array, residual: jax.Array):
    """Error-feedback step: compress (g + residual), carry the error."""
    target = g.astype(jnp.float32) + residual
    q, scale = quantize(target)
    g_hat = dequantize(q, scale)
    new_residual = target - g_hat
    return g_hat.astype(g.dtype), new_residual, jnp.sum(new_residual ** 2)


def compress_tree(grads: Any, residuals: Any):
    """Returns (compressed_grads, new_residuals, total_sq_error)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    outs = [compress_leaf(g, r) for g, r in zip(flat_g, flat_r)]
    g_hat = treedef.unflatten([o[0] for o in outs])
    res = treedef.unflatten([o[1] for o in outs])
    err = jnp.sum(jnp.stack([o[2] for o in outs]))
    return g_hat, res, err
