"""Elastic scaling + straggler mitigation (node-failure posture).

`plan_mesh` chooses the largest healthy mesh given surviving devices: TP
degree is preserved (it is baked into layer shardings and kernel tile
shapes), the data/pod extent shrinks to what remains, and stragglers/failed
hosts are excluded.  After a failure:

    1. detect (heartbeat timeout / jax runtime error),
    2. plan_mesh(surviving_devices)  →  new Mesh,
    3. checkpoint.restore(..., shardings_for(new_mesh))  →  resharded state,
    4. adjust global batch (keep per-device batch; fewer data shards),
    5. resume from the last step recorded in the manifest.

`StragglerWatchdog` is the step-time monitor: an EWMA of step latency with a
multiplicative threshold; slow steps are recorded and surfaced so the
launcher can trigger the re-mesh path (on TPU pods the usual cause is a
failing host NIC or thermal throttling).  Both pieces are pure logic —
unit-tested here, wired to real failure detection in launch/train.py.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    devices_used: int
    data_parallel: int
    global_batch: int


def plan_mesh(num_devices: int, *, tp: int = 16,
              per_replica_batch: int = 8,
              prefer_pods: bool = False,
              pod_size: int = 256) -> MeshPlan:
    """Largest (data, model=tp) mesh that fits the surviving devices."""
    if num_devices < tp:
        raise ValueError(
            f"cannot keep TP={tp} with only {num_devices} devices; "
            "reshard checkpoints to a smaller TP first")
    data = num_devices // tp
    if prefer_pods and num_devices >= pod_size:
        pods = num_devices // pod_size
        data_in_pod = pod_size // tp
        return MeshPlan(shape=(pods, data_in_pod, tp),
                        axis_names=("pod", "data", "model"),
                        devices_used=pods * pod_size,
                        data_parallel=pods * data_in_pod,
                        global_batch=pods * data_in_pod * per_replica_batch)
    return MeshPlan(shape=(data, tp), axis_names=("data", "model"),
                    devices_used=data * tp, data_parallel=data,
                    global_batch=data * per_replica_batch)


def make_mesh(plan: MeshPlan, devices: Optional[Sequence] = None):
    devices = list(devices if devices is not None else jax.devices())
    use = devices[: plan.devices_used]
    arr = np.array(use).reshape(plan.shape)
    return jax.sharding.Mesh(arr, plan.axis_names)


class StragglerWatchdog:
    """EWMA step-time monitor; flags steps slower than `threshold`× the EWMA."""

    def __init__(self, threshold: float = 2.0, alpha: float = 0.1,
                 warmup: int = 3):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = warmup
        self.ewma: Optional[float] = None
        self.steps = 0
        self.slow_steps: List[Tuple[int, float]] = []
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> bool:
        """Returns True if this step was a straggler."""
        dt = time.perf_counter() - self._t0
        return self.observe(dt)

    def observe(self, dt: float) -> bool:
        self.steps += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = self.steps > self.warmup and dt > self.threshold * self.ewma
        if slow:
            # do not fold outliers into the baseline
            self.slow_steps.append((self.steps, dt))
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow

    @property
    def should_remesh(self) -> bool:
        """Persistent stragglers (>=3 of the last 10 steps) ⇒ act."""
        recent = [s for s, _ in self.slow_steps if s > self.steps - 10]
        return len(recent) >= 3
