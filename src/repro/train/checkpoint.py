"""Checkpoint / restart with resharding restore (fault tolerance).

Layout (per checkpoint step):

    <dir>/step_<N>/
        manifest.json     tree structure, shapes, dtypes, mesh metadata,
                          data-pipeline state, wall clock
        <leaf_id>.npy     one array per pytree leaf (host-local full value
                          on single-process; per-host shards would land in
                          host_<i>/ subdirs on real multi-host — the
                          manifest already records the process topology)

Restores are **elastic**: the target sharding at load time may differ from
the sharding at save time (different device count / mesh shape); leaves are
placed with `jax.device_put` against the new shardings, which reshards as
needed.  `latest_step`/GC give crash-restart semantics; `emergency_save`
installs a SIGTERM hook that flushes a checkpoint before preemption.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, _ in flat:
        names.append("__".join(str(getattr(k, "key", getattr(k, "idx", k)))
                               for k in path))
    return flat, names, treedef


def save(ckpt_dir: str, step: int, tree: Any, *,
         extra: Optional[Dict] = None, keep: int = 3) -> str:
    """Atomically write checkpoint `step`; garbage-collect old ones."""
    out = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = out + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat, names, _ = _flatten(tree)
    manifest = {
        "step": step,
        "time": time.time(),
        "process_count": jax.process_count(),
        "device_count": jax.device_count(),
        "leaves": [],
        "extra": extra or {},
    }
    for (path, leaf), name in zip(flat, names):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"].append(
            {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(out):
        shutil.rmtree(out)
    os.rename(tmp, out)
    _gc(ckpt_dir, keep)
    return out


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target_tree: Any,
            shardings: Optional[Any] = None):
    """Load checkpoint `step` into the structure of `target_tree`.

    `shardings` (same tree structure, NamedSharding leaves) may reflect a
    *different* mesh than at save time — this is the elastic-restart path.
    Returns (tree, extra_metadata).
    """
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    flat, names, treedef = _flatten(target_tree)
    by_name = {l["name"]: l for l in manifest["leaves"]}
    leaves = []
    shard_flat = jax.tree_util.tree_leaves(shardings) if shardings is not None \
        else [None] * len(flat)
    for ((path, leaf), name, shd) in zip(flat, names, shard_flat):
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = np.load(os.path.join(src, name + ".npy"))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"leaf {name}: checkpoint shape {arr.shape} != target {leaf.shape}")
        if shd is not None:
            leaves.append(jax.device_put(arr, shd))
        else:
            leaves.append(jax.device_put(arr))
    return treedef.unflatten(leaves), manifest["extra"]


class EmergencySaver:
    """SIGTERM-triggered flush: preemption-safe checkpointing.

    Register once; call `maybe_save(step, tree)` at step boundaries — if a
    signal arrived since the last call, a checkpoint is written immediately.
    """

    def __init__(self, ckpt_dir: str, extra_fn: Optional[Callable[[], Dict]] = None):
        self.ckpt_dir = ckpt_dir
        self.extra_fn = extra_fn
        self.triggered = False
        self._prev = signal.signal(signal.SIGTERM, self._on_signal)

    def _on_signal(self, signum, frame):
        self.triggered = True

    def maybe_save(self, step: int, tree: Any) -> bool:
        if not self.triggered:
            return False
        save(self.ckpt_dir, step, tree,
             extra=(self.extra_fn() if self.extra_fn else {"emergency": True}))
        self.triggered = False
        return True

    def close(self):
        signal.signal(signal.SIGTERM, self._prev)
