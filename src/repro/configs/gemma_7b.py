"""gemma-7b [dense]: 28L d3072 16H (kv=16) dff24576 v256000, GeGLU,
head_dim=256 (q_dim 4096 != d_model). [arXiv:2403.08295; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense", num_layers=28, d_model=3072,
    num_heads=16, num_kv_heads=16, head_dim=256, d_ff=24576,
    vocab_size=256000, mlp="geglu",
).validate()
