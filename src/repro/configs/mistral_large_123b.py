"""mistral-large-123b [dense]: 88L d12288 96H (GQA kv=8) dff28672 v32768.
[hf:mistralai/Mistral-Large-Instruct-2407; unverified] — the memory-heavy
cell: FSDP+TP mandatory, scan+full remat."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense", num_layers=88, d_model=12288,
    num_heads=96, num_kv_heads=8, head_dim=128, d_ff=28672, vocab_size=32768,
    mlp="swiglu", rope_theta=1e6,
).validate()
