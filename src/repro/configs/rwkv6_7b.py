"""rwkv6-7b "Finch" [ssm, attention-free]: 32L d4096 dff14336 v65536 —
data-dependent per-channel decay. [arXiv:2404.05892; hf]

Realized as gated linear attention with 64 heads of dk=dv=64 and
data-dependent log-decay g_t = -softplus(xW+b) (the RWKV6 w_t); chunked
GEMM form for train/prefill (kernels/gla_chunk on TPU), O(1) recurrent
state for decode — long_500k runs with a (dk, dv) state per head."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm", num_layers=32, d_model=4096,
    num_heads=0, num_kv_heads=0, head_dim=0, d_ff=14336, vocab_size=65536,
    mlp="swiglu", ssm_state=64, num_ssm_heads=64,
).validate()
