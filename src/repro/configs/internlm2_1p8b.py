"""internlm2-1.8b [dense]: 24L d2048 16H (GQA kv=8) dff8192 v92544.
[arXiv:2403.17297; hf] — GQA llama-style decoder, SwiGLU, head_dim 128."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b", family="dense", num_layers=24, d_model=2048,
    num_heads=16, num_kv_heads=8, head_dim=128, d_ff=8192, vocab_size=92544,
    mlp="swiglu", rope_theta=1e6,
).validate()
