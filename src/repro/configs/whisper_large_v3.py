"""whisper-large-v3 [audio enc-dec]: 32L enc + 32L dec, d1280 20H kv=20
dff5120 v51866. [arXiv:2212.04356; unverified]

Conv/mel frontend is a STUB per the assignment: input_specs provide
precomputed frame embeddings (B, 1500, 1280).  Positional scheme unified to
RoPE (the original uses sinusoidal/learned) — noted in DESIGN.md."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec", num_layers=32, d_model=1280,
    num_heads=20, num_kv_heads=20, head_dim=64, d_ff=5120, vocab_size=51866,
    mlp="swiglu", encoder_layers=32, enc_seq=1500,
).validate()
