"""llava-next-34b [vlm]: 60L d7168 56H (GQA kv=8) dff20480 v64000.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

Vision tower + anyres tiling are a STUB per the assignment: input_specs
provide 576 precomputed patch embeddings (B, 576, 7168) that are prepended
to the text tokens."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm", num_layers=60, d_model=7168,
    num_heads=56, num_kv_heads=8, head_dim=128, d_ff=20480, vocab_size=64000,
    mlp="swiglu", num_patches=576, rope_theta=5e6,
).validate()
