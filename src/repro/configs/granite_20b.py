"""granite-20b [dense]: 52L d6144 48H (MQA kv=1) dff24576 v49152.
[arXiv:2405.04324; hf] — llama-arch code model; extreme MQA (one KV head)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense", num_layers=52, d_model=6144,
    num_heads=48, num_kv_heads=1, head_dim=128, d_ff=24576, vocab_size=49152,
    mlp="swiglu",
).validate()
