"""Architecture registry: --arch <id> resolution for launch/ and tests."""

from repro.configs.internlm2_1p8b import CONFIG as internlm2_1p8b
from repro.configs.granite_20b import CONFIG as granite_20b
from repro.configs.mistral_large_123b import CONFIG as mistral_large_123b
from repro.configs.gemma_7b import CONFIG as gemma_7b
from repro.configs.whisper_large_v3 import CONFIG as whisper_large_v3
from repro.configs.granite_moe_1b import CONFIG as granite_moe_1b
from repro.configs.olmoe_1b_7b import CONFIG as olmoe_1b_7b
from repro.configs.hymba_1p5b import CONFIG as hymba_1p5b
from repro.configs.llava_next_34b import CONFIG as llava_next_34b
from repro.configs.rwkv6_7b import CONFIG as rwkv6_7b

ARCHITECTURES = {
    "internlm2-1.8b": internlm2_1p8b,
    "granite-20b": granite_20b,
    "mistral-large-123b": mistral_large_123b,
    "gemma-7b": gemma_7b,
    "whisper-large-v3": whisper_large_v3,
    "granite-moe-1b-a400m": granite_moe_1b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "hymba-1.5b": hymba_1p5b,
    "llava-next-34b": llava_next_34b,
    "rwkv6-7b": rwkv6_7b,
}


def get_config(arch: str):
    if arch not in ARCHITECTURES:
        raise KeyError(f"unknown --arch {arch!r}; known: {sorted(ARCHITECTURES)}")
    return ARCHITECTURES[arch]


def list_architectures():
    return sorted(ARCHITECTURES)
