"""hymba-1.5b [hybrid]: 32L d1600 25H (GQA kv=5) dff5504 v32001,
ssm_state=16 — parallel attention + SSM heads. [arXiv:2411.13676; hf]

Simplifications noted in DESIGN.md: sliding-window attention (w=1024) on all
layers (the original keeps 3 global layers); the SSM branch carries global
context, which is what makes long_500k servable; attn/SSM outputs fused by
mean (original uses learned per-head norms); meta-tokens omitted."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", num_layers=32, d_model=1600,
    num_heads=25, num_kv_heads=5, head_dim=64, d_ff=5504, vocab_size=32001,
    mlp="swiglu", ssm_state=16, num_ssm_heads=25, sliding_window=1024,
).validate()
