"""SLO monitor: per-template (or wildcard) serving objectives, evaluated
on delivery.

BlinkDB frames AQP as bounded-error AND bounded-response-time serving;
this module is the response-time half's watchdog.  A :class:`SloTarget`
names a template (the ``trace.sig_hash`` of its constant-stripped group
key, or ``"*"`` for every template) and bounds up to three observables the
per-template time-series already tracks:

* ``p95_latency_s``       — windowed p95 of per-delivery latency,
* ``max_fallback_rate``   — exact-fallback fraction of deliveries,
* ``max_violation_rate``  — audit-mode guarantee-violation fraction
  (observed error > promised ε; requires ``SessionConfig.audit``).

The :class:`SloMonitor` evaluates every matching target after each
delivery (and after each audit record lands).  A breach increments the
``pilotdb_slo_breaches_total`` registry counter, appends a breach record
(surfaced via :meth:`report` / ``gateway.slo_report()``), and emits an
``slo_breach`` flight-recorder event when a recorder is armed.  Like every
obs layer, evaluation only READS — a breached SLO never throttles,
reroutes, or otherwise perturbs query execution.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

__all__ = ["SloTarget", "SloBreach", "SloMonitor"]


@dataclasses.dataclass(frozen=True)
class SloTarget:
    """One serving objective; ``None`` bounds are not evaluated.

    ``template`` is a 12-hex template key (``trace.sig_hash(group_key)``,
    also the keys of ``stats_payload()["timeseries"]["templates"]``) or
    ``"*"``; ``min_samples`` suppresses evaluation until the template has
    delivered that many queries (quantiles over 1-2 samples are noise).
    """

    template: str = "*"
    p95_latency_s: Optional[float] = None
    max_fallback_rate: Optional[float] = None
    max_violation_rate: Optional[float] = None
    min_samples: int = 1

    # observable name -> (bound field, stats key from TemplateSeries.slo_stats)
    _METRICS = (
        ("p95_latency_s", "p95_latency_s"),
        ("max_fallback_rate", "fallback_rate"),
        ("max_violation_rate", "violation_rate"),
    )


@dataclasses.dataclass
class SloBreach:
    """One breach observation (a target exceeded at one evaluation)."""

    t: float                   # wall-clock epoch seconds
    template: str              # the concrete template key that breached
    rule: str                  # the target's template pattern ("*" or key)
    metric: str                # bound field name on SloTarget
    observed: float
    target: float

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


class SloMonitor:
    """Evaluates SLO targets against the per-template time-series."""

    def __init__(self, metrics, timeseries, recorder=None,
                 targets: Tuple[SloTarget, ...] = (),
                 max_recent: int = 64) -> None:
        self._timeseries = timeseries
        self._recorder = recorder
        self._lock = threading.Lock()
        self._targets: List[SloTarget] = list(targets)
        self._recent: "deque[SloBreach]" = deque(maxlen=max_recent)
        self._counts: Dict[Tuple[str, str, str], int] = {}  # (rule,key,metric)
        self._evals = metrics.counter(
            "pilotdb_slo_evaluations_total",
            "SLO target evaluations performed on delivery")
        self._breaches = metrics.counter(
            "pilotdb_slo_breaches_total",
            "SLO target evaluations that observed a breach")

    # -- configuration --------------------------------------------------------
    def set_target(self, target: Optional[SloTarget] = None,
                   **kwargs) -> SloTarget:
        """Add a target (``SloTarget(...)`` or keyword form); returns it."""
        if target is None:
            target = SloTarget(**kwargs)
        elif kwargs:
            target = dataclasses.replace(target, **kwargs)
        with self._lock:
            self._targets.append(target)
        return target

    def targets(self) -> List[SloTarget]:
        with self._lock:
            return list(self._targets)

    # -- evaluation (delivery hook; never raises upward through the session) --
    def evaluate(self, key: str) -> List[SloBreach]:
        """Evaluate every target matching template ``key`` against its
        current windowed stats; record and return any breaches."""
        stats = self._timeseries.slo_stats(key) \
            if self._timeseries is not None else None
        if stats is None:
            return []
        breaches: List[SloBreach] = []
        with self._lock:
            targets = [t for t in self._targets
                       if t.template in ("*", key)]
        for t in targets:
            if stats["samples"] < t.min_samples:
                continue
            for field, stat_key in SloTarget._METRICS:
                bound = getattr(t, field)
                if bound is None:
                    continue
                self._evals.inc()
                observed = float(stats[stat_key])
                if observed > bound:
                    breaches.append(SloBreach(
                        t=time.time(), template=key, rule=t.template,
                        metric=field, observed=observed, target=bound))
        for b in breaches:
            self._breaches.inc()
            with self._lock:
                self._recent.append(b)
                ck = (b.rule, b.template, b.metric)
                self._counts[ck] = self._counts.get(ck, 0) + 1
            if self._recorder is not None:
                self._recorder.emit("slo_breach", template=b.template,
                                    rule=b.rule, metric=b.metric,
                                    observed=round(b.observed, 6),
                                    target=b.target)
        return breaches

    # -- reporting ------------------------------------------------------------
    def report(self) -> List[Dict[str, object]]:
        """Current status of every (target, matching template) pair: the
        observed value next to its bound, whether it breaches NOW, and how
        many breach evaluations it has accumulated."""
        out: List[Dict[str, object]] = []
        if self._timeseries is None:
            return out
        keys = self._timeseries.keys()
        with self._lock:
            targets = list(self._targets)
            counts = dict(self._counts)
        for t in targets:
            matched = keys if t.template == "*" else \
                [k for k in keys if k == t.template]
            for key in matched:
                stats = self._timeseries.slo_stats(key)
                if stats is None:
                    continue
                for field, stat_key in SloTarget._METRICS:
                    bound = getattr(t, field)
                    if bound is None:
                        continue
                    observed = float(stats[stat_key])
                    out.append({
                        "template": key,
                        "rule": t.template,
                        "metric": field,
                        "target": bound,
                        "observed": observed,
                        "samples": stats["samples"],
                        "breached": (stats["samples"] >= t.min_samples
                                     and observed > bound),
                        "breaches_total": counts.get(
                            (t.template, key, field), 0),
                    })
        return out

    def summary(self) -> Dict[str, object]:
        """The ``slo`` collector payload (rides ``stats_payload()``)."""
        with self._lock:
            recent = [b.as_dict() for b in self._recent]
            n_targets = len(self._targets)
        return {
            "enabled": True,
            "targets": n_targets,
            "breaches_total": int(self._breaches.value),
            "evaluations_total": int(self._evals.value),
            "recent_breaches": recent,
        }


def empty_summary() -> Dict[str, object]:
    """The ``slo`` payload section when telemetry is off (same keys)."""
    return {"enabled": False, "targets": 0, "breaches_total": 0,
            "evaluations_total": 0, "recent_breaches": []}
