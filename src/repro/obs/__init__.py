"""Observability: query-lifecycle tracing, unified metrics, guarantee audit.

Three pieces, each opt-in and read-only over the query path:

* :mod:`repro.obs.trace` — per-query span trees (``SessionConfig.tracing``)
  exportable as JSON or Chrome trace-event format via ``handle.trace()``.
* :mod:`repro.obs.metrics` — counter/gauge/histogram registry + collector
  snapshots; Prometheus text exposition via ``gateway.metrics_text()``.
* :mod:`repro.obs.audit` — EXPLAIN-style reports (``handle.explain()``) and
  opt-in observed-vs-promised error auditing (``SessionConfig.audit``).

See ``docs/observability.md`` for the span vocabulary, metric names, and
the audit-mode non-perturbation contract.
"""

from repro.obs.trace import QueryTrace, span, annotate, annotate_count  # noqa: F401
from repro.obs.metrics import MetricsRegistry, GLOBAL  # noqa: F401
from repro.obs.audit import GuaranteeAuditor, AuditRecord, explain  # noqa: F401
