"""Observability: tracing, metrics, guarantee audit, continuous telemetry.

Six pieces, each opt-in and read-only over the query path:

* :mod:`repro.obs.trace` — per-query span trees (``SessionConfig.tracing``,
  or deterministically sampled via ``trace_sample=p``) exportable as JSON
  or Chrome trace-event format via ``handle.trace()``.
* :mod:`repro.obs.metrics` — counter/gauge/histogram registry + collector
  snapshots; Prometheus text exposition via ``gateway.metrics_text()``.
* :mod:`repro.obs.audit` — EXPLAIN-style reports (``handle.explain()``) and
  opt-in observed-vs-promised error auditing (``SessionConfig.audit``).
* :mod:`repro.obs.timeseries` — per-template bounded ring buffers with
  streaming windowed p50/p95/p99 (``SessionConfig.telemetry``), exposed via
  ``stats_payload()["timeseries"]``.
* :mod:`repro.obs.slo` — per-template/wildcard latency, fallback-rate and
  guarantee-violation-rate targets evaluated on delivery; breaches surface
  as registry counters and ``gateway.slo_report()``.
* :mod:`repro.obs.events` — the flight recorder: append-only size-rotated
  JSONL event log (``SessionConfig.flight_recorder``) with offline replay
  (:func:`repro.obs.events.rebuild_timeseries`).

See ``docs/observability.md`` for the span vocabulary, metric names, the
event-record schema, and the non-perturbation contract all six share.
"""

from repro.obs.trace import QueryTrace, span, annotate, annotate_count  # noqa: F401
from repro.obs.metrics import MetricsRegistry, GLOBAL  # noqa: F401
from repro.obs.audit import GuaranteeAuditor, AuditRecord, explain  # noqa: F401
from repro.obs.timeseries import TemplateTimeSeries, Ring  # noqa: F401
from repro.obs.slo import SloMonitor, SloTarget, SloBreach  # noqa: F401
from repro.obs.events import (FlightRecorder, replay,  # noqa: F401
                              rebuild_timeseries)
