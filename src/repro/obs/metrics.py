"""Unified metrics registry: counters, gauges, histograms, collectors.

One :class:`MetricsRegistry` per :class:`Session` absorbs the counters that
used to be scattered across the engine (compile cache hits/misses, result
cache, staged residency, pilot fan-out, frame push/drop, backpressure
rejections): components either own first-class instruments (counter /
gauge / histogram) or register a *collector* — a zero-arg callable returning
a nested dict snapshot of state the component already tracks (cache info
structs, shard scan tallies).  ``SqlGateway.stats_payload()`` is a view over
:meth:`MetricsRegistry.tree`, and :meth:`MetricsRegistry.to_text` renders
everything — instruments and collector snapshots alike — in Prometheus text
exposition format for ``gateway.metrics_text()``.

Collectors hold only weak references to their owners, so registering a
session's caches with the process-wide ``GLOBAL`` registry never extends
their lifetime; dead collectors are pruned at read time.
"""

from __future__ import annotations

import bisect
import re
import threading
import weakref
from typing import Callable, Dict, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "GLOBAL",
    "register_session_collectors",
]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def _sanitize(name: str) -> str:
    name = _NAME_RE.sub("_", name)
    if name and name[0].isdigit():
        name = "_" + name
    return name


def _escape_help(s: str) -> str:
    """Prometheus text exposition: HELP text must escape backslash and
    line feed (an unescaped newline would split the comment into a bogus
    sample line and break the scrape)."""
    return s.replace("\\", "\\\\").replace("\n", "\\n")


class Counter:
    """Monotonic counter (thread-safe)."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Point-in-time value (thread-safe)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


# Default buckets suit sub-second query-stage latencies (seconds).
_DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0)


class Histogram:
    """Cumulative-bucket histogram, Prometheus style (thread-safe)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Tuple[float, ...] = _DEFAULT_BUCKETS):
        self.name = name
        self.help = help
        self.buckets = tuple(sorted(buckets))
        self._lock = threading.Lock()
        self._counts = [0] * (len(self.buckets) + 1)  # +inf tail
        self._sum = 0.0
        self._count = 0
        self._max = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._counts[bisect.bisect_left(self.buckets, v)] += 1
            self._sum += v
            self._count += 1
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    @property
    def max(self) -> float:
        with self._lock:
            return self._max

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            cum, out = 0, []
            for le, n in zip(self.buckets, self._counts):
                cum += n
                out.append((le, cum))
            return {
                "count": self._count,
                "sum": self._sum,
                "max": self._max,
                "buckets": out,
            }


class MetricsRegistry:
    """Named instruments plus weakly-owned collector snapshots."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._instruments: Dict[str, object] = {}
        # name -> (fn, owner_ref | None); owner death prunes the collector
        self._collectors: Dict[
            str, Tuple[Callable[[], Dict], Optional[weakref.ref]]] = {}

    # -- instruments (get-or-create; kind mismatch is a bug) ------------------
    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, help, Counter)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, help, Gauge)

    def histogram(self, name: str, help: str = "",
                  buckets: Tuple[float, ...] = _DEFAULT_BUCKETS) -> Histogram:
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = Histogram(name, help, buckets)
                self._instruments[name] = inst
            elif not isinstance(inst, Histogram):
                raise TypeError(
                    f"metric {name!r} already registered as {inst.kind}")
            return inst

    def _get(self, name, help, cls):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, help)
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {inst.kind}")
            return inst

    # -- collectors -----------------------------------------------------------
    def register_collector(self, name: str, fn: Callable[[], Dict],
                           owner: Optional[object] = None) -> None:
        """Register (or replace) a named snapshot source.  When ``owner`` is
        given only a weak reference is kept; the collector disappears with
        its owner."""
        ref = weakref.ref(owner) if owner is not None else None
        with self._lock:
            self._collectors[name] = (fn, ref)

    def _live_collectors(self) -> List[Tuple[str, Callable[[], Dict]]]:
        with self._lock:
            dead = [n for n, (_, r) in self._collectors.items()
                    if r is not None and r() is None]
            for n in dead:
                del self._collectors[n]
            return [(n, fn) for n, (fn, _) in self._collectors.items()]

    def tree(self) -> Dict[str, Dict]:
        """{collector_name: snapshot_dict} for every live collector."""
        return {name: fn() for name, fn in self._live_collectors()}

    def instruments(self) -> Dict[str, object]:
        with self._lock:
            return dict(self._instruments)

    # -- Prometheus text exposition ------------------------------------------
    def to_text(self) -> str:
        lines: List[str] = []
        # every metric name already emitted: instruments' own names, the
        # histogram child series they synthesize, and flattened collector
        # gauges — a second emission of any of them (e.g. a collector whose
        # flattened path collides with an instrument) would be an invalid
        # exposition (duplicate # TYPE), so later duplicates are skipped
        seen: set = set()
        for name in sorted(self.instruments()):
            inst = self._instruments[name]
            mname = _sanitize(name)
            if mname in seen:
                continue  # two raw names sanitizing to one metric name
            seen.add(mname)
            if inst.help:
                lines.append(f"# HELP {mname} {_escape_help(inst.help)}")
            lines.append(f"# TYPE {mname} {inst.kind}")
            if isinstance(inst, Histogram):
                seen.update((f"{mname}_bucket", f"{mname}_sum",
                             f"{mname}_count"))
                snap = inst.snapshot()
                for le, cum in snap["buckets"]:
                    lines.append(f'{mname}_bucket{{le="{le:g}"}} {cum}')
                lines.append(
                    f'{mname}_bucket{{le="+Inf"}} {snap["count"]}')
                lines.append(f"{mname}_sum {snap['sum']:.9g}")
                lines.append(f"{mname}_count {snap['count']}")
            else:
                lines.append(f"{mname} {inst.value:.9g}")
        # Collector snapshots flatten to gauges by path-joined name.
        for cname, fn in sorted(self._live_collectors()):
            try:
                snap = fn()
            except Exception:  # a dying component must not break scrape
                continue
            for path, value in sorted(_flatten(cname, snap)):
                if path in seen:
                    continue
                seen.add(path)
                lines.append(f"# TYPE {path} gauge")
                lines.append(f"{path} {value:.9g}")
        return "\n".join(lines) + "\n"


def _flatten(prefix: str, obj) -> List[Tuple[str, float]]:
    out: List[Tuple[str, float]] = []
    p = _sanitize(prefix)
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.extend(_flatten(f"{p}_{k}", v))
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            out.extend(_flatten(f"{p}_{i}", v))
    elif isinstance(obj, bool):
        out.append((p, 1.0 if obj else 0.0))
    elif isinstance(obj, (int, float)):
        out.append((p, float(obj)))
    # strings / None are dropped from exposition (kept in tree())
    return out


#: Process-wide registry.  Sessions attach their own registries' collectors
#: here (weakly) so one scrape sees every live session.
GLOBAL = MetricsRegistry()


def register_session_collectors(registry: MetricsRegistry, session) -> None:
    """Wire a session's existing stat sources into ``registry`` as
    collectors.  Duck-typed via getattr so this module never imports
    ``repro.api`` (no circularity); every collector holds the session
    weakly and degrades to zeros/skeletons when a source is absent."""
    ref = weakref.ref(session)

    def compile_cache() -> Dict:
        s = ref()
        if s is None:
            return {}
        info = s.compile_cache_info()  # engine CacheInfo dataclass
        return {
            "hits": info.hits,
            "misses": info.misses,
            "size": info.size,
            "staged_hits": info.staged_hits,
            "staged_misses": info.staged_misses,
            # per-path attribution of the totals above (hits/misses stay the
            # grand totals): pilot lowerings (solo + batched), drain-group
            # batch executables, fused single-launch programs, and local
            # misses whose BUILD was served by a cross-shard adoption
            "pilot_hits": info.pilot_hits,
            "pilot_misses": info.pilot_misses,
            "batched_hits": info.batched_hits,
            "batched_misses": info.batched_misses,
            "fused_hits": info.fused_hits,
            "fused_misses": info.fused_misses,
            "shared_hits": info.shared_hits,
        }

    def result_cache() -> Dict:
        s = ref()
        if s is None:
            return {}
        info = s.result_cache.info()
        return {
            "hits": info.hits,
            "misses": info.misses,
            "evictions": info.evictions,
            "invalidations": info.invalidations,
            "size": info.size,
            "capacity": info.capacity,
            "bytes_used": info.bytes_used,
            "max_bytes": info.max_bytes,
            "hit_rate": info.hit_rate,
        }

    def staged() -> Dict:
        s = ref()
        out = {"hits": 0, "misses": 0, "evictions": 0,
               "resident_bytes": 0, "max_bytes": None, "tables": {}}
        if s is None:
            return out
        info_fn = getattr(s.executor, "staged_info", None)
        if info_fn is not None:
            out.update(info_fn())
        return out

    def shard_scanned_bytes() -> Dict:
        s = ref()
        if s is None:
            return {}
        info_fn = getattr(s.executor, "shard_scan_info", None)
        if info_fn is None:
            return {}
        return {t: list(v) for t, v in info_fn().items()}

    def runtime() -> Dict:
        s = ref()
        if s is None:
            return {}
        out = {
            "queries_run": getattr(s.executor, "queries_run", 0),
            "pilots_run": getattr(s.executor, "pilots_run", 0),
        }
        rt = getattr(s, "runtime", None)
        if rt is not None:
            out.update(rt.totals())
        return out

    def audit() -> Dict:
        s = ref()
        auditor = getattr(s, "auditor", None) if s is not None else None
        if auditor is None:
            return {"runs": 0, "violations": 0, "errors": 0,
                    "max_error_ratio": 0.0}
        return auditor.summary()

    def timeseries() -> Dict:
        s = ref()
        ts = getattr(s, "timeseries", None) if s is not None else None
        if ts is None:  # telemetry off: full-key skeleton, zero state
            from repro.obs.timeseries import empty_snapshot
            return empty_snapshot()
        return ts.snapshot()

    def slo() -> Dict:
        s = ref()
        mon = getattr(s, "slo", None) if s is not None else None
        if mon is None:
            from repro.obs.slo import empty_summary
            return empty_summary()
        return mon.summary()

    registry.register_collector("compile_cache", compile_cache, owner=session)
    registry.register_collector("result_cache", result_cache, owner=session)
    registry.register_collector("staged", staged, owner=session)
    registry.register_collector(
        "shard_scanned_bytes", shard_scanned_bytes, owner=session)
    registry.register_collector("runtime", runtime, owner=session)
    registry.register_collector("audit", audit, owner=session)
    registry.register_collector("timeseries", timeseries, owner=session)
    registry.register_collector("slo", slo, owner=session)
