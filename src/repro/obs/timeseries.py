"""Per-template time-series: bounded rings with streaming windowed quantiles.

Point-in-time snapshots (``stats_payload()``) answer "what is the state
now"; this module answers "how has template X behaved over the last N
deliveries".  A :class:`TemplateTimeSeries` keys bounded :class:`Ring`
buffers by the constant-stripped *template* signature hash (the scheduler's
grouping key, ``trace.sig_hash(handle.group_key)``) and records one row per
DELIVERY — latency, pilot wall, scanned bytes, provenance flags (cached /
shared / fused / staged / fallback / failed) and, when audit mode runs, the
observed/promised error ratio — exposing streaming windowed p50/p95/p99
quantiles per field.

Wiring.  The session's delivery hook (:meth:`Session._observe_delivery`)
feeds the store on every ``_mark_done`` / ``_mark_failed``; scheduler
drains feed the streaming latency rings (:meth:`record_drain`).  The store
registers as a ``timeseries`` collector on the session's
:class:`MetricsRegistry`, so the quantiles flow through ``tree()``,
``stats_payload()["timeseries"]`` and ``metrics_text()`` with no extra
plumbing.  The flight recorder (:mod:`repro.obs.events`) logs the same
rows as ``deliver`` / ``fail`` / ``audit`` events, and
:func:`repro.obs.events.rebuild_timeseries` replays them into a fresh
store offline.

Non-perturbation contract (same as tracing/audit): recording only READS
finished handles — seeds, plans, reductions and answers are untouched, so
telemetry ON is bit-identical to telemetry OFF, and OFF (the default)
allocates nothing on the query path.
"""

from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional

__all__ = ["Ring", "TemplateSeries", "TemplateTimeSeries", "quantile"]

#: The windowed quantiles every ring exposes in snapshots.
QUANTILES = (0.50, 0.95, 0.99)


def quantile(values: List[float], q: float) -> float:
    """Nearest-rank quantile of ``values`` (0.0 on empty input)."""
    if not values:
        return 0.0
    s = sorted(values)
    idx = min(len(s) - 1, max(0, int(math.ceil(q * len(s))) - 1))
    return float(s[idx])


class Ring:
    """Fixed-capacity float ring buffer with a lifetime push counter."""

    __slots__ = ("cap", "_buf", "_head", "total")

    def __init__(self, cap: int):
        if cap < 1:
            raise ValueError(f"ring capacity must be >= 1, got {cap}")
        self.cap = int(cap)
        self._buf: List[float] = []
        self._head = 0          # next overwrite position once full
        self.total = 0          # lifetime pushes (>= len(self))

    def push(self, v: float) -> None:
        v = float(v)
        if len(self._buf) < self.cap:
            self._buf.append(v)
        else:
            self._buf[self._head] = v
            self._head = (self._head + 1) % self.cap
        self.total += 1

    def __len__(self) -> int:
        return len(self._buf)

    def values(self) -> List[float]:
        """Window contents, oldest first."""
        if len(self._buf) < self.cap:
            return list(self._buf)
        return self._buf[self._head:] + self._buf[:self._head]

    def last(self) -> float:
        if not self._buf:
            return 0.0
        return self._buf[self._head - 1] if len(self._buf) == self.cap \
            else self._buf[-1]

    def stats(self) -> Dict[str, float]:
        """Windowed summary: p50/p95/p99, mean, max, last, window length."""
        vals = self._buf  # order is irrelevant for quantiles
        out = {f"p{int(q * 100)}": quantile(vals, q) for q in QUANTILES}
        out["mean"] = float(sum(vals) / len(vals)) if vals else 0.0
        out["max"] = float(max(vals)) if vals else 0.0
        out["last"] = self.last()
        out["window"] = len(vals)
        out["total"] = self.total
        return out


class TemplateSeries:
    """One template's ring set plus provenance counters (lock owned by the
    parent store — all mutation goes through :class:`TemplateTimeSeries`)."""

    __slots__ = ("key", "sql_example", "latency_s", "pilot_wall_s",
                 "scanned_bytes", "error_ratio", "deliveries", "cached",
                 "shared", "fused", "staged", "fallbacks", "failures",
                 "audited", "audit_violations")

    def __init__(self, key: str, window: int):
        self.key = key
        self.sql_example: Optional[str] = None
        self.latency_s = Ring(window)
        self.pilot_wall_s = Ring(window)
        self.scanned_bytes = Ring(window)
        self.error_ratio = Ring(window)
        self.deliveries = 0
        self.cached = 0
        self.shared = 0
        self.fused = 0
        self.staged = 0
        self.fallbacks = 0
        self.failures = 0
        self.audited = 0
        self.audit_violations = 0

    # -- derived rates (cumulative, not windowed) -----------------------------
    @property
    def fallback_rate(self) -> float:
        return self.fallbacks / self.deliveries if self.deliveries else 0.0

    @property
    def failure_rate(self) -> float:
        return self.failures / self.deliveries if self.deliveries else 0.0

    @property
    def violation_rate(self) -> float:
        return self.audit_violations / self.audited if self.audited else 0.0

    def slo_stats(self) -> Dict[str, float]:
        """The observables SLO targets evaluate against (see obs/slo.py)."""
        return {
            "samples": self.deliveries,
            "p95_latency_s": quantile(self.latency_s.values(), 0.95),
            "fallback_rate": self.fallback_rate,
            "violation_rate": self.violation_rate,
        }

    def snapshot(self) -> Dict[str, object]:
        return {
            "sql": self.sql_example,  # dropped by Prometheus flatten
            "deliveries": self.deliveries,
            "cached": self.cached,
            "shared": self.shared,
            "fused": self.fused,
            "staged": self.staged,
            "fallbacks": self.fallbacks,
            "failures": self.failures,
            "audited": self.audited,
            "audit_violations": self.audit_violations,
            "fallback_rate": self.fallback_rate,
            "failure_rate": self.failure_rate,
            "violation_rate": self.violation_rate,
            "latency_s": self.latency_s.stats(),
            "pilot_wall_s": self.pilot_wall_s.stats(),
            "scanned_bytes": self.scanned_bytes.stats(),
            "error_ratio": self.error_ratio.stats(),
        }


class TemplateTimeSeries:
    """Bounded per-template series store (thread-safe).

    ``max_templates`` bounds residency: past it, the least-recently-updated
    template's rings are evicted (its counters go with it — the store is a
    window over recent behavior, not an archive; lifetime totals live in the
    metrics registry).
    """

    def __init__(self, window: int = 256, max_templates: int = 64):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if max_templates < 1:
            raise ValueError(
                f"max_templates must be >= 1, got {max_templates}")
        self.window = int(window)
        self.max_templates = int(max_templates)
        self._lock = threading.Lock()
        self._series: Dict[str, TemplateSeries] = {}  # insert-ordered (LRU)
        # drain-level streaming latency rings (DrainStats feed)
        self.ttff_s = Ring(window)
        self.ttf_s = Ring(window)
        self.drains = 0

    def _get(self, key: str, sql: Optional[str]) -> TemplateSeries:
        s = self._series.pop(key, None)
        if s is None:
            s = TemplateSeries(key, self.window)
            while len(self._series) >= self.max_templates:
                self._series.pop(next(iter(self._series)))
        self._series[key] = s  # re-insert: most-recently-updated last
        if sql is not None and s.sql_example is None:
            s.sql_example = sql
        return s

    # -- recording ------------------------------------------------------------
    def record_delivery(self, key: str, *, sql: Optional[str] = None,
                        latency_s: float = 0.0, pilot_wall_s: float = 0.0,
                        scanned_bytes: float = 0, cached: bool = False,
                        shared: bool = False, fused: bool = False,
                        staged: bool = False, fallback: bool = False,
                        failed: bool = False) -> None:
        with self._lock:
            s = self._get(key, sql)
            s.deliveries += 1
            s.latency_s.push(latency_s)
            if failed:
                s.failures += 1
                return  # no report: pilot/scan rows would be fabricated
            s.pilot_wall_s.push(pilot_wall_s)
            s.scanned_bytes.push(scanned_bytes)
            s.cached += bool(cached)
            s.shared += bool(shared)
            s.fused += bool(fused)
            s.staged += bool(staged)
            s.fallbacks += bool(fallback)

    def record_audit(self, key: str, ratio: float, passed: bool) -> None:
        with self._lock:
            s = self._get(key, None)
            s.audited += 1
            s.audit_violations += not passed
            s.error_ratio.push(ratio)

    def record_drain(self, ttff_s: Optional[float],
                     ttf_s: Optional[float]) -> None:
        """Streaming latency of one drain() call (None field = no frames /
        no terminal frames among the drain's streaming handles)."""
        with self._lock:
            self.drains += 1
            if ttff_s is not None:
                self.ttff_s.push(ttff_s)
            if ttf_s is not None:
                self.ttf_s.push(ttf_s)

    # -- introspection --------------------------------------------------------
    def series(self, key: str) -> Optional[TemplateSeries]:
        with self._lock:
            return self._series.get(key)

    def keys(self) -> List[str]:
        with self._lock:
            return list(self._series)

    def slo_stats(self, key: str) -> Optional[Dict[str, float]]:
        with self._lock:
            s = self._series.get(key)
            return None if s is None else s.slo_stats()

    def values(self, key: str, field: str = "latency_s") -> List[float]:
        """Raw window contents of one template ring (dashboard sparklines)."""
        with self._lock:
            s = self._series.get(key)
            if s is None:
                return []
            ring = getattr(s, field, None)
            return ring.values() if isinstance(ring, Ring) else []

    def snapshot(self) -> Dict[str, object]:
        """The collector payload: per-template windowed stats plus the
        drain-level streaming rings.  Schema is additive-only (it rides
        ``stats_payload()["timeseries"]``)."""
        with self._lock:
            return {
                "enabled": True,
                "window": self.window,
                "drains": self.drains,
                "ttff_s": self.ttff_s.stats(),
                "ttf_s": self.ttf_s.stats(),
                "templates": {k: s.snapshot()
                              for k, s in self._series.items()},
            }


def empty_snapshot() -> Dict[str, object]:
    """The ``timeseries`` payload section when telemetry is off: the same
    top-level keys, zero state — consumers never key-check."""
    return {"enabled": False, "window": 0, "drains": 0,
            "ttff_s": {}, "ttf_s": {}, "templates": {}}
