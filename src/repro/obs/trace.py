"""Query-lifecycle tracing: one span tree per :class:`QueryHandle`.

A :class:`QueryTrace` records the full life of a query as nested timed
spans — parse → lower → schedule → pilot (shared/solo, staged-rung,
shard-fanout tags) → rate solve (§4) → compile (hit/miss + signature) →
final dispatch (batched/solo/staged/per-shard) → deliver — with wall times,
``scanned_bytes`` and fallback reasons as span attributes.  Exportable as a
JSON span tree (:meth:`QueryTrace.to_dict`) or Chrome trace-event format
(:meth:`QueryTrace.to_chrome`, load in ``chrome://tracing`` / Perfetto) via
``handle.trace()`` / ``handle.trace("chrome")``.

Zero-overhead contract.  Tracing is opt-in (``SessionConfig.tracing``,
default False): an untraced handle carries no trace object, nothing is
activated, and every instrumentation point in the engine degrades to a
single context-var read returning the shared no-op span — the default path
is behaviorally identical to the pre-tracing code.  With tracing ON, spans
only *observe* (``time.perf_counter`` + attribute dicts); they never touch
seed derivation, sampling, plan choice, or reduction order — so traced
answers are bit-identical to untraced ones in every configuration (the
``tests/test_obs.py`` matrix pins solo/herd/batched/cached/staged/sharded).

Cross-thread structure.  The runtime executes one query on several threads
(group worker, pilot-pool thread, the client's own thread for cached
serves).  Spans nest per thread: each thread that opens spans inside a
trace keeps its own open-span stack, and a span opened on a thread with no
enclosing span attaches to the root — so concurrent stages never interleave
into a bogus parent chain.  The *active* trace travels via a context var:
layers below the session (executor, physical compiler, staged catalog,
dist executor) call the module-level :func:`span` / :func:`annotate`
helpers and need no handle plumbing.

Closure contract.  ``QueryTrace.finish`` (called by the handle's
``_mark_done`` / ``_mark_failed``) closes every open span and the root —
so every COMPLETED, FALLBACK, or FAILED query yields a closed span tree,
including mid-group captured failures (the ErrorFrame path).
"""

from __future__ import annotations

import contextvars
import dataclasses
import hashlib
import threading
import time
from typing import Dict, List, Optional

import numpy as np

_ACTIVE: "contextvars.ContextVar[Optional[QueryTrace]]" = \
    contextvars.ContextVar("pilotdb_active_trace", default=None)


def _jsonable(v):
    """Coerce an attribute value to something ``json.dump`` accepts."""
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    if isinstance(v, np.generic):
        return v.item()
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return repr(v)


def sig_hash(obj) -> str:
    """Short stable hash of a plan/compile signature for span attributes
    (the full signature repr is kilobytes; a 12-hex-char digest is enough
    to correlate compile spans with cache keys)."""
    return hashlib.blake2b(repr(obj).encode(), digest_size=6).hexdigest()


class Span:
    """One timed, attributed node of the span tree."""

    __slots__ = ("name", "t0", "t1", "attrs", "children", "status", "tid")

    def __init__(self, name: str, t0: Optional[float] = None):
        self.name = name
        self.t0 = time.perf_counter() if t0 is None else t0
        self.t1: Optional[float] = None
        self.attrs: Dict[str, object] = {}
        self.children: List["Span"] = []
        self.status = "ok"
        self.tid = threading.get_ident()

    @property
    def open(self) -> bool:
        return self.t1 is None

    @property
    def duration_s(self) -> float:
        return (time.perf_counter() if self.t1 is None else self.t1) - self.t0

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def to_dict(self, base: float) -> Dict[str, object]:
        return {
            "name": self.name,
            "t_start_s": self.t0 - base,
            "duration_s": self.duration_s,
            "status": self.status,
            "attrs": {k: _jsonable(v) for k, v in self.attrs.items()},
            "children": [c.to_dict(base) for c in self.children],
        }


class _NullSpan:
    """Shared no-op span: what instrumentation points get when no trace is
    active.  Supports the same surface as a live span context."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


NULL_SPAN = _NullSpan()


class _SpanCtx:
    """Context manager pairing a span with its trace's per-thread stack."""

    __slots__ = ("_trace", "_span")

    def __init__(self, trace: "QueryTrace", span: Span):
        self._trace = trace
        self._span = span

    def __enter__(self) -> Span:
        return self._span

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self._span.status = "error"
            self._span.attrs.setdefault(
                "error", f"{exc_type.__name__}: {exc}")
        self._trace._close(self._span)
        return False


class QueryTrace:
    """The span tree of one query; thread-safe, closed at completion."""

    def __init__(self, query_id: int, sql: Optional[str] = None,
                 t_start: Optional[float] = None):
        self._lock = threading.Lock()
        self.query_id = query_id
        self.t0 = time.perf_counter() if t_start is None else t_start
        self.root = Span("query", t0=self.t0)
        self.root.attrs["query_id"] = query_id
        if sql is not None:
            self.root.attrs["sql"] = sql
        # per-thread open-span stacks (root is the implicit stack bottom)
        self._stacks: Dict[int, List[Span]] = {}
        # cross-thread named spans (e.g. "schedule": opened at submission on
        # the client thread, closed by whatever worker starts the query)
        self._named: Dict[str, Span] = {}
        self.status: Optional[str] = None  # None while the query lives

    # -- recording ------------------------------------------------------------
    @property
    def finished(self) -> bool:
        return self.status is not None

    def _parent(self, tid: int) -> Span:
        stack = self._stacks.get(tid)
        return stack[-1] if stack else self.root

    def span(self, name: str, **attrs):
        """Open a nested span on the calling thread (context manager)."""
        with self._lock:
            if self.finished:
                return NULL_SPAN
            sp = Span(name)
            sp.attrs.update(attrs)
            tid = threading.get_ident()
            self._parent(tid).children.append(sp)
            self._stacks.setdefault(tid, []).append(sp)
        return _SpanCtx(self, sp)

    def _close(self, sp: Span) -> None:
        with self._lock:
            if sp.t1 is None:  # finish() may have force-closed it already
                sp.t1 = time.perf_counter()
            stack = self._stacks.get(sp.tid, [])
            if sp in stack:  # pop through sp (tolerates leaked children)
                del stack[stack.index(sp):]

    def record(self, name: str, duration_s: float = 0.0, **attrs) -> Span:
        """Append an already-elapsed span ending now (used where the work
        ran elsewhere — e.g. a member's view of a shared pilot stage, or a
        final that landed inside a batched dispatch)."""
        with self._lock:
            if self.finished:
                return Span(name)
            t1 = time.perf_counter()
            sp = Span(name, t0=t1 - max(0.0, duration_s))
            sp.t1 = t1
            sp.attrs.update(attrs)
            self._parent(threading.get_ident()).children.append(sp)
            return sp

    def open_span(self, name: str, **attrs) -> None:
        """Open a NAMED root-attached span that another thread will close
        (idempotent per name while open)."""
        with self._lock:
            if self.finished or name in self._named:
                return
            sp = Span(name)
            sp.attrs.update(attrs)
            self.root.children.append(sp)
            self._named[name] = sp

    def close_span(self, name: str, **attrs) -> None:
        """Close the named span if open (no-op otherwise)."""
        with self._lock:
            sp = self._named.pop(name, None)
            if sp is not None:
                sp.attrs.update(attrs)
                sp.t1 = time.perf_counter()

    def annotate(self, **attrs) -> None:
        """Set attributes on the calling thread's innermost open span (the
        root when none) — how deep layers tag the enclosing stage span."""
        with self._lock:
            if not self.finished:
                self._parent(threading.get_ident()).attrs.update(attrs)

    def annotate_count(self, key: str, n: int = 1) -> None:
        """Increment a numeric attribute on the innermost open span (e.g.
        compile hits/misses observed while a stage executes)."""
        with self._lock:
            if self.finished:
                return
            attrs = self._parent(threading.get_ident()).attrs
            attrs[key] = int(attrs.get(key, 0)) + n

    def finish(self, status: str = "ok", **attrs) -> None:
        """Close EVERY open span and the root (idempotent).  Called from
        ``_mark_done`` / ``_mark_failed`` — so completed, fallback, and
        failed queries all end with a closed tree."""
        with self._lock:
            if self.finished:
                return
            self.status = status
            t1 = time.perf_counter()
            for stack in self._stacks.values():
                for sp in stack:
                    if sp.t1 is None:
                        sp.t1 = t1
            self._stacks.clear()
            for sp in self._named.values():
                if sp.t1 is None:
                    sp.t1 = t1
            self._named.clear()
            self.root.attrs.update(attrs)
            self.root.status = "ok" if status == "ok" else "error"
            self.root.t1 = t1

    # -- introspection / export ----------------------------------------------
    def open_spans(self) -> List[str]:
        """Names of spans still open (tests assert ``[]`` after completion;
        the root is included until :meth:`finish`)."""
        out: List[str] = []

        def walk(sp: Span) -> None:
            if sp.open:
                out.append(sp.name)
            for c in sp.children:
                walk(c)

        with self._lock:
            walk(self.root)
        return out

    def span_names(self) -> List[str]:
        """Every span name in the tree, preorder."""
        out: List[str] = []

        def walk(sp: Span) -> None:
            out.append(sp.name)
            for c in sp.children:
                walk(c)

        with self._lock:
            walk(self.root)
        return out

    def find(self, name: str) -> List[Span]:
        """All spans named ``name`` (preorder)."""
        out: List[Span] = []

        def walk(sp: Span) -> None:
            if sp.name == name:
                out.append(sp)
            for c in sp.children:
                walk(c)

        with self._lock:
            walk(self.root)
        return out

    def to_dict(self) -> Dict[str, object]:
        """JSON-able span tree (times relative to trace start, seconds)."""
        with self._lock:
            return {
                "query_id": self.query_id,
                "status": self.status or "open",
                "duration_s": self.root.duration_s,
                "root": self.root.to_dict(self.t0),
            }

    def to_chrome(self) -> List[Dict[str, object]]:
        """Chrome trace-event format: a list of complete ("ph": "X") events
        — ``json.dump`` the list and load it in chrome://tracing/Perfetto.
        Thread ids are remapped to small ordinals per trace."""
        events: List[Dict[str, object]] = []
        tids: Dict[int, int] = {}

        def walk(sp: Span) -> None:
            tid = tids.setdefault(sp.tid, len(tids))
            events.append({
                "name": sp.name,
                "ph": "X",
                "ts": (sp.t0 - self.t0) * 1e6,
                "dur": sp.duration_s * 1e6,
                "pid": self.query_id,
                "tid": tid,
                "args": {k: _jsonable(v) for k, v in sp.attrs.items()},
            })
            for c in sp.children:
                walk(c)

        with self._lock:
            walk(self.root)
        return events


# -- context plumbing (what the engine layers call) ---------------------------

def activate(trace: Optional[QueryTrace]):
    """Make ``trace`` the calling thread's active trace; returns a token
    for :func:`deactivate` (None when ``trace`` is None — the no-op case).
    ALWAYS pair with deactivate in a finally: worker threads are pooled and
    a leaked context var would misattribute the next query's spans."""
    if trace is None:
        return None
    return _ACTIVE.set(trace)


def deactivate(token) -> None:
    if token is not None:
        _ACTIVE.reset(token)


def active() -> Optional[QueryTrace]:
    return _ACTIVE.get()


def span(name: str, **attrs):
    """Open a span on the active trace — the shared no-op when none.  This
    is the single instrumentation entry point for layers below the session
    (executor, compiler, staged catalog, dist executor)."""
    tr = _ACTIVE.get()
    if tr is None:
        return NULL_SPAN
    return tr.span(name, **attrs)


def annotate(**attrs) -> None:
    """Tag the active trace's innermost open span (no-op when untraced)."""
    tr = _ACTIVE.get()
    if tr is not None:
        tr.annotate(**attrs)


def annotate_count(key: str, n: int = 1) -> None:
    tr = _ACTIVE.get()
    if tr is not None:
        tr.annotate_count(key, n)
