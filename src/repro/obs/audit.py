"""Guarantee auditor: EXPLAIN-style reports and observed-vs-promised error.

Two facilities, both read-only over the query path:

* :func:`explain` — a per-query text report of what the guarantee machinery
  actually did: promised ε / confidence, the solved §3.2 sampling rates,
  the pilot inputs to the §4 bound (n, θ_p), scanned vs full bytes, and
  answer provenance (fresh / shared-pilot / cached / staged / dist /
  exact-fallback).  Available as ``handle.explain()`` once a query is done.

* :class:`GuaranteeAuditor` — opt-in audit mode (``SessionConfig.audit``):
  after each approximate answer is DELIVERED, the auditor runs the exact
  query alongside and records observed vs promised relative error into the
  metrics registry — the runtime version of the paper's Figure-9 check and
  the gate the TPC-H suite will reuse.

Non-perturbation contract.  Audit runs happen *after* ``_mark_done`` (the
client already has its answer), use :meth:`PilotDB.exact` (no RNG, no
sampling seeds), and never write the result cache — and because every seed
in the system is content-derived (session seed × query text × spec), an
extra exact scan cannot shift any other query's sampling.  Audit mode is
therefore bit-identical to non-audit mode on every answer; it only adds
exact scan cost and registry entries.  The auditor compares against the
BASE answer (before HAVING/LIMIT post-filters) so every group the
guarantee covered is checked, and it never raises into the query path.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional

import numpy as np

__all__ = ["AuditRecord", "GuaranteeAuditor", "explain", "provenance_of"]


@dataclasses.dataclass
class AuditRecord:
    """Observed-vs-promised outcome for one audited query."""

    query_id: int
    promised_error: float
    confidence: float
    observed_error: float        # max relative error over composites x groups
    error_ratio: float           # observed / promised (<= 1.0 means honored)
    passed: bool
    groups_checked: int
    exact_wall_s: float
    provenance: str
    skipped: Optional[str] = None  # reason the exact run was unnecessary


def provenance_of(handle) -> str:
    """Which path produced the answer: ``cached``, ``exact-fallback``,
    ``shared-pilot``, or ``fresh`` — suffixed ``+staged`` / ``+dist`` /
    ``+fused`` when the trace recorded staged-rung or shard-fanout
    execution, or the PR-9 single-launch fused program engaged (the
    ``fused`` span with ``engaged=True``; also reported without a trace
    via the handle's fused-delivery flag)."""
    if handle.cached:
        base = "cached"
    else:
        answer = handle._answer
        report = answer.report if answer is not None else None
        if report is not None and report.fallback:
            base = "exact-fallback"
        elif report is not None and report.pilot_shared:
            base = "shared-pilot"
        else:
            base = "fresh"
    tags = []
    trace = getattr(handle, "_trace", None)
    if trace is not None:

        def walk(sp):
            if sp.attrs.get("staged"):
                tags.append("staged")
            if sp.name == "shard_fanout":
                tags.append("dist")
            if sp.name == "fused" and sp.attrs.get("engaged"):
                tags.append("fused")
            for c in sp.children:
                walk(c)

        walk(trace.root)
    if not handle.cached and getattr(handle, "_fused", False):
        tags.append("fused")  # untraced fused deliveries still report it
    for tag in ("staged", "dist", "fused"):
        if tag in tags:
            base += f"+{tag}"
    return base


class GuaranteeAuditor:
    """Runs exact queries alongside approximate answers and records the
    observed-vs-promised error ratio into the metrics registry."""

    def __init__(self, db, metrics) -> None:
        self.db = db
        self._lock = threading.Lock()
        self._records: List[AuditRecord] = []
        self._errors = 0
        self._max_ratio = 0.0
        self._runs = metrics.counter(
            "pilotdb_audit_runs_total",
            "Queries audited against an exact run")
        self._violations = metrics.counter(
            "pilotdb_audit_violations_total",
            "Audited queries whose observed error exceeded the promise")
        self._audit_errors = metrics.counter(
            "pilotdb_audit_errors_total",
            "Audit attempts that failed internally (answer unaffected)")
        self._ratio = metrics.histogram(
            "pilotdb_audit_error_ratio",
            "Observed / promised relative error per audited query",
            buckets=(0.01, 0.05, 0.1, 0.25, 0.5, 0.75, 1.0, 2.0, 5.0))
        self._max_gauge = metrics.gauge(
            "pilotdb_audit_max_error_ratio",
            "Largest observed/promised error ratio seen")

    # -- recording ------------------------------------------------------------
    def check(self, handle, base_answer) -> Optional[AuditRecord]:
        """Audit one completed query.  ``base_answer`` is the answer BEFORE
        having/limit post-filters.  Never raises; returns the record (also
        stored on ``handle.audit_record``) or None on internal failure."""
        try:
            return self._check(handle, base_answer)
        except Exception:
            with self._lock:
                self._errors += 1
            self._audit_errors.inc()
            return None

    def _check(self, handle, base_answer) -> AuditRecord:
        spec = handle.spec
        report = base_answer.report
        promised = spec.error if spec is not None else 0.0
        confidence = spec.confidence if spec is not None else 1.0
        prov = provenance_of(handle)

        if spec is None or report.fallback:
            # The delivered answer IS exact (requested exact, or fallback):
            # observed error is zero by construction — no second scan.
            rec = AuditRecord(
                query_id=handle.query_id, promised_error=promised,
                confidence=confidence, observed_error=0.0, error_ratio=0.0,
                passed=True, groups_checked=int(base_answer.group_present.sum()),
                exact_wall_s=0.0, provenance=prov,
                skipped="answer is exact")
        else:
            t0 = time.perf_counter()
            exact = self.db.exact(handle.query)
            wall = time.perf_counter() - t0
            observed, n_checked = _max_rel_error(base_answer, exact)
            ratio = observed / promised if promised > 0 else float("inf")
            rec = AuditRecord(
                query_id=handle.query_id, promised_error=promised,
                confidence=confidence, observed_error=observed,
                error_ratio=ratio, passed=observed <= promised,
                groups_checked=n_checked, exact_wall_s=wall,
                provenance=prov)
            self._ratio.observe(ratio)
            if not rec.passed:
                self._violations.inc()
        self._runs.inc()
        with self._lock:
            self._records.append(rec)
            if rec.error_ratio > self._max_ratio:
                self._max_ratio = rec.error_ratio
                self._max_gauge.set(self._max_ratio)
        handle.audit_record = rec
        return rec

    # -- introspection --------------------------------------------------------
    def records(self) -> List[AuditRecord]:
        with self._lock:
            return list(self._records)

    def summary(self) -> Dict[str, object]:
        with self._lock:
            recs = list(self._records)
            errors = self._errors
            max_ratio = self._max_ratio
        audited = [r for r in recs if r.skipped is None]
        return {
            "runs": len(recs),
            "audited": len(audited),
            "skipped_exact": len(recs) - len(audited),
            "violations": sum(1 for r in audited if not r.passed),
            "errors": errors,
            "max_error_ratio": max_ratio,
            "mean_error_ratio": (
                float(np.mean([r.error_ratio for r in audited]))
                if audited else 0.0),
        }


def _max_rel_error(approx, exact):
    """Max relative error over (composite, present-group) cells where the
    exact value is nonzero — the quantity Eq. 1 bounds by ε."""
    present = np.asarray(approx.group_present, dtype=bool) \
        & np.asarray(exact.group_present, dtype=bool)
    n_checked = int(present.sum())
    if n_checked == 0:
        return 0.0, 0
    a = np.asarray(approx.values)[:, present]
    e = np.asarray(exact.values)[:, present]
    nz = (e != 0) & np.isfinite(e) & np.isfinite(a)
    if not nz.any():
        return 0.0, n_checked
    rel = np.abs(a[nz] - e[nz]) / np.abs(e[nz])
    return float(rel.max()), n_checked


# -- EXPLAIN ------------------------------------------------------------------

def explain(handle) -> str:
    """Per-query text report: the guarantee as promised, solved, and paid
    for.  Requires a finished handle (done or failed)."""
    lines: List[str] = []
    qid = handle.query_id
    lines.append(f"Query {qid}: {handle.sql or '<programmatic>'}")
    if handle.status == "failed":
        lines.append(f"  status: FAILED — {handle.error}")
        return "\n".join(lines)
    if not handle.done:
        lines.append(f"  status: {handle.status} (in flight)")
        return "\n".join(lines)

    answer = handle._answer
    report = answer.report
    spec = handle.spec
    lines.append(f"  provenance: {provenance_of(handle)}")
    trace = getattr(handle, "_trace", None)
    fused_spans = trace.find("fused") if trace is not None else []
    if fused_spans:
        sp = fused_spans[0]
        lines.append(
            "  fused: engaged (single launch, 0 host syncs)"
            if sp.attrs.get("engaged")
            else "  fused: attempted, fell back to the two-stage path")
    if spec is None:
        lines.append("  guarantee: none (exact execution requested)")
    else:
        lines.append(
            f"  guarantee: ERROR {spec.error * 100:g}% "
            f"CONFIDENCE {spec.confidence * 100:g}% (a priori, Eq. 1)")
    if report.fallback:
        lines.append(f"  fallback: exact — {report.fallback}")
    if report.pilot_ran or report.pilot_shared:
        shared = " (shared)" if report.pilot_shared else ""
        lines.append(
            f"  pilot{shared}: table={report.pilot_table} "
            f"theta_p={report.theta_pilot:g} "
            f"n_blocks={report.n_pilot_blocks} "
            f"scanned={report.pilot_scanned_bytes:,}B "
            f"wall={report.pilot_time_s * 1e3:.2f}ms")
    if report.plan is not None and not report.fallback:
        rates = ", ".join(
            f"{t}={r:.6f}" for t, r in sorted(report.plan.rates.items()))
        lines.append(
            f"  solved rates (§3.2, {report.candidates} candidates): {rates}")
        lines.append(
            f"  final: scanned={report.final_scanned_bytes:,}B "
            f"vs exact~{report.exact_scanned_bytes:,}B "
            f"wall={report.final_time_s * 1e3:.2f}ms")
    if not report.group_coverage_guaranteed:
        lines.append(
            "  WARNING: group coverage not formally guaranteed "
            "(pilot rate capped below Lemma 3.2)")
    n_groups = int(np.asarray(answer.group_present).sum())
    lines.append(
        f"  answer: {len(answer.names)} aggregate(s) x {n_groups} group(s)"
        + (" [cached]" if handle.cached else ""))
    rec = getattr(handle, "audit_record", None)
    if rec is not None:
        if rec.skipped:
            lines.append(f"  audit: skipped — {rec.skipped}")
        else:
            verdict = "OK" if rec.passed else "VIOLATED"
            lines.append(
                f"  audit: observed={rec.observed_error:.5f} "
                f"promised={rec.promised_error:g} "
                f"ratio={rec.error_ratio:.3f} [{verdict}] "
                f"(exact wall={rec.exact_wall_s * 1e3:.1f}ms)")
    return "\n".join(lines)
