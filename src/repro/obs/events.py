"""Flight recorder: append-only, size-rotated JSONL query-event log.

One compact record per lifecycle event — ``submit`` / ``pilot`` /
``rate_solve`` / ``final`` / ``deliver`` / ``fallback`` / ``fail`` /
``audit`` / ``slo_breach`` / ``trace`` (a sampled span tree, see
``SessionConfig.trace_sample``) — so an operator can reconstruct what a
serving session did long after its in-memory state is gone.  Records are
single JSON lines::

    {"seq": 17, "t": 1754700000.123, "ev": "deliver", "qid": 4,
     "template": "9f2a66c01b7d", "latency_s": 0.0312, ...}

``seq`` is a per-recorder monotone counter (gap-free unless records were
dropped), ``t`` is wall-clock epoch seconds, ``ev`` the event type; the
remaining fields are event-specific (schema in docs/observability.md).

Fault contract.  The recorder NEVER raises into the query path: the file
is opened lazily on first emit, and any I/O failure (unwritable target,
disk full, rotation race) increments ``dropped`` and returns — answers are
unaffected and the next emit retries.  Rotation is size-based: when the
current file would exceed ``max_bytes``, it shifts to ``path.1`` (existing
``path.N`` shift up; the oldest past ``max_files - 1`` is deleted) and a
fresh file opens, so the log's disk footprint is bounded by roughly
``max_bytes * max_files``.

Replay.  :func:`replay` iterates every surviving record oldest-first
(rotated files before the live one, corrupt lines skipped);
:func:`rebuild_timeseries` replays ``deliver`` / ``fail`` / ``audit``
events into a fresh :class:`repro.obs.timeseries.TemplateTimeSeries`, so
the windowed quantiles of a crashed (or remote) session can be rebuilt
offline from its log alone.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, Iterator, Optional

from repro.obs.timeseries import TemplateTimeSeries

__all__ = ["FlightRecorder", "replay", "rebuild_timeseries"]


def _json_default(v):
    """Last-resort coercion so a stray numpy scalar (or any object) can
    never make ``emit`` raise."""
    item = getattr(v, "item", None)
    if callable(item):
        try:
            return item()
        except Exception:
            pass
    return repr(v)


class FlightRecorder:
    """Append-only JSONL event log with size rotation (thread-safe)."""

    def __init__(self, path: str, *, max_bytes: int = 1 << 20,
                 max_files: int = 3):
        self.path = str(path)
        self.max_bytes = max(1024, int(max_bytes))
        self.max_files = max(1, int(max_files))
        self._lock = threading.Lock()
        self._fh = None           # lazily opened: a bad path must not raise
        self._size = 0
        self._seq = 0
        self.emitted = 0
        self.dropped = 0
        self.rotations = 0

    # -- emission (never raises) ----------------------------------------------
    def emit(self, ev: str, **fields) -> bool:
        """Append one event record; returns False (and counts a drop) on any
        failure instead of raising into the query path."""
        try:
            with self._lock:
                self._seq += 1
                rec = {"seq": self._seq, "t": time.time(), "ev": ev}
                rec.update(fields)
                line = json.dumps(rec, separators=(",", ":"),
                                  default=_json_default) + "\n"
                data = line.encode("utf-8")
                if self._fh is not None \
                        and self._size + len(data) > self.max_bytes \
                        and self._size > 0:
                    self._rotate_locked()
                if self._fh is None:
                    self._open_locked()
                self._fh.write(data)
                self._fh.flush()
                self._size += len(data)
                self.emitted += 1
                return True
        except Exception:
            # unwritable target / disk full / closed interpreter: the query
            # path must not observe recorder trouble
            with self._lock:
                self.dropped += 1
            return False

    def _open_locked(self) -> None:
        self._fh = open(self.path, "ab")
        self._size = self._fh.tell()
        if self._size > self.max_bytes:  # resumed onto an oversized log
            self._rotate_locked()
            if self._fh is None:
                self._open_locked()

    def _rotate_locked(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None
        self._size = 0
        if self.max_files <= 1:
            # single-file budget: truncate in place
            open(self.path, "wb").close()
        else:
            oldest = f"{self.path}.{self.max_files - 1}"
            if os.path.exists(oldest):
                os.remove(oldest)
            for i in range(self.max_files - 2, 0, -1):
                src = f"{self.path}.{i}"
                if os.path.exists(src):
                    os.replace(src, f"{self.path}.{i + 1}")
            if os.path.exists(self.path):
                os.replace(self.path, f"{self.path}.1")
        self.rotations += 1

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                try:
                    self._fh.close()
                except Exception:
                    pass
                self._fh = None

    # -- introspection --------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"emitted": self.emitted, "dropped": self.dropped,
                    "rotations": self.rotations}


# -- offline replay -----------------------------------------------------------

def replay(path: str, max_files: int = 16) -> Iterator[dict]:
    """Yield every surviving event record oldest-first: rotated files
    (``path.N`` descending N) before the live file; unreadable files and
    corrupt lines are skipped, so a log torn mid-write still replays."""
    candidates = [f"{path}.{i}" for i in range(max_files, 0, -1)] + [path]
    for fname in candidates:
        try:
            fh = open(fname, "r", encoding="utf-8", errors="replace")
        except OSError:
            continue
        with fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except ValueError:
                    continue  # torn tail of a rotated/crashed write
                if isinstance(rec, dict) and "ev" in rec:
                    yield rec


def rebuild_timeseries(events, *, window: int = 256,
                       max_templates: int = 64) -> TemplateTimeSeries:
    """Replay ``deliver`` / ``fail`` / ``audit`` events into a fresh
    :class:`TemplateTimeSeries` — the offline reconstruction of a session's
    per-template windowed quantiles.  ``events`` is an iterable of record
    dicts (e.g. from :func:`replay`) or a recorder log path, which is
    replayed across its rotations first."""
    if isinstance(events, (str, os.PathLike)):
        events = replay(os.fspath(events))
    ts = TemplateTimeSeries(window=window, max_templates=max_templates)
    for ev in events:
        etype = ev.get("ev")
        key: Optional[str] = ev.get("template")
        if key is None:
            continue
        if etype == "deliver":
            ts.record_delivery(
                key, sql=ev.get("sql"),
                latency_s=float(ev.get("latency_s", 0.0)),
                pilot_wall_s=float(ev.get("pilot_wall_s", 0.0)),
                scanned_bytes=float(ev.get("scanned_bytes", 0)),
                cached=bool(ev.get("cached")), shared=bool(ev.get("shared")),
                fused=bool(ev.get("fused")), staged=bool(ev.get("staged")),
                fallback=bool(ev.get("fallback")))
        elif etype == "fail":
            ts.record_delivery(key, latency_s=float(ev.get("latency_s", 0.0)),
                               failed=True)
        elif etype == "audit":
            ts.record_audit(key, float(ev.get("ratio", 0.0)),
                            bool(ev.get("passed", True)))
    return ts
