"""Physical plans: compiled query pipelines over the kernel layer.

The engine is layered as::

    logical.Plan  --lower-->  compiled physical executable  --run-->  stats
      (what to compute)        (one jitted XLA graph,              (device
       §2.3 plan algebra        kernels for the scan+agg            arrays)
       + TABLESAMPLE clauses)   hot path)

``PhysicalCompiler`` lowers a :class:`logical.Aggregate` tree into a single
jit-compiled executable and caches it under a *plan signature* — the operator
tree shape with sampling rates/seeds stripped AND predicate/expression
constants hoisted (:func:`logical.extract_constants`), the referenced column
set and dtypes, ``block_rows``, ``max_groups``, and the bucketed
sampled-block count.  Constants enter executables as a runtime operand (the
``params`` vector, device scalars / scalar prefetch), so ONE executable
serves every constant variant of a shape: compile misses are O(distinct
shapes), not O(queries) — a dashboard sweeping its date range runs warm.
Repeated pilot/final queries (and many concurrent users issuing structurally
identical queries, the serve-layer scenario) therefore skip recompilation;
``cache_info()`` exposes the hit/miss counters.

``compile_batched_query`` additionally stacks N same-signature members
(block-id matrices + bounds/params matrix) into ONE executable dispatch via
``lax.map`` — the drain-group batching path: N finals cost one launch, and
each member's lane runs the identical per-member HLO, so batched answers are
bit-identical to solo runs.

Kernel routing.  Block-sampled scans and their downstream aggregations are
routed through the Pallas kernels in ``repro.kernels`` when the plan shape
allows:

* ``pallas_filtered`` — single-table ``Aggregate(Filter*(Scan))`` with a
  conjunctive range predicate and SUM(x*y)/SUM(x)/COUNT channels lowers onto
  :func:`repro.kernels.filtered_agg.filtered_agg` (TPC-H Q6 shape): sampled
  block ids travel by scalar prefetch, so unsampled slabs never leave HBM and
  the scan pays θ·bytes, not bytes.
* ``pallas_block``   — filterless ``Aggregate(Scan)`` with SUM(col)/COUNT
  channels lowers onto :func:`repro.kernels.block_agg.block_agg`.
* ``xla_gather``     — everything else (joins, unions, GROUP BY, composite
  expressions) lowers to the kernels' XLA twin: a device-side slab gather
  with static (bucketed) shape followed by one fused multi-channel
  scatter-add.  Same semantics, one graph, no host round-trips.

Pallas routes are selected on TPU backends (``kernel_mode="auto"``) where the
kernels compile to real DMA programs; on CPU containers interpret mode would
run the grid in Python, so ``auto`` falls back to ``xla_gather``.  Tests force
``kernel_mode="pallas"`` at small sizes to pin route equivalence.

Scan-cost attribution lives here too: a compiled executable knows which
tables its kernels stream and charges ``n_real · block_rows · row_bytes`` for
block-sampled scans and full heap bytes for row-sampled/exact scans — the
same row-store accounting the samplers used, now owned by the layer that
actually moves the bytes.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
from concurrent.futures import Future
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import logical as L
from repro.engine.expr import And, Between, BinOp, Cmp, Col, Expr, eval_expr
from repro.engine.table import BlockTable
from repro.kernels.block_agg import block_agg, block_agg_batched
from repro.kernels.filtered_agg import filtered_agg, filtered_agg_batched
from repro.obs import trace as _trace

_BIG_BOUND = 3.0e38       # "unbounded" predicate slot, f32-safe
_INT_MAX = np.int32(2 ** 31 - 1)


# ---------------------------------------------------------------------------
# Scan-cost attribution
# ---------------------------------------------------------------------------

def scan_cost_bytes(table: BlockTable, method: str, n_real: int = 0) -> int:
    """Bytes a scan of ``table`` moves, attributed by the kernel layer.

    Block-sampled scans pay only for real sampled slabs (θ·bytes — the
    padding blocks of the bucketed gather never move in a real storage
    engine); row-sampled and exact scans stream the full heap.  The single
    source of truth for both ``SampleInfo.scanned_bytes`` and compiled
    executables' totals.
    """
    if method == "block":
        return n_real * table.block_rows * table.row_bytes()
    return table.total_bytes()


# ---------------------------------------------------------------------------
# Runtime sampling decisions (the host-side TABLESAMPLE draw)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ScanRuntime:
    """Per-table runtime inputs of a compiled executable.

    The Bernoulli *decision* stays host-side (as a DBMS decides pages before
    scanning them); everything downstream of the decision runs on device.
    ``ids`` is padded to the bucketed length ``n_phys`` with zeros — padding
    entries are masked out inside the graph via ``n_real``, so the executable
    shape (and its cache entry) is shared across nearby sample sizes.
    """

    method: str                             # "none" | "block" | "row"
    n_real: int = 0                         # real sampled blocks (block) — host int
    n_phys: int = 0                         # bucketed physical block count
    ids: Optional[np.ndarray] = None        # (n_phys,) int32, zero-padded
    keep_mask: Optional[np.ndarray] = None  # (padded_rows,) bool (row method)
    # Pre-staged device copies of ids/n_real (repro.engine.staged memoizes a
    # sub-draw once and replays it every query): when set, the per-call
    # host->device transfer is skipped.  Values must match ids/n_real.
    ids_dev: Optional[object] = None
    nreal_dev: Optional[object] = None

    def sig(self) -> tuple:
        if self.method == "block":
            return ("block", self.n_phys)
        return (self.method,)


# ---------------------------------------------------------------------------
# Plan signatures
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1024)
def _template_of(plan: L.Plan) -> Tuple[L.Plan, Tuple[float, ...]]:
    """Memoized constant hoisting (plans are frozen/hashable)."""
    return L.extract_constants(plan)


def plan_template(plan: L.Plan) -> L.Plan:
    """The constant-free template of ``plan`` (Params in constant slots)."""
    return _template_of(plan)[0]


def plan_constants(plan: L.Plan) -> np.ndarray:
    """The runtime constant vector of ``plan``, position-aligned with its
    template's Param slots — the ``params`` operand of compiled executables."""
    return np.asarray(_template_of(plan)[1], np.float32)


def plan_signature(plan: L.Plan, runtimes: Optional[Dict[str, ScanRuntime]] = None,
                   extra: tuple = ()) -> tuple:
    """Hashable structural key for the compile cache.

    Sampling rates and seeds are stripped (they are runtime data); which
    tables are sampled, by which method, and at which bucketed size is kept
    (those are shapes).  Predicate/expression *constants* are hoisted out of
    the key too: they reach executables as the runtime ``params`` operand
    (device scalars / kernel scalar prefetch), exactly as a DBMS binds
    placeholders into one prepared plan — so constant-varied re-issues of a
    shape share one compilation.
    """
    rsig = tuple(sorted((t, r.sig()) for t, r in (runtimes or {}).items()))
    return (plan_template(L.strip_samples(plan)), rsig, tuple(extra))


def _referenced_columns(plan: L.Plan) -> set:
    cols: set = set()

    def walk(p: L.Plan):
        if isinstance(p, L.Aggregate):
            for a in p.aggs:
                if a.expr is not None:
                    cols.update(a.expr.columns())
            if p.group_by is not None:
                cols.add(p.group_by)
            walk(p.child)
        elif isinstance(p, L.Filter):
            cols.update(p.pred.columns())
            walk(p.child)
        elif isinstance(p, L.Join):
            cols.add(p.left_key)
            cols.add(p.right_key)
            walk(p.left)
            walk(p.right)
        elif isinstance(p, L.Union):
            for c in p.inputs:
                walk(c)
        elif isinstance(p, L.Scan):
            pass
        else:
            raise TypeError(p)

    walk(plan)
    return cols


def _needed_by_table(plan: L.Plan, catalog: Dict[str, BlockTable]) -> Dict[str, Tuple[str, ...]]:
    """Referenced columns per scanned table (column pruning for the gather).

    Column names are assumed unique across joined tables — the same invariant
    ``ops.join_unique`` enforces with its collision check.
    """
    referenced = _referenced_columns(plan)
    needed: Dict[str, Tuple[str, ...]] = {}
    for s in plan.scans():
        tab = catalog[s.table]
        needed[s.table] = tuple(sorted(referenced.intersection(tab.columns)))
    return needed


# ---------------------------------------------------------------------------
# Fused multi-channel aggregation primitives (the XLA twin of the kernels)
# ---------------------------------------------------------------------------

def channel_matrix(columns: Dict[str, jnp.ndarray], valid: jnp.ndarray,
                   exprs: Sequence[Optional[Expr]],
                   params=None) -> jnp.ndarray:
    """Stack every aggregate channel's per-row values: (num_channels, rows).

    ``None`` channels are COUNT (ones).  Invalid rows contribute zeros, so a
    single scatter-add over the stacked matrix replaces the legacy
    per-expression Python loop.  ``params`` resolves hoisted-constant Param
    slots in template expressions (compiled lowerings); eager callers pass
    constant-bearing exprs and omit it.
    """
    rows = valid.shape[0]
    outs = []
    for e in exprs:
        if e is None:
            v = jnp.ones(rows, jnp.float32)
        else:
            v = jnp.broadcast_to(
                eval_expr(e, columns, params).astype(jnp.float32), (rows,))
        outs.append(jnp.where(valid, v, 0.0))
    return jnp.stack(outs)


@functools.partial(jax.jit, static_argnames=("exprs", "group_by", "max_groups", "n_origin"))
def dense_block_group_sums(columns, valid, block_id, *, exprs: tuple,
                           group_by: Optional[str], max_groups: int,
                           n_origin: int) -> jnp.ndarray:
    """Per-(origin-block, group) channel sums: (num_channels, n_origin, max_groups).

    One fused scatter-add across all channels; the whole computation is one
    jitted graph with zero host syncs (``ops.block_group_sums`` converts the
    result exactly once at the boundary).
    """
    rows = valid.shape[0]
    if group_by is None:
        gid = jnp.zeros(rows, jnp.int32)
    else:
        gid = jnp.clip(columns[group_by].astype(jnp.int32), 0, max_groups - 1)
    vals = channel_matrix(columns, valid, exprs)
    seg = block_id.astype(jnp.int32) * max_groups + gid
    dense = jnp.zeros((len(exprs), n_origin * max_groups), jnp.float32).at[:, seg].add(vals)
    return dense.reshape(len(exprs), n_origin, max_groups)


@functools.partial(jax.jit, static_argnames=("exprs", "rblock_col", "n_right", "n_origin"))
def dense_block_pair_sums(columns, valid, block_id, lblock_ids, *, exprs: tuple,
                          rblock_col: str, n_right: int, n_origin: int) -> jnp.ndarray:
    """Per-(compact left block, right block) sums: (num_channels, n_p, n_right).

    Left origin blocks compact to their position among ``lblock_ids`` inside
    the graph (scatter-built LUT); rows from unsampled blocks land in a
    scratch slot that is sliced away.
    """
    n_p = lblock_ids.shape[0]
    lut = jnp.full(n_origin, n_p, jnp.int32).at[lblock_ids].set(
        jnp.arange(n_p, dtype=jnp.int32), mode="drop")
    compact = lut[block_id]
    rb = jnp.where(valid, columns[rblock_col].astype(jnp.int32), 0)
    seg = compact * n_right + rb
    vals = channel_matrix(columns, valid, exprs)
    dense = jnp.zeros((len(exprs), (n_p + 1) * n_right), jnp.float32).at[:, seg].add(vals)
    return dense.reshape(len(exprs), n_p + 1, n_right)[:, :n_p]


# ---------------------------------------------------------------------------
# Traced relational pipeline (runs inside jit; static shapes from signatures)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Traced:
    columns: Dict[str, jnp.ndarray]
    valid: jnp.ndarray
    block_id: jnp.ndarray           # origin block id per row
    pblock: Optional[jnp.ndarray]   # compact pilot-block index (pilot lowering)
    block_rows: int
    num_origin_blocks: int


class _Tracer:
    """Evaluates a logical plan symbolically over runtime device arrays.

    Each ``trace`` call happens once per compiled signature (inside
    ``jax.jit``); at runtime the resulting XLA graph executes with no Python
    in the loop and no device→host transfers.
    """

    def __init__(self, catalog: Dict[str, BlockTable],
                 needed: Dict[str, Tuple[str, ...]],
                 methods: Dict[str, str],
                 pilot_table: Optional[str] = None,
                 n_phys_pilot: int = 0,
                 pair_table: Optional[str] = None):
        self.catalog = catalog
        self.needed = needed
        self.methods = methods            # table -> "none" | "block" | "row"
        self.pilot_table = pilot_table
        self.n_phys_pilot = n_phys_pilot  # scratch pblock value == n_phys_pilot
        self.pair_table = pair_table

    # -- scans ---------------------------------------------------------------
    def _scratch_pblock(self, rows: int) -> Optional[jnp.ndarray]:
        if self.pilot_table is None:
            return None
        return jnp.full(rows, self.n_phys_pilot, jnp.int32)

    def _trace_scan(self, plan: L.Scan, rt) -> _Traced:
        name = plan.table
        tab = self.catalog[name]
        cols = {c: rt["cols"][name][c] for c in self.needed[name]}
        valid = rt["valid"][name]
        bid = rt["bid"][name]
        method = self.methods.get(name, "none")
        br = tab.block_rows
        if method == "block":
            ids = rt["ids"][name]
            nreal = rt["nreal"][name]
            n_phys = ids.shape[0]
            row_idx = (ids[:, None].astype(jnp.int32) * br
                       + jnp.arange(br, dtype=jnp.int32)[None, :]).reshape(-1)
            cols = {c: v[row_idx] for c, v in cols.items()}
            real = jnp.repeat(jnp.arange(n_phys, dtype=jnp.int32) < nreal, br)
            valid = valid[row_idx] & real
            bid = bid[row_idx]
            if name == self.pilot_table:
                pblock = jnp.repeat(jnp.arange(n_phys, dtype=jnp.int32), br)
            else:
                pblock = self._scratch_pblock(n_phys * br)
            return _Traced(cols, valid, bid, pblock, br, tab.num_origin_blocks)
        if method == "row":
            valid = valid & rt["mask"][name]
        return _Traced(cols, valid, bid, self._scratch_pblock(tab.padded_rows),
                       br, tab.num_origin_blocks)

    # -- composite operators -------------------------------------------------
    def trace(self, plan: L.Plan, rt) -> _Traced:
        if isinstance(plan, L.Scan):
            return self._trace_scan(plan, rt)
        if isinstance(plan, L.Filter):
            child = self.trace(plan.child, rt)
            mask = eval_expr(plan.pred, child.columns, rt.get("params"))
            return dataclasses.replace(child, valid=child.valid & mask)
        if isinstance(plan, L.Join):
            return self._trace_join(plan, rt)
        if isinstance(plan, L.Union):
            return self._trace_union(plan, rt)
        raise TypeError(plan)

    def _trace_join(self, plan: L.Join, rt) -> _Traced:
        left = self.trace(plan.left, rt)
        right = self.trace(plan.right, rt)
        lkey = left.columns[plan.left_key].astype(jnp.int32)
        rkey = jnp.where(right.valid,
                         right.columns[plan.right_key].astype(jnp.int32), _INT_MAX)
        order = jnp.argsort(rkey)
        sorted_keys = rkey[order]
        pos = jnp.searchsorted(sorted_keys, lkey)
        pos_c = jnp.clip(pos, 0, sorted_keys.shape[0] - 1)
        found = sorted_keys[pos_c] == lkey
        match = order[pos_c]
        valid = left.valid & found
        new_cols = dict(left.columns)
        for cname, col in right.columns.items():
            if cname == plan.right_key:
                continue
            if cname in new_cols:
                raise ValueError(f"column name collision in join: {cname}")
            new_cols[cname] = col[match]
        right_scans = plan.right.scans()
        if (self.pair_table is not None and len(right_scans) == 1
                and right_scans[0].table == self.pair_table):
            new_cols[f"__rblock_{self.pair_table}"] = right.block_id[match].astype(jnp.int32)
        return dataclasses.replace(left, columns=new_cols, valid=valid)

    def _trace_union(self, plan: L.Union, rt) -> _Traced:
        parts = [self.trace(p, rt) for p in plan.inputs]
        names = set(parts[0].columns)
        br = parts[0].block_rows
        offset = 0
        cols = {c: [] for c in names}
        valids, bids, pblocks = [], [], []
        for t in parts:
            if set(t.columns) != names or t.block_rows != br:
                raise ValueError("union inputs must share schema and block size")
            for c in names:
                cols[c].append(t.columns[c])
            valids.append(t.valid)
            bids.append(t.block_id + offset)
            pblocks.append(t.pblock)
            offset += t.num_origin_blocks
        pblock = (jnp.concatenate(pblocks)
                  if self.pilot_table is not None else None)
        return _Traced({c: jnp.concatenate(v) for c, v in cols.items()},
                       jnp.concatenate(valids), jnp.concatenate(bids),
                       pblock, br, offset)


# ---------------------------------------------------------------------------
# Kernel-shape matching (plan suffix -> Pallas lowering)
# ---------------------------------------------------------------------------

def _single_table_chain(child: L.Plan, table: str) -> Optional[List[Expr]]:
    """If ``child`` is Filter*(Scan(table)), return its predicates (maybe [])."""
    preds: List[Expr] = []
    node = child
    while isinstance(node, L.Filter):
        preds.append(node.pred)
        node = node.child
    if isinstance(node, L.Scan) and node.table == table:
        return preds
    return None


def _flatten_conjuncts(pred: Expr) -> List[Expr]:
    if isinstance(pred, And):
        return _flatten_conjuncts(pred.left) + _flatten_conjuncts(pred.right)
    return [pred]


def _match_q6_bounds(preds: List[Expr]) -> Optional[Tuple[Tuple[str, str, str], tuple]]:
    """Map a conjunctive range predicate onto filtered_agg's fixed slots.

    The kernel evaluates ``lo1<=f1<=hi1 AND lo2<=f2<=hi2 AND f3<c3`` with
    *runtime* bounds (scalar prefetch).  Two-sided/non-strict conditions
    fill the f1/f2 slots, a single strict upper bound fills f3; unused slots
    are padded with ±3e38 (never binding for f32 data).  Bound slots are
    either a plain float (the sentinels) or a constant-free :class:`Expr`
    (Param slots of a template plan) evaluated against the params vector at
    trace time.  Returns ((f1,f2,f3) column names, 5 bound slots) or None
    when the predicate doesn't fit.
    """
    conjuncts: List[Expr] = []
    for p in preds:
        conjuncts.extend(_flatten_conjuncts(p))
    two_sided: List[Tuple[str, object, object]] = []
    strict: List[Tuple[str, object]] = []
    for c in conjuncts:
        if isinstance(c, Between) and isinstance(c.arg, Col):
            two_sided.append((c.arg.name, c.lo, c.hi))
        elif isinstance(c, Cmp) and isinstance(c.left, Col) and not c.right.columns():
            v = c.right
            if c.op == "<":
                strict.append((c.left.name, v))
            elif c.op == "<=":
                two_sided.append((c.left.name, -_BIG_BOUND, v))
            elif c.op == ">=":
                two_sided.append((c.left.name, v, _BIG_BOUND))
            else:
                return None
        else:
            return None
    if len(two_sided) > 2 or len(strict) > 1:
        return None
    anchor = (two_sided + [(s[0], -_BIG_BOUND, _BIG_BOUND) for s in strict])
    if not anchor:
        return None  # no predicate at all: the block_agg route handles it
    while len(two_sided) < 2:
        two_sided.append((anchor[0][0], -_BIG_BOUND, _BIG_BOUND))
    if not strict:
        strict.append((anchor[0][0], _BIG_BOUND))
    (f1, lo1, hi1), (f2, lo2, hi2) = two_sided
    f3, c3 = strict[0]
    return (f1, f2, f3), (lo1, hi1, lo2, hi2, c3)


def _bounds_vector(slots: tuple, params) -> jnp.ndarray:
    """Materialize the 5 kernel bound slots as a (5,) runtime f32 vector."""
    vals = []
    for s in slots:
        if isinstance(s, Expr):
            vals.append(jnp.asarray(eval_expr(s, {}, params), jnp.float32))
        else:
            vals.append(jnp.float32(s))
    return jnp.stack(vals)


def _match_channels(exprs: Sequence[Optional[Expr]], *, products: bool):
    """Channels as kernel-computable specs.

    ``products=True`` (filtered route) accepts COUNT / SUM(col) / SUM(a*b);
    ``products=False`` (block route) accepts COUNT / SUM(col).  Returns a
    list of ("count",) | ("prod", x, y|None) specs, or None on mismatch.
    """
    specs = []
    for e in exprs:
        if e is None:
            specs.append(("count",))
        elif isinstance(e, Col):
            specs.append(("prod", e.name, None))
        elif (products and isinstance(e, BinOp) and e.op == "*"
              and isinstance(e.left, Col) and isinstance(e.right, Col)):
            specs.append(("prod", e.left.name, e.right.name))
        else:
            return None
    return specs


# ---------------------------------------------------------------------------
# Compiled executables
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _CompiledBase:
    fn: Callable
    catalog: Dict[str, BlockTable]
    needed: Dict[str, Tuple[str, ...]]
    methods: Dict[str, str]
    route: str

    def _shared_args(self) -> dict:
        """Per-table inputs that do not vary across a batch: column data,
        validity, block ids (the catalog side of the runtime dict)."""
        rt = {"cols": {}, "valid": {}, "bid": {}, "ids": {}, "nreal": {}, "mask": {}}
        for name in self.needed:
            tab = self.catalog[name]
            rt["cols"][name] = {c: tab.columns[c] for c in self.needed[name]}
            rt["valid"][name] = tab.valid
            rt["bid"][name] = tab.block_id
        return rt

    def _runtime_args(self, runtimes: Dict[str, ScanRuntime],
                      params=()) -> dict:
        rt = self._shared_args()
        for name in self.needed:
            r = runtimes.get(name)
            method = self.methods.get(name, "none")
            if method == "block":
                rt["ids"][name] = r.ids_dev if r.ids_dev is not None \
                    else jnp.asarray(r.ids, jnp.int32)
                rt["nreal"][name] = r.nreal_dev if r.nreal_dev is not None \
                    else jnp.asarray(r.n_real, jnp.int32)
            elif method == "row":
                rt["mask"][name] = jnp.asarray(r.keep_mask)
        rt["params"] = jnp.asarray(np.asarray(params, np.float32))
        return rt

    def __call__(self, runtimes: Dict[str, ScanRuntime], params=()):
        return self.fn(self._runtime_args(runtimes, params))

    def scanned_bytes(self, runtimes: Dict[str, ScanRuntime]) -> int:
        """Total scan cost of one run (see :func:`scan_cost_bytes`)."""
        total = 0
        for name in self.needed:
            method = self.methods.get(name, "none")
            n_real = runtimes[name].n_real if method == "block" else 0
            total += scan_cost_bytes(self.catalog[name], method, n_real)
        return total


@dataclasses.dataclass
class CompiledQuery(_CompiledBase):
    """fn(rt) -> (sums (num_channels, max_groups), counts (max_groups,))."""


@dataclasses.dataclass
class CompiledPilot(_CompiledBase):
    """fn(rt) -> (block_sums (n_phys, max_groups, num_channels),
                  group_present (max_groups,) bool,
                  pair (n_phys, n_right, num_channels) or None)."""

    has_pair: bool = False


@dataclasses.dataclass
class CompiledBatch(_CompiledBase):
    """A drain-group batch executable: ``lax.map`` over B same-signature
    members inside ONE jitted dispatch.

    Member lanes differ only in their sampled block ids / row masks and
    their hoisted-constant params row; the per-lane computation is the
    member's solo XLA graph, so lane k of the batch is bit-identical to
    running member k alone.  ``call_batch`` stacks the member runtimes
    (block-id matrix, nreal vector, params matrix) and returns
    (sums (B, num_channels, max_groups), counts (B, max_groups)).
    """

    batch: int = 0

    def call_batch(self, runtimes_list: Sequence[Dict[str, ScanRuntime]],
                   params_list: Sequence[np.ndarray]):
        if len(runtimes_list) != self.batch or len(params_list) != self.batch:
            raise ValueError(
                f"batch executable compiled for {self.batch} members, "
                f"got {len(runtimes_list)}")
        rt = self._shared_args()
        for name in self.needed:
            method = self.methods.get(name, "none")
            if method == "block":
                rt["ids"][name] = jnp.stack(
                    [jnp.asarray(r[name].ids, jnp.int32) for r in runtimes_list])
                rt["nreal"][name] = jnp.asarray(
                    [r[name].n_real for r in runtimes_list], jnp.int32)
            elif method == "row":
                rt["mask"][name] = jnp.stack(
                    [jnp.asarray(r[name].keep_mask) for r in runtimes_list])
        rt["params"] = jnp.asarray(
            np.asarray(params_list, np.float32).reshape(self.batch, -1))
        return self.fn(rt)


@dataclasses.dataclass
class CompiledPilotBatch(CompiledBatch):
    """A batched pilot executable: ``lax.map`` over B same-signature pilot
    scans inside ONE jitted dispatch (the shared-pilot drain-group path).

    ``call_batch`` stacks the member pilot runtimes (block-id matrix, nreal
    vector, params matrix) and returns
    (block_sums (B, n_phys, max_groups, num_channels), present (B, max_groups));
    lane k is bit-identical to member k's solo tracer-route pilot."""


def fused_buckets(num_blocks: int) -> Tuple[int, ...]:
    """Static id-length buckets of the fused final stage.

    Mirrors ``sampling.pad_block_ids``: for any real sampled count n in
    [0, num_blocks], ``min(bucket_blocks(max(n, 1)), num_blocks)`` is one of
    these values — so the on-device ``lax.switch`` branch the fused program
    picks has exactly the physical id length the solo path would pad to.
    """
    out: List[int] = []
    b = 64
    while b < num_blocks:
        out.append(b)
        b <<= 1
    out.append(num_blocks)
    return tuple(out)


@dataclasses.dataclass
class CompiledFused(_CompiledBase):
    """The single-launch TAQA program (pilot -> rate solve -> final).

    fn(rt) -> (block_sums (n_phys_p, max_groups, n_ch), present (max_groups,),
               theta f32, flags int32 bitmask (1 no-groups | 2 bad L_mu |
               4 no feasible plan), nsel int32, padded_ids (num_blocks,) int32,
               sums (n_ch, max_groups), counts (max_groups,)).

    ``call_fused`` adds the three fused-only runtime operands to the standard
    runtime dict: the per-constraint quantile table ``solve`` (n_solve, 5)
    rows [t_q, chi_q, z, z_bin, e], the shared scalar vector ``scal`` (6,)
    [N, max_rate, min_rate, cost_a, cost_b, exact_cost], and the final-draw
    uniform vector ``u`` (num_blocks,) — all host-precomputed, none requiring
    a sync between the stages.
    """

    buckets: Tuple[int, ...] = ()

    def call_fused(self, runtimes: Dict[str, ScanRuntime], params,
                   solve, scal, u):
        rt = self._runtime_args(runtimes, params)
        rt["solve"] = jnp.asarray(
            np.asarray(solve, np.float32).reshape(-1, 5))
        rt["scal"] = jnp.asarray(np.asarray(scal, np.float32))
        rt["u"] = jnp.asarray(np.asarray(u, np.float32))
        return self.fn(rt)


@dataclasses.dataclass
class CacheInfo:
    hits: int = 0
    misses: int = 0
    size: int = 0
    # Staged-sample-catalog serving counters (repro.engine.staged), filled
    # in by Executor.compile_cache_info; zero for a bare compiler.
    staged_hits: int = 0
    staged_misses: int = 0
    # Per-kind attribution of the hit/miss totals above.  ``hits``/``misses``
    # remain the grand totals (existing dashboards keep working); these pairs
    # break out pilot lowerings (solo + batched), drain-group batch
    # executables, and fused TAQA programs so stats_payload() can attribute
    # compilation traffic per path.  Plain query compiles are the remainder.
    pilot_hits: int = 0
    pilot_misses: int = 0
    batched_hits: int = 0
    batched_misses: int = 0
    fused_hits: int = 0
    fused_misses: int = 0
    # Local-cache misses that adopted an executable from a cross-shard
    # SharedBuildStore instead of tracing+compiling (still counted in
    # ``misses``: the local cache did miss — the BUILD was deduplicated).
    shared_hits: int = 0


class SharedBuildStore:
    """Cross-compiler executable store keyed by compile signature.

    Dist shards with identical slab geometry produce identical compile keys
    (keys embed block_rows / padded_rows / bucketed block counts and column
    dtypes, never column data — data enters executables as runtime
    operands).  Same-geometry shard compilers therefore adopt each other's
    built executables: the jitted ``fn`` (and its XLA executable cache) is
    shared and only the catalog binding is rebound per shard, so N
    same-shape shards pay ONE trace+compile instead of N.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._store: Dict[tuple, object] = {}

    def get(self, key):
        with self._lock:
            return self._store.get(key)

    def put(self, key, compiled) -> None:
        with self._lock:
            self._store.setdefault(key, compiled)


# key[0] -> CacheInfo counter kind ("query" keys are the untagged remainder)
_KEY_KIND = {"pilot": "pilot", "pilot_batched": "pilot",
             "batched": "batched", "fused": "fused"}


class PhysicalCompiler:
    """Lowers logical plans to compiled executables, with a signature cache."""

    def __init__(self, catalog: Dict[str, BlockTable], kernel_mode: str = "auto",
                 shared_builds: Optional[SharedBuildStore] = None):
        if kernel_mode not in ("auto", "pallas", "xla"):
            raise ValueError(
                f"kernel_mode must be 'auto', 'pallas', or 'xla', got {kernel_mode!r}")
        self.catalog = catalog
        self.kernel_mode = kernel_mode
        # Optional cross-compiler build store (dist shard dedup): consulted
        # on local-cache misses before building, populated after builds.
        self._shared = shared_builds
        # Values are compiled executables, or a pending Future while one
        # worker builds that key.  The concurrent runtime compiles from
        # worker threads: the lock covers only dict bookkeeping and the
        # hit/miss counters (asserted by scheduler/runtime tests), while
        # tracing/XLA compilation happens OUTSIDE it — distinct plan shapes
        # compile in parallel, cache hits never stall behind a build, and a
        # key still compiles at most once (waiters block on its Future).
        self._cache: Dict[tuple, object] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.shared_hits = 0
        self._kind_hits = {"pilot": 0, "batched": 0, "fused": 0}
        self._kind_misses = {"pilot": 0, "batched": 0, "fused": 0}

    def cache_info(self) -> CacheInfo:
        with self._lock:
            size = sum(1 for v in self._cache.values()
                       if not isinstance(v, Future))
            return CacheInfo(
                self.hits, self.misses, size,
                pilot_hits=self._kind_hits["pilot"],
                pilot_misses=self._kind_misses["pilot"],
                batched_hits=self._kind_hits["batched"],
                batched_misses=self._kind_misses["batched"],
                fused_hits=self._kind_hits["fused"],
                fused_misses=self._kind_misses["fused"],
                shared_hits=self.shared_hits)

    # -- route policy --------------------------------------------------------
    def _use_pallas(self) -> bool:
        if self.kernel_mode == "auto":
            # Interpret mode executes the grid step-by-step in the Pallas
            # interpreter — fine for correctness tests, hopeless as a hot
            # path — so off-TPU the same physical plan lowers to the XLA twin.
            return jax.default_backend() == "tpu"
        return self.kernel_mode == "pallas"

    def _geometry_sig(self, plan: L.Plan, needed) -> tuple:
        out = []
        for t in sorted(needed):
            tab = self.catalog[t]
            out.append((t, tab.block_rows, tab.padded_rows, tab.num_origin_blocks,
                        tuple((c, str(tab.columns[c].dtype)) for c in needed[t])))
        return tuple(out)

    def _lookup(self, key, build):
        kind = _KEY_KIND.get(key[0])
        with self._lock:
            entry = self._cache.get(key)
            if entry is None:  # this thread builds; others wait on the Future
                self.misses += 1
                if kind is not None:
                    self._kind_misses[kind] += 1
                placeholder: Future = Future()
                self._cache[key] = placeholder
            else:
                self.hits += 1  # a waiter did not build — that's a hit
                if kind is not None:
                    self._kind_hits[kind] += 1
        if _trace.active() is not None:  # tag the enclosing stage span
            _trace.annotate_count(
                "compile_misses" if entry is None else "compile_hits")
            _trace.annotate(compile_sig=_trace.sig_hash(key))
        if entry is None:
            try:
                compiled = None
                if self._shared is not None:
                    proto = self._shared.get(key)
                    if proto is not None:
                        # adopt the shared executable: same jitted fn (one
                        # XLA compilation serves all same-geometry shards),
                        # rebound to THIS compiler's catalog for data
                        compiled = dataclasses.replace(proto, catalog=self.catalog)
                        with self._lock:
                            self.shared_hits += 1
                if compiled is None:
                    compiled = build()
                    if self._shared is not None:
                        self._shared.put(key, compiled)
            except BaseException as e:
                with self._lock:  # let a later call retry the build
                    if self._cache.get(key) is placeholder:
                        del self._cache[key]
                placeholder.set_exception(e)
                raise
            with self._lock:
                self._cache[key] = compiled
            placeholder.set_result(compiled)
            return compiled
        if isinstance(entry, Future):
            return entry.result()  # blocks until built; re-raises its error
        return entry

    # -- final / plain queries ----------------------------------------------
    def query_signature(self, plan: L.Aggregate,
                        runtimes: Dict[str, ScanRuntime]) -> tuple:
        """The solo compile key of ``plan`` (constants hoisted) — also the
        bucketing key of the drain-group batch path: members agreeing on it
        share one executable and may share one batched dispatch."""
        needed = _needed_by_table(plan, self.catalog)
        return ("query", self._use_pallas(),
                plan_signature(plan, runtimes, self._geometry_sig(plan, needed)))

    def compile_query(self, plan: L.Aggregate,
                      runtimes: Dict[str, ScanRuntime]) -> CompiledQuery:
        needed = _needed_by_table(plan, self.catalog)
        key = ("query", self._use_pallas(),
               plan_signature(plan, runtimes, self._geometry_sig(plan, needed)))
        return self._lookup(key, lambda: self._build_query(
            plan_template(plan), runtimes, needed))

    def _query_run_fn(self, template, runtimes, needed, allow_kernel=True):
        """The per-member XLA lowering of a (template) query plan: either a
        whole-query Pallas kernel route or the traced gather pipeline.
        Returns (run, route); ``run(rt)`` expects ``rt["params"]``."""
        methods = {t: r.method for t, r in runtimes.items()}
        exprs = tuple(None if a.op == "count" else a.expr for a in template.aggs)
        mg = template.max_groups

        kernel = (self._match_query_kernel(template, runtimes, exprs)
                  if allow_kernel and self._use_pallas() else None)
        if kernel is not None:
            return kernel

        tracer = _Tracer(self.catalog, needed, methods)

        def run(rt):
            tt = tracer.trace(template.child, rt)
            rows = tt.valid.shape[0]
            if template.group_by is None:
                gid = jnp.zeros(rows, jnp.int32)
            else:
                gid = jnp.clip(tt.columns[template.group_by].astype(jnp.int32),
                               0, mg - 1)
            vals = channel_matrix(tt.columns, tt.valid, exprs, rt["params"])
            sums = jnp.zeros((len(exprs), mg), jnp.float32).at[:, gid].add(vals)
            counts = jnp.zeros(mg, jnp.float32).at[gid].add(tt.valid.astype(jnp.float32))
            return sums, counts

        return run, "xla_gather"

    def _build_query(self, template, runtimes, needed) -> CompiledQuery:
        methods = {t: r.method for t, r in runtimes.items()}
        run, route = self._query_run_fn(template, runtimes, needed)
        return CompiledQuery(fn=jax.jit(run), catalog=self.catalog, needed=needed,
                             methods=methods, route=route)

    # -- batched drain-group queries -----------------------------------------
    def compile_batched_query(self, plan: L.Aggregate,
                              runtimes: Dict[str, ScanRuntime],
                              batch: int) -> CompiledBatch:
        """One executable running ``batch`` same-signature members per
        dispatch.  Only the XLA route is batched: the Pallas kernel routes
        own their grids, and off-TPU (where batching matters most — per-call
        dispatch overhead) ``auto`` lowers to XLA anyway.  Callers bucket
        ``batch`` (powers of two) so compile misses stay O(log N)."""
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        needed = _needed_by_table(plan, self.catalog)
        key = ("batched", self._use_pallas(), batch,
               plan_signature(plan, runtimes, self._geometry_sig(plan, needed)))
        return self._lookup(key, lambda: self._build_batched(
            plan_template(plan), runtimes, needed, batch))

    def _build_batched(self, template, runtimes, needed, batch) -> CompiledBatch:
        methods = {t: r.method for t, r in runtimes.items()}
        if self._use_pallas():
            # Megacore-style batched kernel grid: shapes the solo path routes
            # through filtered_agg/block_agg run all B members' finals as ONE
            # kernel launch (grid (B, n_sampled), ids/bounds tables stacked
            # across lanes).  The matcher mirrors _match_query_kernel exactly,
            # so a shape falls through to the lax.map twin below only when
            # the solo route also used xla_gather — lanes stay bit-identical
            # to solo runs either way.
            kb = self._match_batched_query_kernel(template, runtimes)
            if kb is not None:
                run_b, route = kb
                return CompiledBatch(fn=jax.jit(run_b), catalog=self.catalog,
                                     needed=needed, methods=methods,
                                     route=route, batch=batch)
        # lax.map over a Pallas grid is not a supported lowering; shapes the
        # batched kernels cannot take (and every non-pallas route) map the
        # member's XLA graph.
        run, _ = self._query_run_fn(template, runtimes, needed,
                                    allow_kernel=False)

        def run_batched(rt):
            member = {"ids": rt["ids"], "nreal": rt["nreal"],
                      "mask": rt["mask"], "params": rt["params"]}
            shared = {"cols": rt["cols"], "valid": rt["valid"], "bid": rt["bid"]}

            def one(m):
                return run({**shared, **m})

            # lax.map, not vmap: each lane executes the member's own solo
            # graph sequentially inside ONE dispatch, so lane outputs are
            # bit-identical to solo runs (same f32 reduction order).
            return jax.lax.map(one, member)

        return CompiledBatch(fn=jax.jit(run_batched), catalog=self.catalog,
                             needed=needed, methods=methods,
                             route="xla_batched", batch=batch)

    def _match_query_kernel(self, plan, runtimes, exprs):
        """Whole-query kernel route: one block-sampled table, no groups.

        The grouped totals are the per-block kernel stats summed over sampled
        blocks, so the Q6/plain shapes skip the gather entirely.
        """
        if plan.max_groups != 1 or plan.group_by is not None:
            return None
        sampled = [t for t, r in runtimes.items() if r.method != "none"]
        if len(runtimes) != 1 or len(sampled) != 1 or runtimes[sampled[0]].method != "block":
            return None
        table = sampled[0]
        preds = _single_table_chain(plan.child, table)
        if preds is None:
            return None
        lowered = self._lower_block_stats(table, preds, exprs, with_rows=False)
        if lowered is None:
            return None
        stats_fn, route = lowered

        def run(rt):
            ch, cnt = stats_fn(rt)      # (n_phys, n_ch), (n_phys,)
            return ch.sum(axis=0)[:, None], cnt.sum()[None]

        return run, route

    def _match_batched_query_kernel(self, plan, runtimes):
        """Batched whole-query kernel route (the megacore-style grid).

        Same admission conditions as :meth:`_match_query_kernel` — ONE
        block-sampled table, no groups, Filter*(Scan), kernel-computable
        channels — so the batched kernel engages exactly when the solo
        kernel would.  The per-lane reduction (``sum(axis=1)``) runs in the
        same order as the solo route's ``sum(axis=0)``, keeping each lane
        bit-identical to its member's solo kernel run.
        """
        exprs = tuple(None if a.op == "count" else a.expr for a in plan.aggs)
        if plan.max_groups != 1 or plan.group_by is not None:
            return None
        sampled = [t for t, r in runtimes.items() if r.method != "none"]
        if len(runtimes) != 1 or len(sampled) != 1 or runtimes[sampled[0]].method != "block":
            return None
        table = sampled[0]
        preds = _single_table_chain(plan.child, table)
        if preds is None:
            return None
        lowered = self._lower_block_stats_batched(table, preds, exprs)
        if lowered is None:
            return None
        stats_fn, route = lowered

        def run(rt):
            ch, cnt = stats_fn(rt)      # (B, n_phys, n_ch), (B, n_phys)
            return ch.sum(axis=1)[:, :, None], cnt.sum(axis=1)[:, None]

        return run, route

    # -- pilot queries -------------------------------------------------------
    def compile_pilot(self, plan: L.Aggregate, pilot_table: str,
                      runtime: ScanRuntime,
                      pair_table: Optional[str] = None) -> CompiledPilot:
        needed = _needed_by_table(plan, self.catalog)
        key = ("pilot", self._use_pallas(), pilot_table, pair_table,
               plan_signature(plan, {pilot_table: runtime},
                              self._geometry_sig(plan, needed)))
        return self._lookup(key, lambda: self._build_pilot(
            plan_template(plan), pilot_table, runtime.n_phys, pair_table,
            needed))

    def _build_pilot(self, plan, pilot_table, n_phys, pair_table, needed) -> CompiledPilot:
        methods = {pilot_table: "block"}
        mg = plan.max_groups
        # One channel per simple aggregate plus the trailing "__rows" channel
        # (group presence + COUNT/AVG planning), matching PilotStats.
        exprs = tuple([None if a.op == "count" else a.expr for a in plan.aggs] + [None])
        has_pair = pair_table is not None and any(
            isinstance(p, L.Join) and [s.table for s in p.right.scans()] == [pair_table]
            for p in _walk(plan))

        if self._use_pallas() and mg == 1 and not has_pair:
            preds = _single_table_chain(plan.child, pilot_table)
            if preds is not None:
                lowered = self._lower_block_stats(pilot_table, preds, exprs,
                                                  with_rows=True)
                if lowered is not None:
                    stats_fn, route = lowered

                    def run(rt):
                        ch, _ = stats_fn(rt)               # (n_phys, n_ch)
                        block_sums = ch[:, None, :]        # mg == 1
                        present = (ch[:, -1].sum() > 0)[None]
                        return block_sums, present, None

                    return CompiledPilot(fn=jax.jit(run), catalog=self.catalog,
                                         needed=needed, methods=methods,
                                         route=route, has_pair=False)

        run = self._pilot_tracer_run(plan, pilot_table, n_phys, pair_table,
                                     needed, has_pair)
        return CompiledPilot(fn=jax.jit(run), catalog=self.catalog, needed=needed,
                             methods=methods, route="xla_gather", has_pair=has_pair)

    def _pilot_tracer_run(self, plan, pilot_table, n_phys, pair_table, needed,
                          has_pair):
        """The tracer-route pilot body: rt -> (block_sums, present, pair).

        Shared verbatim by the solo pilot lowering, each lane of the batched
        pilot executable, and the pilot half of the fused TAQA program — one
        body, so the three paths cannot drift apart bitwise.
        """
        methods = {pilot_table: "block"}
        mg = plan.max_groups
        exprs = tuple([None if a.op == "count" else a.expr for a in plan.aggs] + [None])
        tracer = _Tracer(self.catalog, needed, methods, pilot_table=pilot_table,
                         n_phys_pilot=n_phys, pair_table=pair_table)
        n_right = self.catalog[pair_table].num_blocks if has_pair else 0
        rcol = f"__rblock_{pair_table}" if has_pair else None

        def run(rt):
            tt = tracer.trace(plan.child, rt)
            rows = tt.valid.shape[0]
            if plan.group_by is None:
                gid = jnp.zeros(rows, jnp.int32)
            else:
                gid = jnp.clip(tt.columns[plan.group_by].astype(jnp.int32), 0, mg - 1)
            vals = channel_matrix(tt.columns, tt.valid, exprs, rt["params"])
            seg = tt.pblock * mg + gid
            dense = jnp.zeros((len(exprs), (n_phys + 1) * mg),
                              jnp.float32).at[:, seg].add(vals)
            bs = dense[:, : n_phys * mg].reshape(len(exprs), n_phys, mg)
            block_sums = bs.transpose(1, 2, 0)
            present = block_sums[:, :, -1].sum(axis=0) > 0
            pair = None
            if has_pair:
                rb = jnp.where(tt.valid, tt.columns[rcol], 0)
                pseg = tt.pblock * n_right + rb
                pdense = jnp.zeros((len(exprs), (n_phys + 1) * n_right),
                                   jnp.float32).at[:, pseg].add(vals)
                pair = pdense[:, : n_phys * n_right].reshape(
                    len(exprs), n_phys, n_right).transpose(1, 2, 0)
            return block_sums, present, pair

        return run

    # -- batched pilots (shared-pilot drain groups) ---------------------------
    def compile_batched_pilot(self, plan: L.Aggregate, pilot_table: str,
                              runtime: ScanRuntime,
                              batch: int) -> "CompiledPilotBatch":
        """One executable running ``batch`` same-signature pilot scans per
        dispatch (``lax.map`` over the solo tracer pilot body).  Pair-table
        shapes and Pallas pilot routes stay solo — callers gate on both."""
        if batch < 2:
            raise ValueError(f"batch must be >= 2, got {batch}")
        needed = _needed_by_table(plan, self.catalog)
        key = ("pilot_batched", batch, pilot_table,
               plan_signature(plan, {pilot_table: runtime},
                              self._geometry_sig(plan, needed)))
        return self._lookup(key, lambda: self._build_batched_pilot(
            plan_template(plan), pilot_table, runtime.n_phys, needed, batch))

    def _build_batched_pilot(self, plan, pilot_table, n_phys, needed,
                             batch) -> "CompiledPilotBatch":
        methods = {pilot_table: "block"}
        run = self._pilot_tracer_run(plan, pilot_table, n_phys, None, needed,
                                     False)

        def run_batched(rt):
            member = {"ids": rt["ids"], "nreal": rt["nreal"],
                      "mask": rt["mask"], "params": rt["params"]}
            shared = {"cols": rt["cols"], "valid": rt["valid"], "bid": rt["bid"]}

            def one(m):
                bs, present, _ = run({**shared, **m})
                return bs, present

            # lax.map, not vmap: lane k executes the solo pilot body
            # sequentially inside ONE dispatch — bit-identical to solo.
            return jax.lax.map(one, member)

        return CompiledPilotBatch(fn=jax.jit(run_batched), catalog=self.catalog,
                                  needed=needed, methods=methods,
                                  route="xla_batched_pilot", batch=batch)

    # -- fused single-launch TAQA ---------------------------------------------
    def compile_fused(self, plan: L.Aggregate, pilot_table: str,
                      runtimes: Dict[str, ScanRuntime],
                      solve_channels: Tuple[int, ...]) -> "CompiledFused":
        """The single-launch TAQA program: pilot scan -> BSAP rate solve ->
        final sampled aggregation, one device dispatch, no host sync between
        the stages.  Gated by callers to the ungrouped / single-sampled-table
        / XLA-route shape; the rate solve on device is ADVISORY (f32) — the
        host re-solves in f64 and verifies the device's final draw before
        trusting its sums (see ``core.taqa.PilotDB.run_fused``)."""
        needed = _needed_by_table(plan, self.catalog)
        num_blocks = self.catalog[pilot_table].num_blocks
        key = ("fused", pilot_table, tuple(solve_channels), num_blocks,
               plan_signature(plan, runtimes, self._geometry_sig(plan, needed)))
        return self._lookup(key, lambda: self._build_fused(
            plan_template(plan), pilot_table, runtimes, needed,
            tuple(solve_channels), num_blocks))

    def _build_fused(self, template, pilot_table, runtimes, needed,
                     solve_channels, num_blocks) -> "CompiledFused":
        methods = {t: r.method for t, r in runtimes.items()}
        n_phys_pilot = runtimes[pilot_table].n_phys
        buckets = fused_buckets(num_blocks)
        pilot_run = self._pilot_tracer_run(template, pilot_table, n_phys_pilot,
                                           None, needed, False)
        # The final body is the member's solo XLA lowering (allow_kernel=False
        # matches the solo path: fused is gated off Pallas routes), traced
        # once per bucket branch with that bucket's static id length.
        final_run, _ = self._query_run_fn(template, runtimes, needed,
                                          allow_kernel=False)
        ch_idx = np.asarray(solve_channels, np.int32)

        def run(rt):
            bs, present, _ = pilot_run(rt)        # (n_phys_p, 1, n_ch), (1,)

            # --- BSAP rate solve, f32 (advisory twin of the f64 host path) --
            # Padding rows of bs are exactly zero, so the moment sums over the
            # full n_phys_p axis equal the n_real-row sums bit-for-bit.
            n = rt["nreal"][pilot_table].astype(jnp.float32)
            solve = rt["solve"]                   # (n_solve, 5) per-constraint
            scal = rt["scal"]                     # (6,) shared scalars
            N, max_rate, min_rate = scal[0], scal[1], scal[2]
            cost_a, cost_b, exact_cost = scal[3], scal[4], scal[5]
            y = bs[:, 0, :][:, ch_idx]            # (n_phys_p, n_solve)
            s1 = y.sum(axis=0)
            s2 = (y * y).sum(axis=0)
            mean = s1 / n
            var = jnp.maximum((s2 - s1 * s1 / n) / jnp.maximum(n - 1.0, 1.0), 0.0)
            t_q, chi_q, z, z_bin, e = (solve[:, i] for i in range(5))
            # L_mu of the population total: N * (block-mean lower bound)
            L_mu = N * (mean - t_q * jnp.sqrt(var) / jnp.sqrt(n))
            var_ub = (n - 1.0) / jnp.maximum(chi_q, 1e-12) * var
            L_ok = jnp.all((L_mu > 0.0) & jnp.isfinite(L_mu))

            def feasible(theta):
                # binomial lower bound on the final sample size, then U_V[θ]
                n_lb = jnp.maximum(
                    N * theta - z_bin * jnp.sqrt(
                        jnp.maximum(N * theta * (1.0 - theta), 0.0)), 0.0)
                u_v = jnp.where(n_lb > 1.0,
                                N * N * (1.0 - theta) * var_ub
                                / jnp.maximum(n_lb, 1e-30), jnp.inf)
                u_v = jnp.where(theta >= 1.0, 0.0, u_v)
                # phi rearranged sync-free: z*sqrt(U_V)/L_mu <= e, L_mu > 0
                ok = (L_mu > 0.0) & (z * jnp.sqrt(jnp.maximum(u_v, 0.0))
                                     <= e * L_mu)
                return jnp.all(ok)

            feas_max = feasible(max_rate)

            def body(_, lohi):
                lo, hi = lohi
                mid = jnp.sqrt(lo * hi)  # geometric: rates span decades
                f = feasible(mid)
                return (jnp.where(f, lo, mid), jnp.where(f, mid, hi))

            _, theta = jax.lax.fori_loop(0, 48, body, (min_rate, max_rate))
            have_plan = feas_max & (cost_a * theta + cost_b < exact_cost)
            go = present[0] & L_ok & have_plan
            flags = (jnp.where(present[0], 0, 1) + jnp.where(L_ok, 0, 2)
                     + jnp.where(have_plan, 0, 4)).astype(jnp.int32)
            theta_eff = jnp.where(go, theta, jnp.float32(0.0))

            # --- final Bernoulli draw + stream compaction (on device) -------
            keep = rt["u"] < theta_eff            # (num_blocks,) f32 uniforms
            nsel = keep.sum().astype(jnp.int32)
            pos = jnp.cumsum(keep.astype(jnp.int32)) - 1
            padded = jnp.zeros(num_blocks, jnp.int32).at[
                jnp.where(keep, pos, num_blocks)].set(
                jnp.arange(num_blocks, dtype=jnp.int32), mode="drop")

            branch_idx = jnp.zeros((), jnp.int32)
            for b in buckets[:-1]:
                branch_idx = branch_idx + (nsel > b).astype(jnp.int32)

            shared = {"cols": rt["cols"], "valid": rt["valid"],
                      "bid": rt["bid"], "mask": rt["mask"],
                      "params": rt["params"]}

            def make_branch(b):
                def br(_):
                    frt = dict(shared)
                    frt["ids"] = {pilot_table: padded[:b]}
                    frt["nreal"] = {pilot_table: nsel}
                    return final_run(frt)
                return br

            sums, counts = jax.lax.switch(
                branch_idx, [make_branch(b) for b in buckets], None)
            return bs, present, theta, flags, nsel, padded, sums, counts

        return CompiledFused(fn=jax.jit(run), catalog=self.catalog,
                             needed=needed, methods=methods, route="xla_fused",
                             buckets=buckets)

    # -- Pallas lowering of per-block stats ----------------------------------
    def _lower_block_stats(self, table: str, preds: List[Expr],
                           exprs: Sequence[Optional[Expr]], *, with_rows: bool):
        """Lower Filter*(Scan) per-block channel stats onto the kernels.

        Returns (stats_fn, route) where ``stats_fn(rt)`` yields
        ``(channel_sums (n_phys, n_ch), counts (n_phys,))`` with padding rows
        (beyond n_real) zeroed, or None when the shape doesn't fit a kernel.
        The sampled block ids reach the kernels via scalar prefetch — and so
        do the predicate bounds, resolved from ``rt["params"]`` at trace
        time, so constant-varied queries share this one kernel compilation.
        """
        tab = self.catalog[table]
        br = tab.block_rows
        if preds:
            q6 = _match_q6_bounds(preds)
            specs = _match_channels(exprs, products=True)
            if q6 is None or specs is None:
                return None
            (f1, f2, f3), slots = q6

            def stats_fn(rt):
                cols = rt["cols"][table]
                valid = rt["valid"][table].astype(jnp.float32)
                ids = rt["ids"][table]
                nreal = rt["nreal"][table]
                n_phys = ids.shape[0]
                bounds = _bounds_vector(slots, rt["params"])
                ones = jnp.ones(tab.padded_rows, jnp.float32)
                outs = {}
                for spec in specs:
                    if spec[0] != "prod" or spec[1:] in outs:
                        continue
                    x = cols[spec[1]]
                    y = ones if spec[2] is None else cols[spec[2]]
                    outs[spec[1:]] = filtered_agg(
                        x, y, cols[f1], cols[f2], cols[f3], valid, br, ids, bounds)
                if not outs:  # COUNT-only query: any column works for cnt
                    c0 = cols[f1]
                    outs[None] = filtered_agg(c0, c0, cols[f1], cols[f2], cols[f3],
                                              valid, br, ids, bounds)
                cnt = next(iter(outs.values()))[:, 0]
                chans = [cnt if s[0] == "count" else outs[s[1:]][:, 1] for s in specs]
                mask = (jnp.arange(n_phys) < nreal).astype(jnp.float32)
                return jnp.stack(chans, axis=1) * mask[:, None], cnt * mask

            return stats_fn, "pallas_filtered"

        specs = _match_channels(exprs, products=False)
        if specs is None:
            return None

        def stats_fn(rt):
            cols = rt["cols"][table]
            valid = rt["valid"][table].astype(jnp.float32)
            ids = rt["ids"][table]
            nreal = rt["nreal"][table]
            n_phys = ids.shape[0]
            outs = {}
            for spec in specs:
                if spec[0] == "prod" and spec[1] not in outs:
                    outs[spec[1]] = block_agg(cols[spec[1]], valid, br, ids)
            if not outs:  # COUNT-only: the cnt lane ignores the value column
                outs[None] = block_agg(valid, valid, br, ids)
            cnt = next(iter(outs.values()))[:, 0]
            chans = [cnt if s[0] == "count" else outs[s[1]][:, 1] for s in specs]
            mask = (jnp.arange(n_phys) < nreal).astype(jnp.float32)
            return jnp.stack(chans, axis=1) * mask[:, None], cnt * mask

        return stats_fn, "pallas_block"

    def _lower_block_stats_batched(self, table: str, preds: List[Expr],
                                   exprs: Sequence[Optional[Expr]]):
        """Batched-lane twin of :meth:`_lower_block_stats`.

        ``rt["ids"][table]`` is (B, n_phys), ``rt["nreal"][table]`` (B,),
        ``rt["params"]`` (B, P).  Returns (stats_fn, route) with
        ``stats_fn(rt)`` yielding ``(channel_sums (B, n_phys, n_ch),
        counts (B, n_phys))`` — per lane exactly the solo stats — or None
        when the shape doesn't fit the kernels.  Per-lane predicate bounds
        resolve from the stacked params matrix (vmapped slot evaluation) and
        ride the scalar-prefetch path next to the stacked block-id table.
        """
        tab = self.catalog[table]
        br = tab.block_rows
        if preds:
            q6 = _match_q6_bounds(preds)
            specs = _match_channels(exprs, products=True)
            if q6 is None or specs is None:
                return None
            (f1, f2, f3), slots = q6

            def stats_fn(rt):
                cols = rt["cols"][table]
                valid = rt["valid"][table].astype(jnp.float32)
                ids = rt["ids"][table]
                nreal = rt["nreal"][table]
                n_phys = ids.shape[1]
                bounds = jax.vmap(lambda p: _bounds_vector(slots, p))(rt["params"])
                ones = jnp.ones(tab.padded_rows, jnp.float32)
                outs = {}
                for spec in specs:
                    if spec[0] != "prod" or spec[1:] in outs:
                        continue
                    x = cols[spec[1]]
                    y = ones if spec[2] is None else cols[spec[2]]
                    outs[spec[1:]] = filtered_agg_batched(
                        x, y, cols[f1], cols[f2], cols[f3], valid, br, ids, bounds)
                if not outs:  # COUNT-only query: any column works for cnt
                    c0 = cols[f1]
                    outs[None] = filtered_agg_batched(
                        c0, c0, cols[f1], cols[f2], cols[f3], valid, br, ids, bounds)
                cnt = next(iter(outs.values()))[:, :, 0]
                chans = [cnt if s[0] == "count" else outs[s[1:]][:, :, 1]
                         for s in specs]
                mask = (jnp.arange(n_phys)[None, :] < nreal[:, None]).astype(jnp.float32)
                return jnp.stack(chans, axis=2) * mask[:, :, None], cnt * mask

            return stats_fn, "pallas_filtered_batched"

        specs = _match_channels(exprs, products=False)
        if specs is None:
            return None

        def stats_fn(rt):
            cols = rt["cols"][table]
            valid = rt["valid"][table].astype(jnp.float32)
            ids = rt["ids"][table]
            nreal = rt["nreal"][table]
            n_phys = ids.shape[1]
            outs = {}
            for spec in specs:
                if spec[0] == "prod" and spec[1] not in outs:
                    outs[spec[1]] = block_agg_batched(cols[spec[1]], valid, br, ids)
            if not outs:  # COUNT-only: the cnt lane ignores the value column
                outs[None] = block_agg_batched(valid, valid, br, ids)
            cnt = next(iter(outs.values()))[:, :, 0]
            chans = [cnt if s[0] == "count" else outs[s[1]][:, :, 1] for s in specs]
            mask = (jnp.arange(n_phys)[None, :] < nreal[:, None]).astype(jnp.float32)
            return jnp.stack(chans, axis=2) * mask[:, :, None], cnt * mask

        return stats_fn, "pallas_block_batched"


def _walk(plan: L.Plan):
    yield plan
    if isinstance(plan, L.Aggregate):
        yield from _walk(plan.child)
    else:
        for c in plan.children():
            yield from _walk(c)
