"""Logical query plans.

PilotDB rewrites SQL; we rewrite these plans.  The supported surface mirrors
§2.3 of the paper: arbitrary compositions of Scan / Filter / equi-Join /
bag-Union under a terminal Aggregate with optional GROUP BY, with linear
aggregates (SUM / COUNT / AVG; AVG is planned as SUM/COUNT via the Table-2
propagation rules).  Non-linear aggregates (MIN/MAX/COUNT DISTINCT) are
rejected exactly as PilotDB rejects them.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.engine.expr import (And, Between, BinOp, Cmp, Const, Expr, Not, Or,
                               Param)

LINEAR_AGG_OPS = ("sum", "count", "avg")


@dataclasses.dataclass(frozen=True)
class SampleClause:
    """TABLESAMPLE SYSTEM (block) / BERNOULLI (row) analogue."""

    method: str  # "block" | "row"
    rate: float  # theta in (0, 1]
    seed: int = 0

    def __post_init__(self):
        if self.method not in ("block", "row"):
            raise ValueError(self.method)
        if not 0.0 < self.rate <= 1.0:
            raise ValueError(f"rate must be in (0,1], got {self.rate}")


@dataclasses.dataclass(frozen=True)
class AggSpec:
    op: str  # sum | count | avg
    expr: Optional[Expr]  # None for COUNT(*)
    name: str

    def __post_init__(self):
        if self.op not in LINEAR_AGG_OPS:
            raise ValueError(
                f"unsupported aggregate {self.op!r}: PilotDB supports linear aggregates only")


class Plan:
    def children(self) -> Tuple["Plan", ...]:
        return ()

    def scans(self) -> List["Scan"]:
        out = []
        if isinstance(self, Scan):
            out.append(self)
        for c in self.children():
            out.extend(c.scans())
        return out


@dataclasses.dataclass(frozen=True)
class Scan(Plan):
    table: str
    sample: Optional[SampleClause] = None


@dataclasses.dataclass(frozen=True)
class Filter(Plan):
    child: Plan
    pred: Expr

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class Join(Plan):
    """Equi-join; the right side's key must be unique among valid rows.

    The physical join preserves the left child's block structure, which is the
    concrete form of Prop. 4.5 (block sampling on the left input commutes with
    the join).
    """

    left: Plan
    right: Plan
    left_key: str
    right_key: str

    def children(self):
        return (self.left, self.right)


@dataclasses.dataclass(frozen=True)
class Union(Plan):
    """Bag union (UNION ALL) of same-schema children (Prop. 4.6)."""

    inputs: Tuple[Plan, ...]

    def children(self):
        return tuple(self.inputs)


@dataclasses.dataclass(frozen=True)
class Aggregate(Plan):
    child: Plan
    aggs: Tuple[AggSpec, ...]
    group_by: Optional[str] = None  # integer-coded group column
    max_groups: int = 1

    def children(self):
        return (self.child,)


def rewrite_scans(plan: Plan, samples: dict) -> Plan:
    """Return a copy of ``plan`` with Scan(table) nodes given sample clauses.

    ``samples`` maps table name -> SampleClause (or None to clear).  This is
    the plan-level analogue of §3.3's "add sampling clauses" rewriting step.
    """
    if isinstance(plan, Scan):
        if plan.table in samples:
            return dataclasses.replace(plan, sample=samples[plan.table])
        return plan
    if isinstance(plan, Filter):
        return dataclasses.replace(plan, child=rewrite_scans(plan.child, samples))
    if isinstance(plan, Join):
        return dataclasses.replace(
            plan,
            left=rewrite_scans(plan.left, samples),
            right=rewrite_scans(plan.right, samples),
        )
    if isinstance(plan, Union):
        return dataclasses.replace(
            plan, inputs=tuple(rewrite_scans(p, samples) for p in plan.inputs))
    if isinstance(plan, Aggregate):
        return dataclasses.replace(plan, child=rewrite_scans(plan.child, samples))
    raise TypeError(plan)


def strip_samples(plan: Plan) -> Plan:
    scans = plan.scans()
    return rewrite_scans(plan, {s.table: None for s in scans})


# ---------------------------------------------------------------------------
# Constant hoisting (template plans for the compile cache)
# ---------------------------------------------------------------------------

def _hoist_expr(e: Expr, out: List[float]) -> Expr:
    if isinstance(e, Const):
        out.append(float(e.value))
        return Param(len(out) - 1)
    if isinstance(e, Param):
        return e  # already a template
    if isinstance(e, BinOp):
        return BinOp(e.op, _hoist_expr(e.left, out), _hoist_expr(e.right, out))
    if isinstance(e, Cmp):
        return Cmp(e.op, _hoist_expr(e.left, out), _hoist_expr(e.right, out))
    if isinstance(e, Between):
        arg = _hoist_expr(e.arg, out)
        if isinstance(e.lo, Expr):
            lo: object = _hoist_expr(e.lo, out)
        else:
            out.append(float(e.lo))
            lo = Param(len(out) - 1)
        if isinstance(e.hi, Expr):
            hi: object = _hoist_expr(e.hi, out)
        else:
            out.append(float(e.hi))
            hi = Param(len(out) - 1)
        return Between(arg, lo, hi)
    if isinstance(e, And):
        return And(_hoist_expr(e.left, out), _hoist_expr(e.right, out))
    if isinstance(e, Or):
        return Or(_hoist_expr(e.left, out), _hoist_expr(e.right, out))
    if isinstance(e, Not):
        return Not(_hoist_expr(e.arg, out))
    return e  # Col, Str: no constants underneath


def _hoist_plan(p: Plan, out: List[float]) -> Plan:
    if isinstance(p, Scan):
        return p
    if isinstance(p, Filter):
        child = _hoist_plan(p.child, out)
        return Filter(child, _hoist_expr(p.pred, out))
    if isinstance(p, Join):
        return dataclasses.replace(p, left=_hoist_plan(p.left, out),
                                   right=_hoist_plan(p.right, out))
    if isinstance(p, Union):
        return Union(tuple(_hoist_plan(c, out) for c in p.inputs))
    if isinstance(p, Aggregate):
        child = _hoist_plan(p.child, out)
        aggs = tuple(
            a if a.expr is None
            else dataclasses.replace(a, expr=_hoist_expr(a.expr, out))
            for a in p.aggs)
        return dataclasses.replace(p, child=child, aggs=aggs)
    raise TypeError(p)


def extract_constants(plan: Plan) -> Tuple[Plan, Tuple[float, ...]]:
    """Split ``plan`` into a constant-free *template* and its constants.

    Every :class:`~repro.engine.expr.Const` value (and ``Between`` bound) is
    replaced by a :class:`~repro.engine.expr.Param` slot, in a fixed
    deterministic traversal order (children before predicates/aggregates,
    left to right), and collected into the returned tuple.  Two plans that
    differ only in predicate/expression constants therefore share one
    template with position-aligned constant vectors — the physical layer
    keys its compile cache on the template and feeds the constants in as a
    runtime operand, so a dashboard sweeping a date range reuses one
    executable instead of recompiling per constant.
    """
    out: List[float] = []
    template = _hoist_plan(plan, out)
    return template, tuple(out)
