"""Logical query plans.

PilotDB rewrites SQL; we rewrite these plans.  The supported surface mirrors
§2.3 of the paper: arbitrary compositions of Scan / Filter / equi-Join /
bag-Union under a terminal Aggregate with optional GROUP BY, with linear
aggregates (SUM / COUNT / AVG; AVG is planned as SUM/COUNT via the Table-2
propagation rules).  Non-linear aggregates (MIN/MAX/COUNT DISTINCT) are
rejected exactly as PilotDB rejects them.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.engine.expr import Expr

LINEAR_AGG_OPS = ("sum", "count", "avg")


@dataclasses.dataclass(frozen=True)
class SampleClause:
    """TABLESAMPLE SYSTEM (block) / BERNOULLI (row) analogue."""

    method: str  # "block" | "row"
    rate: float  # theta in (0, 1]
    seed: int = 0

    def __post_init__(self):
        if self.method not in ("block", "row"):
            raise ValueError(self.method)
        if not 0.0 < self.rate <= 1.0:
            raise ValueError(f"rate must be in (0,1], got {self.rate}")


@dataclasses.dataclass(frozen=True)
class AggSpec:
    op: str  # sum | count | avg
    expr: Optional[Expr]  # None for COUNT(*)
    name: str

    def __post_init__(self):
        if self.op not in LINEAR_AGG_OPS:
            raise ValueError(
                f"unsupported aggregate {self.op!r}: PilotDB supports linear aggregates only")


class Plan:
    def children(self) -> Tuple["Plan", ...]:
        return ()

    def scans(self) -> List["Scan"]:
        out = []
        if isinstance(self, Scan):
            out.append(self)
        for c in self.children():
            out.extend(c.scans())
        return out


@dataclasses.dataclass(frozen=True)
class Scan(Plan):
    table: str
    sample: Optional[SampleClause] = None


@dataclasses.dataclass(frozen=True)
class Filter(Plan):
    child: Plan
    pred: Expr

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class Join(Plan):
    """Equi-join; the right side's key must be unique among valid rows.

    The physical join preserves the left child's block structure, which is the
    concrete form of Prop. 4.5 (block sampling on the left input commutes with
    the join).
    """

    left: Plan
    right: Plan
    left_key: str
    right_key: str

    def children(self):
        return (self.left, self.right)


@dataclasses.dataclass(frozen=True)
class Union(Plan):
    """Bag union (UNION ALL) of same-schema children (Prop. 4.6)."""

    inputs: Tuple[Plan, ...]

    def children(self):
        return tuple(self.inputs)


@dataclasses.dataclass(frozen=True)
class Aggregate(Plan):
    child: Plan
    aggs: Tuple[AggSpec, ...]
    group_by: Optional[str] = None  # integer-coded group column
    max_groups: int = 1

    def children(self):
        return (self.child,)


def rewrite_scans(plan: Plan, samples: dict) -> Plan:
    """Return a copy of ``plan`` with Scan(table) nodes given sample clauses.

    ``samples`` maps table name -> SampleClause (or None to clear).  This is
    the plan-level analogue of §3.3's "add sampling clauses" rewriting step.
    """
    if isinstance(plan, Scan):
        if plan.table in samples:
            return dataclasses.replace(plan, sample=samples[plan.table])
        return plan
    if isinstance(plan, Filter):
        return dataclasses.replace(plan, child=rewrite_scans(plan.child, samples))
    if isinstance(plan, Join):
        return dataclasses.replace(
            plan,
            left=rewrite_scans(plan.left, samples),
            right=rewrite_scans(plan.right, samples),
        )
    if isinstance(plan, Union):
        return dataclasses.replace(
            plan, inputs=tuple(rewrite_scans(p, samples) for p in plan.inputs))
    if isinstance(plan, Aggregate):
        return dataclasses.replace(plan, child=rewrite_scans(plan.child, samples))
    raise TypeError(plan)


def strip_samples(plan: Plan) -> Plan:
    scans = plan.scans()
    return rewrite_scans(plan, {s.table: None for s in scans})
