"""A tiny scalar-expression IR evaluated column-at-a-time with jnp.

Covers the expression surface of the paper's benchmarks (TPC-H Q1/Q6-style
arithmetic, range predicates, conjunctions): columns, constants, +,-,*,/,
comparisons, BETWEEN, AND/OR/NOT.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple, Union

import jax.numpy as jnp

Number = Union[int, float]


class Expr:
    def __add__(self, other):
        return BinOp("+", self, _wrap(other))

    def __sub__(self, other):
        return BinOp("-", self, _wrap(other))

    def __mul__(self, other):
        return BinOp("*", self, _wrap(other))

    def __truediv__(self, other):
        return BinOp("/", self, _wrap(other))

    def __lt__(self, other):
        return Cmp("<", self, _wrap(other))

    def __le__(self, other):
        return Cmp("<=", self, _wrap(other))

    def __gt__(self, other):
        return Cmp(">", self, _wrap(other))

    def __ge__(self, other):
        return Cmp(">=", self, _wrap(other))

    def eq(self, other):
        return Cmp("==", self, _wrap(other))

    def ne(self, other):
        return Cmp("!=", self, _wrap(other))

    def between(self, lo, hi):
        return Between(self, float(lo), float(hi))

    def columns(self) -> Tuple[str, ...]:
        raise NotImplementedError


def _wrap(v) -> "Expr":
    if isinstance(v, Expr):
        return v
    return Const(float(v))


@dataclasses.dataclass(frozen=True)
class Col(Expr):
    name: str

    def columns(self):
        return (self.name,)


@dataclasses.dataclass(frozen=True)
class Const(Expr):
    value: float

    def columns(self):
        return ()


@dataclasses.dataclass(frozen=True)
class Param(Expr):
    """A hoisted constant: slot ``index`` of the runtime parameter vector.

    Produced by :func:`repro.engine.logical.extract_constants`, which rewrites
    every :class:`Const` (and :class:`Between` bound) in a plan into a Param
    so the *template* plan is constant-free.  The physical layer keys its
    compile cache on templates and feeds the constants back in as a device
    operand at call time — one jitted executable serves every constant
    variant of a shape.  Evaluating a Param therefore requires ``params``
    (see :func:`eval_expr`); user-built plans never contain one.
    """

    index: int

    def columns(self):
        return ()


@dataclasses.dataclass(frozen=True)
class Str(Expr):
    """A string literal (dialect surface only).

    Tables are numeric; a Str is meaningful only while it compares against a
    dictionary-encoded column, and the session lowers it to the column's
    integer code (:func:`repro.api.sql.resolve_string_literals`) before any
    plan reaches the engine.  Evaluating an unresolved Str is a type error —
    never a silent coercion.
    """

    value: str

    def columns(self):
        return ()


@dataclasses.dataclass(frozen=True)
class BinOp(Expr):
    op: str
    left: Expr
    right: Expr

    def columns(self):
        return tuple(dict.fromkeys(self.left.columns() + self.right.columns()))


@dataclasses.dataclass(frozen=True)
class Cmp(Expr):
    op: str
    left: Expr
    right: Expr

    def columns(self):
        return tuple(dict.fromkeys(self.left.columns() + self.right.columns()))


@dataclasses.dataclass(frozen=True)
class Between(Expr):
    """Two-sided range test.  ``lo``/``hi`` are floats in user plans; the
    constant-hoisting pass replaces them with :class:`Param` slots in
    template plans, so both spellings must evaluate."""

    arg: Expr
    lo: Union[float, Expr]
    hi: Union[float, Expr]

    def columns(self):
        return self.arg.columns()


@dataclasses.dataclass(frozen=True)
class And(Expr):
    left: Expr
    right: Expr

    def columns(self):
        return tuple(dict.fromkeys(self.left.columns() + self.right.columns()))


@dataclasses.dataclass(frozen=True)
class Or(Expr):
    left: Expr
    right: Expr

    def columns(self):
        return tuple(dict.fromkeys(self.left.columns() + self.right.columns()))


@dataclasses.dataclass(frozen=True)
class Not(Expr):
    arg: Expr

    def columns(self):
        return self.arg.columns()


def eval_expr(expr: Expr, columns, params=None) -> jnp.ndarray:
    """Evaluate ``expr`` against a mapping name -> 1-D array.

    ``params`` is the runtime constant vector :class:`Param` slots index
    into; it is only needed for template plans (user plans carry their
    constants inline as :class:`Const` nodes).
    """
    if isinstance(expr, Col):
        return columns[expr.name]
    if isinstance(expr, Const):
        return jnp.asarray(expr.value)
    if isinstance(expr, Param):
        if params is None:
            raise TypeError(
                f"Param({expr.index}) outside a parametrized execution: "
                "template plans need the runtime constant vector")
        return params[expr.index]
    if isinstance(expr, Str):
        raise TypeError(
            f"unresolved string literal {expr.value!r}: string comparisons "
            "must be lowered to dictionary codes before execution (register "
            "a dictionary for the column on the Session)")
    if isinstance(expr, BinOp):
        l = eval_expr(expr.left, columns, params)
        r = eval_expr(expr.right, columns, params)
        if expr.op == "+":
            return l + r
        if expr.op == "-":
            return l - r
        if expr.op == "*":
            return l * r
        if expr.op == "/":
            return l / r
        raise ValueError(expr.op)
    if isinstance(expr, Cmp):
        l = eval_expr(expr.left, columns, params)
        r = eval_expr(expr.right, columns, params)
        if expr.op == "<":
            return l < r
        if expr.op == "<=":
            return l <= r
        if expr.op == ">":
            return l > r
        if expr.op == ">=":
            return l >= r
        if expr.op == "==":
            return l == r
        if expr.op == "!=":
            return l != r
        raise ValueError(expr.op)
    if isinstance(expr, Between):
        v = eval_expr(expr.arg, columns, params)
        lo = (eval_expr(expr.lo, columns, params)
              if isinstance(expr.lo, Expr) else expr.lo)
        hi = (eval_expr(expr.hi, columns, params)
              if isinstance(expr.hi, Expr) else expr.hi)
        return (v >= lo) & (v <= hi)
    if isinstance(expr, And):
        return (eval_expr(expr.left, columns, params)
                & eval_expr(expr.right, columns, params))
    if isinstance(expr, Or):
        return (eval_expr(expr.left, columns, params)
                | eval_expr(expr.right, columns, params))
    if isinstance(expr, Not):
        return ~eval_expr(expr.arg, columns, params)
    raise TypeError(f"not an Expr: {expr!r}")
