"""Materialized block-sample catalog: pre-staged sample ladders.

Every fresh execution draws its block sample with host RNG over ALL block
ids and gathers the sampled slabs out of the full table arrays.  For hot
tables serving constant-varied dashboard herds — workloads the result cache
cannot answer — that per-query draw + full-table gather is pure overhead:
VerdictDB's "scrambles" and BlinkDB's stratified samples pre-materialize
the sample once and serve every query from it.

This module is that idea made *bit-identical*.  A :class:`StagedLadder`
pins ONE content-derived staging seed per table and materializes the
Bernoulli block draw at a ladder of rates (default 1% / 4% / 16%) as
device-resident :class:`~repro.engine.table.BlockTable` rungs (per shard
for ``ShardedTable``s).  At execution the planner picks the smallest rung
whose rate covers the TAQA-required rate and *sub-draws* from the staged
realization: under the one-uniform-vector Bernoulli draw
(``rng.random(N) < rate``) a draw at rate r <= R with the same seed is a
restriction of the rung's draw — exactly the invariant
``sampling.restrict_block_ids`` already exploits for shards — so the
sub-drawn blocks are rows the rung already holds, addressed by their
*positions* within it.  The query executes against the small pre-gathered
rung arrays with the physical layer's ordinary block-gather lowering, with
the physical block count forced to the value the fresh path would use, so
the compiled graph sees the same rows, the same shapes, and the same
reduction order: answers are bitwise identical to fresh draws, for pilots
and finals, monolithic and distributed.

Lifecycle.  ``register_table`` invalidates the table's ladder (and
refreshes every OTHER ladder's replicated catalog entries in place, the
same sharing the main compiler catalog relies on).  An optional byte
budget bounds rung-array residency, LRU-evicting whole ladders' arrays;
the ladder *record* — crucially its pinned seed — survives eviction, so a
post-eviction fresh draw replays the identical realization and answers
stay bit-identical across the hit/miss boundary.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.engine.physical import PhysicalCompiler
from repro.engine.sampling import (bucket_blocks, draw_block_ids,
                                   restrict_block_ids, subdraw_positions)
from repro.engine.table import BlockTable
from repro.obs import trace as _trace

DEFAULT_STAGED_RATES: Tuple[float, ...] = (0.01, 0.04, 0.16)

# Tolerance for "rung covers rate": TAQA-chosen rates are floats computed
# from pilot statistics; a rung must not be rejected on representation noise.
_RATE_EPS = 1e-12


def validate_rates(rates: Sequence[float]) -> Tuple[float, ...]:
    """Normalize a ladder's rate list: non-empty, each in (0, 1], ascending."""
    rates = tuple(float(r) for r in rates)
    if not rates:
        raise ValueError("staged_rates must be non-empty")
    for r in rates:
        if not (0.0 < r <= 1.0):
            raise ValueError(f"staged rate must be in (0, 1], got {r}")
    return tuple(sorted(rates))


@dataclasses.dataclass
class ShardRungPart:
    """One shard's slice of a rung (dist route): the shard-local rung ids,
    the gathered shard-rung slabs, and a compiler whose catalog maps the
    staged table to them (other tables replicated, as dist execution does)."""

    shard_index: int
    start_block: int             # global offset of this shard's block range
    shard_blocks: int            # the shard's TOTAL block count (fresh n_phys cap)
    local_ids: np.ndarray        # rung block ids local to the shard, ascending
    table: Optional[BlockTable]  # None when the rung misses this shard
    compiler: Optional[PhysicalCompiler]


@dataclasses.dataclass
class StagedRung:
    """One materialized rate of a ladder.

    ``ids`` are the GLOBAL block ids of the staged draw (ascending).  The
    monolithic route uses ``table``/``compiler``; the dist route uses
    ``parts``.  ``resident`` flips to False when the byte budget evicts the
    arrays — the rung then behaves as absent and queries fall back to fresh
    draws under the ladder's pinned seed.
    """

    rate: float
    ids: np.ndarray
    table: Optional[BlockTable] = None
    compiler: Optional[PhysicalCompiler] = None
    parts: Optional[List[ShardRungPart]] = None
    nbytes: int = 0
    resident: bool = True

    def drop_arrays(self) -> None:
        self.table = None
        self.compiler = None
        self.parts = None
        self.nbytes = 0
        self.resident = False


class StagedLadder:
    """A table's staged sample ladder: pinned seed, rungs, sub-draw memo.

    ``sharded`` pins the exact :class:`repro.dist.ShardedTable` the per-shard
    rungs were gathered from; the dist route only serves from the ladder
    while its snapshot IS that object (re-sharding invalidates the ladder
    anyway — the check is belt and braces against racing registrations).
    """

    def __init__(self, name: str, rates: Sequence[float], seed: int,
                 num_blocks: int, rungs: List[StagedRung], sharded=None):
        self.name = name
        self.rates = tuple(rates)
        self.seed = int(seed)
        self.num_blocks = int(num_blocks)
        self.rungs = rungs
        self.sharded = sharded
        self.last_used = 0
        self._lock = threading.Lock()
        # (route, rung rate, query rate) -> prepared sub-draw.  The seed is
        # pinned and the rung realization fixed, so the sub-draw is a pure
        # function of the rate — memoizing it removes the per-query O(N)
        # host RNG + nonzero + searchsorted from the warm path entirely.
        self._memo: Dict[tuple, object] = {}

    def rung_for(self, rate: float) -> Optional[StagedRung]:
        """Smallest resident rung covering ``rate``, or None (fresh path)."""
        for rung in self.rungs:
            if rung.resident and rung.rate >= rate - _RATE_EPS:
                return rung
        return None

    def memo(self, key: tuple, build):
        with self._lock:
            if key not in self._memo:
                self._memo[key] = build()
            return self._memo[key]

    @property
    def resident_bytes(self) -> int:
        return sum(r.nbytes for r in self.rungs if r.resident)

    def drop_rungs(self) -> None:
        for r in self.rungs:
            r.drop_arrays()


class SampleCatalog:
    """Thread-safe registry of staged ladders with an optional byte budget.

    The budget governs rung-array *residency*, not ladder existence:
    eviction drops a cold ladder's device arrays (LRU whole-ladder, like a
    DBMS dropping a materialized sample) but keeps the record and its
    pinned staging seed, so later queries miss to fresh draws of the SAME
    realization — bit-identity survives eviction.
    """

    def __init__(self, max_bytes: Optional[int] = None):
        if max_bytes is not None and max_bytes < 0:
            raise ValueError(f"max_bytes must be >= 0, got {max_bytes}")
        self.max_bytes = max_bytes
        self._lock = threading.Lock()
        self._ladders: Dict[str, StagedLadder] = {}
        self._use_counter = 0
        self.hits = 0        # staged sub-draws served
        self.misses = 0      # fresh draws of ladder-bearing tables
        self.evictions = 0   # ladders whose rung arrays the budget dropped

    # -- registration ---------------------------------------------------------
    def admit(self, ladder: StagedLadder) -> None:
        with self._lock:
            self._use_counter += 1
            ladder.last_used = self._use_counter
            self._ladders[ladder.name] = ladder
            self._enforce_budget()

    def invalidate(self, name: str) -> None:
        with self._lock:
            self._ladders.pop(name, None)

    def refresh_replicated(self, name: str, table: BlockTable) -> None:
        """A table was re-registered: point every OTHER ladder's rung
        compilers at the new arrays (rung catalogs replicate non-staged
        tables exactly as dist shard executors do)."""
        with self._lock:
            ladders = [lad for t, lad in self._ladders.items() if t != name]
        for lad in ladders:
            for rung in lad.rungs:
                if rung.compiler is not None and name in rung.compiler.catalog:
                    rung.compiler.catalog[name] = table
                for part in rung.parts or []:
                    if (part.compiler is not None
                            and name in part.compiler.catalog):
                        part.compiler.catalog[name] = table

    # -- lookup ---------------------------------------------------------------
    def ladder(self, name: str) -> Optional[StagedLadder]:
        with self._lock:
            lad = self._ladders.get(name)
            if lad is not None:
                self._use_counter += 1
                lad.last_used = self._use_counter
            return lad

    def seed_for(self, name: str, default: int) -> int:
        """The pinned staging seed when ``name`` has a ladder, else
        ``default`` — ladder-bearing tables draw every block sample from
        their staging seed so hits and misses share one realization."""
        with self._lock:
            lad = self._ladders.get(name)
        return lad.seed if lad is not None else default

    # -- counters -------------------------------------------------------------
    # The single staged hit/miss choke point (mono and dist routes both land
    # here), so the trace tags ride along with the counters.
    def note_hit(self) -> None:
        with self._lock:
            self.hits += 1
        _trace.annotate_count("staged_hits")

    def note_miss(self) -> None:
        with self._lock:
            self.misses += 1
        _trace.annotate_count("staged_misses")

    # -- budget ---------------------------------------------------------------
    def _enforce_budget(self) -> None:  # caller holds the lock
        if self.max_bytes is None:
            return
        while (sum(l.resident_bytes for l in self._ladders.values())
               > self.max_bytes):
            victims = [l for l in self._ladders.values()
                       if l.resident_bytes > 0]
            if not victims:
                break
            min(victims, key=lambda l: l.last_used).drop_rungs()
            self.evictions += 1

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return sum(l.resident_bytes for l in self._ladders.values())

    # -- reporting ------------------------------------------------------------
    def compile_totals(self) -> Tuple[int, int, int]:
        """(hits, misses, size) summed over every rung compiler's cache."""
        with self._lock:
            ladders = list(self._ladders.values())
        hits = misses = size = 0
        for lad in ladders:
            for rung in lad.rungs:
                compilers = ([rung.compiler] if rung.compiler else []) + \
                    [p.compiler for p in rung.parts or [] if p.compiler]
                for c in compilers:
                    info = c.cache_info()
                    hits += info.hits
                    misses += info.misses
                    size += info.size
        return hits, misses, size

    def info(self) -> Dict[str, object]:
        with self._lock:
            tables = {
                name: {
                    "rates": list(lad.rates),
                    "resident_rates": [r.rate for r in lad.rungs
                                       if r.resident],
                    "resident_bytes": lad.resident_bytes,
                    "sharded": lad.sharded is not None,
                }
                for name, lad in self._ladders.items()
            }
            return {
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "resident_bytes": sum(l.resident_bytes
                                      for l in self._ladders.values()),
                "max_bytes": self.max_bytes,
                "tables": tables,
            }


# -- ladder construction ------------------------------------------------------
def build_ladder(name: str, table: BlockTable, rates: Sequence[float],
                 seed: int, kernel_mode: str,
                 base_catalog: Dict[str, BlockTable]) -> StagedLadder:
    """Materialize a monolithic ladder: one gather per rung, one compiler
    per rung whose catalog maps ``name`` to the rung slabs and replicates
    every other table from ``base_catalog``."""
    rungs: List[StagedRung] = []
    for rate in validate_rates(rates):
        ids = draw_block_ids(table.num_blocks, rate, seed)
        if len(ids):
            rung_table = table.gather_blocks(ids)
            cat = dict(base_catalog)
            cat[name] = rung_table
            rungs.append(StagedRung(
                rate=rate, ids=ids, table=rung_table,
                compiler=PhysicalCompiler(cat, kernel_mode=kernel_mode),
                nbytes=rung_table.total_bytes()))
        else:
            # An empty rung still SERVES: any sub-draw of it is empty, and a
            # fresh draw at a covered rate under the same seed would be
            # empty too (restriction) — the staged path answers "empty"
            # without touching the table.
            rungs.append(StagedRung(rate=rate, ids=ids))
    return StagedLadder(name, [r.rate for r in rungs], seed,
                        table.num_blocks, rungs)


def build_sharded_ladder(name: str, sharded, rates: Sequence[float],
                         seed: int, kernel_mode: str,
                         shard_catalogs: List[Dict[str, BlockTable]]
                         ) -> StagedLadder:
    """Materialize a per-shard ladder for a :class:`repro.dist.ShardedTable`.

    The rung draw is the GLOBAL realization (same seed semantics as the
    monolithic ladder); each shard gathers its restriction of it, so the
    union of shard rungs is the monolithic rung bit-for-bit — the same
    shards-as-restriction invariant ``shard_block_ids`` uses for fresh
    draws.
    """
    rungs: List[StagedRung] = []
    for rate in validate_rates(rates):
        global_ids = draw_block_ids(sharded.num_blocks, rate, seed)
        parts: List[ShardRungPart] = []
        nbytes = 0
        for shard, cat in zip(sharded.shards, shard_catalogs):
            local = restrict_block_ids(global_ids, shard.start_block,
                                       shard.end_block)
            if len(local):
                part_table = shard.table.gather_blocks(local)
                part_cat = dict(cat)
                part_cat[name] = part_table
                compiler = PhysicalCompiler(part_cat, kernel_mode=kernel_mode)
                nbytes += part_table.total_bytes()
            else:
                part_table, compiler = None, None
            parts.append(ShardRungPart(
                shard_index=shard.index, start_block=shard.start_block,
                shard_blocks=shard.num_blocks, local_ids=local,
                table=part_table, compiler=compiler))
        rungs.append(StagedRung(rate=rate, ids=global_ids, parts=parts,
                                nbytes=nbytes))
    return StagedLadder(name, [r.rate for r in rungs], seed,
                        sharded.num_blocks, rungs, sharded=sharded)


# -- sub-draw preparation -----------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MonoSubdraw:
    """A memoized monolithic sub-draw, ready for dispatch: the global block
    ids the query samples, and their rung positions padded to the PHYSICAL
    block count the fresh path would use (bucketed against the ORIGIN block
    count) — forcing the fresh n_phys keeps compiled shapes, padding-row
    masking, and reduction order identical to a fresh draw's.

    ``phys_dev``/``nreal_dev`` are the device copies, staged ONCE at memo
    build: warm dispatches skip the per-call host->device transfer of the
    sample (the fresh path must pay it for every query)."""

    sub_ids: np.ndarray      # global block ids, ascending
    phys: np.ndarray         # rung-local positions, zero-padded to n_phys
    n_real: int
    n_phys: int
    phys_dev: object = None  # jnp.int32 (n_phys,), device-resident
    nreal_dev: object = None  # jnp.int32 scalar, device-resident


def prepare_mono_subdraw(ladder: StagedLadder, rung: StagedRung,
                         rate: float) -> MonoSubdraw:
    def build() -> MonoSubdraw:
        sub_ids, positions = subdraw_positions(
            rung.ids, ladder.num_blocks, rate, ladder.seed)
        n_real = int(len(sub_ids))
        n_phys = min(bucket_blocks(max(n_real, 1)), ladder.num_blocks)
        pad = n_phys - n_real
        phys = np.concatenate([positions, np.zeros(pad, np.int32)]) \
            if pad > 0 else positions
        return MonoSubdraw(sub_ids, phys, n_real, n_phys,
                           phys_dev=jnp.asarray(phys, jnp.int32),
                           nreal_dev=jnp.asarray(n_real, jnp.int32))
    return ladder.memo(("mono", rung.rate, float(rate)), build)


@dataclasses.dataclass(frozen=True)
class ShardSubdraw:
    """One shard's slice of a dist sub-draw (only shards with >= 1 sampled
    block appear, matching ``ShardedTable.partition_ids``).  Like
    :class:`MonoSubdraw`, the padded positions are staged on device once at
    memo build (``n_phys`` forced to the fresh per-shard value)."""

    part: ShardRungPart
    local_ids: np.ndarray    # sub-drawn block ids local to the shard
    positions: np.ndarray    # their positions within the shard's rung
    n_real: int = 0
    n_phys: int = 0
    phys: Optional[np.ndarray] = None   # positions zero-padded to n_phys
    phys_dev: object = None
    nreal_dev: object = None


def prepare_dist_subdraw(ladder: StagedLadder, rung: StagedRung,
                         rate: float) -> Tuple[np.ndarray, List[ShardSubdraw]]:
    """(global sub-drawn ids, per-shard splits) for the dist staged route."""
    def build():
        global_ids = draw_block_ids(ladder.num_blocks, rate, ladder.seed)
        splits: List[ShardSubdraw] = []
        for part in rung.parts or []:
            local = restrict_block_ids(
                global_ids, part.start_block,
                part.start_block + part.shard_blocks)
            if len(local) == 0:
                continue
            positions = np.searchsorted(part.local_ids,
                                        local).astype(np.int32)
            n_real = int(len(local))
            n_phys = min(bucket_blocks(max(n_real, 1)), part.shard_blocks)
            pad = n_phys - n_real
            phys = np.concatenate([positions, np.zeros(pad, np.int32)]) \
                if pad > 0 else positions
            splits.append(ShardSubdraw(
                part, local, positions, n_real=n_real, n_phys=n_phys,
                phys=phys, phys_dev=jnp.asarray(phys, jnp.int32),
                nreal_dev=jnp.asarray(n_real, jnp.int32)))
        return global_ids, splits
    return ladder.memo(("dist", rung.rate, float(rate)), build)
