"""Physical relational operators on BlockTables (pure jnp, static shapes)."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.engine.expr import Expr, eval_expr
from repro.engine.table import BlockTable

_BIG = np.int32(2**31 - 1)  # keys must be < 2^31-1 (x64 is off)


def filter_table(table: BlockTable, pred: Expr) -> BlockTable:
    mask = eval_expr(pred, table.columns)
    return table.with_valid(table.valid & mask)


def join_unique(left: BlockTable, right: BlockTable, left_key: str,
                right_key: str, rblock_col: Optional[str] = None) -> BlockTable:
    """Equi-join where ``right_key`` is unique among valid right rows.

    Preserves the left table's physical layout and block lineage (Prop. 4.5).
    Right columns are appended; optionally the right row's *origin block id*
    is exported as ``rblock_col`` — the pair lineage Lemma 4.8 needs.
    """
    lkey = left.columns[left_key].astype(jnp.int32)
    rkey = jnp.where(right.valid, right.columns[right_key].astype(jnp.int32), _BIG)
    order = jnp.argsort(rkey)
    sorted_keys = rkey[order]
    pos = jnp.searchsorted(sorted_keys, lkey)
    pos_c = jnp.clip(pos, 0, sorted_keys.shape[0] - 1)
    found = sorted_keys[pos_c] == lkey
    match = order[pos_c]
    valid = left.valid & found

    new_cols = dict(left.columns)
    for cname, col in right.columns.items():
        if cname == right_key:
            continue
        if cname in new_cols:
            raise ValueError(f"column name collision in join: {cname}")
        new_cols[cname] = col[match]
    if rblock_col is not None:
        new_cols[rblock_col] = right.block_id[match].astype(jnp.int32)
    return dataclasses.replace(left, columns=new_cols, valid=valid)


def union_all(tables: list[BlockTable]) -> BlockTable:
    """Bag union; block ids are offset so origins stay distinct (Prop. 4.6)."""
    if not tables:
        raise ValueError("empty union")
    br = tables[0].block_rows
    names = set(tables[0].columns)
    offset = 0
    cols = {c: [] for c in names}
    valids, bids = [], []
    rows = 0
    for t in tables:
        if set(t.columns) != names or t.block_rows != br:
            raise ValueError("union inputs must share schema and block size")
        for c in names:
            cols[c].append(t.columns[c])
        valids.append(t.valid)
        bids.append(t.block_id + offset)
        offset += t.num_origin_blocks
        rows += t.num_rows
    return BlockTable(
        name="union",
        columns={c: jnp.concatenate(v) for c, v in cols.items()},
        block_rows=br,
        num_rows=rows,
        valid=jnp.concatenate(valids),
        block_id=jnp.concatenate(bids),
        num_origin_blocks=offset,
    )


# ---------------------------------------------------------------------------
# Aggregation
# ---------------------------------------------------------------------------

def _agg_values(table: BlockTable, expr: Optional[Expr]) -> jnp.ndarray:
    if expr is None:
        vals = jnp.ones(table.padded_rows, dtype=jnp.float32)
    else:
        vals = eval_expr(expr, table.columns).astype(jnp.float32)
    return jnp.where(table.valid, vals, 0.0)


def group_ids(table: BlockTable, group_by: Optional[str], max_groups: int) -> jnp.ndarray:
    if group_by is None:
        return jnp.zeros(table.padded_rows, dtype=jnp.int32)
    gid = table.columns[group_by].astype(jnp.int32)
    return jnp.clip(gid, 0, max_groups - 1)


def grouped_sums(table: BlockTable, exprs, group_by: Optional[str],
                 max_groups: int) -> jnp.ndarray:
    """Returns (num_aggs, max_groups) sums of each expr per group."""
    gid = group_ids(table, group_by, max_groups)
    outs = []
    for expr in exprs:
        vals = _agg_values(table, expr)
        outs.append(jnp.zeros(max_groups, jnp.float32).at[gid].add(vals))
    return jnp.stack(outs)


def grouped_counts(table: BlockTable, group_by: Optional[str], max_groups: int) -> jnp.ndarray:
    gid = group_ids(table, group_by, max_groups)
    return jnp.zeros(max_groups, jnp.float32).at[gid].add(
        table.valid.astype(jnp.float32))


def block_group_sums(table: BlockTable, exprs, group_by: Optional[str],
                     max_groups: int, block_ids: np.ndarray) -> np.ndarray:
    """Per-(origin-block, group) sums: shape (len(block_ids), max_groups, num_aggs).

    This is the pilot query's "GROUP BY physical block" (§3.3 step 2) — the
    statistics BSAP consumes.  ``block_ids`` lists the sampled origin blocks;
    blocks without surviving rows contribute zeros (they are real population
    units with zero contribution).

    Built on the physical layer's fused multi-channel scatter: all channels
    run in one jitted graph and the device→host transfer happens exactly once
    at this boundary (the compiled pilot path in ``engine.physical`` avoids
    even that — this entry point serves the eager executor and direct users).
    """
    from repro.engine import physical

    dense = physical.dense_block_group_sums(
        table.columns, table.valid, table.block_id,
        exprs=tuple(exprs), group_by=group_by, max_groups=max_groups,
        n_origin=int(table.num_origin_blocks))
    stacked = np.asarray(dense).transpose(1, 2, 0)  # (n_origin, groups, aggs)
    return stacked[np.asarray(block_ids, dtype=np.int64)]


def block_pair_sums(table: BlockTable, exprs, lblock_ids: np.ndarray,
                    rblock_col: str, n_right_blocks: int) -> np.ndarray:
    """Per-(left origin block, right origin block) sums for Lemma 4.8.

    Returns shape (len(lblock_ids), n_right_blocks, num_aggs).  Left origin
    blocks are compacted to their position among ``lblock_ids`` before the
    scatter so the dense buffer is n_p × N2, not N1 × N2.  The compaction
    LUT, channel evaluation, and scatter are one jitted graph in the physical
    layer; the single host transfer happens here.
    """
    from repro.engine import physical

    lblock_ids = np.asarray(lblock_ids, dtype=np.int64)
    dense = physical.dense_block_pair_sums(
        table.columns, table.valid, table.block_id,
        jnp.asarray(lblock_ids, jnp.int32),
        exprs=tuple(exprs), rblock_col=rblock_col,
        n_right=n_right_blocks, n_origin=int(table.num_origin_blocks))
    return np.asarray(dense).transpose(1, 2, 0)
