"""Bytes-moved cost model (§3.2 "Cost-based Optimization").

PilotDB asks the DBMS's cost estimator for plan costs; for in-memory engines
(DuckDB) the paper falls back to "volume of scanned data".  We are the storage
engine, so we use the same proxy: HBM→VMEM bytes a plan will move.

* exact / row-sampled scan: all referenced column bytes stream;
* block-sampled scan at rate θ: only ≈θ of the slabs move (expected bytes);
* joins/aggregations add a small per-row processing term so that plans which
  keep more rows alive cost more (matters when comparing candidate plans that
  sample different tables).
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.engine import logical as L
from repro.engine.table import BlockTable

PROCESS_BYTES_PER_ROW = 4  # processing term, bytes-equivalent per surviving row


def _referenced_columns(plan: L.Plan, acc: Dict[str, set]):
    if isinstance(plan, L.Scan):
        acc.setdefault(plan.table, set())
    elif isinstance(plan, L.Filter):
        _referenced_columns(plan.child, acc)
        for c in plan.pred.columns():
            for t in acc:
                acc[t].add(c)
    elif isinstance(plan, L.Join):
        _referenced_columns(plan.left, acc)
        _referenced_columns(plan.right, acc)
        for t in acc:
            acc[t].update((plan.left_key, plan.right_key))
    elif isinstance(plan, L.Union):
        for p in plan.inputs:
            _referenced_columns(p, acc)
    elif isinstance(plan, L.Aggregate):
        _referenced_columns(plan.child, acc)
        for a in plan.aggs:
            if a.expr is not None:
                for c in a.expr.columns():
                    for t in acc:
                        acc[t].add(c)
        if plan.group_by:
            for t in acc:
                acc[t].add(plan.group_by)


def column_bytes(table: BlockTable, columns: Optional[set] = None) -> int:
    import numpy as np

    total = 0
    for name, col in table.columns.items():
        if columns is None or name in columns or not columns:
            total += int(np.dtype(col.dtype).itemsize) * table.padded_rows
    return total


def plan_cost(plan: L.Aggregate, catalog: Dict[str, BlockTable],
              rates: Optional[Dict[str, float]] = None) -> float:
    """Estimated cost (bytes) of executing ``plan`` with optional per-table
    block sampling rates overriding the plan's own sample clauses."""
    rates = dict(rates or {})
    acc: Dict[str, set] = {}
    _referenced_columns(plan, acc)

    cost = 0.0
    for scan in plan.scans():
        t = catalog[scan.table]
        cols = acc.get(scan.table)
        base = column_bytes(t, cols if cols else None)
        theta = rates.get(scan.table)
        if theta is None and scan.sample is not None:
            theta = scan.sample.rate if scan.sample.method == "block" else 1.0
        theta = 1.0 if theta is None else min(max(theta, 0.0), 1.0)
        cost += theta * base + theta * t.num_rows * PROCESS_BYTES_PER_ROW
    return cost


def exact_cost(plan: L.Aggregate, catalog: Dict[str, BlockTable]) -> float:
    return plan_cost(L.strip_samples(plan), catalog, rates={})
