"""Bernoulli samplers.

Sampling *decisions* are host-side (numpy RNG) — exactly as a DBMS's
TABLESAMPLE decides pages before scanning them — and data movement is
device-side:

* block sampling gathers only the selected slabs (cost ∝ θ · bytes),
* row sampling masks in place (cost ∝ full bytes; the whole column streams).

Both are Bernoulli (each unit kept i.i.d. with prob θ, no replacement), the
paper's §3.1 choice, so sample sizes are Binomial — TAQA's bounds account for
that.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.engine.table import BlockTable


@dataclasses.dataclass
class SampleInfo:
    method: str
    rate: float
    seed: int
    n_sampled_blocks: Optional[int] = None
    n_total_blocks: Optional[int] = None
    sampled_block_ids: Optional[np.ndarray] = None
    scanned_bytes: int = 0
    n_sampled_rows: Optional[int] = None  # row-Bernoulli kept rows
    n_total_rows: Optional[int] = None


def _bucket(k: int) -> int:
    """Round the sampled-block count up to the next power of two.  Sampled
    tables then recur in log-many shapes, so XLA's per-shape executable
    cache is hit across queries — without bucketing, every distinct sample
    size recompiles the whole eager op pipeline (~1.4 s, measured: 76
    compiles per query), drowning the scan savings on warm paths.  The <=2x
    physical overshoot gathers padding rows that are invalid and excluded
    from the scanned-bytes accounting."""
    if k <= 64:
        return 64
    return 1 << (k - 1).bit_length()


def block_sample(table: BlockTable, rate: float, seed: int) -> tuple[BlockTable, SampleInfo]:
    """TABLESAMPLE SYSTEM analogue: Bernoulli over blocks, gather hit slabs.

    The gathered table is padded to a bucketed block count with all-invalid
    copies of block 0 (they contribute nothing to any statistic and are not
    listed in sampled_block_ids); scanned_bytes counts REAL blocks only —
    padding rows would not move in a real storage engine."""
    rng = np.random.default_rng(seed)
    keep = rng.random(table.num_blocks) < rate
    ids = np.nonzero(keep)[0].astype(np.int32)
    n_real = int(len(ids))
    target = min(_bucket(max(n_real, 1)), table.num_blocks)
    pad = max(target - n_real, 0)
    phys = np.concatenate([ids, np.zeros(pad, np.int32)]) if pad else ids
    sampled = table.gather_blocks(phys)
    if pad or n_real == 0:
        mask = np.ones(len(phys) * table.block_rows, dtype=bool)
        mask[n_real * table.block_rows:] = False
        sampled = sampled.with_valid(sampled.valid & jnp.asarray(mask))
    info = SampleInfo(
        "block", rate, seed, n_real, table.num_blocks, ids,
        scanned_bytes=n_real * table.block_rows * table.row_bytes())
    return sampled, info


def row_sample(table: BlockTable, rate: float, seed: int) -> tuple[BlockTable, SampleInfo]:
    """TABLESAMPLE BERNOULLI analogue: per-row mask; full scan is paid."""
    rng = np.random.default_rng(seed)
    keep = jnp.asarray(rng.random(table.padded_rows) < rate)
    new_valid = table.valid & keep
    out = table.with_valid(new_valid)
    info = SampleInfo("row", rate, seed, None, table.num_blocks, None,
                      scanned_bytes=table.total_bytes())
    info.n_sampled_rows = int(np.asarray(new_valid.sum()))
    info.n_total_rows = table.num_rows
    return out, info
