"""Bernoulli samplers.

Sampling *decisions* are host-side (numpy RNG) — exactly as a DBMS's
TABLESAMPLE decides pages before scanning them — and data movement is
device-side:

* block sampling gathers only the selected slabs (cost ∝ θ · bytes),
* row sampling masks in place (cost ∝ full bytes; the whole column streams).

Both are Bernoulli (each unit kept i.i.d. with prob θ, no replacement), the
paper's §3.1 choice, so sample sizes are Binomial — TAQA's bounds account for
that.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.engine.table import BlockTable


@dataclasses.dataclass
class SampleInfo:
    method: str
    rate: float
    seed: int
    n_sampled_blocks: Optional[int] = None
    n_total_blocks: Optional[int] = None
    sampled_block_ids: Optional[np.ndarray] = None
    scanned_bytes: int = 0
    n_sampled_rows: Optional[int] = None  # row-Bernoulli kept rows
    n_total_rows: Optional[int] = None


def bucket_blocks(k: int) -> int:
    """Round the sampled-block count up to the next power of two.  Sampled
    tables then recur in log-many shapes, so the physical layer's compile
    cache (and XLA's per-shape executable cache) is hit across queries —
    without bucketing, every distinct sample size recompiles the whole
    pipeline (~1.4 s, measured: 76 compiles per query), drowning the scan
    savings on warm paths.  The <=2x physical overshoot gathers padding rows
    that are invalid and excluded from the scanned-bytes accounting."""
    if k <= 64:
        return 64
    return 1 << (k - 1).bit_length()


_bucket = bucket_blocks  # backward-compatible alias


def draw_block_ids(num_blocks: int, rate: float, seed: int) -> np.ndarray:
    """The host-side Bernoulli block draw — the TABLESAMPLE SYSTEM decision.

    The ONE RNG stream both the eager samplers and the compiled physical
    path consume, so identical seeds give identical samples on either path.
    """
    rng = np.random.default_rng(seed)
    keep = rng.random(num_blocks) < rate
    return np.nonzero(keep)[0].astype(np.int32)


def restrict_block_ids(ids: np.ndarray, lo: int, hi: int) -> np.ndarray:
    """Restrict a drawn block-id set to the range ``[lo, hi)``, re-based.

    This is the distributed TABLESAMPLE sub-draw (``repro.dist``): every
    shard computes the SAME global realization from the shared
    content-derived seed and keeps its own block range, so the union of
    the per-shard sub-draws equals the monolithic draw bit-for-bit — a
    property independent per-shard seeds could not provide (they would
    yield a different realization per shard count, breaking equal-seed
    replay).
    """
    ids = np.asarray(ids)
    return (ids[(ids >= lo) & (ids < hi)] - lo).astype(np.int32)


def subdraw_positions(rung_ids: np.ndarray, num_blocks: int, rate: float,
                      seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Sub-draw at ``rate`` from a staged rung drawn at a rate >= ``rate``
    with the SAME seed, returning ``(sub_ids, positions)``.

    The nesting property of the one-uniform-vector Bernoulli draw
    (``rng.random(N) < rate``) makes the sub-draw a *restriction* of the
    rung's realization: every block kept at rate r is also kept at any
    R >= r under the same seed, so ``sub_ids`` is guaranteed to be a subset
    of ``rung_ids`` and ``positions`` — indices of the sub-drawn blocks
    WITHIN the rung (both ascending, so searchsorted is exact) — lets a
    staged rung stand in for the full table without changing which global
    blocks the query sees (``repro.engine.staged``).
    """
    sub_ids = draw_block_ids(num_blocks, rate, seed)
    positions = np.searchsorted(np.asarray(rung_ids), sub_ids).astype(np.int32)
    return sub_ids, positions


def pad_block_ids(ids: np.ndarray, num_blocks: int) -> tuple[np.ndarray, int, int]:
    """Zero-pad sampled ids to the bucketed physical count.

    Returns ``(phys_ids, n_real, n_phys)``; padding entries re-point at
    block 0 and must be masked out downstream (rows >= n_real).
    """
    n_real = int(len(ids))
    n_phys = min(bucket_blocks(max(n_real, 1)), num_blocks)
    pad = max(n_phys - n_real, 0)
    phys = np.concatenate([ids, np.zeros(pad, np.int32)]) if pad else ids
    return phys, n_real, n_phys


def block_sample(table: BlockTable, rate: float, seed: int) -> tuple[BlockTable, SampleInfo]:
    """TABLESAMPLE SYSTEM analogue: Bernoulli over blocks, gather hit slabs.

    The gathered table is padded to a bucketed block count with all-invalid
    copies of block 0 (they contribute nothing to any statistic and are not
    listed in sampled_block_ids); scanned_bytes counts REAL blocks only —
    padding rows would not move in a real storage engine."""
    from repro.engine.physical import scan_cost_bytes

    ids = draw_block_ids(table.num_blocks, rate, seed)
    phys, n_real, _ = pad_block_ids(ids, table.num_blocks)
    sampled = table.gather_blocks(phys)
    if len(phys) > n_real:
        mask = np.ones(len(phys) * table.block_rows, dtype=bool)
        mask[n_real * table.block_rows:] = False
        sampled = sampled.with_valid(sampled.valid & jnp.asarray(mask))
    info = SampleInfo(
        "block", rate, seed, n_real, table.num_blocks, ids,
        scanned_bytes=scan_cost_bytes(table, "block", n_real))
    return sampled, info


def row_sample(table: BlockTable, rate: float, seed: int) -> tuple[BlockTable, SampleInfo]:
    """TABLESAMPLE BERNOULLI analogue: per-row mask; full scan is paid."""
    rng = np.random.default_rng(seed)
    keep = jnp.asarray(rng.random(table.padded_rows) < rate)
    new_valid = table.valid & keep
    out = table.with_valid(new_valid)
    info = SampleInfo("row", rate, seed, None, table.num_blocks, None,
                      scanned_bytes=table.total_bytes())
    info.n_sampled_rows = int(np.asarray(new_valid.sum()))
    info.n_total_rows = table.num_rows
    return out, info
