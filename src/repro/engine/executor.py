"""Plan executor.

Executes logical plans through the compiled physical layer
(:mod:`repro.engine.physical`): each plan shape lowers once to a single
jitted executable — block-sampled scans route through the Pallas
block-aggregation kernels (or their XLA twin off-TPU) — and repeated
structurally-identical queries hit the compile cache.  The *scan cost*
(bytes moved HBM→VMEM) is attributed by that layer: block-sampled scans pay
only for sampled slabs, row-sampled and exact scans stream everything
(Fig. 1 / Fig. 4).

The pre-physical eager interpreter is retained (``use_compiled=False``) as
the comparison baseline for tests and benchmarks.

Besides plain execution it produces the two artifacts TAQA needs:

* ``QueryResult``     — per-group aggregate values (+ lineage/cost),
* ``execute_pilot``   — per-block (and per block-pair, for Lemma 4.8) pilot
                        statistics of every simple aggregate, computed with
                        zero host syncs between the scan and the statistics.

A sampled scan that draws zero blocks/rows raises :class:`EmptySampleError`
instead of fabricating an upscale factor — callers (``core.taqa``) take
their exact-execution fallback path explicitly.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.engine import logical as L
from repro.engine import ops
from repro.engine.physical import (PhysicalCompiler, ScanRuntime,
                                   plan_constants, scan_cost_bytes)
from repro.engine.sampling import (SampleInfo, block_sample, draw_block_ids,
                                   pad_block_ids, row_sample)
from repro.engine.staged import (DEFAULT_STAGED_RATES, SampleCatalog,
                                 build_ladder, prepare_mono_subdraw)
from repro.engine.table import BlockTable
from repro.obs import trace as _trace


class EmptySampleError(RuntimeError):
    """A sampled scan produced zero sampled units (blocks or rows).

    No unbiased upscale exists for an empty sample; rather than fabricating a
    scale (the old ``max(n, 1)`` behaviour, which silently degraded the
    estimate), the executor surfaces the condition so the caller can fall
    back to exact execution or re-sample at a higher rate.
    """

    def __init__(self, table: str, method: str, rate: float):
        self.table = table
        self.method = method
        self.rate = rate
        super().__init__(
            f"sampled scan of {table!r} ({method}, rate={rate}) drew 0 units")


@dataclasses.dataclass
class QueryResult:
    agg_names: List[str]
    values: np.ndarray           # (num_aggs, max_groups) float64, upscaled
    raw_sums: np.ndarray         # (num_aggs, max_groups) unscaled sample sums
    group_counts: np.ndarray     # (max_groups,) raw surviving row counts
    group_present: np.ndarray    # (max_groups,) bool
    scanned_bytes: int
    sample_infos: Dict[str, SampleInfo]
    wall_time_s: float

    def scalar(self, name: str, group: int = 0) -> float:
        return float(self.values[self.agg_names.index(name), group])


@dataclasses.dataclass
class PilotStats:
    """Per-block statistics from the pilot query (§3.1, §3.3).

    block_sums: (n_p, max_groups, num_aggs) — sum of each simple aggregate's
        expression within each sampled origin block of the pilot table.
    pair_sums: optional {right_table: (n_p, N_right, num_aggs)} for Lemma 4.8.
    """

    table: str
    theta_p: float
    n_sampled_blocks: int
    n_total_blocks: int
    block_rows: int
    agg_names: List[str]
    block_sums: np.ndarray
    group_present: np.ndarray
    pair_sums: Dict[str, np.ndarray]
    right_total_blocks: Dict[str, int]
    scanned_bytes: int
    wall_time_s: float


class Executor:
    def __init__(self, catalog: Dict[str, BlockTable], *,
                 use_compiled: bool = True, kernel_mode: str = "auto",
                 staged_bytes: Optional[int] = None, shared_builds=None):
        self.catalog = dict(catalog)
        self.use_compiled = use_compiled
        # shared_builds: an optional physical.SharedBuildStore letting
        # same-geometry compilers (dist shards) adopt each other's built
        # executables instead of tracing+compiling N times.
        self.physical = PhysicalCompiler(self.catalog, kernel_mode=kernel_mode,
                                         shared_builds=shared_builds)
        # Pre-staged block-sample ladders (repro.engine.staged): tables
        # opted in via register_staged() serve covered sampled scans from
        # materialized rungs; staged_bytes bounds rung-array residency.
        self.staged = SampleCatalog(max_bytes=staged_bytes)
        # Execution counters, lock-guarded: the concurrent runtime
        # (repro.runtime) runs queries from a worker pool, and its tests /
        # benchmarks assert pilot-sharing through exactly these numbers
        # (`+= 1` on an attribute is not atomic under threads).
        # pilots_run counts pilot STAGES (incremented by PilotDB.run_pilot,
        # once per stage regardless of undershoot retries); queries_run
        # counts execute() calls.
        self._counter_lock = threading.Lock()
        self.pilots_run = 0
        self.queries_run = 0
        # device_dispatches counts compiled-executable invocations (solo,
        # staged, batched bucket, pilot, fused) — the launch inventory the
        # fused-TAQA benchmark derives its host-sync count from.
        self.device_dispatches = 0

    def _count(self, attr: str) -> None:
        with self._counter_lock:
            setattr(self, attr, getattr(self, attr) + 1)

    # -- catalog management ---------------------------------------------------
    def register_table(self, name: str, table: BlockTable) -> None:
        """Add (or replace) a catalog table.

        The physical compiler shares this catalog dict, so new tables are
        immediately compilable.  Replacing a table needs no *engine-level*
        cache invalidation: column data enters compiled executables as
        runtime arguments (``_CompiledBase._runtime_args``), and a geometry
        change alters the plan signature, forcing a fresh compilation.
        Higher layers may cache table *statistics* — go through their own
        registration (e.g. ``api.Session.register_table``, which refreshes
        its group-domain cache) rather than calling this directly.
        """
        self.catalog[name] = table
        # Staged lifecycle: the replaced table's ladder holds stale gathered
        # slabs — drop it (re-staging is the registrant's call); other
        # ladders replicate this table in their rung-compiler catalogs and
        # must see the new arrays.
        self.staged.invalidate(name)
        self.staged.refresh_replicated(name, table)

    def register_staged(self, name: str,
                        rates=DEFAULT_STAGED_RATES, *, seed: int = 0) -> None:
        """Materialize a staged sample ladder for catalog table ``name``.

        ``seed`` pins the table's one staging realization: EVERY block draw
        of the table (staged hit or fresh miss, pilot or final) replays it,
        which is what makes staged and fresh answers bit-identical.  The
        eager executor has no physical layer to serve rungs through, so
        staging is a no-op there (``use_compiled=False``).
        """
        if name not in self.catalog:
            raise KeyError(f"unknown table {name!r}")
        if not self.use_compiled:
            return
        self.staged.admit(build_ladder(
            name, self.catalog[name], rates, seed,
            self.physical.kernel_mode, self.catalog))

    # -- table metadata (the "DBMS statistics" TAQA consults) ---------------
    def table_rows(self, name: str) -> int:
        return self.catalog[name].num_rows

    def table_blocks(self, name: str) -> int:
        return self.catalog[name].num_blocks

    def block_rows(self, name: str) -> int:
        return self.catalog[name].block_rows

    def is_sharded(self, name: str) -> bool:
        """Whether ``name`` executes as sharded sub-scans (DistExecutor
        overrides).  A monolithic executor never shards."""
        return False

    def table_bytes(self, name: str) -> int:
        return self.catalog[name].total_bytes()

    def compile_cache_info(self):
        """Hit/miss/size counters of the physical-plan compile cache
        (including every staged rung's compiler) plus staged-path
        hit/miss counters.

        ``hits``/``misses`` are grand totals; pilot lowerings (solo and
        batched), drain-group batch executables, and fused TAQA programs are
        additionally broken out into ``pilot_*`` / ``batched_*`` /
        ``fused_*`` pairs, and ``shared_hits`` counts local misses served by
        adopting another same-geometry compiler's build (dist shard dedup).
        Rung compilers contribute to the totals only (their keys are plain
        query shapes)."""
        info = self.physical.cache_info()
        rung_hits, rung_misses, rung_size = self.staged.compile_totals()
        info.hits += rung_hits
        info.misses += rung_misses
        info.size += rung_size
        info.staged_hits = self.staged.hits
        info.staged_misses = self.staged.misses
        return info

    def staged_info(self) -> Dict[str, object]:
        """Staged-catalog serving counters and per-table ladder state."""
        return self.staged.info()

    # -- host-side sampling decisions ---------------------------------------
    def _scan_runtimes(
        self, plan: L.Plan, exclude: Optional[str] = None,
    ) -> Tuple[Dict[str, ScanRuntime], Dict[str, SampleInfo]]:
        """Draw every scan's TABLESAMPLE decision (host RNG, as a DBMS picks
        pages before scanning) and package it as compiled-executable inputs.

        Uses the same RNG stream as the eager samplers, so the two paths see
        identical samples for identical seeds.  A table with a staged ladder
        draws from its pinned staging seed (one realization per table —
        hits and misses agree bitwise); ``exclude`` skips one table whose
        runtime the staged route supplies itself.
        """
        runtimes: Dict[str, ScanRuntime] = {}
        infos: Dict[str, SampleInfo] = {}
        for s in plan.scans():
            if s.table == exclude:
                continue
            table = self.catalog[s.table]
            if s.sample is None:
                runtimes[s.table] = ScanRuntime("none")
                infos[s.table] = SampleInfo(
                    "none", 1.0, 0, table.num_blocks, table.num_blocks,
                    np.arange(table.num_blocks),
                    scanned_bytes=scan_cost_bytes(table, "none"))
            elif s.sample.method == "block":
                lad = self.staged.ladder(s.table)
                seed = s.sample.seed if lad is None else lad.seed
                if lad is not None and s.sample.rate < 1.0:
                    # a ladder-bearing table drawn fresh: rate uncovered,
                    # rung arrays evicted, or a route that bypasses staging
                    self.staged.note_miss()
                ids = draw_block_ids(table.num_blocks, s.sample.rate, seed)
                phys, n_real, n_phys = pad_block_ids(ids, table.num_blocks)
                runtimes[s.table] = ScanRuntime("block", n_real, n_phys, phys)
                infos[s.table] = SampleInfo(
                    "block", s.sample.rate, seed, n_real,
                    table.num_blocks, ids,
                    scanned_bytes=scan_cost_bytes(table, "block", n_real))
            else:
                rng = np.random.default_rng(s.sample.seed)
                keep = rng.random(table.padded_rows) < s.sample.rate
                n_kept = int((np.asarray(table.valid) & keep).sum())
                runtimes[s.table] = ScanRuntime("row", keep_mask=keep)
                info = SampleInfo("row", s.sample.rate, s.sample.seed, None,
                                  table.num_blocks, None,
                                  scanned_bytes=scan_cost_bytes(table, "row"))
                info.n_sampled_rows = n_kept
                info.n_total_rows = table.num_rows
                infos[s.table] = info
        return runtimes, infos

    @staticmethod
    def _check_empty(infos: Dict[str, SampleInfo]) -> None:
        for name, info in infos.items():
            if info.rate >= 1.0:
                continue
            if info.method == "block" and not info.n_sampled_blocks:
                raise EmptySampleError(name, "block", info.rate)
            if info.method == "row" and not info.n_sampled_rows:
                raise EmptySampleError(name, "row", info.rate)

    @staticmethod
    def _upscale(infos: Dict[str, SampleInfo]) -> float:
        """Upscaling (§3.3 final rewriting step 2).  With exactly one sampled
        table we use the Hájek scale N/n (conditional-SRS estimator matching
        BSAP's Lemma-B.1 bounds); with two or more we use Horvitz–Thompson
        1/∏θ (matching Lemma 4.8's variance expansion).  AVG is the ratio of
        two upscaled sums, so the scale cancels either way.  Empty samples
        raise EmptySampleError before this point — no fabricated scales.
        """
        sampled = [i for i in infos.values()
                   if i.method in ("block", "row") and i.rate < 1.0]
        if len(sampled) == 1:
            info = sampled[0]
            if info.method == "block":
                return info.n_total_blocks / info.n_sampled_blocks
            n = info.n_sampled_rows
            return (info.n_total_rows or n) / n
        scale = 1.0
        for info in sampled:
            scale /= info.rate
        return scale

    @staticmethod
    def _compose_values(plan: L.Aggregate, sums: np.ndarray, counts: np.ndarray,
                        scale: float) -> np.ndarray:
        values = np.zeros_like(sums)
        for i, a in enumerate(plan.aggs):
            if a.op in ("sum", "count"):
                values[i] = sums[i] * scale
            elif a.op == "avg":
                with np.errstate(invalid="ignore", divide="ignore"):
                    values[i] = np.where(counts > 0,
                                         sums[i] / np.maximum(counts, 1), np.nan)
        return values

    # -- eager relational execution (the pre-physical interpreter) -----------
    def _run_relational(
        self, plan: L.Plan, infos: Dict[str, SampleInfo],
        pair_for: Optional[Tuple[str, str]] = None,
    ) -> BlockTable:
        if isinstance(plan, L.Scan):
            table = self.catalog[plan.table]
            if plan.sample is None:
                infos[plan.table] = SampleInfo(
                    "none", 1.0, 0, table.num_blocks, table.num_blocks,
                    np.arange(table.num_blocks),
                    scanned_bytes=table.total_bytes())
                return table
            if plan.sample.method == "block":
                sampled, info = block_sample(table, plan.sample.rate, plan.sample.seed)
            else:
                sampled, info = row_sample(table, plan.sample.rate, plan.sample.seed)
            infos[plan.table] = info
            return sampled
        if isinstance(plan, L.Filter):
            child = self._run_relational(plan.child, infos, pair_for)
            return ops.filter_table(child, plan.pred)
        if isinstance(plan, L.Join):
            left = self._run_relational(plan.left, infos, pair_for)
            right = self._run_relational(plan.right, infos, pair_for)
            rblock_col = None
            if pair_for is not None and pair_for[1] == self._scan_table(plan.right):
                rblock_col = f"__rblock_{pair_for[1]}"
            return ops.join_unique(left, right, plan.left_key, plan.right_key,
                                   rblock_col=rblock_col)
        if isinstance(plan, L.Union):
            return ops.union_all(
                [self._run_relational(p, infos, pair_for) for p in plan.inputs])
        raise TypeError(plan)

    @staticmethod
    def _scan_table(plan: L.Plan) -> Optional[str]:
        scans = plan.scans()
        return scans[0].table if len(scans) == 1 else None

    # -- public API ----------------------------------------------------------
    def execute(self, plan: L.Aggregate) -> QueryResult:
        self._count("queries_run")
        with _trace.span("scan") as sp:
            if self.use_compiled:
                res = self._execute_compiled(plan)
            else:
                res = self._execute_eager(plan)
            sp.set(scanned_bytes=res.scanned_bytes)
        return res

    def _staged_route(self, plan: L.Aggregate):
        """(table, SampleClause, ladder, rung) when ``plan`` can run against
        a monolithic staged rung, else None (the fresh path — which still
        draws under the ladder seed, so both routes agree bitwise).

        Conservative like ``dist._dist_route``: compiled XLA lowering only,
        exactly one block-sampled (rate < 1) scan, and that scan's table
        must hold a resident monolithic rung covering the rate.
        """
        if not self.use_compiled or self.physical._use_pallas():
            return None
        sampled = [s for s in plan.scans()
                   if s.sample is not None and s.sample.rate < 1.0]
        if len(sampled) != 1 or sampled[0].sample.method != "block":
            return None
        target = sampled[0]
        lad = self.staged.ladder(target.table)
        if lad is None or lad.sharded is not None:
            return None
        rung = lad.rung_for(target.sample.rate)
        if rung is None:
            return None
        return target.table, target.sample, lad, rung

    def _execute_staged(self, plan: L.Aggregate, table: str, sample,
                        lad, rung) -> QueryResult:
        """Execute against a staged rung: memoized sub-draw (a restriction
        of the ladder's one realization), block POSITIONS within the rung in
        place of global block ids, and the rung's own compiler — with the
        physical block count forced to the fresh path's value, the compiled
        graph gathers the same rows in the same order from the small staged
        arrays, so the answer is bitwise identical to a fresh draw's.
        """
        t0 = time.perf_counter()
        origin = self.catalog[table]
        sub = prepare_mono_subdraw(lad, rung, sample.rate)
        self.staged.note_hit()
        _trace.annotate(staged=True, staged_table=table,
                        staged_rate=sample.rate, staged_rung=rung.rate)
        if sub.n_real == 0:
            # a fresh draw under the pinned seed would be empty too
            raise EmptySampleError(table, "block", sample.rate)
        runtimes, infos = self._scan_runtimes(plan, exclude=table)
        self._check_empty(infos)
        runtimes[table] = ScanRuntime("block", sub.n_real, sub.n_phys,
                                      sub.phys, ids_dev=sub.phys_dev,
                                      nreal_dev=sub.nreal_dev)
        infos[table] = SampleInfo(
            "block", sample.rate, lad.seed, sub.n_real, lad.num_blocks,
            sub.sub_ids,
            scanned_bytes=scan_cost_bytes(origin, "block", sub.n_real))
        compiled = rung.compiler.compile_query(plan, runtimes)
        self._count("device_dispatches")
        sums_d, counts_d = compiled(runtimes, plan_constants(plan))
        sums = np.asarray(sums_d, dtype=np.float64)
        counts = np.asarray(counts_d, dtype=np.float64)
        values = self._compose_values(plan, sums, counts, self._upscale(infos))
        return QueryResult(
            agg_names=[a.name for a in plan.aggs],
            values=values,
            raw_sums=sums,
            group_counts=counts,
            group_present=counts > 0,
            scanned_bytes=compiled.scanned_bytes(runtimes),
            sample_infos=infos,
            wall_time_s=time.perf_counter() - t0,
        )

    def _execute_compiled(self, plan: L.Aggregate) -> QueryResult:
        route = self._staged_route(plan)
        if route is not None:
            return self._execute_staged(plan, *route)
        t0 = time.perf_counter()
        runtimes, infos = self._scan_runtimes(plan)
        self._check_empty(infos)
        compiled = self.physical.compile_query(plan, runtimes)
        # Predicate/expression constants ride as a runtime operand: the
        # compiled executable is shared across every constant variant.
        self._count("device_dispatches")
        sums_d, counts_d = compiled(runtimes, plan_constants(plan))
        # Single device→host boundary: the whole scan→aggregate pipeline ran
        # as one executable.
        sums = np.asarray(sums_d, dtype=np.float64)
        counts = np.asarray(counts_d, dtype=np.float64)
        values = self._compose_values(plan, sums, counts, self._upscale(infos))
        return QueryResult(
            agg_names=[a.name for a in plan.aggs],
            values=values,
            raw_sums=sums,
            group_counts=counts,
            group_present=counts > 0,
            scanned_bytes=compiled.scanned_bytes(runtimes),
            sample_infos=infos,
            wall_time_s=time.perf_counter() - t0,
        )

    def _execute_eager(self, plan: L.Aggregate) -> QueryResult:
        t0 = time.perf_counter()
        infos: Dict[str, SampleInfo] = {}
        table = self._run_relational(plan.child, infos)

        exprs, names = [], []
        for a in plan.aggs:
            names.append(a.name)
            exprs.append(None if a.op == "count" else a.expr)
        sums = np.asarray(
            ops.grouped_sums(table, exprs, plan.group_by, plan.max_groups),
            dtype=np.float64)
        counts = np.asarray(
            ops.grouped_counts(table, plan.group_by, plan.max_groups), dtype=np.float64)

        self._check_empty(infos)
        values = self._compose_values(plan, sums, counts, self._upscale(infos))
        scanned = sum(info.scanned_bytes for info in infos.values())
        return QueryResult(
            agg_names=names,
            values=values,
            raw_sums=sums,
            group_counts=counts,
            group_present=counts > 0,
            scanned_bytes=scanned,
            sample_infos=infos,
            wall_time_s=time.perf_counter() - t0,
        )

    # -- batched execution (drain-group finals) ------------------------------
    def _execute_captured(self, plan: L.Aggregate):
        """execute(), with EmptySampleError returned instead of raised (the
        per-member contract of :meth:`execute_batch`)."""
        try:
            return self.execute(plan)
        except EmptySampleError as e:
            return e

    def execute_batch(self, plans: List[L.Aggregate],
                      on_result=None) -> List[object]:
        """Execute several plans, batching same-signature members into ONE
        device dispatch each (see ``physical.compile_batched_query``).

        ``on_result(i, result)`` (optional) is invoked the moment
        ``plans[i]``'s entry materializes — per member on the solo/fallback
        paths, per bucket chunk on the batched path — so callers can deliver
        early answers while later buckets are still dispatching (the
        progressive-streaming drain).  The callback must not raise; an
        escaping exception is swallowed here — delivery machinery must never
        sink the batch (callers' completion loops still own every entry).

        Members are grouped by their solo compile key — the constant-hoisted
        plan signature including sampling methods and bucketed shapes — and
        every group of two or more runs as one ``lax.map`` executable over
        stacked block-id matrices and params rows; lanes are bit-identical
        to solo runs.  Groups are padded to a power-of-two batch size
        (duplicating the last member; padded lanes are discarded) so batch
        executables recur in log-many sizes.

        Returns one entry per plan, position-aligned: a
        :class:`QueryResult`, or the :class:`EmptySampleError` that member's
        sampled scan raised — callers take their per-member exact fallback,
        matching the serial path's semantics.  Singleton groups and the eager
        executor fall back to per-member execution.  Pallas kernel routes
        batch too: shapes the solo path runs through ``filtered_agg`` /
        ``block_agg`` compile to a megacore-style batched kernel grid (one
        launch for the whole bucket); shapes the kernels cannot take use the
        ``lax.map`` XLA twin, exactly like the solo route's fallback.

        Buckets split greedily into power-of-two chunks (11 members → 8+2+1)
        rather than padding up: batch executables recur in log-many sizes
        with ZERO wasted lanes — padding would recompute up to 2x of the
        device work, which at CPU scale costs more than the dispatches it
        saves.
        """
        results: List[object] = [None] * len(plans)

        def _land(i: int, res: object) -> None:
            results[i] = res
            if on_result is not None:
                try:
                    on_result(i, res)
                except Exception:
                    pass

        if not self.use_compiled or len(plans) < 2:
            for i, p in enumerate(plans):
                _land(i, self._execute_captured(p))
            return results

        drawn: Dict[int, tuple] = {}
        buckets: Dict[tuple, List[int]] = {}
        for i, plan in enumerate(plans):
            if self._staged_route(plan) is not None:
                # staged members run solo against their rung arrays — their
                # dispatch is already the cheap path, and batching them
                # would redraw fresh (the ladder seed keeps that bitwise
                # identical, but it forfeits the staged win)
                _land(i, self._execute_captured(plan))
                continue
            runtimes, infos = self._scan_runtimes(plan)
            try:
                self._check_empty(infos)
            except EmptySampleError as e:
                self._count("queries_run")
                _land(i, e)
                continue
            drawn[i] = (runtimes, infos)
            key = self.physical.query_signature(plan, runtimes)
            buckets.setdefault(key, []).append(i)

        for idxs in buckets.values():
            while idxs:
                take = min(1 << (len(idxs).bit_length() - 1), len(idxs))
                chunk, idxs = idxs[:take], idxs[take:]
                if len(chunk) == 1:
                    # the solo path redraws the same content-derived sample
                    _land(chunk[0], self._execute_captured(plans[chunk[0]]))
                    continue
                try:
                    self._run_bucket(plans, chunk, drawn, results)
                except Exception:
                    # a batch-level failure (e.g. the batched executable
                    # failing to compile) must not sink the other buckets —
                    # nor these members, who would succeed solo: fall back
                    # to per-member dispatches, bit-identical by design
                    for i in chunk:
                        if results[i] is None:
                            results[i] = self._execute_captured(plans[i])
                # per-bucket landing: the whole chunk materializes in one
                # device dispatch, so its members are announced together
                if on_result is not None:
                    for i in chunk:
                        try:
                            on_result(i, results[i])
                        except Exception:
                            pass
        return results

    def _run_bucket(self, plans, idxs, drawn, results) -> None:
        t0 = time.perf_counter()
        compiled = self.physical.compile_batched_query(
            plans[idxs[0]], drawn[idxs[0]][0], len(idxs))
        self._count("device_dispatches")
        sums_b, counts_b = compiled.call_batch(
            [drawn[i][0] for i in idxs],
            [plan_constants(plans[i]) for i in idxs])
        # one device→host boundary for the whole bucket
        sums_b = np.asarray(sums_b, dtype=np.float64)
        counts_b = np.asarray(counts_b, dtype=np.float64)
        wall = time.perf_counter() - t0
        for k, i in enumerate(idxs):
            self._count("queries_run")
            runtimes, infos = drawn[i]
            sums, counts = sums_b[k], counts_b[k]
            values = self._compose_values(plans[i], sums, counts,
                                          self._upscale(infos))
            results[i] = QueryResult(
                agg_names=[a.name for a in plans[i].aggs],
                values=values,
                raw_sums=sums,
                group_counts=counts,
                group_present=counts > 0,
                scanned_bytes=compiled.scanned_bytes(runtimes),
                sample_infos=infos,
                wall_time_s=wall,
            )

    def execute_pilot(
        self,
        plan: L.Aggregate,
        pilot_table: str,
        theta_p: float,
        seed: int,
        pair_tables: Tuple[str, ...] = (),
    ) -> PilotStats:
        """Run the pilot query: block-sample ``pilot_table`` at theta_p and
        compute per-block (and per block-pair) sums of each simple aggregate.

        Not counted here: ``pilots_run`` counts pilot *stages* and is
        incremented by :meth:`repro.core.taqa.PilotDB.run_pilot` — a stage's
        Bernoulli-undershoot retries re-enter this method but are one stage.
        """
        # A staged pilot table draws from its pinned staging seed on EVERY
        # path (compiled, eager, staged rung), so retries and route changes
        # can never fork the realization.
        seed = self.staged.seed_for(pilot_table, seed)
        # One "scan" span per attempt: a stage's undershoot retries show as
        # sibling spans under the handle's "pilot" span.
        with _trace.span("scan", pilot=True, table=pilot_table,
                         theta_pilot=theta_p) as sp:
            # The compiled lowering traces one pair table; the (currently
            # unused by TAQA) multi-pair shape takes the eager path so both
            # paths return pair_sums for every requested table.
            if self.use_compiled and len(pair_tables) <= 1:
                stats = self._execute_pilot_compiled(
                    plan, pilot_table, theta_p, seed, pair_tables)
            else:
                stats = self._execute_pilot_eager(
                    plan, pilot_table, theta_p, seed, pair_tables)
            sp.set(scanned_bytes=stats.scanned_bytes,
                   n_blocks=stats.n_sampled_blocks)
        return stats

    def _execute_pilot_compiled(self, plan, pilot_table, theta_p, seed,
                                pair_tables) -> PilotStats:
        t0 = time.perf_counter()
        table = self.catalog[pilot_table]
        # Staged route: serve the pilot draw as a sub-draw of the table's
        # staged realization (execute_pilot already pinned ``seed`` to the
        # ladder's, so hit and miss replay one realization either way).
        lad = self.staged.ladder(pilot_table)
        rung = None
        if (lad is not None and lad.sharded is None
                and not self.physical._use_pallas()):
            rung = lad.rung_for(theta_p)
        if rung is not None:
            sub = prepare_mono_subdraw(lad, rung, theta_p)
            self.staged.note_hit()
            _trace.annotate(staged=True, staged_table=pilot_table,
                            staged_rate=theta_p, staged_rung=rung.rate)
            ids, n_real = sub.sub_ids, sub.n_real
        else:
            if lad is not None:
                self.staged.note_miss()
            ids = draw_block_ids(table.num_blocks, theta_p, seed)
            n_real = int(len(ids))
        names = [a.name for a in plan.aggs] + ["__rows"]

        if n_real == 0:
            other = {s.table for s in plan.scans() if s.table != pilot_table}
            scanned = sum(self.catalog[t].total_bytes() for t in other)
            return PilotStats(
                table=pilot_table, theta_p=theta_p, n_sampled_blocks=0,
                n_total_blocks=table.num_blocks, block_rows=table.block_rows,
                agg_names=names,
                block_sums=np.zeros((0, plan.max_groups, len(names))),
                group_present=np.zeros(plan.max_groups, bool),
                pair_sums={}, right_total_blocks={}, scanned_bytes=scanned,
                wall_time_s=time.perf_counter() - t0)

        if rung is not None:
            # positions within the rung, padded to the FRESH physical block
            # count — identical graph shapes and masking, smaller gather
            runtime = ScanRuntime("block", sub.n_real, sub.n_phys, sub.phys,
                                  ids_dev=sub.phys_dev,
                                  nreal_dev=sub.nreal_dev)
            compiler = rung.compiler
        else:
            phys, n_real, n_phys = pad_block_ids(ids, table.num_blocks)
            runtime = ScanRuntime("block", n_real, n_phys, phys)
            compiler = self.physical
        pair_table = pair_tables[0] if pair_tables else None
        compiled = compiler.compile_pilot(plan, pilot_table, runtime,
                                          pair_table)
        # One executable from sampled scan to per-block statistics — zero
        # host syncs in between; the conversions below are the boundary.
        self._count("device_dispatches")
        bs_d, present_d, pair_d = compiled({pilot_table: runtime},
                                           plan_constants(plan))
        block_sums = np.asarray(bs_d, dtype=np.float64)[:n_real]
        present = np.asarray(present_d, dtype=bool)
        pair_sums: Dict[str, np.ndarray] = {}
        right_total: Dict[str, int] = {}
        if pair_d is not None:
            pair_sums[pair_table] = np.asarray(pair_d, dtype=np.float64)[:n_real]
            right_total[pair_table] = self.catalog[pair_table].num_blocks
        return PilotStats(
            table=pilot_table,
            theta_p=theta_p,
            n_sampled_blocks=n_real,
            n_total_blocks=table.num_blocks,
            block_rows=table.block_rows,
            agg_names=names,
            block_sums=block_sums,
            group_present=present,
            pair_sums=pair_sums,
            right_total_blocks=right_total,
            scanned_bytes=compiled.scanned_bytes({pilot_table: runtime}),
            wall_time_s=time.perf_counter() - t0,
        )

    def _execute_pilot_eager(self, plan, pilot_table, theta_p, seed,
                             pair_tables) -> PilotStats:
        t0 = time.perf_counter()
        sampled_plan = L.rewrite_scans(
            plan, {pilot_table: L.SampleClause("block", theta_p, seed)})
        infos: Dict[str, SampleInfo] = {}
        pair_for = (pilot_table, pair_tables[0]) if pair_tables else None
        table = self._run_relational(sampled_plan.child, infos, pair_for)

        # One channel per simple aggregate plus a trailing row-count channel
        # ("__rows") used for group-presence detection and COUNT/AVG planning.
        exprs = [None if a.op == "count" else a.expr for a in plan.aggs] + [None]
        names = [a.name for a in plan.aggs] + ["__rows"]
        info = infos[pilot_table]
        ids = info.sampled_block_ids
        if ids is None or len(ids) == 0:
            ids = np.zeros(0, dtype=np.int64)
            block_sums = np.zeros((0, plan.max_groups, len(exprs)))
        else:
            block_sums = ops.block_group_sums(
                table, exprs, plan.group_by, plan.max_groups, ids)

        pair_sums: Dict[str, np.ndarray] = {}
        right_total: Dict[str, int] = {}
        for rt in pair_tables:
            col = f"__rblock_{rt}"
            if col in table.columns and len(ids) > 0:
                nrb = self.catalog[rt].num_blocks
                pair_sums[rt] = ops.block_pair_sums(table, exprs, ids, col, nrb)
                right_total[rt] = nrb
        scanned = sum(i.scanned_bytes for i in infos.values())
        block_sums = np.asarray(block_sums, dtype=np.float64)
        present = (block_sums[..., -1].sum(axis=0) > 0) if len(ids) \
            else np.zeros(plan.max_groups, bool)
        return PilotStats(
            table=pilot_table,
            theta_p=theta_p,
            n_sampled_blocks=int(len(ids)),
            n_total_blocks=self.catalog[pilot_table].num_blocks,
            block_rows=self.catalog[pilot_table].block_rows,
            agg_names=names,
            block_sums=block_sums,
            group_present=present,
            pair_sums=pair_sums,
            right_total_blocks=right_total,
            scanned_bytes=scanned,
            wall_time_s=time.perf_counter() - t0,
        )

    # -- batched pilots (shared-pilot drain groups) --------------------------
    def execute_pilots_batched(
        self,
        plans: List[L.Aggregate],
        pilot_table: str,
        thetas: List[float],
        runtimes_list: List[Dict[str, ScanRuntime]],
    ) -> List[PilotStats]:
        """One stacked device dispatch for B same-signature pilot scans.

        Callers (``core.taqa.PilotDB.run_pilots_batched``) have already
        host-resolved each member's Bernoulli draw — including undershoot
        retries, which are a pure host-RNG computation — so every lane
        arrives with its final block ids.  Lane k runs the solo tracer-route
        pilot body under ``lax.map`` and is bit-identical to member k's solo
        ``execute_pilot``.  Pair-table, Pallas-route, staged-ladder and
        sharded pilots never reach here (the caller gates them to solo).
        """
        batch = len(plans)
        compiled = self.physical.compile_batched_pilot(
            plans[0], pilot_table, runtimes_list[0][pilot_table], batch)
        names_l = [[a.name for a in p.aggs] + ["__rows"] for p in plans]
        t0 = time.perf_counter()
        with _trace.span("scan", pilot=True, table=pilot_table,
                         batched=batch) as sp:
            self._count("device_dispatches")
            bs_d, present_d = compiled.call_batch(
                runtimes_list, [plan_constants(p) for p in plans])
            # one device→host boundary for the whole pilot group
            bs_b = np.asarray(bs_d, dtype=np.float64)
            present_b = np.asarray(present_d, dtype=bool)
            sp.set(n_blocks=sum(r[pilot_table].n_real for r in runtimes_list))
        wall = time.perf_counter() - t0
        table = self.catalog[pilot_table]
        out: List[PilotStats] = []
        for k in range(batch):
            runtime = runtimes_list[k][pilot_table]
            out.append(PilotStats(
                table=pilot_table,
                theta_p=thetas[k],
                n_sampled_blocks=runtime.n_real,
                n_total_blocks=table.num_blocks,
                block_rows=table.block_rows,
                agg_names=names_l[k],
                block_sums=bs_b[k, :runtime.n_real],
                group_present=present_b[k],
                pair_sums={},
                right_total_blocks={},
                scanned_bytes=compiled.scanned_bytes(runtimes_list[k]),
                wall_time_s=wall,
            ))
        return out

    # -- fused single-launch TAQA --------------------------------------------
    def execute_fused(
        self,
        plan: L.Aggregate,
        pilot_table: str,
        runtimes: Dict[str, ScanRuntime],
        solve: np.ndarray,
        scal: np.ndarray,
        u: np.ndarray,
        solve_channels: Tuple[int, ...],
    ):
        """Dispatch the single-launch TAQA program and return its raw device
        outputs (converted at one host boundary).

        The caller (``core.taqa.PilotDB.run_fused``) owns every host-side
        decision: it precomputed the pilot draw, the per-constraint quantile
        table, the cost line, and the final-draw uniforms; it re-solves the
        rate in f64 afterwards and verifies the device's final draw before
        trusting the returned sums.  This method is exactly ONE compiled
        dispatch — no host sync between pilot, solve, and final.
        """
        compiled = self.physical.compile_fused(plan, pilot_table, runtimes,
                                               tuple(solve_channels))
        with _trace.span("scan", fused=True, table=pilot_table) as sp:
            self._count("device_dispatches")
            bs_d, present_d, theta_d, flags_d, nsel_d, padded_d, sums_d, counts_d = \
                compiled.call_fused(runtimes, plan_constants(plan),
                                    solve, scal, u)
            # the fused program's single device→host boundary
            out = {
                "block_sums": np.asarray(bs_d, dtype=np.float64),
                "present": np.asarray(present_d, dtype=bool),
                "theta": float(theta_d),
                "flags": int(flags_d),
                "nsel": int(nsel_d),
                "padded": np.asarray(padded_d),
                "sums": np.asarray(sums_d, dtype=np.float64),
                "counts": np.asarray(counts_d, dtype=np.float64),
            }
            sp.set(n_blocks=runtimes[pilot_table].n_real,
                   theta_final=out["theta"], fused_flags=out["flags"])
        return out, compiled
