"""Plan executor.

Executes logical plans eagerly with jnp operators, tracking the *scan cost*
(bytes moved HBM→VMEM) per table — block-sampled scans pay only for sampled
slabs, row-sampled and exact scans stream everything (Fig. 1 / Fig. 4).

Besides plain execution it produces the two artifacts TAQA needs:

* ``QueryResult``     — per-group aggregate values (+ lineage/cost),
* ``execute_pilot``   — per-block (and per block-pair, for Lemma 4.8) pilot
                        statistics of every simple aggregate.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.engine import logical as L
from repro.engine import ops
from repro.engine.sampling import SampleInfo, block_sample, row_sample
from repro.engine.table import BlockTable


@dataclasses.dataclass
class QueryResult:
    agg_names: List[str]
    values: np.ndarray           # (num_aggs, max_groups) float64, upscaled
    raw_sums: np.ndarray         # (num_aggs, max_groups) unscaled sample sums
    group_counts: np.ndarray     # (max_groups,) raw surviving row counts
    group_present: np.ndarray    # (max_groups,) bool
    scanned_bytes: int
    sample_infos: Dict[str, SampleInfo]
    wall_time_s: float

    def scalar(self, name: str, group: int = 0) -> float:
        return float(self.values[self.agg_names.index(name), group])


@dataclasses.dataclass
class PilotStats:
    """Per-block statistics from the pilot query (§3.1, §3.3).

    block_sums: (n_p, max_groups, num_aggs) — sum of each simple aggregate's
        expression within each sampled origin block of the pilot table.
    pair_sums: optional {right_table: (n_p, N_right, num_aggs)} for Lemma 4.8.
    """

    table: str
    theta_p: float
    n_sampled_blocks: int
    n_total_blocks: int
    block_rows: int
    agg_names: List[str]
    block_sums: np.ndarray
    group_present: np.ndarray
    pair_sums: Dict[str, np.ndarray]
    right_total_blocks: Dict[str, int]
    scanned_bytes: int
    wall_time_s: float


class Executor:
    def __init__(self, catalog: Dict[str, BlockTable]):
        self.catalog = dict(catalog)

    # -- table metadata (the "DBMS statistics" TAQA consults) ---------------
    def table_rows(self, name: str) -> int:
        return self.catalog[name].num_rows

    def table_blocks(self, name: str) -> int:
        return self.catalog[name].num_blocks

    def block_rows(self, name: str) -> int:
        return self.catalog[name].block_rows

    def table_bytes(self, name: str) -> int:
        return self.catalog[name].total_bytes()

    # -- relational execution ------------------------------------------------
    def _run_relational(
        self, plan: L.Plan, infos: Dict[str, SampleInfo],
        pair_for: Optional[Tuple[str, str]] = None,
    ) -> BlockTable:
        if isinstance(plan, L.Scan):
            table = self.catalog[plan.table]
            if plan.sample is None:
                infos[plan.table] = SampleInfo(
                    "none", 1.0, 0, table.num_blocks, table.num_blocks,
                    np.arange(table.num_blocks),
                    scanned_bytes=table.total_bytes())
                return table
            if plan.sample.method == "block":
                sampled, info = block_sample(table, plan.sample.rate, plan.sample.seed)
            else:
                sampled, info = row_sample(table, plan.sample.rate, plan.sample.seed)
            infos[plan.table] = info
            return sampled
        if isinstance(plan, L.Filter):
            child = self._run_relational(plan.child, infos, pair_for)
            return ops.filter_table(child, plan.pred)
        if isinstance(plan, L.Join):
            left = self._run_relational(plan.left, infos, pair_for)
            right = self._run_relational(plan.right, infos, pair_for)
            rblock_col = None
            if pair_for is not None and pair_for[1] == self._scan_table(plan.right):
                rblock_col = f"__rblock_{pair_for[1]}"
            return ops.join_unique(left, right, plan.left_key, plan.right_key,
                                   rblock_col=rblock_col)
        if isinstance(plan, L.Union):
            return ops.union_all(
                [self._run_relational(p, infos, pair_for) for p in plan.inputs])
        raise TypeError(plan)

    @staticmethod
    def _scan_table(plan: L.Plan) -> Optional[str]:
        scans = plan.scans()
        return scans[0].table if len(scans) == 1 else None

    # -- public API ----------------------------------------------------------
    def execute(self, plan: L.Aggregate) -> QueryResult:
        t0 = time.perf_counter()
        infos: Dict[str, SampleInfo] = {}
        table = self._run_relational(plan.child, infos)

        exprs, names = [], []
        for a in plan.aggs:
            names.append(a.name)
            exprs.append(None if a.op == "count" else a.expr)
        sums = np.asarray(
            ops.grouped_sums(table, exprs, plan.group_by, plan.max_groups),
            dtype=np.float64)
        counts = np.asarray(
            ops.grouped_counts(table, plan.group_by, plan.max_groups), dtype=np.float64)

        # Upscaling (§3.3 final rewriting step 2).  With exactly one sampled
        # table we use the Hájek scale N/n (conditional-SRS estimator matching
        # BSAP's Lemma-B.1 bounds); with two or more we use Horvitz–Thompson
        # 1/∏θ (matching Lemma 4.8's variance expansion).  AVG is the ratio of
        # two upscaled sums, so the scale cancels either way.
        sampled = [i for i in infos.values()
                   if i.method in ("block", "row") and i.rate < 1.0]
        if len(sampled) == 1:
            info = sampled[0]
            if info.method == "block":
                n = max(info.n_sampled_blocks or 0, 1)
                scale = info.n_total_blocks / n
            else:
                n = max(info.n_sampled_rows or 0, 1)
                scale = (info.n_total_rows or n) / n
        else:
            scale = 1.0
            for info in sampled:
                scale /= info.rate
        values = np.zeros_like(sums)
        for i, a in enumerate(plan.aggs):
            if a.op in ("sum", "count"):
                values[i] = sums[i] * scale
            elif a.op == "avg":
                with np.errstate(invalid="ignore", divide="ignore"):
                    values[i] = np.where(counts > 0, sums[i] / np.maximum(counts, 1), np.nan)
        scanned = sum(info.scanned_bytes for info in infos.values())
        return QueryResult(
            agg_names=names,
            values=values,
            raw_sums=sums,
            group_counts=counts,
            group_present=counts > 0,
            scanned_bytes=scanned,
            sample_infos=infos,
            wall_time_s=time.perf_counter() - t0,
        )

    def execute_pilot(
        self,
        plan: L.Aggregate,
        pilot_table: str,
        theta_p: float,
        seed: int,
        pair_tables: Tuple[str, ...] = (),
    ) -> PilotStats:
        """Run the pilot query: block-sample ``pilot_table`` at theta_p and
        compute per-block (and per block-pair) sums of each simple aggregate.
        """
        t0 = time.perf_counter()
        sampled_plan = L.rewrite_scans(
            plan, {pilot_table: L.SampleClause("block", theta_p, seed)})
        infos: Dict[str, SampleInfo] = {}
        pair_for = (pilot_table, pair_tables[0]) if pair_tables else None
        table = self._run_relational(sampled_plan.child, infos, pair_for)

        # One channel per simple aggregate plus a trailing row-count channel
        # ("__rows") used for group-presence detection and COUNT/AVG planning.
        exprs = [None if a.op == "count" else a.expr for a in plan.aggs] + [None]
        names = [a.name for a in plan.aggs] + ["__rows"]
        info = infos[pilot_table]
        ids = info.sampled_block_ids
        if ids is None or len(ids) == 0:
            ids = np.zeros(0, dtype=np.int64)
            block_sums = np.zeros((0, plan.max_groups, len(exprs)))
        else:
            block_sums = ops.block_group_sums(
                table, exprs, plan.group_by, plan.max_groups, ids)

        pair_sums: Dict[str, np.ndarray] = {}
        right_total: Dict[str, int] = {}
        for rt in pair_tables:
            col = f"__rblock_{rt}"
            if col in table.columns and len(ids) > 0:
                nrb = self.catalog[rt].num_blocks
                pair_sums[rt] = ops.block_pair_sums(table, exprs, ids, col, nrb)
                right_total[rt] = nrb
        scanned = sum(i.scanned_bytes for i in infos.values())
        block_sums = np.asarray(block_sums, dtype=np.float64)
        present = (block_sums[..., -1].sum(axis=0) > 0) if len(ids) \
            else np.zeros(plan.max_groups, bool)
        return PilotStats(
            table=pilot_table,
            theta_p=theta_p,
            n_sampled_blocks=int(len(ids)),
            n_total_blocks=self.catalog[pilot_table].num_blocks,
            block_rows=self.catalog[pilot_table].block_rows,
            agg_names=names,
            block_sums=block_sums,
            group_present=present,
            pair_sums=pair_sums,
            right_total_blocks=right_total,
            scanned_bytes=scanned,
            wall_time_s=time.perf_counter() - t0,
        )
