"""Synthetic benchmark data.

Mirrors the paper's evaluation corpora at CPU-container scale:

* ``make_lineitem`` / ``make_orders`` — TPC-H-like star schema (Q1/Q6/Q14-ish
  queries in benchmarks/), with a ``clustered`` switch that sorts the fact
  table by ship date.  Clustered layouts give homogeneous blocks — the regime
  where naive row-level CLT under block sampling fails hardest (Fig. 16/17)
  and where Lemma 4.1's efficiency ratio is worst.
* ``make_skewed`` — DSB-like skew: exponential aggregation column, Zipf-ish
  group sizes, correlated join keys (§5.3 "PilotDB Accelerates Queries on
  Skewed Data").
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.engine.table import BlockTable


def make_lineitem(num_rows: int = 200_000, block_rows: int = 256, *,
                  num_orders: int = 50_000, clustered: bool = False,
                  seed: int = 0) -> BlockTable:
    rng = np.random.default_rng(seed)
    shipdate = rng.integers(0, 2526, size=num_rows)  # days since 1992-01-01
    if clustered:
        shipdate = np.sort(shipdate)
    quantity = rng.integers(1, 51, size=num_rows).astype(np.float32)
    extendedprice = (quantity * rng.uniform(900.0, 1100.0, num_rows)).astype(np.float32)
    discount = rng.integers(0, 11, size=num_rows).astype(np.float32) / 100.0
    tax = rng.integers(0, 9, size=num_rows).astype(np.float32) / 100.0
    orderkey = rng.integers(0, num_orders, size=num_rows).astype(np.int32)
    returnflag = rng.integers(0, 3, size=num_rows).astype(np.int32)
    linestatus = rng.integers(0, 2, size=num_rows).astype(np.int32)
    return BlockTable.from_numpy(
        "lineitem",
        {
            "l_orderkey": orderkey,
            "l_quantity": quantity,
            "l_extendedprice": extendedprice,
            "l_discount": discount,
            "l_tax": tax,
            "l_shipdate": shipdate.astype(np.int32),
            "l_returnflag": returnflag,
            "l_linestatus": linestatus,
        },
        block_rows,
    )


def make_orders(num_orders: int = 50_000, block_rows: int = 256, *,
                seed: int = 1) -> BlockTable:
    rng = np.random.default_rng(seed)
    orderkey = np.arange(num_orders, dtype=np.int32)
    rng.shuffle(orderkey)  # physical order decorrelated from key
    totalprice = rng.gamma(4.0, 30_000.0, num_orders).astype(np.float32)
    orderdate = rng.integers(0, 2406, size=num_orders).astype(np.int32)
    custkey = rng.integers(0, max(num_orders // 10, 1), size=num_orders).astype(np.int32)
    orderpriority = rng.integers(0, 5, size=num_orders).astype(np.int32)
    return BlockTable.from_numpy(
        "orders",
        {
            "o_orderkey": orderkey,
            "o_totalprice": totalprice,
            "o_orderdate": orderdate,
            "o_custkey": custkey,
            "o_orderpriority": orderpriority,
        },
        block_rows,
    )


def make_skewed(num_rows: int = 200_000, block_rows: int = 256, *,
                num_groups: int = 8, seed: int = 7,
                clustered_groups: bool = False) -> BlockTable:
    """DSB-like skewed fact table: exponential measure, Zipf group sizes."""
    rng = np.random.default_rng(seed)
    measure = rng.exponential(100.0, num_rows).astype(np.float32)
    # Zipf-ish group assignment
    weights = 1.0 / np.arange(1, num_groups + 1) ** 1.2
    weights /= weights.sum()
    group = rng.choice(num_groups, size=num_rows, p=weights).astype(np.int32)
    if clustered_groups:
        order = np.argsort(group, kind="stable")
        measure, group = measure[order], group[order]
    filter_col = rng.uniform(0.0, 1.0, num_rows).astype(np.float32)
    key = rng.integers(0, max(num_rows // 8, 1), size=num_rows).astype(np.int32)
    return BlockTable.from_numpy(
        "skewed",
        {"s_measure": measure, "s_group": group, "s_filter": filter_col, "s_key": key},
        block_rows,
    )


def tpch_catalog(scale_rows: int = 200_000, block_rows: int = 256, *,
                 clustered: bool = False, seed: int = 0) -> Dict[str, BlockTable]:
    num_orders = max(scale_rows // 4, 16)
    return {
        "lineitem": make_lineitem(scale_rows, block_rows, num_orders=num_orders,
                                  clustered=clustered, seed=seed),
        "orders": make_orders(num_orders, block_rows, seed=seed + 1),
    }
