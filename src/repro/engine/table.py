"""Columnar, block-structured tables.

A :class:`BlockTable` is the TPU-native analogue of a DBMS heap file: every
column is one contiguous 1-D array of length ``num_blocks * block_rows`` and a
*block* — the paper's "minimum unit of data accessing in the storage layer" —
is a contiguous ``block_rows`` slab of every column.  Block sampling therefore
touches only the sampled slabs (HBM→VMEM DMA granularity), while row-level
Bernoulli sampling must stream every slab (mask-based).  This reproduces the
system-efficiency asymmetry of Fig. 1/Fig. 4 on-device.

Rows carry two pieces of lineage that BSAP needs:

* ``valid``    — row liveness (filters/joins clear bits instead of compacting,
                 keeping shapes static for jit),
* ``block_id`` — the *origin* block index in the base table.  Relational
                 operators preserve it (Props. 4.4–4.6: block sampling commutes
                 with selection/join/union), so per-block pilot statistics can
                 be computed after arbitrary plan suffixes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class BlockTable:
    """A columnar table with a fixed physical block size."""

    name: str
    columns: Dict[str, jnp.ndarray]  # each shape (num_blocks * block_rows,)
    block_rows: int
    num_rows: int  # logical rows (<= padded length)
    valid: Optional[jnp.ndarray] = None  # bool, same shape as columns
    block_id: Optional[jnp.ndarray] = None  # int32 origin block per row
    num_origin_blocks: Optional[int] = None  # blocks in the *base* table

    def __post_init__(self):
        n = self.padded_rows
        for cname, col in self.columns.items():
            if col.shape != (n,):
                raise ValueError(
                    f"column {cname!r} has shape {col.shape}, expected ({n},)")
        if self.valid is None:
            valid = np.zeros(n, dtype=bool)
            valid[: self.num_rows] = True
            self.valid = jnp.asarray(valid)
        if self.block_id is None:
            self.block_id = jnp.asarray(
                np.repeat(np.arange(self.num_blocks, dtype=np.int32), self.block_rows))
        if self.num_origin_blocks is None:
            self.num_origin_blocks = self.num_blocks

    # -- geometry ----------------------------------------------------------
    @property
    def padded_rows(self) -> int:
        some = next(iter(self.columns.values()))
        return int(some.shape[0])

    @property
    def num_blocks(self) -> int:
        return self.padded_rows // self.block_rows

    @property
    def column_names(self):
        return list(self.columns.keys())

    def row_bytes(self) -> int:
        return sum(int(np.dtype(c.dtype).itemsize) for c in self.columns.values())

    def total_bytes(self) -> int:
        return self.row_bytes() * self.padded_rows

    # -- constructors --------------------------------------------------------
    @staticmethod
    def from_numpy(name: str, columns: Dict[str, np.ndarray], block_rows: int) -> "BlockTable":
        num_rows = len(next(iter(columns.values())))
        pad = (-num_rows) % block_rows
        cols = {}
        for cname, col in columns.items():
            col = np.asarray(col)
            if pad:
                col = np.concatenate([col, np.zeros(pad, dtype=col.dtype)])
            cols[cname] = jnp.asarray(col)
        return BlockTable(name=name, columns=cols, block_rows=block_rows, num_rows=num_rows)

    # -- derived tables -----------------------------------------------------
    def with_valid(self, valid: jnp.ndarray) -> "BlockTable":
        return dataclasses.replace(self, valid=valid)

    def with_columns(self, columns: Dict[str, jnp.ndarray]) -> "BlockTable":
        return dataclasses.replace(self, columns=columns)

    def gather_blocks(self, block_indices: np.ndarray) -> "BlockTable":
        """Materialize only the given blocks (the block-sampling fast path).

        The result re-labels physical blocks 0..k-1 but keeps ``block_id``
        pointing at the *origin* block indices so BSAP statistics stay valid.
        """
        block_indices = np.asarray(block_indices, dtype=np.int32)
        row_idx = (block_indices[:, None] * self.block_rows
                   + np.arange(self.block_rows, dtype=np.int32)[None, :]).reshape(-1)
        row_idx_j = jnp.asarray(row_idx)
        cols = {c: v[row_idx_j] for c, v in self.columns.items()}
        return BlockTable(
            name=self.name,
            columns=cols,
            block_rows=self.block_rows,
            num_rows=len(row_idx),
            valid=self.valid[row_idx_j],
            block_id=self.block_id[row_idx_j],
            num_origin_blocks=self.num_origin_blocks,
        )

    def to_numpy(self) -> Dict[str, np.ndarray]:
        mask = np.asarray(self.valid)
        return {c: np.asarray(v)[mask] for c, v in self.columns.items()}
