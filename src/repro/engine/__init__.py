from repro.engine.table import BlockTable
from repro.engine.expr import Col, Const, BinOp, Cmp, Between, And, Or, Not, eval_expr
from repro.engine import logical
from repro.engine.executor import Executor

__all__ = [
    "BlockTable",
    "Col",
    "Const",
    "BinOp",
    "Cmp",
    "Between",
    "And",
    "Or",
    "Not",
    "eval_expr",
    "logical",
    "Executor",
]
