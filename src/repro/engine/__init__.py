from repro.engine.table import BlockTable
from repro.engine.expr import Col, Const, BinOp, Cmp, Between, And, Or, Not, eval_expr
from repro.engine import logical
from repro.engine import physical
from repro.engine.executor import EmptySampleError, Executor

__all__ = [
    "BlockTable",
    "Col",
    "Const",
    "BinOp",
    "Cmp",
    "Between",
    "And",
    "Or",
    "Not",
    "eval_expr",
    "logical",
    "physical",
    "EmptySampleError",
    "Executor",
]
