"""User-facing specifications (§2.4) and sampling plans (§3.1)."""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.engine.expr import Expr

COMPOSITE_KINDS = ("sum", "count", "avg", "ratio", "product", "add")


@dataclasses.dataclass(frozen=True)
class CompositeAgg:
    """A user-level aggregate, possibly a composite of simple SUM/COUNT parts.

    kind:
      sum / count — simple linear aggregates (one channel)
      avg         — SUM(expr)/COUNT(*)              (division rule, Table 2)
      ratio       — SUM(expr)/SUM(expr2)            (division rule)
      product     — SUM(expr)*SUM(expr2)            (multiplication rule)
      add         — w1*SUM(expr)+w2*SUM(expr2)      (addition rule)
    """

    name: str
    kind: str
    expr: Optional[Expr] = None
    expr2: Optional[Expr] = None
    weights: Tuple[float, float] = (1.0, 1.0)

    def __post_init__(self):
        if self.kind not in COMPOSITE_KINDS:
            raise ValueError(self.kind)
        if self.kind != "count" and self.expr is None:
            raise ValueError(f"{self.kind} needs expr")
        if self.kind in ("ratio", "product", "add") and self.expr2 is None:
            raise ValueError(f"{self.kind} needs expr2")

    @property
    def num_channels(self) -> int:
        return 1 if self.kind in ("sum", "count") else 2


@dataclasses.dataclass(frozen=True)
class ErrorSpec:
    """ERROR e% CONFIDENCE p% (§2.4) plus TAQA's tunables (§3.1).

    The guarantee is joint over all aggregates and groups (Eq. 1):
      P[ ∀ i,j : |rel err of mu_ij| <= error ] >= confidence.
    """

    error: float
    confidence: float
    group_min_size: int = 200        # g in Lemma 3.2
    group_miss_prob: float = 0.05    # p_f in Lemma 3.2
    theta_pilot: float = 0.0005      # default pilot rate theta_p
    min_pilot_blocks: int = 30       # ">30 units" recommendation (§3.1)
    max_final_rate: float = 0.10     # sampling-plan domain bound (§3.2)
    max_pilot_rate: float = 0.05     # cap on theta_p (pilot must stay cheap)
    # Lemma 3.2's theta can approach 1 when protected groups span few blocks
    # (its union bound covers every *hypothetical* group).  If the lemma rate
    # exceeds max_pilot_rate: strict mode executes exactly (coverage formally
    # guaranteed); default mode caps theta_p and flags the report, matching
    # the paper's empirical setting where real groups are block-plentiful.
    strict_group_coverage: bool = False

    def __post_init__(self):
        if not 0.0 < self.error < 1.0:
            raise ValueError("error must be in (0,1)")
        if not 0.0 < self.confidence < 1.0:
            raise ValueError("confidence must be in (0,1)")


@dataclasses.dataclass
class SamplingPlan:
    """Theta = [theta_1..theta_k]: block-sampling rate per sampled table."""

    rates: Dict[str, float]
    est_cost: float = 0.0

    def tables(self):
        return [t for t, r in self.rates.items() if r < 1.0]
