"""Sampling-plan optimization (§3.2).

Plan space Θ̃ (paper, verbatim): for every subset S of the large tables and
every i ∈ S, the plan that *minimizes θ_i* subject to the conjunction of all
per-(aggregate, group) constraints φ and the domain D(Θ, S):
θ_j ∈ (0, 0.1] for j ∈ S, θ_j = 1 otherwise.

Every U_V term is monotonically decreasing in each θ (each (1−θ)/θ factor
is), so the 1-D minimization of θ_i given fixed θ_{j≠i} is solved exactly by
guarded bisection — same argmin as the paper's trust-region solver, but
deterministic and dependency-free.  Candidates are then costed with the
engine's bytes-moved model and plans costlier than the exact query are
rejected (the PilotDB fallback-to-exact rule).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Callable, Dict, List, Optional, Sequence

from repro.core import bsap
from repro.core.spec import SamplingPlan


@dataclasses.dataclass
class Constraint:
    """One simple-channel × group constraint φ_{i,j}(Θ)."""

    label: str
    z: float                      # z_{(1+p')/2}
    L_mu: float                   # probabilistic lower bound of the aggregate
    error: float                  # channel budget e
    var_fn: Callable[[Dict[str, float]], float]  # Θ -> U_V[Θ]

    def holds(self, rates: Dict[str, float]) -> bool:
        return bsap.phi_satisfied(self.z, self.var_fn(rates), self.L_mu, self.error)


def _feasible(constraints: Sequence[Constraint], rates: Dict[str, float]) -> bool:
    return all(c.holds(rates) for c in constraints)


def solve_candidates(
    constraints: Sequence[Constraint],
    sampleable_tables: Sequence[str],
    max_rate: float = 0.10,
    min_rate: float = 1e-6,
    max_subset: int = 2,
    bisect_iters: int = 48,
) -> List[SamplingPlan]:
    """Enumerate Θ̃: argmin_{θ_i} plans for each (S, i)."""
    out: List[SamplingPlan] = []
    tables = list(sampleable_tables)
    for r in range(1, min(len(tables), max_subset) + 1):
        for S in itertools.combinations(tables, r):
            for i in S:
                rates = {t: 1.0 for t in tables}
                for j in S:
                    rates[j] = max_rate
                if not _feasible(constraints, rates):
                    continue  # even the loosest plan in this domain fails
                lo, hi = min_rate, max_rate
                for _ in range(bisect_iters):
                    mid = math.sqrt(lo * hi)  # geometric: rates span decades
                    rates[i] = mid
                    if _feasible(constraints, rates):
                        hi = mid
                    else:
                        lo = mid
                rates[i] = hi
                out.append(SamplingPlan(rates={t: r_ for t, r_ in rates.items()}))
    return out


def pick_plan(
    candidates: List[SamplingPlan],
    cost_fn: Callable[[Dict[str, float]], float],
    exact_cost: float,
) -> Optional[SamplingPlan]:
    """Cost-based selection + rejection of plans costlier than exact (§3.2)."""
    best: Optional[SamplingPlan] = None
    for cand in candidates:
        cand.est_cost = float(cost_fn(cand.rates))
        if cand.est_cost >= exact_cost:
            continue
        if best is None or cand.est_cost < best.est_cost:
            best = cand
    return best
