"""Confidence allocation across aggregates, groups, and probabilistic bounds.

§2.4's guarantee is a *joint* probability over k·m (aggregate × group) events.
TAQA decomposes it with Boole's inequality (§3.1 "Multi-Aggregate Queries"):
with C total simple-channel constraints each allocated confidence
p_c = 1 − (1−p)/C, the joint holds at p.  Within each channel, Procedure 1
spends δ1 (for L_μ) and δ2 (for U_V) and inflates the CLT confidence to
p' = p_c + δ1 + δ2 (Theorem 3.1), default δ1 = δ2 = (1−p_c)/3.

If Lemma 3.2's group-coverage bound is in play, its failure probability p_f
is a further Boole term: the user-facing confidence p is first debited by
p_f before channel allocation (conservative; the paper treats coverage as a
separate high-probability event).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChannelBudget:
    error: float        # relative-error budget e for this simple channel
    confidence: float   # p_c allocated by Boole
    delta1: float       # failure prob of the L_mu bound
    delta2: float       # failure prob of the U_V bound
    p_prime: float      # adjusted CLT confidence (Thm 3.1)


def allocate(total_confidence: float, num_channels: int, channel_error: float,
             delta_split: tuple[float, float] | None = None,
             coverage_debit: float = 0.0) -> ChannelBudget:
    """Allocate confidence for one of ``num_channels`` simple constraints."""
    if num_channels < 1:
        raise ValueError(num_channels)
    p_eff = total_confidence + coverage_debit  # debit: need stronger base
    if p_eff >= 1.0:
        raise ValueError(
            f"confidence {total_confidence} + coverage debit {coverage_debit} "
            "is unattainable (>= 1)")
    p_c = 1.0 - (1.0 - p_eff) / num_channels
    if delta_split is None:
        d1 = d2 = (1.0 - p_c) / 3.0
    else:
        d1, d2 = delta_split
        if d1 + d2 >= 1.0 - p_c:
            raise ValueError("delta1 + delta2 must be < 1 - p_c")
    p_prime = p_c + d1 + d2
    return ChannelBudget(error=channel_error, confidence=p_c,
                         delta1=d1, delta2=d2, p_prime=p_prime)
