"""Sampling-equivalence rules (§4.2, Props. 4.4–4.6).

Block sampling commutes with selection, join (on the non-sampled side's
uniqueness pattern), bag union, projection, and group-by.  Our physical
operators realize the commutativity *pathwise*: conditioning on the kept
block set S, `op(gather(T, S))` and `gather(op-preserving-layout(T), S)`
produce identical surviving multisets.  Pathwise equality under a shared
coupling implies Definition 4.2's distributional equality (and hence
Prop. 4.3: identical aggregate distributions) — this module exposes both
sides of each rule so tests can verify equality exhaustively.

`normalize` implements Eq. 8: push every sample clause to its base-table
scan, yielding the standard form AGG(⋈ᵢ B_θᵢ(T̃ᵢ)) that BSAP's statistics
assume.  Our logical IR only *carries* samples on scans, so normalization
amounts to validation plus the pre/post execution pair used in tests.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.engine import logical as L
from repro.engine import ops
from repro.engine.expr import Expr
from repro.engine.table import BlockTable


def sample_then_filter(table: BlockTable, keep_blocks: np.ndarray, pred: Expr) -> BlockTable:
    return ops.filter_table(table.gather_blocks(keep_blocks), pred)


def filter_then_sample(table: BlockTable, keep_blocks: np.ndarray, pred: Expr) -> BlockTable:
    return ops.filter_table(table, pred).gather_blocks(keep_blocks)


def sample_then_join(left: BlockTable, keep_blocks: np.ndarray, right: BlockTable,
                     lk: str, rk: str) -> BlockTable:
    return ops.join_unique(left.gather_blocks(keep_blocks), right, lk, rk)


def join_then_sample(left: BlockTable, keep_blocks: np.ndarray, right: BlockTable,
                     lk: str, rk: str) -> BlockTable:
    return ops.join_unique(left, right, lk, rk).gather_blocks(keep_blocks)


def sample_then_union(tables, keeps) -> BlockTable:
    return ops.union_all([t.gather_blocks(k) for t, k in zip(tables, keeps)])


def union_then_sample(tables, keeps) -> BlockTable:
    u = ops.union_all(list(tables))
    offs, out = 0, []
    for t, k in zip(tables, keeps):
        out.append(np.asarray(k) + offs)
        offs += t.num_origin_blocks
    return u.gather_blocks(np.concatenate(out) if out else np.zeros(0, np.int32))


def surviving_rows(table: BlockTable, columns=None) -> dict:
    """Canonical multiset of surviving rows for equality checks."""
    data = table.to_numpy()
    cols = sorted(columns or data.keys())
    rows = np.stack([np.asarray(data[c], dtype=np.float64) for c in cols], axis=-1)
    order = np.lexsort(rows.T[::-1]) if len(rows) else np.zeros(0, np.int64)
    return {"cols": cols, "rows": rows[order]}


def normalize(plan: L.Plan) -> L.Plan:
    """Eq. 8 standard form: verify all sampling sits on base-table scans.

    Raises if a sample clause is attached anywhere else (our IR cannot even
    express that — this is the middleware invariant TAQA relies on)."""
    for scan in plan.scans():
        if scan.sample is not None and scan.sample.method not in ("block", "row"):
            raise ValueError(f"unknown sampling method {scan.sample.method}")
    return plan
