"""TAQA — Two-stage Approximate Query Algorithm (§3) — the PilotDB driver.

Stage 1 (sample planning): rewrite Q_in into Q_pilot (block sampling at θ_p on
the most expensive-to-scan table, aggregates grouped by physical block), run
it, and turn the pilot block statistics into per-channel probabilistic bounds
(L_μ, U_V[Θ]) via BSAP.  Stage 2: solve the sampling-plan optimization, rewrite
Q_in into Q_final with the winning plan, execute, and Horvitz–Thompson-combine
the channels into user-facing estimates.  Any failure (too-few pilot blocks,
non-positive L_μ, no feasible plan, plan costlier than exact) falls back to
exact execution — PilotDB never returns an unguaranteed estimate.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import bsap, propagation
from repro.core.allocation import ChannelBudget, allocate
from repro.core.planner import Constraint, pick_plan, solve_candidates
from repro.core.spec import CompositeAgg, ErrorSpec, SamplingPlan
from repro.engine import cost as cost_mod
from repro.engine import logical as L
from repro.engine.executor import (EmptySampleError, Executor, PilotStats,
                                   QueryResult)
from repro.engine.physical import ScanRuntime
from repro.engine.sampling import draw_block_ids, pad_block_ids
from repro.stats import chi2_ppf, normal_ppf, student_t_ppf


@dataclasses.dataclass(frozen=True)
class Query:
    """User query: relational child plan + composite aggregates (§2.3)."""

    child: L.Plan
    aggs: Tuple[CompositeAgg, ...]
    group_by: Optional[str] = None
    max_groups: int = 1


@dataclasses.dataclass
class TaqaReport:
    pilot_table: Optional[str] = None
    theta_pilot: float = 0.0
    n_pilot_blocks: int = 0
    plan: Optional[SamplingPlan] = None
    fallback: Optional[str] = None        # reason, if exact execution was used
    num_channels: int = 0
    exact_cost: float = 0.0
    pilot_time_s: float = 0.0
    plan_time_s: float = 0.0
    final_time_s: float = 0.0
    pilot_scanned_bytes: int = 0
    final_scanned_bytes: int = 0
    exact_scanned_bytes: int = 0
    candidates: int = 0
    group_coverage_guaranteed: bool = True
    # True when a pilot stage actually executed for this query (False for
    # pre-pilot fallbacks: no large table, strict-coverage violation).
    pilot_ran: bool = False
    # True when this answer reused another structurally identical query's
    # pilot statistics (the runtime's one-pilot-per-group fan-out); the
    # pilot_* fields then describe that shared pilot stage.
    pilot_shared: bool = False


@dataclasses.dataclass
class ApproxAnswer:
    names: List[str]
    values: np.ndarray          # (num_composites, max_groups)
    group_present: np.ndarray   # (max_groups,)
    report: TaqaReport

    def scalar(self, name: str, group: int = 0) -> float:
        return float(self.values[self.names.index(name), group])


def _decompose(aggs: Tuple[CompositeAgg, ...]) -> Tuple[List[L.AggSpec], List[Tuple[int, ...]]]:
    """Composite aggregates -> simple engine channels (§3.3 pilot step 3)."""
    specs: List[L.AggSpec] = []
    comp_channels: List[Tuple[int, ...]] = []
    for comp in aggs:
        idxs = []
        if comp.kind == "sum":
            specs.append(L.AggSpec("sum", comp.expr, f"ch{len(specs)}"))
            idxs.append(len(specs) - 1)
        elif comp.kind == "count":
            specs.append(L.AggSpec("count", None, f"ch{len(specs)}"))
            idxs.append(len(specs) - 1)
        elif comp.kind == "avg":
            specs.append(L.AggSpec("sum", comp.expr, f"ch{len(specs)}"))
            idxs.append(len(specs) - 1)
            specs.append(L.AggSpec("count", None, f"ch{len(specs)}"))
            idxs.append(len(specs) - 1)
        elif comp.kind in ("ratio", "product", "add"):
            specs.append(L.AggSpec("sum", comp.expr, f"ch{len(specs)}"))
            idxs.append(len(specs) - 1)
            specs.append(L.AggSpec("sum", comp.expr2, f"ch{len(specs)}"))
            idxs.append(len(specs) - 1)
        else:
            raise ValueError(comp.kind)
        comp_channels.append(tuple(idxs))
    return specs, comp_channels


def build_engine_plan(q: Query) -> Tuple[L.Aggregate, List[Tuple[int, ...]]]:
    """Lower a user query to the engine plan: composites decomposed into
    simple channels under one terminal Aggregate (§3.3 pilot step 3)."""
    specs, comp_channels = _decompose(q.aggs)
    plan = L.Aggregate(child=q.child, aggs=tuple(specs),
                       group_by=q.group_by, max_groups=q.max_groups)
    return plan, comp_channels


def structural_signature(q: Query) -> L.Aggregate:
    """Hashable structural identity of a query's physical shape, predicate
    constants INCLUDED.

    Two queries with equal signatures lower to the same engine plan modulo
    TABLESAMPLE clauses.  This constant-bearing key is what pilot *sharing*
    and pilot-seed derivation must use: pilot block statistics depend on
    predicate selectivity, so sharing a pilot across different constants
    would silently break the §4 error guarantees even though the queries
    compile to one executable.
    """
    plan, _ = build_engine_plan(q)
    return L.strip_samples(plan)


def template_signature(q: Query) -> L.Plan:
    """The constant-STRIPPED structural signature (the compile-cache key
    modulo shapes): :func:`structural_signature` with every predicate/
    expression constant hoisted into a Param slot.

    Queries agreeing on this template share every executable the physical
    layer compiles — constants enter at runtime as the params operand — so
    the scheduler groups submissions by it: a herd of dashboard queries
    differing only in a WHERE constant drains as ONE group, compiles at most
    once, and its finals can launch as one batched dispatch.  (Pilot sharing
    inside the group still sub-keys on the full constant-bearing
    signature — see :func:`structural_signature`.)
    """
    from repro.engine.physical import plan_template  # memoized extraction
    return plan_template(structural_signature(q))


def pilot_params(spec: ErrorSpec) -> Tuple:
    """The ErrorSpec fields that shape the *pilot* stage (and nothing else).

    theta_p and the retry loop depend only on these — never on the error /
    confidence targets, which enter at stage 2.  Two queries with equal
    structural signatures and equal pilot params run byte-identical pilots,
    which is the sharing key ``repro.runtime.shared_pilot`` groups by.
    """
    return (spec.theta_pilot, spec.min_pilot_blocks, spec.max_pilot_rate,
            spec.group_min_size, spec.group_miss_prob,
            spec.strict_group_coverage)


@dataclasses.dataclass
class FinalStage:
    """One query's stage 2, planned but (possibly) not yet executed.

    :meth:`PilotDB.prepare_final` runs the planning half — constraints,
    sampling-plan optimization, the final-plan rewrite — and returns this.
    When planning short-circuits (pilot fallback, infeasible constraints, no
    plan cheaper than exact), ``answer`` is already set; otherwise
    ``final_plan`` awaits execution via :meth:`PilotDB.run_final` (solo) or
    :meth:`PilotDB.run_finals_batched` (one stacked dispatch per drain-group
    bucket).  Splitting planning from execution is what lets the runtime
    batch N members' final scans into a single launch.
    """

    q: Query
    spec: ErrorSpec
    plan: "L.Aggregate"
    comp_channels: List[Tuple[int, ...]]
    report: TaqaReport
    final_plan: Optional["L.Aggregate"] = None
    answer: Optional[ApproxAnswer] = None


@dataclasses.dataclass(frozen=True)
class PilotEstimate:
    """An ADVISORY pilot-stage estimate of every user-facing aggregate.

    This is what progressive streaming shows while the guarantee converges
    (:mod:`repro.stream`) and what the result cache records so cached
    re-issues can replay a provisional frame: Hájek point estimates per
    group plus provisional CI half-widths — compact (two
    ``(num_aggs, max_groups)`` arrays), never the per-block matrix.

    The interval is the pilot sample's t-interval propagated through the
    Table-2 composite rules (:mod:`repro.core.propagation`); it carries NO
    a-priori guarantee — only the final answer's §4 report does.
    """

    names: Tuple[str, ...]
    values: np.ndarray          # (num_aggs, max_groups) float64
    half_widths: np.ndarray     # absolute CI half-widths, same shape
    group_present: np.ndarray   # (max_groups,) bool — groups seen by the pilot
    confidence: float
    theta_pilot: float
    n_pilot_blocks: int

    def nbytes(self) -> int:
        """Byte footprint for result-cache accounting."""
        return (self.values.nbytes + self.half_widths.nbytes
                + self.group_present.nbytes
                + sum(len(n) for n in self.names))

    def scalar(self, name: str, group: int = 0) -> float:
        return float(self.values[self.names.index(name), group])

    def half_width(self, name: str, group: int = 0) -> float:
        return float(self.half_widths[self.names.index(name), group])


def advisory_estimate(q: Query, outcome: "PilotOutcome",
                      confidence: float) -> Optional[PilotEstimate]:
    """Construct the advisory estimate a pilot outcome already paid for.

    Point estimates are the Hájek totals ``N·ȳ_p`` per simple channel,
    combined into composites by the same rules as the final answer
    (:func:`_combine`).  Half-widths are two-sided t-intervals on each
    channel total, propagated to composites through the Table-2 relative-
    error rules (:mod:`repro.core.propagation`): division/avg
    ``(e1+e2)/(1−max)``, product ``e1+e2+e1·e2``, addition ``max(e1,e2)``
    — ``inf`` wherever a channel cannot be bounded (zero estimate, or a
    propagated relative error ≥ 1).

    Returns None when no advisory estimate exists: the pilot never ran,
    sampled fewer than 2 blocks, or stage 1 already decided on the exact
    fallback (the terminal frame will be exact — a provisional estimate
    would only mislead).
    """
    from repro.stats import student_t_ppf
    pilot = outcome.pilot
    if pilot is None or outcome.fallback is not None:
        return None
    bs = np.asarray(pilot.block_sums, dtype=np.float64)
    n_p = bs.shape[0]
    if n_p < 2:
        return None
    N = float(pilot.n_total_blocks)
    # channel totals and t-interval half-widths: (channels, max_groups)
    ch_vals = (N * bs.mean(axis=0)).T
    delta = min(max((1.0 - confidence) / 2.0, 1e-12), 0.5)
    t_q = student_t_ppf(1.0 - delta, n_p - 1)
    ch_hw = (N * t_q / np.sqrt(n_p) * bs.std(axis=0, ddof=1)).T
    values = _combine(q, outcome.comp_channels, ch_vals)
    with np.errstate(divide="ignore", invalid="ignore"):
        rel = np.where(ch_vals != 0.0,
                       np.abs(ch_hw / np.where(ch_vals == 0.0, 1.0, ch_vals)),
                       np.inf)
        half = np.full_like(values, np.inf)
        for k, (comp, idxs) in enumerate(zip(q.aggs, outcome.comp_channels)):
            if comp.num_channels == 1:
                half[k] = np.abs(ch_hw[idxs[0]])
                continue
            e1, e2 = rel[idxs[0]], rel[idxs[1]]
            if comp.kind in ("avg", "ratio"):
                m = np.maximum(e1, e2)
                e = np.where(m < 1.0, (e1 + e2) / np.maximum(1.0 - m, 1e-300),
                             np.inf)
            elif comp.kind == "product":
                e = e1 + e2 + e1 * e2
            else:  # "add"
                e = np.maximum(e1, e2)
            half[k] = e * np.abs(values[k])
    return PilotEstimate(
        names=tuple(c.name for c in q.aggs), values=values, half_widths=half,
        group_present=np.asarray(pilot.group_present, dtype=bool),
        confidence=float(confidence), theta_pilot=float(outcome.theta_p),
        n_pilot_blocks=int(pilot.n_sampled_blocks))


@dataclasses.dataclass
class PilotOutcome:
    """Everything stage 1 produces, reusable across same-signature queries.

    ``fallback`` records a pilot-stage reason to execute exactly (no large
    table, pilot too small, no groups, strict-coverage violation); each
    query finishing from this outcome then takes its own exact path.  The
    ``report`` is a template — :meth:`PilotDB.finish_from_pilot` copies it
    per query before filling stage-2 fields.
    """

    plan: "L.Aggregate"
    comp_channels: List[Tuple[int, ...]]
    report: TaqaReport
    pilot: Optional[PilotStats] = None
    pilot_table: Optional[str] = None
    pair_tables: Tuple[str, ...] = ()
    theta_p: float = 0.0
    fallback: Optional[str] = None


class PilotDB:
    """The middleware.  `query()` is the user entry point (Fig. 2 workflow).

    This is the internal representation's driver; the public front door is
    :class:`repro.api.Session`, which owns an instance of this class per
    session and derives per-query seeds from the session PRNG.
    """

    def __init__(self, executor: Executor, large_table_rows: int = 50_000):
        self.ex = executor
        self.large_table_rows = large_table_rows

    # -- helpers -------------------------------------------------------------
    def _engine_plan(self, q: Query) -> Tuple[L.Aggregate, List[Tuple[int, ...]]]:
        return build_engine_plan(q)

    def _large_tables(self, plan: L.Aggregate) -> List[str]:
        seen: Dict[str, None] = {}
        for s in plan.scans():
            if self.ex.table_rows(s.table) >= self.large_table_rows:
                seen.setdefault(s.table, None)
        return sorted(seen, key=lambda t: -self.ex.table_bytes(t))

    def _exact(self, q: Query, plan: L.Aggregate, comp_channels, report: TaqaReport,
               reason: str) -> ApproxAnswer:
        report.fallback = reason
        t0 = time.perf_counter()
        res = self.ex.execute(L.strip_samples(plan))
        report.final_time_s = time.perf_counter() - t0
        report.final_scanned_bytes = res.scanned_bytes
        values = _combine(q, comp_channels, res.values)
        return ApproxAnswer([c.name for c in q.aggs], values, res.group_present, report)

    # -- the two-stage algorithm ----------------------------------------------
    def query(self, q: Query, spec: ErrorSpec, seed: int = 0,
              pilot_seed: Optional[int] = None) -> ApproxAnswer:
        """Full TAQA: pilot stage then final stage.

        ``seed`` drives the *final* sampled scan; ``pilot_seed`` (defaulting
        to ``seed``) drives the pilot sample.  Callers that share one pilot
        across structurally identical queries (``repro.runtime``) derive
        ``pilot_seed`` from the plan signature so a query answered from a
        shared pilot is bit-identical to the same query run solo.
        """
        outcome = self.run_pilot(
            q, spec, seed if pilot_seed is None else pilot_seed)
        return self.finish_from_pilot(q, spec, outcome, seed)

    def run_pilot(self, q: Query, spec: ErrorSpec,
                  pilot_seed: int) -> "PilotOutcome":
        """Stage 1: rewrite to Q_pilot, run it, collect per-block statistics.

        The returned :class:`PilotOutcome` is spec-dependent only through the
        pilot-stage tunables (theta_pilot / min_pilot_blocks / max_pilot_rate
        / group coverage) — see :func:`pilot_params`.  Queries agreeing on
        those fields and on the sampling-stripped plan signature can share
        one outcome and finish independently via :meth:`finish_from_pilot`.
        """
        outcome, theta_p = self._pilot_prelude(q, spec)
        if outcome.fallback is not None:
            return outcome
        return self._pilot_scan(outcome, spec, theta_p, pilot_seed)

    def _pilot_prelude(self, q: Query,
                       spec: ErrorSpec) -> Tuple["PilotOutcome", float]:
        """Everything stage 1 decides BEFORE any device work: cost model,
        pilot-table election, theta_p, group-coverage checks, pair tables.
        Pure host computation with no counters — a prelude-level fallback
        (no large table, strict coverage violated) never counts as a pilot
        stage, matching the pre-refactor ``run_pilot``."""
        plan, comp_channels = self._engine_plan(q)
        report = TaqaReport()
        report.exact_cost = cost_mod.exact_cost(plan, self.ex.catalog)
        # bytes accounting: full row bytes of every scanned table, matching
        # the samplers' scanned_bytes semantics (row-store physical reads)
        report.exact_scanned_bytes = sum(
            self.ex.table_bytes(s.table) for s in plan.scans())
        outcome = PilotOutcome(plan=plan, comp_channels=comp_channels,
                               report=report)

        large = self._large_tables(plan)
        if not large:
            outcome.fallback = "no large table to sample"
            return outcome, 0.0
        pilot_table = large[0]
        report.pilot_table = pilot_table
        outcome.pilot_table = pilot_table

        n_blocks = self.ex.table_blocks(pilot_table)
        block_rows = self.ex.block_rows(pilot_table)
        # 1.5x margin over the minimum pilot size: Bernoulli undershoot
        # would otherwise force a re-pilot at 4x the rate (latency spike)
        theta_p = max(spec.theta_pilot,
                      min(1.0, 1.5 * spec.min_pilot_blocks / n_blocks))
        if q.group_by is not None:
            theta_cov = bsap.group_coverage_rate(
                n_blocks, block_rows, spec.group_min_size, spec.group_miss_prob)
            if theta_cov > spec.max_pilot_rate:
                if spec.strict_group_coverage:
                    outcome.fallback = (
                        f"group coverage for g={spec.group_min_size} needs "
                        f"theta_p={theta_cov:.3f} > pilot cap (strict mode)")
                    return outcome, theta_p
                report.group_coverage_guaranteed = False
                theta_p = max(theta_p, spec.max_pilot_rate)
            else:
                theta_p = max(theta_p, theta_cov)
        theta_p = min(theta_p, 1.0)

        pair_tables: Tuple[str, ...] = ()
        if q.group_by is None and len(large) > 1:
            pair_tables = (large[1],)
        outcome.pair_tables = pair_tables
        return outcome, theta_p

    def _pilot_scan(self, outcome: "PilotOutcome", spec: ErrorSpec,
                    theta_p: float, pilot_seed: int) -> "PilotOutcome":
        """The device half of stage 1: the pilot scan with its Bernoulli
        undershoot retries (one pilot STAGE however many retries), then the
        shared postlude."""
        plan, pilot_table = outcome.plan, outcome.pilot_table
        n_blocks = self.ex.table_blocks(pilot_table)
        pilot: Optional[PilotStats] = None
        # one pilot STAGE, however many undershoot retries it takes — the
        # counter the runtime's sharing tests and benchmarks assert against
        self.ex._count("pilots_run")
        t0 = time.perf_counter()
        for attempt in range(3):
            pilot = self.ex.execute_pilot(plan, pilot_table, theta_p,
                                          pilot_seed + 101 * attempt,
                                          pair_tables=outcome.pair_tables)
            if pilot.n_sampled_blocks >= min(spec.min_pilot_blocks, n_blocks):
                break
            theta_p = min(theta_p * 4.0, 1.0)
        return self._pilot_postlude(outcome, pilot, theta_p,
                                    time.perf_counter() - t0)

    def _pilot_postlude(self, outcome: "PilotOutcome", pilot: PilotStats,
                        theta_p: float, elapsed_s: float) -> "PilotOutcome":
        """Fill the report from one pilot stage's statistics and apply the
        too-small / no-groups fallbacks — shared by the solo loop, the
        batched-pilot path, and the fused program's host postlude."""
        report = outcome.report
        report.pilot_time_s = elapsed_s
        report.theta_pilot = theta_p
        report.n_pilot_blocks = pilot.n_sampled_blocks
        report.pilot_scanned_bytes = pilot.scanned_bytes
        report.pilot_ran = True
        outcome.pilot = pilot
        outcome.theta_p = theta_p
        if pilot.n_sampled_blocks < 2:
            outcome.fallback = "pilot sample too small"
            return outcome
        if len(np.nonzero(pilot.group_present)[0]) == 0:
            outcome.fallback = "no groups in pilot"
        return outcome

    def run_pilots_batched(self, reqs: List[Tuple[Query, ErrorSpec, int]]
                           ) -> List[object]:
        """Stage 1 for many independent pilot subgroups at once, stacking
        same-shape pilot scans into single device dispatches
        (``Executor.execute_pilots_batched``).

        ``reqs`` holds one ``(query, spec, pilot_seed)`` per subgroup
        leader; the returned list is position-aligned and each entry is the
        :class:`PilotOutcome` :meth:`run_pilot` would have produced — or
        the exception it would have raised (captured per member, so one
        failing subgroup cannot sink its siblings).

        Stacking eligibility mirrors the batched pilot lowering's envelope:
        compiled XLA route, no join-pair statistics, no staged ladder
        serving the pilot table, not sharded.  Undershoot retries are a
        pure host-RNG computation — the draw sizes are known before any
        device work — so eligible members arrive at the stacked dispatch
        with their final block ids and the retry loop costs zero launches.
        Ineligible members, singleton shapes, and any member whose stacked
        dispatch fails take the solo loop; either way the pilot seeds are
        content-derived, so the answers are bit-identical.
        """
        ex = self.ex
        results: List[object] = [None] * len(reqs)
        prel: List[Optional[Tuple[PilotOutcome, float]]] = [None] * len(reqs)
        solo: List[int] = []
        pend: Dict[tuple, List[tuple]] = {}
        for i, (q, spec, pseed) in enumerate(reqs):
            try:
                outcome, theta_p = self._pilot_prelude(q, spec)
            except Exception as e:  # noqa: BLE001 — per-member capture
                results[i] = e
                continue
            prel[i] = (outcome, theta_p)
            if outcome.fallback is not None:
                results[i] = outcome
                continue
            pt = outcome.pilot_table
            if (not ex.use_compiled or ex.physical._use_pallas()
                    or outcome.pair_tables
                    or ex.staged.ladder(pt) is not None
                    or ex.is_sharded(pt)):
                solo.append(i)
                continue
            # host-resolve the member's draw, undershoot retries included —
            # the exact seeds and x4 bumps of the solo loop
            n_blocks = ex.table_blocks(pt)
            need = min(spec.min_pilot_blocks, n_blocks)
            th, drawn_th = theta_p, theta_p
            ids = np.zeros(0, np.int64)
            for attempt in range(3):
                ids = draw_block_ids(n_blocks, th, pseed + 101 * attempt)
                drawn_th = th
                if len(ids) >= need:
                    break
                th = min(th * 4.0, 1.0)
            if len(ids) == 0:
                solo.append(i)  # solo path owns empty-draw bookkeeping
                continue
            phys, n_real, n_phys = pad_block_ids(ids, n_blocks)
            runtime = ScanRuntime("block", n_real, n_phys, phys)
            key = ex.physical.query_signature(outcome.plan, {pt: runtime})
            pend.setdefault((pt, key), []).append((i, runtime, th, drawn_th))

        for (pt, _), members in pend.items():
            if len(members) < 2:
                solo.extend(m[0] for m in members)
                continue
            idxs = [m[0] for m in members]
            try:
                stats = ex.execute_pilots_batched(
                    [prel[i][0].plan for i in idxs], pt,
                    [m[3] for m in members],
                    [{pt: m[1]} for m in members])
            except Exception:
                # stacking is an optimization, never a failure mode: these
                # members re-run solo, bit-identical by seed derivation
                solo.extend(idxs)
                continue
            for (i, _, th, _), st in zip(members, stats):
                ex._count("pilots_run")
                results[i] = self._pilot_postlude(prel[i][0], st, th,
                                                  st.wall_time_s)

        for i in solo:
            _, spec, pseed = reqs[i]
            outcome, theta_p = prel[i]
            try:
                results[i] = self._pilot_scan(outcome, spec, theta_p, pseed)
            except Exception as e:  # noqa: BLE001 — per-member capture
                results[i] = e
        return results

    def run_fused(self, q: Query, spec: ErrorSpec, seed: int = 0,
                  pilot_seed: Optional[int] = None) -> Optional[ApproxAnswer]:
        """Single-launch TAQA: pilot scan, BSAP rate solve, and the final
        sampled aggregation as ONE device program with no host sync between
        the stages (``physical.compile_fused``).

        Returns None when the query is outside the fused envelope — eager
        executor, Pallas kernel mode, grouped queries, join-pair sampling,
        a sharded pilot table, no (or more than one) large table, or a
        pilot draw too small to bound — and the caller runs the ordinary
        two-stage path, which is the semantic and bitwise oracle.

        Bit-identity is by construction, not hope: the device solve is an
        ADVISORY f32 twin; the pilot block statistics come back from the
        same launch and feed the SAME f64 ``prepare_final`` as the
        two-stage path, and the device's final block draw is verified
        against the host RNG (same content-derived uniforms) before its
        sums are trusted.  Any disagreement — e.g. f32 rounding of the
        solved rate flipping a Bernoulli comparison — discards the fused
        final sums and re-runs stage 2 solo.
        """
        ex = self.ex
        if not ex.use_compiled or ex.physical._use_pallas():
            return None
        if q.group_by is not None or q.max_groups != 1:
            return None
        outcome, theta_p = self._pilot_prelude(q, spec)
        if outcome.fallback is not None or outcome.pair_tables:
            return None
        pilot_table = outcome.pilot_table
        if ex.is_sharded(pilot_table):
            return None
        plan, report = outcome.plan, outcome.report
        psd = seed if pilot_seed is None else pilot_seed
        n_blocks = ex.table_blocks(pilot_table)

        # Host-resolved pilot draw, undershoot retries included: draw sizes
        # are pure host RNG, so the retry loop costs zero launches.  Seeds,
        # the x4 bump (applied even past a failed last attempt), and the
        # staged-seed pinning replicate the two-stage loop exactly.
        need = min(spec.min_pilot_blocks, n_blocks)
        ids = np.zeros(0, np.int64)
        theta_drawn = theta_p
        for attempt in range(3):
            eff = ex.staged.seed_for(pilot_table, psd + 101 * attempt)
            ids = draw_block_ids(n_blocks, theta_p, eff)
            theta_drawn = theta_p
            if len(ids) >= need:
                break
            theta_p = min(theta_p * 4.0, 1.0)
        if len(ids) < 2:
            return None  # two-stage takes its "pilot sample too small" path

        phys, n_real, n_phys = pad_block_ids(ids, n_blocks)
        runtimes = {pilot_table: ScanRuntime("block", n_real, n_phys, phys)}
        for s in plan.scans():
            if s.table != pilot_table:
                runtimes.setdefault(s.table, ScanRuntime("none"))

        # Per-channel quantile rows for the on-device solve: the exact
        # constants prepare_final's f64 solve will use (one group, g=0).
        n_constraints = sum(len(idxs) for idxs in outcome.comp_channels)
        solve_rows: List[List[float]] = []
        solve_channels: List[int] = []
        for comp, idxs in zip(q.aggs, outcome.comp_channels):
            e_part = propagation.split_budget(comp.kind, spec.error)
            for ch in idxs:
                budget = allocate(spec.confidence, n_constraints, e_part)
                solve_rows.append([
                    student_t_ppf(1.0 - budget.delta1, n_real - 1),
                    chi2_ppf(budget.delta2 / 2.0, n_real - 1),
                    bsap.z_for(budget.p_prime),
                    normal_ppf(1.0 - budget.delta2 / 2.0),
                    budget.error,
                ])
                solve_channels.append(ch)

        # plan_cost is linear in the single table's rate: two probes give
        # the device its whole cost line
        cost_b = cost_mod.plan_cost(plan, ex.catalog, {pilot_table: 0.0})
        cost_a = cost_mod.plan_cost(plan, ex.catalog,
                                    {pilot_table: 1.0}) - cost_b
        scal = [float(n_blocks), float(spec.max_final_rate), 1e-6,
                cost_a, cost_b, report.exact_cost]
        fseed = ex.staged.seed_for(pilot_table, seed + 977)
        u = np.random.default_rng(fseed).random(n_blocks)

        ex._count("pilots_run")
        t0 = time.perf_counter()
        out, compiled = ex.execute_fused(
            plan, pilot_table, runtimes, np.asarray(solve_rows, np.float64),
            np.asarray(scal, np.float64), u, tuple(solve_channels))
        launch_wall = time.perf_counter() - t0

        names = [a.name for a in plan.aggs] + ["__rows"]
        pilot = PilotStats(
            table=pilot_table, theta_p=theta_drawn, n_sampled_blocks=n_real,
            n_total_blocks=n_blocks, block_rows=ex.block_rows(pilot_table),
            agg_names=names, block_sums=out["block_sums"][:n_real],
            group_present=out["present"], pair_sums={},
            right_total_blocks={},
            scanned_bytes=compiled.scanned_bytes(runtimes),
            wall_time_s=launch_wall)
        self._pilot_postlude(outcome, pilot, theta_p, launch_wall)

        # Authoritative f64 re-solve: the same stage-2 code path as
        # two-stage, fed the same (device-computed) pilot statistics.
        stage = self.prepare_final(q, spec, outcome, seed)
        if stage.answer is not None:
            # exact fallback (no groups, infeasible bounds, plan costlier
            # than exact): prepare_final already executed it, identically
            # to the two-stage path — the fused final sums are discarded
            return stage.answer
        rate = stage.report.plan.rates.get(pilot_table, 1.0)
        host_ids = draw_block_ids(n_blocks, rate, fseed) if rate < 1.0 \
            else np.zeros(0, np.int64)
        nsel = out["nsel"]
        if (rate >= 1.0 or nsel < 1 or len(host_ids) != nsel
                or not np.array_equal(out["padded"][:nsel], host_ids)):
            # the device draw disagrees with the f64 plan (or the final is
            # unsampled): run stage 2 solo — bit-identical, one extra launch
            return self.run_final(stage)

        # The device's final draw IS the host draw: compose the answer from
        # the fused launch's sums exactly as the solo final dispatch would.
        t1 = time.perf_counter()
        ex._count("queries_run")
        runtimes_f, infos = ex._scan_runtimes(stage.final_plan)
        sums, counts = out["sums"], out["counts"]
        values = Executor._compose_values(stage.final_plan, sums, counts,
                                          Executor._upscale(infos))
        res = QueryResult(
            agg_names=[a.name for a in stage.final_plan.aggs],
            values=values, raw_sums=sums, group_counts=counts,
            group_present=counts > 0,
            scanned_bytes=compiled.scanned_bytes(runtimes_f),
            sample_infos=infos, wall_time_s=launch_wall)
        return self._finish_result(stage, res, time.perf_counter() - t1)

    def finish_from_pilot(self, q: Query, spec: ErrorSpec,
                          outcome: "PilotOutcome", seed: int,
                          shared: bool = False) -> ApproxAnswer:
        """Stage 2 for one query, from a (possibly shared) pilot outcome.

        Builds this query's own probabilistic constraints from ``spec``,
        solves the sampling-plan optimization, and runs the final query with
        this query's ``seed`` — so two queries finishing from the same pilot
        still draw their final samples independently.  ``shared=True`` marks
        the report as having reused another query's pilot stage.

        This is ``prepare_final`` + ``run_final``; the runtime calls the two
        halves separately so same-bucket finals batch into one dispatch.
        """
        return self.run_final(self.prepare_final(q, spec, outcome, seed,
                                                 shared=shared))

    def prepare_final(self, q: Query, spec: ErrorSpec,
                      outcome: "PilotOutcome", seed: int,
                      shared: bool = False) -> FinalStage:
        """The planning half of stage 2: constraints, plan optimization, and
        the final-plan rewrite — everything except the final scan itself."""
        plan, comp_channels = outcome.plan, outcome.comp_channels
        # per-query copy: members finishing from one shared outcome must not
        # see each other's plan/final timings or fallback reasons
        report = dataclasses.replace(outcome.report)
        report.pilot_shared = shared
        stage = FinalStage(q=q, spec=spec, plan=plan,
                           comp_channels=comp_channels, report=report)
        if outcome.fallback is not None:
            stage.answer = self._exact(q, plan, comp_channels, report,
                                       outcome.fallback)
            return stage
        pilot = outcome.pilot
        pilot_table = outcome.pilot_table
        pair_tables = outcome.pair_tables
        theta_p = outcome.theta_p

        # --- budgets & constraints -------------------------------------------
        t0 = time.perf_counter()
        present = np.nonzero(pilot.group_present)[0]

        channel_budgets: List[Tuple[int, ChannelBudget]] = []
        n_constraints = 0
        for comp, idxs in zip(q.aggs, comp_channels):
            n_constraints += len(idxs) * len(present)
        report.num_channels = n_constraints

        constraints: List[Constraint] = []
        infeasible_reason = None
        for comp, idxs in zip(q.aggs, comp_channels):
            e_part = propagation.split_budget(comp.kind, spec.error)
            for ch in idxs:
                budget = allocate(spec.confidence, n_constraints, e_part)
                for g in present:
                    y = pilot.block_sums[:, g, ch]
                    # L_μ of the population total: N · (block-mean lower bound)
                    L_mu = pilot.n_total_blocks * bsap.block_mean_lower(y, budget.delta1)
                    if not np.isfinite(L_mu) or L_mu <= 0.0:
                        infeasible_reason = (
                            f"non-positive aggregate lower bound (agg={comp.name}, group={g})")
                        break
                    z = bsap.z_for(budget.p_prime)
                    var_fn = self._make_var_fn(pilot, pilot_table, pair_tables,
                                               ch, g, theta_p, budget.delta2)
                    constraints.append(Constraint(
                        label=f"{comp.name}[g{g}]ch{ch}", z=z, L_mu=L_mu,
                        error=budget.error, var_fn=var_fn))
                if infeasible_reason:
                    break
            if infeasible_reason:
                break
        if infeasible_reason:
            report.plan_time_s = time.perf_counter() - t0
            stage.answer = self._exact(q, plan, comp_channels, report,
                                       infeasible_reason)
            return stage

        # --- Stage 2: plan optimization ----------------------------------------
        sampleable = [pilot_table] + [t for t in pair_tables]
        candidates = solve_candidates(constraints, sampleable,
                                      max_rate=spec.max_final_rate)
        report.candidates = len(candidates)
        chosen = pick_plan(
            candidates,
            cost_fn=lambda rates: cost_mod.plan_cost(plan, self.ex.catalog, rates),
            exact_cost=report.exact_cost,
        )
        report.plan_time_s = time.perf_counter() - t0
        if chosen is None:
            stage.answer = self._exact(q, plan, comp_channels, report,
                                       "no feasible plan cheaper than exact")
            return stage
        report.plan = chosen

        # --- final-plan rewrite (execution is run_final's / the batch's) ------
        samples = {t: L.SampleClause("block", r, seed + 977)
                   for t, r in chosen.rates.items() if r < 1.0}
        stage.final_plan = L.rewrite_scans(plan, samples)
        return stage

    def run_final(self, stage: FinalStage) -> ApproxAnswer:
        """The execution half of stage 2 for one query, solo."""
        if stage.answer is not None:
            return stage.answer
        t0 = time.perf_counter()
        try:
            res = self.ex.execute(stage.final_plan)
        except EmptySampleError as e:
            # The planner's rate drew zero blocks — no unbiased upscale
            # exists, so PilotDB's "never return an unguaranteed estimate"
            # contract forces the exact path (explicitly, not via a
            # fabricated scale).
            stage.report.final_time_s = time.perf_counter() - t0
            return self._exact(stage.q, stage.plan, stage.comp_channels,
                               stage.report, f"final sample empty ({e.table})")
        return self._finish_result(stage, res, time.perf_counter() - t0)

    def run_finals_batched(self, stages: List[FinalStage],
                           on_answer=None) -> None:
        """Execute many prepared finals, one stacked device dispatch per
        same-signature bucket (``Executor.execute_batch``), filling each
        stage's ``answer``.

        Lane k of a batch runs member k's solo XLA graph (``lax.map``), so
        answers are bit-identical to :meth:`run_final`; a member whose
        sampled scan comes back empty takes its own exact fallback, exactly
        as it would solo.

        Answers land PER BUCKET, not at batch end: ``on_answer(stage)`` (if
        given) fires the moment a stage's answer is filled — a streaming
        drain delivers each bucket's FinalFrames while later buckets are
        still dispatching.  Each member's ``final_time_s`` is the elapsed
        time until ITS bucket completed (the latency its client observed),
        not the whole batch's wall.  ``on_answer`` must capture its own
        exceptions; one that escapes is swallowed here (batching is an
        optimization, never a failure mode) and the member completes on the
        caller's serial completion path instead.
        """
        pend = [s for s in stages if s.answer is None]
        if not pend:
            return
        t0 = time.perf_counter()

        def _land(i: int, res) -> None:
            stage = pend[i]
            elapsed = time.perf_counter() - t0
            if isinstance(res, EmptySampleError):
                stage.report.final_time_s = elapsed
                stage.answer = self._exact(
                    stage.q, stage.plan, stage.comp_channels, stage.report,
                    f"final sample empty ({res.table})")
            else:
                stage.answer = self._finish_result(stage, res, elapsed)
            if on_answer is not None:
                try:
                    on_answer(stage)
                except Exception:
                    pass  # the caller's completion loop still owns delivery

        self.ex.execute_batch([s.final_plan for s in pend], on_result=_land)

    def _finish_result(self, stage: FinalStage, res,
                       elapsed_s: float) -> ApproxAnswer:
        stage.report.final_time_s = elapsed_s
        stage.report.final_scanned_bytes = res.scanned_bytes
        values = _combine(stage.q, stage.comp_channels, res.values)
        return ApproxAnswer([c.name for c in stage.q.aggs], values,
                            res.group_present, stage.report)

    # -- variance-bound factory ------------------------------------------------
    def _make_var_fn(self, pilot: PilotStats, pilot_table: str,
                     pair_tables: Tuple[str, ...], ch: int, g: int,
                     theta_p: float, delta2: float):
        y = pilot.block_sums[:, g, ch]
        if pair_tables and pair_tables[0] in pilot.pair_sums:
            other = pair_tables[0]
            uv2 = bsap.join_var_ub(pilot.pair_sums[other][:, :, ch],
                                   pilot.n_total_blocks, delta2)
            uv1 = bsap.single_table_var_ub(y, theta_p, delta2,
                                           n_blocks=pilot.n_total_blocks)

            def var_fn(rates: Dict[str, float]) -> float:
                t1 = rates.get(pilot_table, 1.0)
                t2 = rates.get(other, 1.0)
                if t2 >= 1.0:
                    return uv1(t1) if t1 < 1.0 else 0.0
                return uv2(t1, t2)

            return var_fn

        uv1 = bsap.single_table_var_ub(y, theta_p, delta2,
                                       n_blocks=pilot.n_total_blocks)

        def var_fn(rates: Dict[str, float]) -> float:
            t1 = rates.get(pilot_table, 1.0)
            return uv1(t1) if t1 < 1.0 else 0.0

        return var_fn

    # -- ground truth -----------------------------------------------------------
    def exact(self, q: Query) -> ApproxAnswer:
        plan, comp_channels = self._engine_plan(q)
        report = TaqaReport()
        return self._exact(q, plan, comp_channels, report, "requested exact")


def _combine(q: Query, comp_channels, channel_values: np.ndarray) -> np.ndarray:
    """Combine simple-channel estimates into composite values per group."""
    n_groups = channel_values.shape[1]
    out = np.zeros((len(q.aggs), n_groups))
    for k, (comp, idxs) in enumerate(zip(q.aggs, comp_channels)):
        if comp.num_channels == 1:
            out[k] = channel_values[idxs[0]]
        else:
            v1, v2 = channel_values[idxs[0]], channel_values[idxs[1]]
            with np.errstate(invalid="ignore", divide="ignore"):
                if comp.kind in ("avg", "ratio"):
                    out[k] = np.where(v2 != 0, v1 / np.where(v2 == 0, 1, v2), np.nan)
                elif comp.kind == "product":
                    out[k] = v1 * v2
                elif comp.kind == "add":
                    out[k] = comp.weights[0] * v1 + comp.weights[1] * v2
    return out
