"""BSAP — Block SAmpling with a Priori guarantees (§4, Appendix B).

Everything here consumes only *per-block* (or per-block-pair) pilot sums:
that is the whole point of the sampling-equivalence rules (Props. 4.4–4.6 /
Eq. 8) — after normalization, any supported query's estimator statistics are
functions of block-level aggregate contributions of the sampled base tables.

Estimator conventions (must match repro.engine.executor's upscaling):

* single sampled table — Hájek total μ̂ = N·ȳ_S; conditional-on-n SRS
  analysis (Lemma B.1 at block granularity: chi² bound on σ_b², binomial
  bound on n).  This is the paper's Lemma B.1 pipeline and avoids the
  sample-size noise that dominates the plain HT total under Bernoulli
  sampling (cf. §5.5's fixed-size comparison).
* two sampled tables — Horvitz–Thompson μ̂ = (1/(θ1θ2))ΣΣ J, whose exact
  variance expansion is Lemma 4.8's three-term form.
"""

from __future__ import annotations

import math
from typing import Callable, Optional

import numpy as np

from repro.stats import (
    binomial_lower_bound,
    chi2_ppf,
    normal_ppf,
    population_lower_bound,
    student_t_ppf,
)

# ---------------------------------------------------------------------------
# Student-t bounds on population block sums (the U_y[δ] of Lemma 4.8)
# ---------------------------------------------------------------------------


def t_bound_sum(y: np.ndarray, n_total: int, delta: float, side: str) -> float:
    """Probabilistic bound of the population total Σ_{i=1..N} y_i from a
    Bernoulli pilot sample of blocks.

    The paper's Lemma 4.8 writes U_y[δ] = (1/θ_p)(Σ_pilot y + √n σ̂ t), whose
    spread term is the conditional-SRS one; the (1/θ_p)Σ scaling however adds
    Bernoulli sample-*size* noise (∝ μ_y²) that the spread does not cover, so
    the printed bound under-covers whenever |ȳ| ≫ σ̂(y) (we measured 83% at a
    nominal 95%).  Our catalog knows N exactly, so we use the Hájek form

      U_y[δ] = N·(ȳ_p + t_{1-δ,n_p-1}·σ̂(y)/√n_p)

    which is the same quantity conditioned on n_p — and the conditional
    analysis is exact for Bernoulli sampling (given its size, the sample is
    SRS).  Coverage is restored (validated in tests/test_bsap.py).
    """
    y = np.asarray(y, dtype=np.float64)
    n_p = y.shape[0]
    if n_p < 2:
        return math.inf if side == "upper" else -math.inf
    t = student_t_ppf(1.0 - delta, n_p - 1)
    spread = t * float(y.std(ddof=1)) / math.sqrt(n_p)
    if side == "upper":
        return n_total * (float(y.mean()) + spread)
    return n_total * (float(y.mean()) - spread)


def upper_sum(y, n_total, delta):
    return t_bound_sum(y, n_total, delta, "upper")


def lower_sum(y, n_total, delta):
    return t_bound_sum(y, n_total, delta, "lower")


# ---------------------------------------------------------------------------
# Single-table bounds (Lemma B.1 with blocks as the sampling unit)
# ---------------------------------------------------------------------------
#
# Estimator convention for single-table plans: the final query estimates the
# population TOTAL with the Hájek form  μ̂ = N · ȳ_S  (N exact from catalog
# metadata, ȳ_S the mean block contribution among the n sampled blocks).
# Conditioned on its size, a Bernoulli sample is a simple random sample, so
#   Var[μ̂ | n] = N² (1−θ) σ_b² / n,
# with σ_b² bounded by the chi-squared bound and n by the binomial bound —
# exactly the paper's Lemma B.1 pipeline, at block granularity.  This avoids
# the sample-size noise that dominates the plain HT total (1/θ)Σ and matches
# the paper's observation that Bernoulli costs only a few % versus fixed-size
# sampling (§5.5), not a constant factor.


def block_mean_lower(y: np.ndarray, delta1: float) -> float:
    """L of the population block mean:  ȳ_p − t_{1−δ1} σ̂_p/√n_p."""
    y = np.asarray(y, dtype=np.float64)
    n_p = y.shape[0]
    if n_p < 2:
        return -math.inf
    t = student_t_ppf(1.0 - delta1, n_p - 1)
    return float(y.mean()) - t * float(y.std(ddof=1)) / math.sqrt(n_p)


def single_table_var_ub(y: np.ndarray, theta_p: float, delta2: float,
                        n_blocks: Optional[int] = None) -> Callable[[float], float]:
    """U_V[θ]: variance bound of the total estimator N·ȳ_S (Lemma B.1).

    δ2 is split across the probabilistic bounds used: chi-squared (σ_b²),
    binomial (final sample size n), and — when N must itself be estimated
    from the pilot (``n_blocks=None``) — the population bound L_N.
    """
    y = np.asarray(y, dtype=np.float64)
    n_p = y.shape[0]
    if n_p < 2:
        return lambda theta: math.inf
    parts = 2.0 if n_blocks is not None else 3.0
    chi = chi2_ppf(delta2 / parts, n_p - 1)
    var_ub = (n_p - 1) / max(chi, 1e-12) * float(y.var(ddof=1))
    if n_blocks is not None:
        N = float(n_blocks)
    else:
        N = population_lower_bound(n_p, theta_p, delta2 / parts)

    def U_V(theta: float) -> float:
        if theta >= 1.0:
            return 0.0
        n_lb = binomial_lower_bound(N, theta, delta2 / parts)
        if n_lb <= 1.0:
            return math.inf
        return N * N * (1.0 - theta) * var_ub / n_lb

    return U_V


# ---------------------------------------------------------------------------
# Two-table join variance bound (Lemma 4.8)
# ---------------------------------------------------------------------------

def join_var_ub(pair: np.ndarray, n1_total: int,
                delta2: float) -> Callable[[float, float], float]:
    """U_V[Θ] for SUM over a join with block sampling on both tables.

    ``pair``: (n_p, N2) — J(t_{1,i}, t_{2,i2}) block-pair sums from a pilot
    that sampled T_1 (T_2 fully scanned, so its block sums are exact *given*
    the sampled T_1 blocks).  ``n1_total`` = N1, T_1's total block count.

    Lemma 4.8, with δ' = δ2/(N2+2):
      U_V[θ1,θ2] = (1-θ1)/θ1 · U_{y⁽¹⁾}[δ']
                 + (1-θ2)/θ2 · Σ_{i2} (U_{y⁽²⁾_{i2}}[δ'])²
                 + (1-θ1)(1-θ2)/(θ1 θ2) · U_{y⁽³⁾}[δ']
    (population sums over T_1 bounded with the Hájek t-form, see t_bound_sum).
    """
    pair = np.asarray(pair, dtype=np.float64)
    n_p, n2 = pair.shape
    dprime = delta2 / (n2 + 2.0)

    y1 = np.square(pair.sum(axis=1))          # (n_p,)
    y3 = np.square(pair).sum(axis=1)          # (n_p,)
    u_y1 = max(upper_sum(y1, n1_total, dprime), 0.0)
    u_y3 = max(upper_sum(y3, n1_total, dprime), 0.0)
    # Per-i2 column sums over ALL T1 blocks, bounded from the pilot.
    u_cols = np.zeros(n2)
    if n_p >= 2:
        t = student_t_ppf(1.0 - dprime, n_p - 1)
        col_mean = pair.mean(axis=0)
        col_std = pair.std(axis=0, ddof=1)
        u_cols = n1_total * (col_mean + t * col_std / math.sqrt(n_p))
    sum_u_cols_sq = float(np.square(np.maximum(u_cols, 0.0)).sum())

    def U_V(theta1: float, theta2: float) -> float:
        v = 0.0
        if theta1 < 1.0:
            v += (1.0 - theta1) / theta1 * u_y1
        if theta2 < 1.0:
            v += (1.0 - theta2) / theta2 * sum_u_cols_sq
        if theta1 < 1.0 and theta2 < 1.0:
            v += (1.0 - theta1) * (1.0 - theta2) / (theta1 * theta2) * u_y3
        return v

    return U_V


# ---------------------------------------------------------------------------
# Group coverage (Lemma 3.2)
# ---------------------------------------------------------------------------

def group_coverage_rate(num_blocks: int, block_rows: int, group_min_size: int,
                        miss_prob: float) -> float:
    """Minimum block-sampling rate θ such that every group of >= g rows
    survives with probability >= 1 - p_f (Lemma 3.2 / B.5)."""
    n0 = max(int(math.ceil(group_min_size / block_rows)), 1)
    if num_blocks <= n0:
        return 1.0
    inner = 1.0 - (1.0 - miss_prob) ** (n0 / num_blocks)
    theta = 1.0 - inner ** (1.0 / n0)
    return min(max(theta, 0.0), 1.0)


def group_miss_prob_ub(theta: float, num_blocks: int, block_rows: int,
                       group_min_size: int) -> float:
    """Inverse of Lemma 3.2: upper bound on P[miss any group of size >= g]."""
    n0 = max(int(math.ceil(group_min_size / block_rows)), 1)
    include_all = (1.0 - (1.0 - theta) ** n0) ** (num_blocks / n0)
    return 1.0 - include_all


# ---------------------------------------------------------------------------
# Statistical efficiency (Lemma 4.1)
# ---------------------------------------------------------------------------

def efficiency_ratio(values: np.ndarray, block_rows: int) -> float:
    """b · (1 − E[σ_j²]/Var[X]) — ratio of block-sample rows to row-sample
    rows needed for equal accuracy.  < 1 ⇒ block sampling needs FEWER rows."""
    values = np.asarray(values, dtype=np.float64)
    n = (len(values) // block_rows) * block_rows
    blocks = values[:n].reshape(-1, block_rows)
    within = blocks.var(axis=1, ddof=0).mean()
    total = values[:n].var(ddof=0)
    if total <= 0:
        return 0.0
    return block_rows * (1.0 - within / total)


# ---------------------------------------------------------------------------
# Row-level naive CLT machinery (Lemma B.1) — the Appendix-A.1 baseline that
# BSAP replaces, and the row-level path for PilotDB-R / Quickr ablations.
# ---------------------------------------------------------------------------

def naive_row_bounds(mean_p: float, var_p: float, n_p: int, theta_p: float,
                     delta1: float, delta2: float, exact_N: float | None = None):
    """Returns (L_mu_mean, U_V(theta)) treating pilot rows as i.i.d. (invalid
    under block sampling — that is the point of Fig. 16/17).

    L_mu is a lower bound of the population *mean*; U_V(theta) bounds the
    variance of the final sample mean with row rate theta (Lemma B.1).
    """
    if n_p < 2:
        return -math.inf, lambda theta: math.inf
    sd_p = math.sqrt(max(var_p, 0.0))
    t = student_t_ppf(1.0 - delta1, n_p - 1)
    L_mu = mean_p - t * sd_p / math.sqrt(n_p)

    chi = chi2_ppf(delta2 / 3.0, n_p - 1)
    var_ub = (n_p - 1) / max(chi, 1e-12) * max(var_p, 0.0)
    L_N = exact_N if exact_N is not None else population_lower_bound(
        n_p, theta_p, delta2 / 3.0)

    def U_V(theta: float) -> float:
        n_lb = binomial_lower_bound(L_N, theta, delta2 / 3.0)
        if n_lb <= 1:
            return math.inf
        return var_ub / n_lb

    return L_mu, U_V


# ---------------------------------------------------------------------------
# The per-aggregate constraint φ (§3.2) and the adjusted-confidence z value
# ---------------------------------------------------------------------------

def z_for(p_prime: float) -> float:
    p_prime = min(p_prime, 1.0 - 1e-12)
    return normal_ppf((1.0 + p_prime) / 2.0)


def phi_satisfied(z: float, U_V: float, L_mu: float, e: float) -> bool:
    """φ(Θ) ≡ z·sqrt(U_V[Θ])/L_μ <= e (Inequality 6)."""
    if L_mu <= 0.0 or not math.isfinite(U_V):
        return False
    return z * math.sqrt(max(U_V, 0.0)) / L_mu <= e
