"""Error propagation for composite aggregates (Table 2, Appendix B.3).

Upper bounds of the composite relative error given component errors e1, e2:

  product:  e1 + e2 + e1·e2
  division: (e1 + e2) / (1 − max(e1, e2))     [corrected — see below]
  addition: max(e1, e2)        (positive weights/components)

NOTE on division: the paper's Table 2 states (e1+e2)/(1+min(e1,e2)), but its
own Lemma B.3 derivation shows the two-sided interval
  −(e1+e2)/(1+e1) ≤ rel ≤ (e1+e2)/(1−e2),
whose worst absolute side is the RIGHT one; (e1+e2)/(1+min) takes the *left*
denominator and is violated when the denominator estimate errs low (found by
property-based testing: μ̂2 = μ2(1−e2) gives rel = (e1+e2)/(1−e2) > bound).
We use the valid bound (e1+e2)/(1−max(e1,e2)); both agree to O(e²), so
planned sampling rates change by ~e only.

TAQA splits a composite budget *evenly* across components (§3.1): the
component budget e' is the largest symmetric budget whose propagated bound
stays <= e.
"""

from __future__ import annotations

import math


def propagate_product(e1: float, e2: float) -> float:
    return e1 + e2 + e1 * e2


def propagate_division(e1: float, e2: float) -> float:
    m = max(e1, e2)
    if m >= 1.0:
        return math.inf
    return (e1 + e2) / (1.0 - m)


def propagate_addition(e1: float, e2: float) -> float:
    return max(e1, e2)


def split_budget(kind: str, e: float) -> float:
    """Even per-component budget e' such that propagate(e', e') <= e."""
    if kind in ("sum", "count"):
        return e
    if kind == "product":
        # e' + e' + e'^2 = e  =>  e' = sqrt(e+1) - 1  (§3.1)
        return math.sqrt(e + 1.0) - 1.0
    if kind in ("avg", "ratio"):
        # 2e'/(1-e') = e  =>  e' = e / (2 + e)   (corrected division rule)
        return e / (2.0 + e)
    if kind == "add":
        return e
    raise ValueError(kind)


def combine_estimates(kind: str, v1: float, v2: float | None,
                      weights=(1.0, 1.0)) -> float:
    if kind in ("sum", "count"):
        return v1
    if kind in ("avg", "ratio"):
        return v1 / v2 if v2 not in (0.0, None) else float("nan")
    if kind == "product":
        return v1 * v2
    if kind == "add":
        return weights[0] * v1 + weights[1] * v2
    raise ValueError(kind)
