# PilotDB's primary contribution: TAQA (two-stage online AQP, §3) + BSAP
# (block-sampling statistics with a priori guarantees, §4), implemented over
# the repro.engine columnar JAX substrate.
from repro.core.spec import CompositeAgg, ErrorSpec, SamplingPlan
from repro.core.taqa import (ApproxAnswer, PilotDB, Query, TaqaReport,
                             build_engine_plan, structural_signature)
from repro.core.quickr import RowSamplingAQP

__all__ = [
    "CompositeAgg",
    "ErrorSpec",
    "SamplingPlan",
    "ApproxAnswer",
    "PilotDB",
    "Query",
    "TaqaReport",
    "RowSamplingAQP",
    "build_engine_plan",
    "structural_signature",
]
