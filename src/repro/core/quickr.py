"""Quickr-style baseline (§5.4) and the PilotDB-R ablation (§5.5).

Quickr injects *row-level uniform* samplers into the plan at query time and
needs one full pass over the data (its own paper's stated property).  We model
it as: run the same two-stage pilot machinery, but with row-level Bernoulli
statistics (the units are rows, Lemma B.1) and a row-sampled final query whose
scan cost is the full input (blocks cannot be skipped).  `quickr_bsap` is the
§5.4 augmentation: the identical planner but with BSAP block statistics and a
block-sampled final query — the speedup between the two is the paper's
Fig. 12.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional

import numpy as np

from repro.core import bsap
from repro.core.allocation import allocate
from repro.core.spec import ErrorSpec
from repro.core.taqa import ApproxAnswer, PilotDB, Query, TaqaReport, _combine
from repro.engine import logical as L
from repro.engine.executor import EmptySampleError


@dataclasses.dataclass
class RowPilot:
    n_rows: int
    mean: dict      # (group, channel) -> sample mean
    var: dict       # (group, channel) -> sample variance


def _row_pilot_stats(pilot_block_sums: np.ndarray, pilot_sq_sums: np.ndarray,
                     pilot_counts: np.ndarray):
    """Row-level mean/variance per (group, channel) from block channels."""
    tot = pilot_block_sums.sum(axis=0)          # (groups, ch)
    tot_sq = pilot_sq_sums.sum(axis=0)
    n = pilot_counts.sum(axis=0)                # (groups,)
    mean = np.where(n[:, None] > 0, tot / np.maximum(n[:, None], 1), 0.0)
    var = np.where(n[:, None] > 1,
                   tot_sq / np.maximum(n[:, None], 1) - mean ** 2, 0.0)
    return mean, np.maximum(var, 0.0), n


class RowSamplingAQP(PilotDB):
    """PilotDB with BSAP swapped for row-level Bernoulli sampling (PilotDB-R).

    The planner uses Lemma B.1 directly (rows as units).  The final query uses
    TABLESAMPLE BERNOULLI — a full scan is paid.  This both (a) reproduces the
    Quickr cost profile and (b) is the PilotDB-R ablation row of Table 5.
    """

    def query(self, q: Query, spec: ErrorSpec, seed: int = 0) -> ApproxAnswer:
        plan, comp_channels = self._engine_plan(q)
        report = TaqaReport()
        from repro.engine import cost as cost_mod

        report.exact_cost = cost_mod.exact_cost(plan, self.ex.catalog)
        report.exact_scanned_bytes = int(report.exact_cost)
        large = self._large_tables(plan)
        if not large:
            return self._exact(q, plan, comp_channels, report, "no large table")
        table = large[0]
        report.pilot_table = table

        # Row-level pilot: row Bernoulli at a rate giving >= ~1000 rows.
        n_rows = self.ex.table_rows(table)
        theta_p = max(spec.theta_pilot, min(1.0, 1000.0 / n_rows))
        report.theta_pilot = theta_p
        t0 = time.perf_counter()
        pplan = L.rewrite_scans(plan, {table: L.SampleClause("row", theta_p, seed)})
        try:
            pres = self.ex.execute(pplan)
        except EmptySampleError:
            report.pilot_time_s = time.perf_counter() - t0
            return self._exact(q, plan, comp_channels, report, "pilot sample empty")
        # Re-run with squared exprs to get row-level variances.
        sq_aggs = []
        for a in plan.aggs:
            expr = None if a.op == "count" else a.expr
            sq_aggs.append(L.AggSpec("sum", expr * expr if expr is not None else None,
                                     a.name + "_sq") if expr is not None
                           else L.AggSpec("count", None, a.name + "_sq"))
        sq_plan = L.Aggregate(pplan.child, tuple(sq_aggs), plan.group_by, plan.max_groups)
        sqres = self.ex.execute(sq_plan)
        report.pilot_time_s = time.perf_counter() - t0
        report.pilot_scanned_bytes = pres.scanned_bytes + sqres.scanned_bytes

        counts = pres.group_counts
        # The row-level estimator is N_rows × (mean over ALL kept rows,
        # zeros included for rows failing predicates/other groups), so the
        # planning moments must also be over the full kept sample — using
        # qualifying-row moments only would ignore selectivity variance.
        n_kept = pres.sample_infos[table].n_sampled_rows or 0
        if n_kept < spec.min_pilot_blocks or counts.sum() < 2:
            return self._exact(q, plan, comp_channels, report, "pilot too small")
        report.n_pilot_blocks = int(n_kept)

        # Allocate budgets & find the minimal row rate satisfying Lemma B.1.
        t0 = time.perf_counter()
        present = np.nonzero(pres.group_present)[0]
        n_constraints = sum(len(ix) for ix in comp_channels) * max(len(present), 1)
        theta_needed = 0.0
        feasible = True
        from repro.core import propagation

        for comp, idxs in zip(q.aggs, comp_channels):
            e_part = propagation.split_budget(comp.kind, spec.error)
            for ch in idxs:
                budget = allocate(spec.confidence, n_constraints, e_part)
                for g in present:
                    if counts[g] < 2:
                        feasible = False
                        break
                    # Full-population per-row moments: zeros for rows outside
                    # the predicate/group are part of the population.
                    mean = pres.raw_sums[ch, g] / n_kept
                    mean_sq = sqres.raw_sums[ch, g] / n_kept
                    var = max(mean_sq - mean ** 2, 0.0)
                    L_mu, U_V = bsap.naive_row_bounds(
                        mean, var, int(n_kept), theta_p, budget.delta1, budget.delta2,
                        exact_N=float(n_rows))
                    if L_mu <= 0:
                        feasible = False
                        break
                    z = bsap.z_for(budget.p_prime)
                    lo, hi = 1e-6, spec.max_final_rate
                    if not bsap.phi_satisfied(z, U_V(hi), L_mu, budget.error):
                        feasible = False
                        break
                    for _ in range(48):
                        mid = math.sqrt(lo * hi)
                        if bsap.phi_satisfied(z, U_V(mid), L_mu, budget.error):
                            hi = mid
                        else:
                            lo = mid
                    theta_needed = max(theta_needed, hi)
                if not feasible:
                    break
            if not feasible:
                break
        report.plan_time_s = time.perf_counter() - t0
        if not feasible or theta_needed <= 0:
            return self._exact(q, plan, comp_channels, report, "row plan infeasible")

        from repro.core.spec import SamplingPlan

        report.plan = SamplingPlan(rates={table: theta_needed})
        t0 = time.perf_counter()
        fplan = L.rewrite_scans(plan, {table: L.SampleClause("row", theta_needed, seed + 977)})
        try:
            res = self.ex.execute(fplan)
        except EmptySampleError as e:
            report.final_time_s = time.perf_counter() - t0
            return self._exact(q, plan, comp_channels, report,
                               f"final sample empty ({e.table})")
        report.final_time_s = time.perf_counter() - t0
        report.final_scanned_bytes = res.scanned_bytes
        values = _combine(q, comp_channels, res.values)
        return ApproxAnswer([c.name for c in q.aggs], values, res.group_present, report)
