from repro.aqpeval.evaluator import GuaranteedEvaluator
__all__ = ["GuaranteedEvaluator"]
