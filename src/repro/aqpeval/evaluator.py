"""Approximate evaluation with a-priori error guarantees (the paper's
technique as a first-class training-framework feature).

Evaluating a model on a large held-out corpus is exactly the workload
PilotDB targets: an aggregation (mean loss / accuracy) over a huge table
whose scan cost dominates.  Here the "table" is the eval corpus, a "block"
is one shard slab of `block_seqs` sequences (the unit the storage layer
serves), and "scanning a block" is running the model's forward pass on it.
TAQA's two stages become:

  pilot:  run the model on a few sampled blocks, collect per-block sums;
  plan:   BSAP single-table bounds (Lemma B.1 at block level) give the
          minimal block-sampling rate whose CLT interval meets (e, p);
  final:  run the model on the planned sample only, report the Hájek
          estimate — with P[|rel err| <= e] >= p, decided *before* the
          expensive evaluation runs.

Speedup = blocks actually evaluated / total blocks, typically 10-100×
for loose (5-10%) eval-loss tolerances — same economics as the paper's
Fig. 8, with TPU-hours instead of I/O as the saved resource.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional

import numpy as np

from repro.core import bsap
from repro.core.allocation import allocate
from repro.stats import normal_ppf


@dataclasses.dataclass
class ApproxEvalResult:
    estimate: float
    error_bound: float
    confidence: float
    pilot_blocks: int
    final_blocks: int
    total_blocks: int
    theta: float
    exact: bool = False

    @property
    def blocks_saved_frac(self) -> float:
        used = self.pilot_blocks + self.final_blocks
        return 1.0 - min(used / max(self.total_blocks, 1), 1.0)


class GuaranteedEvaluator:
    """Plans and runs a guaranteed-error approximate evaluation.

    block_metric(block_indices) -> (sums, counts): per-block metric sums and
    element counts for the requested blocks (i.e. "run the model on these
    shards").  The estimated quantity is total_sum / total_count (mean
    metric), a ratio of two totals — both planned via the corrected division
    rule (Table 2).
    """

    def __init__(self, num_blocks: int,
                 block_metric: Callable[[np.ndarray], tuple],
                 *, seed: int = 0):
        self.n = num_blocks
        self.block_metric = block_metric
        self.rng = np.random.default_rng(seed)

    def evaluate(self, *, error: float, confidence: float,
                 pilot_blocks: int = 24, max_rate: float = 0.5) -> ApproxEvalResult:
        n = self.n
        theta_p = min(max(pilot_blocks / n, 1e-6), 1.0)
        keep = self.rng.random(n) < theta_p
        pilot_ids = np.nonzero(keep)[0]
        if len(pilot_ids) < 2:
            pilot_ids = self.rng.choice(n, size=min(2, n), replace=False)
        sums, counts = self.block_metric(pilot_ids)
        sums, counts = np.asarray(sums, float), np.asarray(counts, float)

        # ratio composite: numerator (sum of metric) and denominator (count)
        e_part = error / (2.0 + error)
        budgets = [allocate(confidence, 2, e_part) for _ in range(2)]
        theta_req = 0.0
        feasible = True
        for y, budget in zip((sums, counts), budgets):
            L_mu = n * bsap.block_mean_lower(y, budget.delta1)
            if not np.isfinite(L_mu) or L_mu <= 0:
                feasible = False
                break
            uv = bsap.single_table_var_ub(y, theta_p, budget.delta2, n_blocks=n)
            z = bsap.z_for(budget.p_prime)
            lo, hi = 1e-6, max_rate
            if not bsap.phi_satisfied(z, uv(hi), L_mu, budget.error):
                feasible = False
                break
            for _ in range(48):
                mid = math.sqrt(lo * hi)
                if bsap.phi_satisfied(z, uv(mid), L_mu, budget.error):
                    hi = mid
                else:
                    lo = mid
            theta_req = max(theta_req, hi)

        if not feasible:
            # exact fallback: evaluate everything (guarantee trivially holds)
            ids = np.arange(n)
            s, c = self.block_metric(ids)
            return ApproxEvalResult(float(np.sum(s) / np.sum(c)), error,
                                    confidence, len(pilot_ids), int(n), n,
                                    1.0, exact=True)

        keep = self.rng.random(n) < theta_req
        ids = np.nonzero(keep)[0]
        if len(ids) == 0:
            ids = self.rng.choice(n, size=1)
        s, c = self.block_metric(ids)
        est = float(np.sum(s) / np.maximum(np.sum(c), 1e-12))
        return ApproxEvalResult(est, error, confidence, len(pilot_ids),
                                int(len(ids)), n, float(theta_req))
