"""Batched/GQA wrapper around the flash attention kernel."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.kernel import flash_attention_kernel
from repro.kernels.flash_attn.ref import attention_ref


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, scale: Optional[float] = None,
                    bq: int = 128, bk: int = 128,
                    interpret: Optional[bool] = None, use_ref: bool = False):
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D) with Hq % Hkv == 0.

    Pads sequences to block multiples (padded keys are masked via kv_len;
    padded query rows are sliced off) and vmaps the single-head kernel.
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    scale = float(scale if scale is not None else 1.0 / (d ** 0.5))
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)

    if use_ref:
        fn = lambda qi, ki, vi: attention_ref(qi, ki, vi, scale=scale,
                                              causal=causal, kv_len=skv)
        return jax.vmap(jax.vmap(fn))(q, k, v)

    bq_ = min(bq, max(sq, 8))
    bk_ = min(bk, max(skv, 8))
    pad_q = (-sq) % bq_
    pad_k = (-skv) % bk_
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    fn = lambda qi, ki, vi: flash_attention_kernel(
        qi, ki, vi, scale=scale, causal=causal, kv_len=skv, bq=bq_, bk=bk_,
        interpret=_auto_interpret(interpret))
    out = jax.vmap(jax.vmap(fn))(qp, kp, vp)
    return out[:, :, :sq, :]
