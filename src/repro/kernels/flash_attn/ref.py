"""Dense-softmax oracle for flash attention."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, scale: float, causal: bool, kv_len=None):
    """q: (Sq, d); k/v: (Skv, d).  Full-materialization softmax attention."""
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    s = (qf @ kf.T) * scale
    skv = k.shape[0]
    mask = jnp.ones((q.shape[0], skv), dtype=bool)
    if kv_len is not None:
        mask = mask & (jnp.arange(skv)[None, :] < kv_len)
    if causal:
        mask = mask & (jnp.arange(skv)[None, :] <= jnp.arange(q.shape[0])[:, None])
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(axis=1, keepdims=True))
    p = p / p.sum(axis=1, keepdims=True)
    return (p @ vf).astype(q.dtype)
