"""Blockwise online-softmax attention (FlashAttention, TPU-native tiling).

Grid = (num_q_blocks, num_kv_blocks); the kv dimension is the inner sequential
axis so the running max / denominator / accumulator live in VMEM scratch and
are carried across kv steps.  Causal q-blocks skip kv blocks entirely above
the diagonal — on TPU this prunes both the DMA and the MXU work (the same
block-skipping idea PilotDB applies to table scans, applied to the score
matrix).  Block shapes default to (128, 128): MXU-aligned and small enough
that q/k/v tiles + scratch fit VMEM for head_dim <= 256.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, bq: int, bk: int, nk: int,
                 kv_len: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[:] = jnp.full_like(m_scr, NEG_INF)
        l_scr[:] = jnp.zeros_like(l_scr)
        acc_scr[:] = jnp.zeros_like(acc_scr)

    if causal:
        run = j * bk < (i + 1) * bq  # block intersects the causal triangle
    else:
        run = jnp.bool_(True)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = cols < kv_len
        if causal:
            rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            mask = mask & (cols <= rows)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:]
        l_prev = l_scr[:]
        m_cur = jnp.max(s, axis=1)[:, None]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)[:, None]
        acc_scr[:] = acc_scr[:] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[:] = m_new
        l_scr[:] = l_new

    @pl.when(j == nk - 1)
    def _fin():
        l = l_scr[:]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0] = (acc_scr[:] / safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "scale", "causal", "bq", "bk", "kv_len", "interpret"))
def flash_attention_kernel(q, k, v, *, scale: float, causal: bool, kv_len: int,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = False):
    """q: (Sq, d); k, v: (Skv, d) — both padded to block multiples."""
    sq, d = q.shape
    skv = k.shape[0]
    nq, nk = sq // bq, skv // bk
    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, bq=bq, bk=bk, nk=nk,
        kv_len=kv_len)
    return pl.pallas_call(
        kernel,
        grid=(nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda i, j: (0, i, 0)),
            pl.BlockSpec((1, bk, d), lambda i, j: (0, j, 0)),
            pl.BlockSpec((1, bk, d), lambda i, j: (0, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda i, j: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((1, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q[None], k[None], v[None])[0]
