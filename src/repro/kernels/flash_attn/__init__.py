from repro.kernels.flash_attn.ops import flash_attention
from repro.kernels.flash_attn.ref import attention_ref

__all__ = ["flash_attention", "attention_ref"]
