"""Batched wrapper for chunked gated linear attention."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.gla_chunk.kernel import gla_chunked_kernel
from repro.kernels.gla_chunk.ref import gla_recurrent_ref

G_CLAMP = -8.0  # per-step log-decay floor: keeps within-chunk ratios bounded


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def gla_chunked(q, k, v, g, *, chunk: int = 64,
                interpret: Optional[bool] = None, use_ref: bool = False):
    """q,k,g: (B, H, T, dk); v: (B, H, T, dv).  Returns (o, final_state).

    g is the per-step log-decay (<= 0).  T is padded to a chunk multiple with
    zero-decay/zero-kv steps (padding emits garbage o rows that are sliced
    off and does not perturb the state because k rows are zero).
    """
    B, H, T, dk = q.shape
    g = jnp.clip(g, G_CLAMP, 0.0)
    if use_ref:
        fn = lambda qi, ki, vi, gi: gla_recurrent_ref(qi, ki, vi, gi)
        o, s = jax.vmap(jax.vmap(fn))(q, k, v, g)
        return o, s

    pad = (-T) % chunk
    if pad:
        zq = ((0, 0), (0, 0), (0, pad), (0, 0))
        q, k, v = (jnp.pad(x, zq) for x in (q, k, v))
        g = jnp.pad(g, zq)  # zero log-decay: state preserved through padding
    fn = lambda qi, ki, vi, gi: gla_chunked_kernel(
        qi, ki, vi, gi, chunk=chunk, interpret=_auto_interpret(interpret))
    o, s = jax.vmap(jax.vmap(fn))(q, k, v, g)
    return o[:, :, :T, :], s
