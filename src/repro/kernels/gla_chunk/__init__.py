from repro.kernels.gla_chunk.ops import gla_chunked
from repro.kernels.gla_chunk.ref import gla_recurrent_ref

__all__ = ["gla_chunked", "gla_recurrent_ref"]
