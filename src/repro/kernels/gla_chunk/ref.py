"""Sequential-recurrence oracle for gated linear attention (RWKV6/GLA/SSD).

State S in R^{dk x dv}; per-step, per-key-channel decay lambda_t = exp(g_t):

    S_t = diag(lambda_t) S_{t-1} + k_t v_t^T
    o_t = S_t^T q_t

This one recurrence family covers RWKV-6 "Finch" (data-dependent per-channel
decay), GLA, and Mamba-2/SSD (scalar decay broadcast over channels).  The
chunked kernel must match it exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gla_recurrent_ref(q, k, v, g, *, initial_state=None):
    """q,k,g: (T, dk); v: (T, dv).  Returns (o (T, dv), final_state)."""
    T, dk = q.shape
    dv = v.shape[1]
    qf, kf, vf, gf = (x.astype(jnp.float32) for x in (q, k, v, g))
    s0 = (jnp.zeros((dk, dv), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(S, inp):
        qt, kt, vt, gt = inp
        S = S * jnp.exp(gt)[:, None] + kt[:, None] * vt[None, :]
        return S, S.T @ qt

    S, o = jax.lax.scan(step, s0, (qf, kf, vf, gf))
    return o.astype(q.dtype), S
