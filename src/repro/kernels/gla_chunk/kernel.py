"""Chunked gated-linear-attention kernel (the TPU-native RWKV6/SSM hot path).

The sequential recurrence S_t = diag(e^{g_t}) S_{t-1} + k_t v_t^T is a poor
fit for the MXU (rank-1 updates, O(T) serial steps).  The chunked/parallel
form turns it into dense matmuls — the standard GLA/SSD reformulation, which
*is* the hardware adaptation for TPU:

with b_i = exp(cumsum g) inside a chunk of length C, S0 the carried state:
    q~_i = q_i * b_i,   k~_j = k_j / b_j
    o    = q~ @ S0  +  ((q~ @ k~^T) * causal_mask) @ v          (two MXU GEMMs)
    S'   = diag(b_C) S0  +  (k~ * b_C)^T @ v                    (one MXU GEMM)

Grid = (num_chunks,), sequential; the state is VMEM scratch carried across
grid steps.  Numerics: b ratios stay bounded because |g|·C is clamped by the
wrapper (decay close to 1 within a chunk — true for trained RWKV/SSM decays).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


SUB = 16  # intra-chunk sub-block size (two-level scheme, see below)


def _gla_kernel(q_ref, k_ref, v_ref, g_ref, o_ref, ostate_ref, s_scr, *,
                chunk: int, nchunks: int):
    """Numerical-safety note: the textbook factorization q~=q·e^L, k~=k·e^-L
    overflows for strong decays (e^-L grows like e^{|g|·C}).  We therefore
    keep every exponent <= 0:

    * inter-chunk and state-carry terms use e^{L} and e^{L_C - L}, both <= 1;
    * intra-chunk attention is computed per sub-block pair (SUB x SUB),
      re-based at the column sub-block's end so both factors' exponents are
      <= 0; diagonal sub-blocks mask j > i *before* exponentiation.
    Underflow to 0 is the mathematically correct limit (fully forgotten)."""
    c = pl.program_id(0)

    @pl.when(c == 0)
    def _init():
        s_scr[:] = jnp.zeros_like(s_scr)

    q = q_ref[0].astype(jnp.float32)          # (C, dk)
    k = k_ref[0].astype(jnp.float32)          # (C, dk)
    v = v_ref[0].astype(jnp.float32)          # (C, dv)
    g = g_ref[0].astype(jnp.float32)          # (C, dk) log-decay (<= 0)
    L = jnp.cumsum(g, axis=0)                 # (C, dk), decreasing
    L_last = L[-1:, :]                        # (1, dk)

    s0 = s_scr[:]                             # (dk, dv)
    q_in = q * jnp.exp(L)                     # e^{L} <= 1
    inter = jax.lax.dot_general(q_in, s0, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # intra-chunk: two-level sub-block scheme
    ns = chunk // SUB
    out_rows = []
    for r in range(ns):
        qr = q[r * SUB:(r + 1) * SUB]
        Lr = L[r * SUB:(r + 1) * SUB]
        acc = jnp.zeros((SUB, v.shape[1]), jnp.float32)
        for cb in range(r + 1):
            vc = v[cb * SUB:(cb + 1) * SUB]
            if cb < r:
                base = L[(cb + 1) * SUB - 1:(cb + 1) * SUB]   # (1, dk)
                qq = qr * jnp.exp(Lr - base)                  # rows later: <= 0
                kk = k[cb * SUB:(cb + 1) * SUB] * jnp.exp(
                    base - L[cb * SUB:(cb + 1) * SUB])        # cols earlier: <= 0
                attn = jax.lax.dot_general(qq, kk, (((1,), (1,)), ((), ())),
                                           preferred_element_type=jnp.float32)
            else:
                Lc = L[cb * SUB:(cb + 1) * SUB]
                dif = Lr[:, None, :] - Lc[None, :, :]         # (s, s, dk)
                rows_i = jax.lax.broadcasted_iota(jnp.int32, (SUB, SUB), 0)
                cols_j = jax.lax.broadcasted_iota(jnp.int32, (SUB, SUB), 1)
                mask = (cols_j <= rows_i)[:, :, None]
                dif = jnp.where(mask, dif, -jnp.inf)          # mask BEFORE exp
                kc = k[cb * SUB:(cb + 1) * SUB]
                attn = jnp.sum(qr[:, None, :] * kc[None, :, :] * jnp.exp(dif),
                               axis=-1)
            acc = acc + jax.lax.dot_general(attn, vc, (((1,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32)
        out_rows.append(acc)
    intra = jnp.concatenate(out_rows, axis=0)
    o_ref[0] = (inter + intra).astype(o_ref.dtype)

    k_carry = k * jnp.exp(L_last - L)         # e^{L_C - L_j} <= 1
    s_new = s0 * jnp.exp(L_last).T + jax.lax.dot_general(
        k_carry, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    s_scr[:] = s_new

    @pl.when(c == nchunks - 1)
    def _emit_state():
        ostate_ref[:] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def gla_chunked_kernel(q, k, v, g, *, chunk: int = 64, interpret: bool = False):
    """q,k,g: (T, dk); v: (T, dv); T % chunk == 0.

    Returns (o: (T, dv), final_state: (dk, dv) float32).
    """
    T, dk = q.shape
    dv = v.shape[1]
    nchunks = T // chunk
    kernel = functools.partial(_gla_kernel, chunk=chunk, nchunks=nchunks)
    o, state = pl.pallas_call(
        kernel,
        grid=(nchunks,),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda c: (0, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda c: (0, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda c: (0, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda c: (0, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda c: (0, c, 0)),
            pl.BlockSpec((dk, dv), lambda c: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, T, dv), q.dtype),
            jax.ShapeDtypeStruct((dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(q[None], k[None], v[None], g[None])
    return o[0], state
