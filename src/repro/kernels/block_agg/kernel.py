"""Sampled-block aggregation kernel — the TPU realization of BSAP's scan.

The grid ranges over *sampled* blocks only.  The sampled block ids arrive via
scalar prefetch and drive the BlockSpec index_map, so each grid step DMAs
exactly one (1, block_rows) slab of the column from HBM into VMEM —
non-sampled slabs never move.  This is `TABLESAMPLE SYSTEM` as a memory
system primitive: the cost is θ·bytes, not bytes.

Output per sampled block: (count, sum, sum-of-squares, min, max, 0, 0, 0) —
exactly the per-block statistics the pilot query groups by `ctid` (§3.3) and
that BSAP's bounds consume (count/sum/sumsq) plus min/max for future outlier
indexes.  Lane-padded to 8 for clean TPU stores.

Empty-block sentinel: a sampled block with zero valid rows reports
count=0, sum=0, sumsq=0 and **min=max=NaN** (not the float32 ±3.4e38 extremes
of the masked reduction).  Consumers must mask min/max on count>0; sums are
safe to use unmasked.  The oracle in ``ref.py`` follows the same convention.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

STATS = 8  # count, sum, sumsq, min, max, pad, pad, pad


def _kernel(ids_ref, vals_ref, valid_ref, out_ref):
    v = vals_ref[0, :].astype(jnp.float32)
    m = valid_ref[0, :].astype(jnp.float32)
    cnt = jnp.sum(m)
    s = jnp.sum(v * m)
    ss = jnp.sum(v * v * m)
    big = jnp.float32(3.4e38)
    nan = jnp.float32(jnp.nan)
    mn = jnp.where(cnt > 0, jnp.min(jnp.where(m > 0, v, big)), nan)
    mx = jnp.where(cnt > 0, jnp.max(jnp.where(m > 0, v, -big)), nan)
    zero = jnp.float32(0.0)
    out_ref[0, :] = jnp.stack([cnt, s, ss, mn, mx, zero, zero, zero])


def _kernel_batched(ids_ref, vals_ref, valid_ref, out_ref):
    # Batched-grid twin of _kernel: lane b of the (batch, n_sampled) grid
    # scans ITS sampled blocks (ids_ref[b, i]); per-block math is identical.
    v = vals_ref[0, :].astype(jnp.float32)
    m = valid_ref[0, :].astype(jnp.float32)
    cnt = jnp.sum(m)
    s = jnp.sum(v * m)
    ss = jnp.sum(v * v * m)
    big = jnp.float32(3.4e38)
    nan = jnp.float32(jnp.nan)
    mn = jnp.where(cnt > 0, jnp.min(jnp.where(m > 0, v, big)), nan)
    mx = jnp.where(cnt > 0, jnp.max(jnp.where(m > 0, v, -big)), nan)
    zero = jnp.float32(0.0)
    out_ref[0, 0, :] = jnp.stack([cnt, s, ss, mn, mx, zero, zero, zero])


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def block_agg_batched_kernel(values: jax.Array, valid: jax.Array,
                             ids: jax.Array, *, block_rows: int,
                             interpret: bool = False) -> jax.Array:
    """values/valid: (num_blocks, block_rows); ids: (batch, n_sampled) int32.

    One launch, megacore-style batched grid: lane b's sampled blocks are
    driven by row b of the stacked scalar-prefetch id table.  Returns
    (batch, n_sampled, 8) per-block stats, each lane bit-identical to the
    solo ``block_agg_kernel`` on its id row.
    """
    batch, n_sampled = ids.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(batch, n_sampled),
        in_specs=[
            pl.BlockSpec((1, block_rows), lambda b, i, ids: (ids[b, i], 0)),
            pl.BlockSpec((1, block_rows), lambda b, i, ids: (ids[b, i], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, STATS), lambda b, i, ids: (b, i, 0)),
    )
    return pl.pallas_call(
        _kernel_batched,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((batch, n_sampled, STATS), jnp.float32),
        interpret=interpret,
    )(ids, values, valid)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def block_agg_kernel(values: jax.Array, valid: jax.Array, ids: jax.Array,
                     *, block_rows: int, interpret: bool = False) -> jax.Array:
    """values/valid: (num_blocks, block_rows); ids: (n_sampled,) int32.

    Returns (n_sampled, 8) per-block stats.
    """
    n_sampled = ids.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_sampled,),
        in_specs=[
            pl.BlockSpec((1, block_rows), lambda i, ids: (ids[i], 0)),
            pl.BlockSpec((1, block_rows), lambda i, ids: (ids[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, STATS), lambda i, ids: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_sampled, STATS), jnp.float32),
        interpret=interpret,
    )(ids, values, valid)
