"""Public wrapper: 1-D columns in, per-sampled-block stats out.

On CPU containers the Pallas TPU lowering is unavailable, so the wrapper
selects interpret mode automatically (`interpret=None` -> True off-TPU);
production TPU binaries pass interpret=False and get the compiled kernel.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.block_agg.kernel import (block_agg_batched_kernel,
                                            block_agg_kernel)
from repro.kernels.block_agg.ref import block_agg_ref

LANE = 128  # TPU lane width: pad block_rows up to a multiple


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def block_agg(column: jax.Array, valid: jax.Array, block_rows: int,
              ids: np.ndarray, *, interpret: Optional[bool] = None,
              use_ref: bool = False) -> jax.Array:
    """Per-sampled-block (count, sum, sumsq, min, max) for a 1-D column.

    column/valid: (num_blocks * block_rows,); ids: sampled block indices.
    Blocks with zero valid rows report min=max=NaN with count=0 (the
    empty-block sentinel; mask min/max on count>0 downstream).
    """
    n_blocks = column.shape[0] // block_rows
    v2 = column.reshape(n_blocks, block_rows).astype(jnp.float32)
    m2 = valid.reshape(n_blocks, block_rows).astype(jnp.float32)
    pad = (-block_rows) % LANE
    if pad:
        v2 = jnp.pad(v2, ((0, 0), (0, pad)))
        m2 = jnp.pad(m2, ((0, 0), (0, pad)))
    ids = jnp.asarray(ids, dtype=jnp.int32)
    if use_ref:
        out = block_agg_ref(v2, m2, ids, block_rows=block_rows + pad)
    else:
        out = block_agg_kernel(v2, m2, ids, block_rows=block_rows + pad,
                               interpret=_auto_interpret(interpret))
    return out[:, :5]


def block_agg_batched(column: jax.Array, valid: jax.Array, block_rows: int,
                      ids, *, interpret: Optional[bool] = None) -> jax.Array:
    """Batched per-sampled-block stats: B lanes share the column slabs.

    column/valid: (num_blocks * block_rows,); ids: (B, n_sampled) per-lane
    sampled block indices.  One launch serves a whole drain group; returns
    (B, n_sampled, 5), each lane bit-identical to its solo ``block_agg``.
    """
    n_blocks = column.shape[0] // block_rows
    v2 = column.reshape(n_blocks, block_rows).astype(jnp.float32)
    m2 = valid.reshape(n_blocks, block_rows).astype(jnp.float32)
    pad = (-block_rows) % LANE
    if pad:
        v2 = jnp.pad(v2, ((0, 0), (0, pad)))
        m2 = jnp.pad(m2, ((0, 0), (0, pad)))
    ids = jnp.asarray(ids, dtype=jnp.int32)
    out = block_agg_batched_kernel(v2, m2, ids, block_rows=block_rows + pad,
                                   interpret=_auto_interpret(interpret))
    return out[:, :, :5]
