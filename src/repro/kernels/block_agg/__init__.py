from repro.kernels.block_agg.ops import block_agg
from repro.kernels.block_agg.ref import block_agg_ref

__all__ = ["block_agg", "block_agg_ref"]
