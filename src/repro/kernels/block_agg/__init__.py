from repro.kernels.block_agg.ops import block_agg, block_agg_batched
from repro.kernels.block_agg.ref import block_agg_ref

__all__ = ["block_agg", "block_agg_batched", "block_agg_ref"]
