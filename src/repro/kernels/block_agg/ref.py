"""Pure-jnp oracle for the block_agg kernel.

Follows the kernel's empty-block sentinel: blocks with zero valid rows
report count=0, sum=sumsq=0, min=max=NaN (mask on count>0 downstream).
"""

from __future__ import annotations

import jax.numpy as jnp


def block_agg_ref(values, valid, ids, *, block_rows: int):
    """values/valid: (num_blocks, block_rows); ids: (n,) -> (n, 8) stats."""
    v = values[ids].astype(jnp.float32)
    m = valid[ids].astype(jnp.float32)
    cnt = (m).sum(axis=1)
    s = (v * m).sum(axis=1)
    ss = (v * v * m).sum(axis=1)
    big = jnp.float32(3.4e38)
    nan = jnp.float32(jnp.nan)
    mn = jnp.where(cnt > 0, jnp.where(m > 0, v, big).min(axis=1), nan)
    mx = jnp.where(cnt > 0, jnp.where(m > 0, v, -big).max(axis=1), nan)
    z = jnp.zeros_like(cnt)
    return jnp.stack([cnt, s, ss, mn, mx, z, z, z], axis=1)
