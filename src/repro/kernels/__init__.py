# Pallas TPU kernels for the compute hot-spots PilotDB optimizes, plus the
# LM-stack hot paths.  Each subpackage: kernel.py (pl.pallas_call + BlockSpec
# VMEM tiling), ops.py (jit'd public wrapper), ref.py (pure-jnp oracle).
#
#   block_agg    — gather *sampled* blocks (scalar-prefetch ids) and emit
#                  per-block (count, sum, sumsq, min, max): the BSAP pilot /
#                  final scan hot path.  Non-sampled blocks never leave HBM.
#   filtered_agg — fused Q6-style predicate evaluation + block aggregation.
#   flash_attn   — blockwise-softmax attention for prefill.
#   gla_chunk    — chunked gated-linear-attention (RWKV6 / SSM hot path).
