"""Fused predicate + sampled-block aggregation (TPC-H Q6 shape).

Computes, over sampled blocks only (scalar-prefetched ids):

  SUM(x*y), COUNT(*)  WHERE  lo1<=f1<=hi1 AND lo2<=f2<=hi2 AND f3<c

in a single HBM pass: five column slabs stream HBM→VMEM per block, the
predicate evaluates in VREGs, and only 8 lanes per block are stored.  This is
the paper's "data scanning is the latency bottleneck" (§1) case: fusing the
filter avoids materializing a mask column and a second pass.

Predicate bounds are *runtime scalars* riding the same scalar-prefetch path
as the sampled block ids (SMEM, available before the grid body runs).  One
compiled kernel therefore serves every constant variant of the shape — the
serve-layer case of a dashboard sweeping its date range — instead of
recompiling per constant set as the earlier static-bounds lowering did.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

STATS = 8  # count, sum(x*y), sum((x*y)^2), pad...
BOUNDS = 5  # lo1, hi1, lo2, hi2, c3


def _kernel(ids_ref, bounds_ref, x_ref, y_ref, f1_ref, f2_ref, f3_ref,
            valid_ref, out_ref):
    lo1 = bounds_ref[0]
    hi1 = bounds_ref[1]
    lo2 = bounds_ref[2]
    hi2 = bounds_ref[3]
    c3 = bounds_ref[4]
    x = x_ref[0, :].astype(jnp.float32)
    y = y_ref[0, :].astype(jnp.float32)
    f1 = f1_ref[0, :].astype(jnp.float32)
    f2 = f2_ref[0, :].astype(jnp.float32)
    f3 = f3_ref[0, :].astype(jnp.float32)
    m = valid_ref[0, :].astype(jnp.float32)
    keep = ((f1 >= lo1) & (f1 <= hi1) & (f2 >= lo2) & (f2 <= hi2)
            & (f3 < c3)).astype(jnp.float32) * m
    prod = x * y
    cnt = jnp.sum(keep)
    s = jnp.sum(prod * keep)
    ss = jnp.sum(prod * prod * keep)
    zero = jnp.float32(0.0)
    out_ref[0, :] = jnp.stack([cnt, s, ss, zero, zero, zero, zero, zero])


def _kernel_batched(ids_ref, bounds_ref, x_ref, y_ref, f1_ref, f2_ref, f3_ref,
                    valid_ref, out_ref):
    # Megacore-style batched grid (batch, n_sampled): lane b scans ITS
    # sampled blocks (ids_ref[b, i]) under ITS predicate bounds
    # (bounds_ref[b]); per-block math is byte-identical to _kernel.
    b = pl.program_id(0)
    lo1 = bounds_ref[b, 0]
    hi1 = bounds_ref[b, 1]
    lo2 = bounds_ref[b, 2]
    hi2 = bounds_ref[b, 3]
    c3 = bounds_ref[b, 4]
    x = x_ref[0, :].astype(jnp.float32)
    y = y_ref[0, :].astype(jnp.float32)
    f1 = f1_ref[0, :].astype(jnp.float32)
    f2 = f2_ref[0, :].astype(jnp.float32)
    f3 = f3_ref[0, :].astype(jnp.float32)
    m = valid_ref[0, :].astype(jnp.float32)
    keep = ((f1 >= lo1) & (f1 <= hi1) & (f2 >= lo2) & (f2 <= hi2)
            & (f3 < c3)).astype(jnp.float32) * m
    prod = x * y
    cnt = jnp.sum(keep)
    s = jnp.sum(prod * keep)
    ss = jnp.sum(prod * prod * keep)
    zero = jnp.float32(0.0)
    out_ref[0, 0, :] = jnp.stack([cnt, s, ss, zero, zero, zero, zero, zero])


@functools.partial(
    jax.jit,
    static_argnames=("block_rows", "interpret"))
def filtered_agg_batched_kernel(x, y, f1, f2, f3, valid, ids, bounds, *,
                                block_rows: int,
                                interpret: bool = False) -> jax.Array:
    """Batched lanes over shared column slabs.

    ids: (batch, n_sampled) int32 — each lane's sampled block ids;
    bounds: (batch, BOUNDS) f32 — each lane's predicate bounds.  Both ride
    scalar prefetch (stacked tables).  One kernel launch covers a whole
    drain group's finals: out (batch, n_sampled, STATS).
    """
    batch, n_sampled = ids.shape
    col_spec = pl.BlockSpec((1, block_rows), lambda b, i, ids, bounds: (ids[b, i], 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # stacked block-id table + stacked bounds table
        grid=(batch, n_sampled),
        in_specs=[col_spec] * 6,
        out_specs=pl.BlockSpec((1, 1, STATS), lambda b, i, ids, bounds: (b, i, 0)),
    )
    return pl.pallas_call(
        _kernel_batched,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((batch, n_sampled, STATS), jnp.float32),
        interpret=interpret,
    )(ids, jnp.asarray(bounds, jnp.float32), x, y, f1, f2, f3, valid)


@functools.partial(
    jax.jit,
    static_argnames=("block_rows", "interpret"))
def filtered_agg_kernel(x, y, f1, f2, f3, valid, ids, bounds, *,
                        block_rows: int, interpret: bool = False) -> jax.Array:
    n_sampled = ids.shape[0]
    col_spec = pl.BlockSpec((1, block_rows), lambda i, ids, bounds: (ids[i], 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # sampled block ids + predicate bounds
        grid=(n_sampled,),
        in_specs=[col_spec] * 6,
        out_specs=pl.BlockSpec((1, STATS), lambda i, ids, bounds: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_sampled, STATS), jnp.float32),
        interpret=interpret,
    )(ids, jnp.asarray(bounds, jnp.float32), x, y, f1, f2, f3, valid)
