"""Pure-jnp oracle for filtered_agg."""

from __future__ import annotations

import jax.numpy as jnp


def filtered_agg_ref(x, y, f1, f2, f3, valid, ids, *, bounds):
    """All columns (num_blocks, block_rows); returns (n, 3): cnt, sum, sumsq.

    ``bounds`` may be a tuple of floats or a (5,) runtime array."""
    b = jnp.asarray(bounds, jnp.float32)
    lo1, hi1, lo2, hi2, c3 = b[0], b[1], b[2], b[3], b[4]
    xs, ys = x[ids], y[ids]
    keep = ((f1[ids] >= lo1) & (f1[ids] <= hi1)
            & (f2[ids] >= lo2) & (f2[ids] <= hi2)
            & (f3[ids] < c3)).astype(jnp.float32) * valid[ids].astype(jnp.float32)
    prod = xs.astype(jnp.float32) * ys.astype(jnp.float32)
    cnt = keep.sum(axis=1)
    s = (prod * keep).sum(axis=1)
    ss = (prod * prod * keep).sum(axis=1)
    return jnp.stack([cnt, s, ss], axis=1)
