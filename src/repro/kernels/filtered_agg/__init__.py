from repro.kernels.filtered_agg.ops import filtered_agg, filtered_agg_batched
from repro.kernels.filtered_agg.ref import filtered_agg_ref

__all__ = ["filtered_agg", "filtered_agg_batched", "filtered_agg_ref"]
