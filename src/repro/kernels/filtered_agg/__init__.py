from repro.kernels.filtered_agg.ops import filtered_agg
from repro.kernels.filtered_agg.ref import filtered_agg_ref

__all__ = ["filtered_agg", "filtered_agg_ref"]
