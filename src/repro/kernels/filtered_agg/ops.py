"""Public wrapper for the fused Q6-style scan."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.filtered_agg.kernel import (filtered_agg_batched_kernel,
                                               filtered_agg_kernel)
from repro.kernels.filtered_agg.ref import filtered_agg_ref

LANE = 128


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def filtered_agg(x, y, f1, f2, f3, valid, block_rows: int, ids: np.ndarray,
                 bounds, *, interpret: Optional[bool] = None,
                 use_ref: bool = False) -> jax.Array:
    """Fused Q6 scan over sampled blocks of 1-D columns.

    bounds = (lo1, hi1, lo2, hi2, c3) — a tuple or a (5,) device array;
    either way it reaches the kernel as a *runtime* scalar operand (scalar
    prefetch), so constant-varied calls share one compiled kernel.  Returns
    (n_sampled, 3) cnt/sum/sumsq.  Rows failing the predicate are excluded;
    padding rows are invalid.
    """
    n_blocks = x.shape[0] // block_rows
    pad = (-block_rows) % LANE

    def prep(col):
        c = jnp.asarray(col).reshape(n_blocks, block_rows).astype(jnp.float32)
        return jnp.pad(c, ((0, 0), (0, pad))) if pad else c

    cols = [prep(c) for c in (x, y, f1, f2, f3, valid)]
    ids = jnp.asarray(ids, dtype=jnp.int32)
    bounds = jnp.asarray(bounds, jnp.float32)
    if use_ref:
        return filtered_agg_ref(*cols[:5], cols[5], ids, bounds=bounds)
    out = filtered_agg_kernel(*cols, ids, bounds,
                              block_rows=block_rows + pad,
                              interpret=_auto_interpret(interpret))
    return out[:, :3]


def filtered_agg_batched(x, y, f1, f2, f3, valid, block_rows: int, ids,
                         bounds, *,
                         interpret: Optional[bool] = None) -> jax.Array:
    """Batched fused Q6 scan: B lanes share the column slabs.

    ids: (B, n_sampled) per-lane sampled block ids; bounds: (B, 5) per-lane
    predicate bounds.  One kernel launch computes every lane's per-block
    stats — the drain-group finals path.  Returns (B, n_sampled, 3)
    cnt/sum/sumsq, each lane bit-identical to its solo ``filtered_agg``.
    """
    n_blocks = x.shape[0] // block_rows
    pad = (-block_rows) % LANE

    def prep(col):
        c = jnp.asarray(col).reshape(n_blocks, block_rows).astype(jnp.float32)
        return jnp.pad(c, ((0, 0), (0, pad))) if pad else c

    cols = [prep(c) for c in (x, y, f1, f2, f3, valid)]
    ids = jnp.asarray(ids, dtype=jnp.int32)
    bounds = jnp.asarray(bounds, jnp.float32)
    out = filtered_agg_batched_kernel(*cols, ids, bounds,
                                      block_rows=block_rows + pad,
                                      interpret=_auto_interpret(interpret))
    return out[:, :, :3]
