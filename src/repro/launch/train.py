"""End-to-end training driver (deliverable b's train entry point).

Wires every runtime piece together: config registry, AQP-planned data
mixture, sharded AdamW, microbatch accumulation, optional int8 error-feedback
gradient compression, checkpoint/restart (+ SIGTERM emergency save), the
straggler watchdog, and guaranteed-error approximate evaluation.

On this CPU container it trains reduced configs end-to-end (examples/ call
it with ~100M-class settings); on a real pod the same driver runs with
--mesh production shardings from train.sharding.

  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --reduced --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ck
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.aqpeval import GuaranteedEvaluator
from repro.configs import get_config
from repro.models import build_model
from repro.train import checkpoint as ckpt
from repro.train.data import TokenPipeline, make_domain_metadata, plan_mixture_weights
from repro.train.elastic import StragglerWatchdog
from repro.train.optimizer import AdamWConfig
from repro.train.step import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--aqp-mixture", action="store_true",
                    help="plan the data mixture with a guaranteed-error AQP query")
    ap.add_argument("--approx-eval", action="store_true",
                    help="finish with a guaranteed-error approximate eval")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)

    # ---- data (optionally AQP-planned mixture) ------------------------------
    domains = {"default": 1.0}
    if args.aqp_mixture:
        meta = make_domain_metadata({"web": 2000, "code": 1000, "books": 1000},
                                    block_rows=64, seed=args.seed)
        weights, report = plan_mixture_weights(meta, 3, error=0.1, confidence=0.9,
                                               seed=args.seed)
        names = ["books", "code", "web"]
        domains = {names[g]: w for g, w in weights.items()}
        frac = (report.pilot_scanned_bytes + report.final_scanned_bytes) \
            / max(report.exact_scanned_bytes, 1)
        print(f"[aqp-mixture] weights={domains} "
              f"(scanned {frac:.1%} of metadata, fallback={report.fallback})")
    pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq,
                         domains=domains, seed=args.seed)

    # ---- state / resume ------------------------------------------------------
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps, weight_decay=0.0)
    state = init_train_state(model, jax.random.PRNGKey(args.seed),
                             compress=args.compress_grads)
    start_step = 0
    saver = None
    if args.ckpt_dir:
        os.makedirs(args.ckpt_dir, exist_ok=True)
        saver = ckpt.EmergencySaver(args.ckpt_dir)
        if args.resume:
            latest = ckpt.latest_step(args.ckpt_dir)
            if latest is not None:
                state_tree, extra = ckpt.restore(args.ckpt_dir, latest, state)
                state = state_tree
                start_step = extra.get("step", latest)
                pipe.state.step = extra.get("data_step", start_step)
                print(f"[resume] from step {start_step}")

    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      microbatches=args.microbatches,
                                      compress=args.compress_grads))
    watchdog = StragglerWatchdog()

    losses = []
    for step in range(start_step, args.steps):
        batch_np = pipe.next_batch()
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        watchdog.start()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])  # blocks; makes the timing honest
        slow = watchdog.stop()
        losses.append(loss)
        if slow:
            print(f"[watchdog] step {step} straggled "
                  f"(remesh advised: {watchdog.should_remesh})")
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, state,
                      extra={"step": step + 1, "data_step": pipe.state.step})
        if saver is not None:
            saver.maybe_save(step + 1, state)

    # ---- guaranteed-error approximate eval -----------------------------------
    if args.approx_eval:
        rng = np.random.default_rng(args.seed + 1)
        n_blocks = 64
        shards = rng.integers(0, cfg.vocab_size,
                              (n_blocks, 2, args.seq + 1), dtype=np.int32)

        @jax.jit
        def shard_loss(tokens):
            logits, _ = model.forward(state.params, {"tokens": tokens[:, :-1]})
            lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            nll = -jnp.take_along_axis(lp, tokens[:, 1:, None], axis=-1)
            return nll.sum()

        def block_metric(ids):
            sums = np.array([float(shard_loss(jnp.asarray(shards[i]))) for i in ids])
            return sums, np.full(len(ids), 2 * args.seq, float)

        ev = GuaranteedEvaluator(n_blocks, block_metric, seed=args.seed)
        res = ev.evaluate(error=0.05, confidence=0.9, pilot_blocks=12)
        print(f"[approx-eval] loss≈{res.estimate:.4f} ±5% @90% "
              f"(evaluated {res.pilot_blocks + res.final_blocks}/{res.total_blocks} "
              f"blocks, saved {res.blocks_saved_frac:.0%})")

    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f}); "
          f"stragglers={len(watchdog.slow_steps)}")
    return losses


if __name__ == "__main__":
    main()
