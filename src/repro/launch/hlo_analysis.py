"""Optimized-HLO text analysis: loop-aware FLOPs + collective wire bytes.

`compiled.cost_analysis()` counts while-loop bodies ONCE (verified
empirically), but scan-over-layers puts ~all compute inside a while loop —
so we recursively walk the HLO call graph, multiplying each while body by
its static trip count (recovered from the loop condition's comparison
constant).  The same walk tallies per-device collective wire bytes, which
cost_analysis does not expose at all.

Structural profiler semantics:
  * dot FLOPs exact (result shape × contraction size from the operand's
    definition);  elementwise ops ignored (dots dominate LM steps; the
    deviation is reported via the MODEL_FLOPS ratio in the roofline);
  * collective wire bytes per device use ring formulas:
      all-gather       out_bytes · (n-1)/n
      reduce-scatter   in_bytes  · (n-1)/n
      all-reduce       2 · in_bytes · (n-1)/n
      all-to-all       in_bytes  · (n-1)/n
      collective-permute  in_bytes
    with n = participants per replica group.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{$")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_WHILE_RE = re.compile(r"while\(")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")


def _split_type(rhs: str) -> Tuple[str, str]:
    """Split an op definition into (result type string, remainder).

    Handles tuple types: '(s32[], f32[2,2]{1,0}) while(...)' and plain
    types: 'f32[64,64]{1,0} dot(...)'."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rhs[: i + 1], rhs[i + 1:].strip()
    parts = rhs.split(" ", 1)
    return parts[0], (parts[1] if len(parts) > 1 else "")


def _bytes_of_shape(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _first_shapes_bytes(typestr: str) -> int:
    """Total bytes of all array shapes in a (possibly tuple) type string."""
    return sum(_bytes_of_shape(dt, dm) for dt, dm in _SHAPE_RE.findall(typestr))


def _group_size(line: str, num_partitions: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return num_partitions


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    mem_bytes: float = 0.0   # HBM traffic model: op operands+results at
    #                          fusion boundaries (fusion internals stay in
    #                          VMEM/VREGs on TPU)
    coll_bytes: float = 0.0
    coll_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    calls: List[Tuple[str, float, str]] = dataclasses.field(default_factory=list)


# ops that do not move HBM bytes themselves
_NO_MEM_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "rng-get-and-update-state",
    # layout/dtype ops: fused into neighbours on TPU (CPU-backend HLO keeps
    # them standalone, which would inflate the traffic model ~5-20x)
    "copy", "convert", "transpose", "reshape", "broadcast", "bitcast-convert",
    # control flow: bodies are accounted via the call graph; the op's own
    # result is the aliased loop-carried buffer
    "while", "conditional",
}


def analyze_hlo(hlo: str, num_partitions: int = 1) -> Dict[str, object]:
    # ---- split into computations, keep raw op lines ----
    comps: Dict[str, List[str]] = {}
    entry_name: Optional[str] = None
    cur: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if not line:
            continue
        hm = _HEADER_RE.match(line)
        if hm:
            cur = hm.group(2)
            comps[cur] = []
            if hm.group(1):
                entry_name = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)

    mp = re.search(r"num_partitions=(\d+)", hlo)
    if mp:
        num_partitions = int(mp.group(1))

    # ---- per-computation pass ----
    stats: Dict[str, CompStats] = {}
    trip_cache: Dict[str, float] = {}

    def type_of(defline: str) -> str:
        return _split_type(defline)[0]

    for name, lines in comps.items():
        st = CompStats()
        symtab: Dict[str, str] = {}
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            var, rhs = dm.group(1), dm.group(2)
            symtab[var] = rhs

        def shape_bytes_of_var(var: str) -> int:
            rhs = symtab.get(var.lstrip("%"))
            if rhs is None:
                return 0
            return _first_shapes_bytes(type_of(rhs))

        def dims_of_var(var: str) -> List[int]:
            rhs = symtab.get(var.lstrip("%"))
            if rhs is None:
                return []
            m = _SHAPE_RE.search(type_of(rhs))
            if not m:
                return []
            return [int(d) for d in m.group(2).split(",") if d]

        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            rhs = dm.group(2)
            typestr, rest = _split_type(rhs)
            op_kind = rest.split("(", 1)[0].strip().split()[-1] if "(" in rest else ""

            # dots
            if op_kind == "dot":
                shapes = _SHAPE_RE.findall(typestr)
                out_elems = 1
                if shapes:
                    dims = shapes[0][1]
                    for d in dims.split(","):
                        if d:
                            out_elems *= int(d)
                ops = _OPERANDS_RE.search(rest[rest.index("dot("):])
                cdm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
                if ops and cdm:
                    lhs_var = ops.group(1).split(",")[0].strip()
                    ldims = dims_of_var(lhs_var)
                    contraction = 1
                    for ci in cdm.group(1).split(","):
                        if ci and int(ci) < len(ldims):
                            contraction *= ldims[int(ci)]
                    st.flops += 2.0 * out_elems * contraction

            # collectives (sync and async -start; skip -done)
            base = op_kind.replace("-start", "")
            if base in _COLLECTIVES and not op_kind.endswith("-done"):
                ops = _OPERANDS_RE.search(rest[rest.index(op_kind + "("):])
                in_bytes = 0
                if ops:
                    for v in ops.group(1).split(","):
                        v = v.strip().lstrip("%")
                        if v in symtab:
                            in_bytes += shape_bytes_of_var(v)
                out_bytes = _first_shapes_bytes(typestr)
                n = max(_group_size(rhs, num_partitions), 1)
                ring = (n - 1) / n
                wire = {
                    "all-gather": out_bytes * ring,
                    "reduce-scatter": in_bytes * ring,
                    "all-reduce": 2.0 * in_bytes * ring,
                    "all-to-all": in_bytes * ring,
                    "collective-permute": float(in_bytes),
                }[base]
                st.coll_bytes += wire
                st.coll_counts[base] = st.coll_counts.get(base, 0) + 1

            # HBM traffic at fusion boundaries.  Scan accumulators
            # (dynamic-update-slice, and fusions rooted in one) write only
            # the UPDATE slice in place on TPU — counting their full-buffer
            # result per loop iteration would overcount by the trip count,
            # so the aliased buffer operand and result are excluded.
            if op_kind and op_kind not in _NO_MEM_OPS:
                result_bytes = _first_shapes_bytes(typestr)
                operand_bytes = []
                ops_m = _OPERANDS_RE.search(rest[rest.index("("):]) if "(" in rest else None
                if ops_m:
                    for v in ops_m.group(1).split(","):
                        v = v.strip().lstrip("%")
                        if v in symtab:
                            operand_bytes.append(shape_bytes_of_var(v))
                is_dus = op_kind == "dynamic-update-slice"
                if op_kind == "fusion":
                    cm = _CALLS_RE.search(rhs)
                    if cm:
                        for cl in comps.get(cm.group(1), []):
                            if cl.startswith("ROOT") and "dynamic-update-slice" in cl:
                                is_dus = True
                if is_dus:
                    # drop the aliased buffer (same size as the result)
                    rest_ops = sorted(operand_bytes)
                    if rest_ops and rest_ops[-1] >= result_bytes:
                        rest_ops = rest_ops[:-1]
                    st.mem_bytes += sum(rest_ops)
                else:
                    st.mem_bytes += result_bytes + sum(operand_bytes)

            # call edges
            if op_kind == "while":
                cm, bm = _COND_RE.search(rhs), _BODY_RE.search(rhs)
                if bm:
                    trips = 1.0
                    if cm:
                        trips = _trip_count(comps.get(cm.group(1), []), trip_cache,
                                            cm.group(1))
                    st.calls.append((bm.group(1), trips, "loop"))
            elif op_kind == "conditional":
                for m in re.finditer(r"\w+_computation=%?([\w\.\-]+)", rhs):
                    st.calls.append((m.group(1), 1.0, "loop"))
            else:
                # fusion/reduce/etc.: callee FLOPs count, callee bytes do NOT
                # (the call site's operands/results are the HBM traffic)
                for m in _CALLS_RE.finditer(rhs):
                    st.calls.append((m.group(1), 1.0, "fusion"))
        stats[name] = st

    # ---- recursive rollup ----
    if entry_name is None:
        called = {c for st in stats.values() for c, _ in st.calls}
        candidates = [n for n in stats if n not in called]
        entry_name = candidates[0] if candidates else next(iter(stats))

    memo: Dict[str, Tuple[float, float, float, Dict[str, float]]] = {}

    def dfs(name: str):
        if name in memo:
            return memo[name]
        st = stats.get(name)
        if st is None:
            return 0.0, 0.0, 0.0, {}
        memo[name] = (0.0, 0.0, 0.0, {})
        fl, mb, cb = st.flops, st.mem_bytes, st.coll_bytes
        counts = {k: float(v) for k, v in st.coll_counts.items()}
        for callee, mult, kind in st.calls:
            cfl, cmb, ccb, ccnt = dfs(callee)
            fl += mult * cfl
            cb += mult * ccb
            if kind == "loop":
                mb += mult * cmb
            # fusion callees: bytes stay at the call site
            for k, v in ccnt.items():
                counts[k] = counts.get(k, 0.0) + mult * v
        memo[name] = (fl, mb, cb, counts)
        return memo[name]

    flops, mem_bytes, coll_bytes, counts = dfs(entry_name)
    return {
        "flops_per_device": flops,
        "hbm_bytes_per_device": mem_bytes,
        "collective_bytes_per_device": coll_bytes,
        "collective_counts": counts,
        "entry": entry_name,
        "num_computations": len(comps),
        "num_partitions": num_partitions,
    }


def _trip_count(cond_lines: List[str], cache: Dict[str, float], key: str) -> float:
    if key in cache:
        return cache[key]
    const = None
    for line in cond_lines:
        m = re.search(r"constant\((\d+)\)", line)
        if m:
            const = int(m.group(1))
    cache[key] = float(const) if const is not None else 1.0
    return cache[key]
