import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks the device count on first
# init).  512 placeholder host devices back both production meshes:
# single-pod (16, 16) uses the first 256, multi-pod (2, 16, 16) uses all.

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell:
  1. build the abstract model/optimizer state (ShapeDtypeStructs — nothing
     is allocated),
  2. derive FSDP×TP shardings from train.sharding,
  3. `jit(step).lower(...)` + `.compile()` against the production mesh,
  4. record `memory_analysis()` (fits-per-device proof), `cost_analysis()`,
     and the loop-aware HLO profile (FLOPs + collective wire bytes) that
     §Roofline consumes.

Results stream to benchmarks/results/dryrun_<mesh>.json incrementally, so a
partial run is still useful.  Any sharding mismatch, compile OOM, or
unsupported collective surfaces here as a hard failure — by design.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch all --shape all
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi  --arch rwkv6-7b --shape train_4k
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_architectures
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES, batch_specs, cell_supported, decode_specs
from repro.models import build_model
from repro.train import sharding as shd
from repro.train.optimizer import AdamWConfig, OptState
from repro.train.step import TrainState, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results")


def _abstract_state(model, params_abs):
    mu = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), params_abs)
    nu = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), params_abs)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    return TrainState(params=params_abs, opt=OptState(step, mu, nu), residual=None)


def _state_shardings(mesh, params_abs, scan_layers=True):
    psh = shd.params_shardings(params_abs, mesh, scan_layers)
    rep = NamedSharding(mesh, P())
    return TrainState(params=psh,
                      opt=OptState(rep, jax.tree.map(lambda s: s, psh),
                                   jax.tree.map(lambda s: s, psh)),
                      residual=None)


def lower_cell(arch: str, shape_name: str, mesh,
               variant: str = "baseline") -> Dict[str, Any]:
    import dataclasses as _dc

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": reason}

    if variant == "opt":
        # beyond-baseline levers (§Perf iteration log in EXPERIMENTS.md):
        #   - sub-block GLA for SSM/hybrid (confirmed: -42% HBM, -60% FLOPs)
        #   - dense-all-experts MoE for train (kills dispatch collectives)
        #   - token-chunked MoE for prefill (dispatch-buffer memory)
        #   - sqrt-remat for deep/wide dense archs (residual-stream memory)
        # sequence-parallel constraint hints were tried and REFUTED (GSPMD
        # reshards inside chunked attention; coll bytes 9x worse).
        over = {}
        if cfg.has_ssm:
            over["gla_impl"] = "subblock"
        if cfg.is_moe and shape.kind == "train":
            over["moe_dense_train"] = True
        if cfg.is_moe and shape.kind == "prefill":
            over["moe_chunk"] = 16384
        if cfg.num_layers * cfg.d_model >= 52 * 6144:  # deep/wide dense
            for g in (8, 6, 4, 2):
                if cfg.num_layers % g == 0:
                    over["remat_groups"] = g
                    break
        if over:
            cfg = _dc.replace(cfg, **over)

    model = build_model(cfg)
    dp = shd.data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    model.shard_hints = {
        "dp": dp,
        "tp": shd.tp_axis(mesh),
        "dp_ok": shape.global_batch % max(dp_size, 1) == 0,
        # sequence-parallel hints: REFUTED for attention archs (GSPMD
        # reshards inside chunked attention — mistral coll 9x worse) and for
        # pure SSM (rwkv's 64x64 f32 state reshards per chunk — 10x worse);
        # CONFIRMED for hybrid (hymba: tiny 16-dim state, and the dominant
        # seq-elementwise GLA traffic shards cleanly: -42% memory term).
        "sp": (variant == "opt" and cfg.family == "hybrid" and shape.kind == "train"
               and shape.seq_len % mesh.shape[shd.tp_axis(mesh) or "model"] == 0),
    }
    params_abs = model.init_abstract()
    t0 = time.time()

    if shape.kind == "train":
        batch_abs = batch_specs(cfg, shape)
        state_abs = _abstract_state(model, params_abs)
        state_sh = _state_shardings(mesh, params_abs, cfg.scan_layers)
        batch_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                shd.batch_pspecs(batch_abs, mesh))
        # Sequence-level microbatching: per-device microbatch = 1 sequence.
        # The layer scan saves its carry (the residual stream) per layer for
        # backward even under full remat, so activation memory is
        # L x (microbatch tokens) x D — at 88 layers x 12288 wide that only
        # fits HBM with the smallest microbatch.  Grad accumulation keeps
        # numerics identical (tests/test_train.py).
        microbatches = max(shape.global_batch // max(dp_size, 1), 1)
        step_fn = make_train_step(model, AdamWConfig(), microbatches=microbatches)
        with mesh:
            lowered = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                              out_shardings=(state_sh, None),
                              donate_argnums=(0,)).lower(state_abs, batch_abs)
    elif shape.kind == "prefill":
        batch_abs = batch_specs(cfg, shape)
        params_sh = shd.params_shardings(params_abs, mesh, cfg.scan_layers)
        batch_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                shd.batch_pspecs(batch_abs, mesh))
        cache_abs = model.cache_spec(shape.global_batch, shape.seq_len)
        cache_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                shd.cache_pspecs(cache_abs, mesh))
        fn = lambda p, b: model.prefill(p, b, cache_len=shape.seq_len)
        with mesh:
            lowered = jax.jit(fn, in_shardings=(params_sh, batch_sh),
                              out_shardings=(None, cache_sh)).lower(
                params_abs, batch_abs)
    else:  # decode
        token_abs, cache_abs = decode_specs(cfg, shape)
        params_sh = shd.params_shardings(params_abs, mesh, cfg.scan_layers)
        cache_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                shd.cache_pspecs(cache_abs, mesh))
        token_sh = NamedSharding(mesh, shd.batch_pspec(mesh, shape.global_batch))
        with mesh:
            lowered = jax.jit(model.decode_step,
                              in_shardings=(params_sh, cache_sh, token_sh),
                              out_shardings=(None, cache_sh),
                              donate_argnums=(1,)).lower(
                params_abs, cache_abs, token_abs)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    prof = hlo_analysis.analyze_hlo(hlo)

    return {
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": mem.peak_memory_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "cost_analysis": {
            "flops_loop_body_once": cost.get("flops", -1.0),
            "bytes_accessed": cost.get("bytes accessed", -1.0),
        },
        "hlo_profile": {
            "flops_per_device": prof["flops_per_device"],
            "hbm_bytes_per_device": prof["hbm_bytes_per_device"],
            "collective_bytes_per_device": prof["collective_bytes_per_device"],
            "collective_counts": prof["collective_counts"],
            "num_partitions": prof["num_partitions"],
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--variant", choices=["baseline", "opt"], default="baseline")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    assert jax.device_count() == 512, \
        f"dry-run needs 512 placeholder devices, got {jax.device_count()}"
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    archs = list_architectures() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]

    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = "" if args.variant == "baseline" else f"_{args.variant}"
    out_path = args.out or os.path.join(
        RESULTS_DIR, f"dryrun_{args.mesh}{suffix}.json")
    results: Dict[str, Any] = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)

    failures = 0
    for arch in archs:
        for shape in shapes:
            key = f"{arch}|{shape}"
            if results.get(key, {}).get("status") in ("ok", "skipped"):
                print(f"[cached] {key}: {results[key]['status']}")
                continue
            print(f"[dryrun:{args.mesh}] {key} ...", flush=True)
            try:
                res = lower_cell(arch, shape, mesh, variant=args.variant)
            except Exception as e:  # noqa: BLE001 — failures ARE the signal
                res = {"status": "failed", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                failures += 1
            results[key] = res
            with open(out_path, "w") as f:
                json.dump(results, f, indent=1)
            if res["status"] == "ok":
                m = res["memory"]
                print(f"  ok: compile={res['compile_s']}s "
                      f"args={m['argument_bytes']/2**30:.2f}GiB "
                      f"peak_temp={m['temp_bytes']/2**30:.2f}GiB "
                      f"flops/dev={res['hlo_profile']['flops_per_device']:.3e} "
                      f"coll/dev={res['hlo_profile']['collective_bytes_per_device']/2**30:.3f}GiB",
                      flush=True)
            else:
                print(f"  {res['status']}: {res.get('reason') or res.get('error')}",
                      flush=True)
    print(f"done; {failures} failures -> {out_path}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
