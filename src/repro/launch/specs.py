"""Assigned input shapes × per-arch input specs (ShapeDtypeStruct stand-ins).

40 cells total: 10 architectures × 4 shapes.  `decode_*`/`long_*` lower
`serve_step` (one token against a seq_len cache); `train_4k` lowers
`train_step`; `prefill_32k` lowers the prefill graph.  `long_500k` requires
sub-quadratic attention — pure full-attention archs skip it (recorded, per
the assignment; see DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full attention: O(S^2) attention and a 500k KV "
                       "cache are not servable; skipped per assignment "
                       "(runs for ssm/hybrid)")
    return True, ""


def _sd(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model-input ShapeDtypeStructs for train/prefill kinds (weak-type
    correct, shardable, zero allocation)."""
    b, s = shape.global_batch, shape.seq_len
    batch: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "vlm":
        text = s - cfg.num_patches
        batch["tokens"] = _sd((b, text), jnp.int32)
        batch["patch_embeds"] = _sd((b, cfg.num_patches, cfg.d_model), jnp.float32)
        if shape.kind == "train":
            batch["labels"] = _sd((b, s), jnp.int32)
        return batch
    batch["tokens"] = _sd((b, s), jnp.int32)
    if cfg.family == "encdec":
        batch["frames"] = _sd((b, cfg.enc_seq, cfg.d_model), jnp.float32)
    if shape.kind == "train":
        batch["labels"] = _sd((b, s), jnp.int32)
    return batch


def decode_specs(cfg: ModelConfig, shape: ShapeSpec):
    """(token, cache) ShapeDtypeStructs for decode kinds."""
    from repro.models import build_model

    model = build_model(cfg)
    token = _sd((shape.global_batch,), jnp.int32)
    cache = model.cache_spec(shape.global_batch, shape.seq_len)
    return token, cache


def input_specs(cfg: ModelConfig, shape_name: str):
    """All model inputs for the cell, as ShapeDtypeStructs."""
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        token, cache = decode_specs(cfg, shape)
        return {"token": token, "cache": cache}
    return {"batch": batch_specs(cfg, shape)}
