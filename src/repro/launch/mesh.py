"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS for 512 placeholder devices before any
jax import and only then calls these.
"""

from __future__ import annotations

import jax

TP = 16          # model-parallel degree (divides every arch's sharded dims)
POD_DATA = 16    # data-parallel degree within a pod (16x16 = 256 chips/pod)
PODS = 2


def make_production_mesh(*, multi_pod: bool = False):
    shape = (PODS, POD_DATA, TP) if multi_pod else (POD_DATA, TP)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke paths."""
    return jax.make_mesh((1, 1), ("data", "model"))
