"""Serving driver: batched requests through the slot engine.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --reduced \
      --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params, batch_slots=args.slots,
                         cache_len=args.cache_len, seed=args.seed)

    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    ids = []
    for _ in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, rng.integers(2, 8)).tolist()
        ids.append(engine.submit(prompt, max_new_tokens=args.max_new))
    out = engine.run()
    dt = time.perf_counter() - t0
    tokens = sum(len(v) for v in out.values())
    print(f"served {len(out)}/{args.requests} requests, {tokens} tokens in "
          f"{dt:.2f}s ({tokens / dt:.1f} tok/s, {engine.steps} engine steps, "
          f"{args.slots} slots)")
    for rid in ids[:3]:
        print(f"  req {rid}: {out[rid]}")
    return out


if __name__ == "__main__":
    main()
