"""Roofline analysis (deliverable g) — reads the dry-run JSON.

Per (arch × shape) on the single-pod mesh, derive the three roofline terms
from the compiled artifact (per-device quantities; uniform SPMD means
per-device == global/chips):

  compute    = HLO_FLOPs/dev / peak_FLOPs          (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes/dev / HBM_bw              (819 GB/s)
  collective = wire_bytes/dev / ICI link bw        (50 GB/s/link)

plus MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE; 2·N·D inference) and the
usefulness ratio MODEL/HLO that catches remat and redundancy waste.  The
dominant term is the bottleneck §Perf iterates on.

Usage: PYTHONPATH=src python -m repro.launch.roofline [--json path] [--md]
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict

from repro.configs import get_config
from repro.launch.specs import SHAPES
from repro.models import build_model
from repro.models.model import padded_vocab

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results")


def param_counts(arch: str) -> Dict[str, float]:
    """Total and active (per-token) parameter counts, embeddings excluded
    from the FLOPs-relevant count's gather side but head included."""
    cfg = get_config(arch)
    model = build_model(cfg)
    abs_params = model.init_abstract()
    import numpy as np

    total = active = 0.0
    def visit(path, leaf):
        nonlocal total, active
        n = float(np.prod(leaf.shape))
        name = path[-1]
        total += n
        if name == "embed":
            return  # gather, not matmul
        if name.startswith("e_w"):
            active += n * cfg.top_k / max(cfg.num_experts, 1)
        else:
            active += n

    def walk(tree, path=()):
        if isinstance(tree, dict):
            for k, v in tree.items():
                walk(v, path + (k,))
        else:
            visit(path, tree)

    walk(abs_params)
    return {"total": total, "active_matmul": active}


def model_flops(arch: str, shape_name: str, chips: int) -> float:
    """Per-device MODEL_FLOPS for the cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    pc = param_counts(arch)
    n_act = pc["active_matmul"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens / chips
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch / chips


def _advice(dom: str, arch: str, shape: str) -> str:
    return {
        "compute": "raise MFU: fuse small ops, widen per-device batch, or cut "
                   "remat recompute (choose a dots-saveable policy)",
        "memory": "cut HBM traffic: bf16 boundaries, fuse norms/residuals, "
                  "larger fusion blocks (weight-streaming bound at decode)",
        "collective": "cut wire bytes: bf16 collectives, sequence-parallel TP "
                      "(reduce-scatter instead of all-reduce), or overlap "
                      "param gathers with compute",
    }[dom]


def analyze(dryrun_json: str, chips: int = 256) -> Dict[str, dict]:
    with open(dryrun_json) as f:
        cells = json.load(f)
    out: Dict[str, dict] = {}
    for key, res in sorted(cells.items()):
        if res.get("status") != "ok":
            out[key] = {"status": res.get("status", "missing"),
                        "reason": res.get("reason") or res.get("error", "")[:200]}
            continue
        arch, shape = key.split("|")
        prof = res["hlo_profile"]
        t_compute = prof["flops_per_device"] / PEAK_FLOPS
        t_memory = prof.get("hbm_bytes_per_device", 0.0) / HBM_BW
        t_coll = prof["collective_bytes_per_device"] / ICI_BW
        terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
        dom = max(terms, key=terms.get)
        mf = model_flops(arch, shape, chips)
        bound = max(terms.values())
        out[key] = {
            "status": "ok",
            "compute_s": t_compute,
            "memory_s": t_memory,
            "collective_s": t_coll,
            "dominant": dom,
            "model_flops_per_device": mf,
            "useful_ratio": mf / prof["flops_per_device"]
            if prof["flops_per_device"] else 0.0,
            "roofline_fraction": t_compute / bound if bound > 0 else 0.0,
            "peak_temp_gib": res["memory"]["temp_bytes"] / 2**30,
            "advice": _advice(dom, arch, shape),
        }
    return out


def to_markdown(table: Dict[str, dict]) -> str:
    lines = [
        "| cell | compute (s) | memory (s) | collective (s) | dominant | "
        "MODEL/HLO | roofline frac | peak temp |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for key, row in table.items():
        if row.get("status") != "ok":
            lines.append(f"| {key} | — | — | — | {row.get('status')} "
                         f"| — | — | {row.get('reason','')[:60]} |")
            continue
        lines.append(
            f"| {key} | {row['compute_s']:.3f} | {row['memory_s']:.3f} | "
            f"{row['collective_s']:.3f} | **{row['dominant']}** | "
            f"{row['useful_ratio']:.2f} | {row['roofline_fraction']:.2f} | "
            f"{row['peak_temp_gib']:.1f} GiB |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=os.path.join(RESULTS_DIR, "dryrun_single.json"))
    ap.add_argument("--out", default=os.path.join(RESULTS_DIR, "roofline.json"))
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    table = analyze(args.json)
    with open(args.out, "w") as f:
        json.dump(table, f, indent=1)
    print(to_markdown(table))
    print(f"\nwritten: {args.out}")


if __name__ == "__main__":
    main()
