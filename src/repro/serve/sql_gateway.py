"""Multi-client SQL gateway over one Session — the AQP serving front.

Mirrors :class:`repro.serve.engine.ServeEngine`'s submit/run idiom for the
query side of the house: many clients post dialect SQL, the gateway parses
each request immediately (a client's syntax error fails only that client's
ticket, never the batch) and enqueues the rest on the session's
:class:`QueryScheduler`.  ``run()`` drains in signature-grouped,
submission-fair batches, so a thundering herd of structurally identical
dashboard queries compiles once and runs warm — the paper's middleware
stance (§2.4) at serving scale.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.api.scheduler import QueryScheduler
from repro.api.session import QueryHandle, Session


@dataclasses.dataclass
class GatewayStats:
    requests: int = 0
    rejected: int = 0          # failed at parse, never scheduled
    served: int = 0
    drains: int = 0
    compile_misses: int = 0
    compile_hits: int = 0

    @property
    def cache_hit_rate(self) -> float:
        total = self.compile_hits + self.compile_misses
        return self.compile_hits / total if total else 0.0


class SqlGateway:
    def __init__(self, session: Session, *, batch_size: Optional[int] = None):
        if batch_size is not None and batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        self.session = session
        self.batch_size = batch_size
        # A private scheduler over the shared session: draining this gateway
        # never executes (or counts) queries submitted elsewhere on the
        # session, and two gateways over one session keep separate stats.
        self.scheduler = QueryScheduler(session)
        self.stats = GatewayStats()
        self._tickets: Dict[int, Tuple[str, QueryHandle]] = {}

    # -- client API -----------------------------------------------------------
    def submit(self, client_id: str, sql: str) -> int:
        """Post one client request; returns a ticket (the query id)."""
        self.stats.requests += 1
        try:
            handle = self.scheduler.submit(self.session.prepare(sql))
        except (ValueError, RecursionError) as e:
            # ValueError covers SqlSyntaxError/UnsupportedSqlError (both
            # subclass it); anything else — an internal bug — propagates
            # loudly instead of being blamed on the client.
            # one client's unparseable request (including pathological
            # inputs like a parser-depth-busting predicate chain) fails
            # only that ticket, never the batch
            handle = self.session.failed_handle(sql, f"{type(e).__name__}: {e}")
            self.stats.rejected += 1
        self._tickets[handle.query_id] = (client_id, handle)
        return handle.query_id

    def run(self) -> Dict[int, QueryHandle]:
        """Drain every scheduled request; returns ticket -> finished handle.

        Only *this round's* results are returned: delivered tickets are
        pruned, so a long-lived submit/run loop neither re-delivers stale
        answers nor accumulates every answer ever served.
        """
        while self.scheduler.pending_count:
            done = self.scheduler.drain(self.batch_size)
            self.stats.drains += 1
            self.stats.served += len(done)
            drain = self.scheduler.last_drain
            self.stats.compile_misses += drain.compile_misses
            self.stats.compile_hits += drain.compile_hits
        delivered = {qid: h for qid, (_, h) in self._tickets.items()
                     if h.done}
        for qid in delivered:
            del self._tickets[qid]
        return delivered

    def results_for(self, client_id: str) -> List[QueryHandle]:
        """This client's not-yet-delivered handles (pending or undelivered
        failures); answers already returned by ``run()`` are pruned."""
        return [h for cid, h in self._tickets.values() if cid == client_id]
