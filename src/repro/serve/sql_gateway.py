"""Multi-client SQL gateway over one Session — the AQP serving front.

Mirrors :class:`repro.serve.engine.ServeEngine`'s submit/run idiom for the
query side of the house: many clients post dialect SQL, the gateway parses
each request immediately (a client's syntax error fails only that client's
ticket, never the batch) and enqueues the rest on its scheduler.  ``run()``
drains in signature-grouped, submission-fair batches through the session's
concurrent runtime — a thundering herd of structurally identical dashboard
queries compiles once, runs ONE shared pilot, and repeated identical
requests answer straight from the session result cache — the paper's
middleware stance (§2.4) at serving scale.

Backpressure.  Admission is bounded two ways, both raising
:class:`repro.runtime.BackpressureError` *before* a ticket exists (the
request is refused, not failed — the client retries after results drain):

* ``max_pending`` caps this gateway's total unfinished admitted work —
  queries still queued AND queries in flight on runtime workers (work
  admitted by other gateways or direct session drains never consumes this
  gateway's budget);
* ``max_inflight_per_client`` caps one client's share of it, so a single
  dashboard storm cannot monopolize the admission queue.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.api.scheduler import QueryScheduler
from repro.api.session import QueryHandle, Session
from repro.obs import slo as _slo
from repro.obs import timeseries as _timeseries
from repro.runtime import BackpressureError
from repro.stream import Frame


@dataclasses.dataclass
class GatewayStats:
    requests: int = 0
    rejected: int = 0          # failed at parse, never scheduled
    throttled: int = 0         # refused admission (backpressure), no ticket
    served: int = 0
    drains: int = 0
    compile_misses: int = 0
    compile_hits: int = 0
    pilots_run: int = 0        # pilot stages executed on behalf of this gateway
    result_hits: int = 0       # tickets answered from the session result cache
    streams: int = 0           # tickets admitted via submit_streaming
    frames_pushed: int = 0     # frames landed in client queues
    frames_dropped: int = 0    # advisory frames evicted by the queue bound

    @property
    def cache_hit_rate(self) -> float:
        total = self.compile_hits + self.compile_misses
        return self.compile_hits / total if total else 0.0

    def as_dict(self) -> Dict[str, object]:
        out = dataclasses.asdict(self)
        out["cache_hit_rate"] = self.cache_hit_rate
        return out


# Distinguishes collector names when several gateways share one session's
# metrics registry (each gateway keeps separate GatewayStats).
_GATEWAY_SEQ = itertools.count()


class SqlGateway:
    def __init__(self, session: Session, *, batch_size: Optional[int] = None,
                 max_pending: Optional[int] = None,
                 max_inflight_per_client: Optional[int] = None,
                 max_frames_per_client: int = 1024):
        if batch_size is not None and batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        if max_inflight_per_client is not None and max_inflight_per_client < 1:
            raise ValueError(f"max_inflight_per_client must be >= 1, "
                             f"got {max_inflight_per_client}")
        if max_frames_per_client < 1:
            raise ValueError(f"max_frames_per_client must be >= 1, "
                             f"got {max_frames_per_client}")
        self.session = session
        self.batch_size = batch_size
        self.max_pending = max_pending
        self.max_inflight_per_client = max_inflight_per_client
        self.max_frames_per_client = max_frames_per_client
        # A private scheduler over the shared session: draining this gateway
        # never executes (or counts) queries submitted elsewhere on the
        # session, and two gateways over one session keep separate stats.
        self.scheduler = QueryScheduler(session)
        self.stats = GatewayStats()
        # Expose this gateway's counters through the session's metrics
        # registry: the collector holds the gateway only weakly (owner), so
        # a dropped gateway disappears from scrapes instead of leaking.
        self._collector_name = f"gateway_{next(_GATEWAY_SEQ)}"
        session.metrics.register_collector(
            self._collector_name, self.stats.as_dict, owner=self)
        self._tickets: Dict[int, Tuple[str, QueryHandle]] = {}
        # per-client bounded frame queues (submit_streaming tickets push
        # here from runtime workers; frames_for drains on the client's turn)
        self._frames: Dict[str, Deque[Frame]] = {}
        self._frame_lock = threading.Lock()

    # -- admission control ----------------------------------------------------
    def _admitted_load(self) -> int:
        """THIS gateway's admitted work still queued or executing (tickets
        whose handles are not done — queued requests are ticketed at
        submission).  Other gateways / direct session drains sharing the
        runtime never consume this gateway's admission budget."""
        return sum(1 for _, h in self._tickets.values() if not h.done)

    def _check_admission(self, client_id: str) -> None:
        if (self.max_pending is not None
                and self._admitted_load() >= self.max_pending):
            self.stats.throttled += 1
            raise BackpressureError(
                f"admission queue full ({self.max_pending} pending); "
                "drain results (run()) and retry")
        if self.max_inflight_per_client is not None:
            mine = sum(1 for cid, h in self._tickets.values()
                       if cid == client_id and not h.done)
            if mine >= self.max_inflight_per_client:
                self.stats.throttled += 1
                raise BackpressureError(
                    f"client {client_id!r} has {mine} queries in flight "
                    f"(cap {self.max_inflight_per_client}); collect results "
                    "and retry")

    # -- client API -----------------------------------------------------------
    def submit(self, client_id: str, sql: str) -> int:
        """Post one client request; returns a ticket (the query id).

        Raises :class:`BackpressureError` when admission bounds are hit —
        the request was never admitted and no ticket exists.
        """
        self._check_admission(client_id)
        self.stats.requests += 1
        try:
            handle = self.scheduler.submit(self.session.prepare(sql))
        except (ValueError, RecursionError) as e:
            # ValueError covers SqlSyntaxError/UnsupportedSqlError (both
            # subclass it); anything else — an internal bug — propagates
            # loudly instead of being blamed on the client.
            # one client's unparseable request (including pathological
            # inputs like a parser-depth-busting predicate chain) fails
            # only that ticket, never the batch
            handle = self.session.failed_handle(sql, f"{type(e).__name__}: {e}")
            self.stats.rejected += 1
        self._tickets[handle.query_id] = (client_id, handle)
        return handle.query_id

    # -- progressive streaming ------------------------------------------------
    def _push_client_frame(self, client_id: str, frame: Frame) -> None:
        """Land one frame in ``client_id``'s bounded queue (runtime-worker
        side).  On overflow the OLDEST ADVISORY frame is evicted — advisory
        estimates are superseded by newer ones, so dropping stale ones loses
        nothing a client is owed; terminal frames are never dropped (their
        count is already bounded by the admission caps: one per ticket)."""
        with self._frame_lock:
            q = self._frames.setdefault(client_id, deque())
            if frame.advisory and len(q) >= self.max_frames_per_client:
                for i, old in enumerate(q):
                    if old.advisory:
                        del q[i]
                        break
                else:  # all resident frames terminal: drop the newcomer
                    self.stats.frames_dropped += 1
                    return
                self.stats.frames_dropped += 1
            q.append(frame)
            self.stats.frames_pushed += 1

    def submit_streaming(self, client_id: str, sql: str) -> int:
        """Post one client request as a STREAMING ticket: same admission,
        parsing, and scheduling as :meth:`submit`, but every frame of the
        query — the advisory pilot estimate(s) and the terminal frame — is
        additionally pushed to ``client_id``'s bounded frame queue, drained
        with :meth:`frames_for`.  The terminal FinalFrame carries the very
        answer object the ticket's handle delivers, so collecting frames
        instead of handles never changes an answer.
        """
        self._check_admission(client_id)
        self.stats.requests += 1
        try:
            handle = self.scheduler.submit(
                self.session.prepare(sql, stream=True))
        except (ValueError, RecursionError) as e:
            # same parse-failure capture as submit(); enabling streaming on
            # the pre-failed handle synthesizes its terminal ErrorFrame, so
            # the client's frame queue still sees the stream end
            handle = self.session.failed_handle(sql, f"{type(e).__name__}: {e}")
            self.stats.rejected += 1
        self.stats.streams += 1
        handle.on_frame(lambda f: self._push_client_frame(client_id, f))
        self._tickets[handle.query_id] = (client_id, handle)
        return handle.query_id

    def frames_for(self, client_id: str,
                   max_frames: Optional[int] = None) -> List[Frame]:
        """Drain up to ``max_frames`` of ``client_id``'s queued frames (all
        of them by default), oldest first.  Frames are delivered once."""
        with self._frame_lock:
            q = self._frames.get(client_id)
            if not q:
                return []
            n = len(q) if max_frames is None else min(max_frames, len(q))
            return [q.popleft() for _ in range(n)]

    def run(self) -> Dict[int, QueryHandle]:
        """Drain every scheduled request; returns ticket -> finished handle.

        Only *this round's* results are returned: delivered tickets are
        pruned, so a long-lived submit/run loop neither re-delivers stale
        answers nor accumulates every answer ever served.
        """
        while self.scheduler.pending_count:
            done = self.scheduler.drain(self.batch_size)
            self.stats.drains += 1
            self.stats.served += len(done)
            drain = self.scheduler.last_drain
            self.stats.compile_misses += drain.compile_misses
            self.stats.compile_hits += drain.compile_hits
            self.stats.pilots_run += drain.pilots_run
            self.stats.result_hits += drain.result_hits
        delivered = {qid: h for qid, (_, h) in self._tickets.items()
                     if h.done}
        for qid in delivered:
            del self._tickets[qid]
        return delivered

    def stats_payload(self) -> Dict[str, object]:
        """One serving-stats payload — a VIEW over the session's metrics
        registry (:meth:`repro.obs.MetricsRegistry.tree`) plus this
        gateway's own request counters.  The key schema below is PINNED
        (tests/test_serve.py asserts it recursively); new keys are additive
        only, existing keys never change type or disappear.

        * ``gateway``       — the per-gateway :class:`GatewayStats` counters:
          ``requests`` / ``rejected`` (parse failures) / ``throttled``
          (backpressure refusals) / ``served`` / ``drains`` /
          ``compile_misses`` / ``compile_hits`` / ``pilots_run`` /
          ``result_hits`` / ``streams`` / ``frames_pushed`` /
          ``frames_dropped`` / derived ``cache_hit_rate``;
        * ``compile_cache`` — :meth:`repro.engine.Executor.compile_cache_info`
          (``hits`` / ``misses`` / ``size`` resident executables plus
          ``staged_hits`` / ``staged_misses``, session-global); the grand
          totals additionally break out per path as ``pilot_hits`` /
          ``pilot_misses`` (solo and batched pilot lowerings),
          ``batched_hits`` / ``batched_misses`` (drain-group batch
          executables), ``fused_hits`` / ``fused_misses`` (single-launch
          fused TAQA programs), and ``shared_hits`` (local misses whose
          build was adopted from a same-geometry dist shard);
        * ``result_cache``  — result-cache ``hits`` / ``misses`` /
          ``evictions`` / ``invalidations`` / ``size`` / ``capacity`` AND
          byte counters ``bytes_used`` / ``max_bytes`` / derived
          ``hit_rate`` (session-global);
        * ``shard_scanned_bytes`` — per-shard sampled-slab byte attribution
          per partitioned table (``repro.dist``), empty when nothing is
          sharded;
        * ``staged``        — the materialized sample-catalog state
          (:meth:`repro.engine.Executor.staged_info`: ``hits`` / ``misses``
          / ``evictions`` counters, ``resident_bytes`` / ``max_bytes``,
          per-table ladders under ``tables``).  ALWAYS present with the
          full key schema — a session with no ladders (or an executor
          without a staged catalog) reports zero counters and empty
          ``tables``, so payload consumers never key-check;
        * ``runtime``       — async-runtime totals (``workers`` /
          ``pilot_workers`` / ``in_flight`` / ``groups_total`` / pilot
          fan-out counters) plus executor ``queries_run`` / ``pilots_run``;
        * ``audit``         — guarantee-auditor summary (``runs`` /
          ``violations`` / ``errors`` / ``max_error_ratio``; zeros when
          :attr:`SessionConfig.audit` is off);
        * ``timeseries``    — the per-template time-series snapshot
          (:meth:`repro.obs.TemplateTimeSeries.snapshot`: windowed
          p50/p95/p99 rings per template plus drain TTFF/TTF rings;
          ``enabled`` False with empty ``templates`` when
          :attr:`SessionConfig.telemetry` is off);
        * ``slo``           — the SLO-monitor summary
          (:meth:`repro.obs.SloMonitor.summary`: target count, breach
          totals, recent breaches; ``enabled`` False when telemetry is
          off).
        """
        tree = self.session.metrics.tree()
        # pinned payload schema: merge the registry's staged snapshot over a
        # full-key skeleton (duck-typed executors may lack staged_info)
        staged_info = {"hits": 0, "misses": 0, "evictions": 0,
                       "resident_bytes": 0, "max_bytes": None, "tables": {}}
        staged_info.update(tree.get("staged") or {})
        audit_info = {"runs": 0, "violations": 0, "errors": 0,
                      "max_error_ratio": 0.0}
        audit_info.update(tree.get("audit") or {})
        ts_info = _timeseries.empty_snapshot()
        ts_info.update(tree.get("timeseries") or {})
        slo_info = _slo.empty_summary()
        slo_info.update(tree.get("slo") or {})
        return {
            "gateway": self.stats.as_dict(),
            "compile_cache": tree.get("compile_cache") or {},
            "result_cache": tree.get("result_cache") or {},
            "shard_scanned_bytes": tree.get("shard_scanned_bytes") or {},
            "staged": staged_info,
            "runtime": tree.get("runtime") or {},
            "audit": audit_info,
            "timeseries": ts_info,
            "slo": slo_info,
        }

    def slo_report(self) -> List[Dict[str, object]]:
        """Current state of every configured SLO rule against its template's
        windowed statistics — one row per (rule, matching template) pair
        with the observed value, the target, and whether it is breached NOW
        (see :meth:`repro.obs.SloMonitor.report`).  Empty when the session
        has no SLO monitor (``telemetry`` off or no targets)."""
        slo = getattr(self.session, "slo", None)
        return slo.report() if slo is not None else []

    def metrics_text(self) -> str:
        """The session's full metrics registry — first-class instruments
        plus every live collector snapshot (this gateway's counters
        included) — rendered in Prometheus text exposition format."""
        return self.session.metrics.to_text()

    def results_for(self, client_id: str) -> List[QueryHandle]:
        """This client's not-yet-delivered handles (pending or undelivered
        failures); answers already returned by ``run()`` are pruned."""
        return [h for cid, h in self._tickets.values() if cid == client_id]
