"""Batched serving engine: slot-based continuous batching over decode_step.

A fixed pool of B slots share one jit'd decode_step (the batch dimension is
static, so there is exactly one compiled graph).  Requests join free slots;
finished/empty slots decode padding tokens whose outputs are ignored.
Per-slot state (remaining budget, emitted tokens) lives on the host — the
device sees only (tokens, cache).  This is the vLLM-style architecture with
the paper-aligned twist that the KV cache is *block*-structured
(cache_len-slabs), the same storage geometry BSAP samples.

Greedy sampling by default; temperature sampling via host RNG on the
returned logits (decode logits are tiny: B × vocab).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: List[int]
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, *, batch_slots: int = 4,
                 cache_len: int = 256, greedy: bool = True, seed: int = 0):
        self.model = model
        self.params = params
        self.b = batch_slots
        self.cache_len = cache_len
        self.greedy = greedy
        self.rng = np.random.default_rng(seed)
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self._next_id = 0
        self._decode = jax.jit(model.decode_step)
        self.cache = model.init_cache(batch_slots, cache_len)
        self.steps = 0

    # -- client API -----------------------------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int = 16) -> int:
        req = Request(self._next_id, list(prompt), max_new_tokens)
        self._next_id += 1
        self.queue.append(req)
        return req.req_id

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Decode until all submitted requests finish.  Returns outputs."""
        finished: Dict[int, List[int]] = {}
        pending_prefill: Dict[int, List[int]] = {}  # slot -> prompt remainder
        last_token = np.zeros(self.b, np.int32)

        for _ in range(max_steps):
            # admit queued requests into free slots (prompt fed token-by-token
            # through the same decode graph — single compiled path)
            for i in range(self.b):
                if self.slots[i] is None and self.queue:
                    req = self.queue.pop(0)
                    self.slots[i] = req
                    pending_prefill[i] = list(req.prompt)
                    last_token[i] = pending_prefill[i].pop(0) if req.prompt else 0
                    self._reset_slot(i)
            if all(s is None for s in self.slots):
                break

            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(last_token))
            self.steps += 1
            lg = np.asarray(logits, np.float32)

            for i, req in enumerate(self.slots):
                if req is None:
                    last_token[i] = 0
                    continue
                if pending_prefill.get(i):
                    last_token[i] = pending_prefill[i].pop(0)  # still prefill
                    continue
                tok = int(lg[i, : self.model.cfg.vocab_size].argmax()) \
                    if self.greedy else self._sample(lg[i])
                req.out_tokens.append(tok)
                last_token[i] = tok
                if len(req.out_tokens) >= req.max_new_tokens:
                    req.done = True
                    finished[req.req_id] = req.out_tokens
                    self.slots[i] = None
                    pending_prefill.pop(i, None)
        return finished

    def _reset_slot(self, i: int):
        """Fresh sequence state for a newly-admitted request: position 0 and
        cleared SSM state.  Stale KV entries need no clearing — the per-slot
        position mask hides everything past pos, and slots are overwritten
        as the new sequence advances."""
        self.cache = dict(self.cache)
        self.cache["pos"] = self.cache["pos"].at[i].set(0)
        if "ssm" in self.cache:
            self.cache["ssm"] = self.cache["ssm"].at[:, i].set(0.0)

    def _sample(self, logits: np.ndarray, temp: float = 1.0) -> int:
        v = self.model.cfg.vocab_size
        p = logits[:v] / temp
        p = np.exp(p - p.max())
        p /= p.sum()
        return int(self.rng.choice(v, p=p))
