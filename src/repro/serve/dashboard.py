"""Static ops dashboard: one self-contained HTML page from live telemetry.

:func:`render_dashboard` reads a session's observability surfaces — the
metrics registry tree, the per-template time-series, the SLO monitor, the
flight-recorder stats, and the recent sampled traces — and renders them as
a single HTML string with no external assets (inline CSS, inline SVG
sparklines), so the page can be written next to a benchmark run, attached
to a CI artifact, or served from a dumb file endpoint and opened offline.

Sections:

* header cards      — session totals (drains, queries, cache hit rates);
* template table    — one row per tracked template: deliveries, provenance
  mix, windowed latency p50/p95/p99, and a latency sparkline drawn from
  the ring's raw window (``TemplateTimeSeries.values``);
* SLO table         — ``SloMonitor.report()`` rows with breached rules
  highlighted;
* recent breaches   — the monitor's bounded recent-breach list;
* sampled traces    — the session's ``recent_traces`` ring (root span,
  duration, child count per trace);
* flight recorder   — emitted/dropped/rotation counters when armed;
* registry text     — the full Prometheus exposition in a ``<pre>``.

Read-only like every obs layer: rendering never mutates session state.
"""

from __future__ import annotations

import html
import json
from typing import List, Optional

__all__ = ["render_dashboard", "write_dashboard"]

_CSS = """
body { font-family: -apple-system, 'Segoe UI', Roboto, sans-serif;
       margin: 1.5rem; color: #1b2733; background: #f7f9fb; }
h1 { font-size: 1.4rem; } h2 { font-size: 1.05rem; margin-top: 1.6rem; }
table { border-collapse: collapse; background: #fff; font-size: 0.82rem; }
th, td { border: 1px solid #d8e0e8; padding: 0.3rem 0.55rem;
         text-align: right; }
th { background: #eef2f6; } td.k, th.k { text-align: left;
     font-family: ui-monospace, monospace; }
.cards { display: flex; flex-wrap: wrap; gap: 0.6rem; }
.card { background: #fff; border: 1px solid #d8e0e8; border-radius: 6px;
        padding: 0.5rem 0.9rem; min-width: 7rem; }
.card .v { font-size: 1.25rem; font-weight: 600; }
.card .l { font-size: 0.72rem; color: #5b6b7b; text-transform: uppercase; }
.breach { background: #fde8e8; } .ok { color: #2c7a3f; }
.bad { color: #b42318; font-weight: 600; }
svg.spark { vertical-align: middle; }
pre { background: #fff; border: 1px solid #d8e0e8; padding: 0.7rem;
      font-size: 0.72rem; overflow-x: auto; }
.muted { color: #5b6b7b; font-size: 0.8rem; }
"""


def _sparkline(values: List[float], width: int = 120, height: int = 24) -> str:
    """Inline SVG polyline over ``values`` (empty string when < 2 points)."""
    if len(values) < 2:
        return ""
    lo, hi = min(values), max(values)
    span = (hi - lo) or 1.0
    n = len(values)
    pts = " ".join(
        f"{i * (width - 2) / (n - 1) + 1:.1f},"
        f"{height - 2 - (v - lo) / span * (height - 4):.1f}"
        for i, v in enumerate(values))
    return (f'<svg class="spark" width="{width}" height="{height}" '
            f'viewBox="0 0 {width} {height}">'
            f'<polyline fill="none" stroke="#3b82c4" stroke-width="1.2" '
            f'points="{pts}"/></svg>')


def _card(label: str, value) -> str:
    return (f'<div class="card"><div class="v">{html.escape(str(value))}'
            f'</div><div class="l">{html.escape(label)}</div></div>')


def _fmt(v, digits: int = 4) -> str:
    if isinstance(v, bool):
        return "yes" if v else "no"
    if isinstance(v, float):
        return f"{v:.{digits}g}"
    return str(v)


def render_dashboard(session, *, title: str = "PilotDB telemetry",
                     max_traces: int = 8) -> str:
    """Render ``session``'s current telemetry as one self-contained HTML
    page.  Works on any session: with telemetry off the template/SLO
    sections state so instead of rendering empty tables."""
    tree = session.metrics.tree()
    ts = getattr(session, "timeseries", None)
    slo = getattr(session, "slo", None)
    recorder = getattr(session, "recorder", None)
    parts: List[str] = [
        "<!doctype html><html><head><meta charset='utf-8'>",
        f"<title>{html.escape(title)}</title>",
        f"<style>{_CSS}</style></head><body>",
        f"<h1>{html.escape(title)}</h1>",
    ]

    # -- header cards ---------------------------------------------------------
    runtime = tree.get("runtime") or {}
    result = tree.get("result_cache") or {}
    compile_ = tree.get("compile_cache") or {}
    snap = ts.snapshot() if ts is not None else None
    cards = [
        _card("queries run", runtime.get("queries_run", 0)),
        _card("pilots run", runtime.get("pilots_run", 0)),
        _card("compile hits", compile_.get("hits", 0)),
        _card("result hits", result.get("hits", 0)),
    ]
    if snap is not None:
        cards += [
            _card("drains", snap["drains"]),
            _card("templates", len(snap["templates"])),
        ]
    if slo is not None:
        s = slo.summary()
        cards.append(_card("SLO breaches", s["breaches_total"]))
    if recorder is not None:
        rstats = recorder.stats()
        cards.append(_card("events logged", rstats["emitted"]))
    parts.append(f'<div class="cards">{"".join(cards)}</div>')

    # -- per-template time-series --------------------------------------------
    parts.append("<h2>Per-template time-series</h2>")
    if snap is None or not snap["templates"]:
        parts.append('<p class="muted">Telemetry is off (or no deliveries '
                     'yet) — enable with SessionConfig(telemetry=True).</p>')
    else:
        parts.append(
            "<table><tr><th class='k'>template</th><th>deliveries</th>"
            "<th>cached</th><th>shared</th><th>fused</th><th>fallbacks</th>"
            "<th>failures</th><th>lat p50 (s)</th><th>lat p95 (s)</th>"
            "<th>lat p99 (s)</th><th>latency window</th>"
            "<th class='k'>sql example</th></tr>")
        for key, t in snap["templates"].items():
            lat = t["latency_s"]
            spark = _sparkline(ts.values(key, "latency_s"))
            sql = t.get("sql") or ""
            if len(sql) > 70:
                sql = sql[:67] + "..."
            parts.append(
                f"<tr><td class='k'>{html.escape(key)}</td>"
                f"<td>{t['deliveries']}</td><td>{t['cached']}</td>"
                f"<td>{t['shared']}</td><td>{t['fused']}</td>"
                f"<td>{t['fallbacks']}</td><td>{t['failures']}</td>"
                f"<td>{_fmt(lat.get('p50', 0.0))}</td>"
                f"<td>{_fmt(lat.get('p95', 0.0))}</td>"
                f"<td>{_fmt(lat.get('p99', 0.0))}</td>"
                f"<td>{spark}</td>"
                f"<td class='k'>{html.escape(sql)}</td></tr>")
        parts.append("</table>")
        ttff = snap["ttff_s"] or {}
        if ttff.get("window"):
            parts.append(
                f'<p class="muted">streaming: time-to-first-frame '
                f'p50={_fmt(ttff.get("p50", 0.0))}s '
                f'p95={_fmt(ttff.get("p95", 0.0))}s over '
                f'{ttff.get("window", 0)} drains</p>')

    # -- SLO ------------------------------------------------------------------
    parts.append("<h2>SLOs</h2>")
    rows = slo.report() if slo is not None else []
    if not rows:
        parts.append('<p class="muted">No SLO targets configured '
                     '(SessionConfig(slo_targets=...) or '
                     'session.slo.set_target(...)).</p>')
    else:
        parts.append(
            "<table><tr><th class='k'>template</th><th class='k'>rule</th>"
            "<th class='k'>metric</th><th>target</th><th>observed</th>"
            "<th>samples</th><th>state</th><th>breaches</th></tr>")
        for r in rows:
            cls = ' class="breach"' if r["breached"] else ""
            state = '<span class="bad">BREACHED</span>' if r["breached"] \
                else '<span class="ok">ok</span>'
            parts.append(
                f"<tr{cls}><td class='k'>{html.escape(r['template'])}</td>"
                f"<td class='k'>{html.escape(r['rule'])}</td>"
                f"<td class='k'>{html.escape(r['metric'])}</td>"
                f"<td>{_fmt(r['target'])}</td><td>{_fmt(r['observed'])}</td>"
                f"<td>{r['samples']}</td><td>{state}</td>"
                f"<td>{r['breaches_total']}</td></tr>")
        parts.append("</table>")
        recent = slo.summary()["recent_breaches"]
        if recent:
            parts.append(f'<p class="muted">{len(recent)} recent breach '
                         f'record(s); latest: '
                         f'{html.escape(json.dumps(recent[-1]))}</p>')

    # -- sampled traces -------------------------------------------------------
    traces = list(getattr(session, "recent_traces", []) or [])
    parts.append("<h2>Sampled traces</h2>")
    if not traces:
        parts.append('<p class="muted">No sampled traces '
                     '(SessionConfig(trace_sample=p) with p &gt; 0).</p>')
    else:
        parts.append("<table><tr><th>query</th><th class='k'>root span</th>"
                     "<th>duration (s)</th><th>spans</th></tr>")
        for tr in traces[-max_traces:]:
            root = tr.get("root") or tr

            def _count(sp):
                return 1 + sum(_count(c) for c in sp.get("children", ()))

            parts.append(
                f"<tr><td>{tr.get('query_id', '?')}</td>"
                f"<td class='k'>{html.escape(str(root.get('name', '?')))}"
                f"</td><td>{_fmt(root.get('duration_s', 0.0))}</td>"
                f"<td>{_count(root)}</td></tr>")
        parts.append("</table>")

    # -- flight recorder ------------------------------------------------------
    if recorder is not None:
        rstats = recorder.stats()
        parts.append(
            f"<h2>Flight recorder</h2><p class='muted'>"
            f"{html.escape(recorder.path)} — {rstats['emitted']} emitted, "
            f"{rstats['dropped']} dropped, {rstats['rotations']} "
            f"rotation(s)</p>")

    # -- raw registry ---------------------------------------------------------
    parts.append("<h2>Metrics registry</h2>")
    parts.append(f"<pre>{html.escape(session.metrics.to_text())}</pre>")
    parts.append("</body></html>")
    return "".join(parts)


def write_dashboard(path: str, session, *,
                    title: str = "PilotDB telemetry",
                    max_traces: int = 8) -> Optional[str]:
    """Render and write the dashboard to ``path``; returns the path, or
    None when the write failed (dashboards are observability — a full disk
    must not fail the caller)."""
    try:
        doc = render_dashboard(session, title=title, max_traces=max_traces)
        with open(path, "w", encoding="utf-8") as fh:
            fh.write(doc)
        return path
    except OSError:
        return None
