# Lazy exports: SqlGateway (AQP serving) must not drag the LM model stack
# in, and ServeEngine (LLM serving) must not drag the query engine in —
# each resolves on first attribute access (PEP 562).
_EXPORTS = {
    "ServeEngine": ("repro.serve.engine", "ServeEngine"),
    "SqlGateway": ("repro.serve.sql_gateway", "SqlGateway"),
    "GatewayStats": ("repro.serve.sql_gateway", "GatewayStats"),
    "render_dashboard": ("repro.serve.dashboard", "render_dashboard"),
    "write_dashboard": ("repro.serve.dashboard", "write_dashboard"),
}

__all__ = list(_EXPORTS)


def __getattr__(name):
    try:
        module_name, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(module_name), attr)
