# Partitioned tables + shard-parallel distributed execution: block-range
# ShardedTables (device round-robin placement), restriction-based per-shard
# Bernoulli sub-draws of the one content-derived realization, per-shard
# dispatches merged through per-block BSAP statistics — bit-identical for
# every shard count by construction.
from repro.dist.executor import DistExecutor
from repro.dist.merge import (ShardPart, merge_block_stats, merge_pilot_stats,
                              reduce_group_totals)
from repro.dist.shard import Shard, ShardedTable, shard_block_ids

__all__ = [
    "DistExecutor",
    "ShardedTable",
    "Shard",
    "shard_block_ids",
    "ShardPart",
    "merge_block_stats",
    "merge_pilot_stats",
    "reduce_group_totals",
]
