"""Combining per-shard BSAP state: block statistics, partial aggregates,
group bitmaps.

Everything the dist executor moves between shards is *per-block*: each
shard's dispatch returns the per-(sampled block, group) channel sums of its
own blocks, and this module combines them.  Per-block granularity is what
makes the combination exact:

* a block is never split across shards, so a block's f32 channel sums are
  computed wholly inside one dispatch and do not depend on which other
  blocks shared it (the same property the Pallas kernels' per-block grids
  rely on);
* concatenating per-shard rows in ascending shard order recovers the global
  ascending sampled-id order — bit-identical to a monolithic dispatch's
  block-statistics matrix;
* group totals are then DEFINED as the float64 reduction of the per-block
  sums in that global block order.  The reduction's input array is
  identical for every shard count, so the result is shard-count-invariant
  bitwise — re-sharding a table can never change an answer.

(The monolithic non-sharded route reduces f32 per-row on device instead;
the two routes agree to f32 rounding — exactly like the Pallas and XLA
kernel routes today — and exactly on counts and group bitmaps, whose
summands are integers.)

Empty samples keep the engine-wide semantics: a sampled scan whose GLOBAL
draw selects zero blocks raises :class:`repro.engine.executor.EmptySampleError`
— no unbiased upscale exists, and TAQA takes its explicit exact-execution
fallback.  A single *shard* drawing zero blocks is not an error: it simply
contributes no rows to the merge.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.engine.executor import EmptySampleError, PilotStats

__all__ = ["ShardPart", "merge_block_stats", "reduce_group_totals",
           "merge_pilot_stats", "EmptySampleError"]


@dataclasses.dataclass
class ShardPart:
    """One shard dispatch's contribution to a merge.

    ``block_sums`` is ``(n_real, max_groups, num_channels)`` float64 — the
    shard's per-(sampled block, group) channel sums, rows in ascending
    global block order.  ``pair_sums`` is the optional Lemma-4.8 per
    block-pair matrix ``(n_real, n_right, num_channels)``.
    """

    shard_index: int
    global_ids: np.ndarray               # (n_real,) ascending global block ids
    block_sums: np.ndarray
    pair_sums: Optional[np.ndarray] = None
    scanned_bytes: int = 0


def merge_block_stats(parts: List[ShardPart]) -> Tuple[np.ndarray, np.ndarray]:
    """Concatenate per-shard block statistics in global block order.

    Returns ``(global_ids, block_sums)`` with rows ascending in global
    block id — the same matrix a monolithic dispatch over the union of the
    sampled blocks produces.  Parts must arrive in ascending shard order
    (``ShardedTable.partition_ids`` emits them that way).
    """
    if not parts:
        raise ValueError("merge_block_stats needs at least one shard part")
    ids = np.concatenate([p.global_ids for p in parts])
    if len(ids) > 1 and not np.all(np.diff(ids) > 0):
        raise ValueError("shard parts must concatenate to ascending "
                         "global block order (disjoint ranges, shard order)")
    return ids, np.concatenate([p.block_sums for p in parts], axis=0)


def reduce_group_totals(block_sums: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Per-group channel totals from merged per-block statistics.

    ``block_sums`` is ``(n_blocks, max_groups, num_channels)`` where the
    LAST channel is the surviving-row count ("__rows").  Returns
    ``(sums (num_aggs, max_groups), counts (max_groups,))`` as float64
    reductions over blocks in the given (global) order — deterministic and
    shard-count-invariant because the input array is.
    """
    totals = block_sums.astype(np.float64, copy=False).sum(axis=0)  # (mg, C)
    channels = totals.T                                             # (C, mg)
    return channels[:-1], channels[-1]


def merge_group_present(block_sums: np.ndarray) -> np.ndarray:
    """Group-presence bitmap: a group exists iff any merged block saw a
    surviving row (row counts are non-negative, so the OR over shards and
    the sign of the summed count agree exactly)."""
    if block_sums.shape[0] == 0:
        return np.zeros(block_sums.shape[1], dtype=bool)
    return block_sums[:, :, -1].sum(axis=0) > 0


def merge_pilot_stats(
    *,
    table: str,
    theta_p: float,
    n_total_blocks: int,
    block_rows: int,
    agg_names: List[str],
    max_groups: int,
    parts: List[ShardPart],
    pair_table: Optional[str] = None,
    n_right_blocks: int = 0,
    replicated_bytes: int = 0,
    wall_time_s: float = 0.0,
) -> PilotStats:
    """Combine per-shard pilot dispatches into one :class:`PilotStats`.

    The merged ``block_sums``/``pair_sums`` are bit-identical to a
    monolithic pilot over the same sampled set (per-block statistics are
    dispatch-invariant); ``scanned_bytes`` charges each shard its own
    sampled slabs plus the replicated (unsharded) tables once.
    """
    num_channels = len(agg_names)
    if not parts:
        return PilotStats(
            table=table, theta_p=theta_p, n_sampled_blocks=0,
            n_total_blocks=n_total_blocks, block_rows=block_rows,
            agg_names=agg_names,
            block_sums=np.zeros((0, max_groups, num_channels)),
            group_present=np.zeros(max_groups, bool),
            pair_sums={}, right_total_blocks={},
            scanned_bytes=replicated_bytes, wall_time_s=wall_time_s)
    ids, block_sums = merge_block_stats(parts)
    pair_sums: Dict[str, np.ndarray] = {}
    right_total: Dict[str, int] = {}
    if pair_table is not None and all(p.pair_sums is not None for p in parts):
        pair_sums[pair_table] = np.concatenate(
            [p.pair_sums for p in parts], axis=0)
        right_total[pair_table] = n_right_blocks
    return PilotStats(
        table=table,
        theta_p=theta_p,
        n_sampled_blocks=int(len(ids)),
        n_total_blocks=n_total_blocks,
        block_rows=block_rows,
        agg_names=agg_names,
        block_sums=block_sums,
        group_present=merge_group_present(block_sums),
        pair_sums=pair_sums,
        right_total_blocks=right_total,
        scanned_bytes=sum(p.scanned_bytes for p in parts) + replicated_bytes,
        wall_time_s=wall_time_s,
    )
