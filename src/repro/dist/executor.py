"""Shard-parallel plan execution over partitioned tables.

:class:`DistExecutor` extends the engine :class:`Executor` with partitioned
registrations (:meth:`register_sharded`): a table registered with N shards
keeps its monolithic arrays in the catalog (metadata, eager paths and exact
execution are untouched) while block-sampled scans of it fan out as ONE
dispatch per shard holding sampled blocks, each against that shard's own
arrays (placed round-robin across devices by :mod:`repro.dist.shard`), and
re-join through :mod:`repro.dist.merge`.

Route.  Per-shard dispatches reuse the physical layer's *pilot* lowering —
the per-(sampled block, group) channel-sum executable — because per-block
statistics are exactly the mergeable unit (§4: block sampling commutes with
the plan suffix).  Final answers reduce the merged per-block sums in f64
over the global block order; pilot statistics ARE the merged matrix.  Both
are bit-identical for every shard count by construction (see merge.py).
Every shard runs its own compiled executable from its own compile cache, so
a shard geometry compiles once and re-dispatches warm.

Scope (documented, enforced by fallback): the dist route engages for plans
whose SINGLE sharded table carries a block sample at rate < 1; unsharded
tables in the plan (join sides) are replicated to every shard's catalog
view.  Everything else — exact scans, row sampling, multi-table sampling
plans, the eager executor — falls back to the monolithic arrays, which are
shard-count-independent by definition, so the bit-identity guarantee
survives the fallback.  An empty GLOBAL draw raises
:class:`EmptySampleError` exactly as the monolithic samplers do (TAQA's
explicit exact fallback); an empty single shard merely contributes nothing.

Accounting.  Each shard is charged its own sampled slabs
(``shard_scan_info()`` — cumulative per-shard scanned bytes, summing to the
monolithic total for the same draw); replicated tables are charged once per
query, matching the monolithic attribution.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.dist import merge
from repro.dist.shard import Shard, ShardedTable, shard_block_ids
from repro.engine import logical as L
from repro.engine.executor import (EmptySampleError, Executor, PilotStats,
                                   QueryResult)
from repro.engine.physical import (ScanRuntime, SharedBuildStore,
                                   plan_constants, scan_cost_bytes)
from repro.engine.sampling import SampleInfo, pad_block_ids
from repro.engine.staged import (DEFAULT_STAGED_RATES, ShardSubdraw,
                                 build_sharded_ladder, prepare_dist_subdraw)
from repro.engine.table import BlockTable
from repro.obs import trace as _trace


class DistExecutor(Executor):
    """An :class:`Executor` whose catalog may hold partitioned tables."""

    def __init__(self, catalog: Dict[str, BlockTable], *,
                 use_compiled: bool = True, kernel_mode: str = "auto",
                 staged_bytes: Optional[int] = None):
        super().__init__(catalog, use_compiled=use_compiled,
                         kernel_mode=kernel_mode, staged_bytes=staged_bytes)
        self._sharded: Dict[str, ShardedTable] = {}
        # Cross-shard executable store: same-geometry shard compilers (the
        # common case — equal block ranges shard into identical slab
        # shapes) adopt each other's built executables, so N shards pay
        # ONE trace+compile per plan shape.  Adoptions surface as
        # ``shared_hits`` in compile_cache_info().
        self._shared_builds = SharedBuildStore()
        # one engine Executor per shard: its catalog holds the shard slice
        # under the table's name plus every other table's monolithic arrays
        self._shard_executors: Dict[str, List[Executor]] = {}
        self._shard_lock = threading.Lock()
        # cumulative per-shard sampled-slab bytes, per sharded table
        self._shard_scanned: Dict[str, List[int]] = {}

    # -- catalog management ---------------------------------------------------
    def register_sharded(self, name: str, table: BlockTable, shards: int,
                         devices=None) -> ShardedTable:
        """Register ``table`` partitioned into ``shards`` block ranges.

        The monolithic arrays stay in the catalog (metadata / exact /
        fallback paths); block-sampled scans of ``name`` route per shard.
        Re-registering via :meth:`register_table` drops the partitioning.
        """
        sharded = ShardedTable.from_table(table, shards, devices=devices)
        super().register_table(name, table)
        executors = []
        for s in sharded.shards:
            cat = {t: v for t, v in self.catalog.items() if t != name}
            cat[name] = s.table
            executors.append(Executor(cat, use_compiled=self.use_compiled,
                                      kernel_mode=self.physical.kernel_mode,
                                      shared_builds=self._shared_builds))
        with self._shard_lock:
            self._sharded[name] = sharded
            self._shard_executors[name] = executors
            self._shard_scanned[name] = [0] * shards
        self._refresh_shard_catalogs(name, table)
        return sharded

    def register_staged(self, name: str,
                        rates=DEFAULT_STAGED_RATES, *, seed: int = 0) -> None:
        """Materialize a staged ladder; a sharded table stages PER SHARD —
        each shard gathers its restriction of the rung's one global draw, so
        the staged realization is shard-count-independent exactly like a
        fresh ``shard_block_ids`` draw."""
        if not self.use_compiled:
            return
        snap = self._shard_snapshot(name)
        if snap is None:
            return super().register_staged(name, rates, seed=seed)
        sharded, executors = snap
        self.staged.admit(build_sharded_ladder(
            name, sharded, rates, seed, self.physical.kernel_mode,
            [ex.catalog for ex in executors]))

    def register_table(self, name: str, table: BlockTable) -> None:
        """Plain (monolithic) registration; drops any existing sharding of
        ``name`` and refreshes every shard view of it."""
        super().register_table(name, table)
        with self._shard_lock:
            self._sharded.pop(name, None)
            self._shard_executors.pop(name, None)
            self._shard_scanned.pop(name, None)
        self._refresh_shard_catalogs(name, table)

    def _refresh_shard_catalogs(self, name: str, table: BlockTable) -> None:
        """Other sharded tables' shard executors see ``name`` replicated —
        keep those views current when it is (re-)registered."""
        with self._shard_lock:
            items = [(t, exs) for t, exs in self._shard_executors.items()
                     if t != name]
        for _, executors in items:
            for ex in executors:
                ex.register_table(name, table)

    def sharded_tables(self) -> Dict[str, int]:
        with self._shard_lock:
            return {t: st.num_shards for t, st in self._sharded.items()}

    def is_sharded(self, name: str) -> bool:
        """Whether ``name`` currently executes as sharded sub-scans (the
        fused single-launch program gates itself off such tables — its one
        device program cannot span shard dispatches)."""
        with self._shard_lock:
            return name in self._sharded

    def compile_cache_info(self):
        """Aggregate compile-cache counters: the monolithic compiler PLUS
        every shard executor's compiler — dist dispatches compile there, and
        session/gateway/drain stats must see them.  Per-kind breakouts
        (pilot/batched/fused) and cross-shard build adoptions
        (``shared_hits``) aggregate the same way."""
        info = super().compile_cache_info()
        with self._shard_lock:
            executors = [ex for exs in self._shard_executors.values()
                         for ex in exs]
        for ex in executors:
            shard_info = ex.compile_cache_info()
            info.hits += shard_info.hits
            info.misses += shard_info.misses
            info.size += shard_info.size
            info.staged_hits += shard_info.staged_hits
            info.staged_misses += shard_info.staged_misses
            info.pilot_hits += shard_info.pilot_hits
            info.pilot_misses += shard_info.pilot_misses
            info.batched_hits += shard_info.batched_hits
            info.batched_misses += shard_info.batched_misses
            info.fused_hits += shard_info.fused_hits
            info.fused_misses += shard_info.fused_misses
            info.shared_hits += shard_info.shared_hits
        return info

    def shard_scan_info(self) -> Dict[str, Tuple[int, ...]]:
        """Cumulative sampled-slab bytes per shard, per sharded table.
        For any given draw the entries sum to the monolithic scanned-bytes
        attribution of the same sampled block set."""
        with self._shard_lock:
            return {t: tuple(v) for t, v in self._shard_scanned.items()}

    def _note_shard_scan(self, table: str, shard_index: int, nbytes: int) -> None:
        with self._shard_lock:
            if table in self._shard_scanned:
                self._shard_scanned[table][shard_index] += nbytes

    # -- routing --------------------------------------------------------------
    def _dist_route(self, plan: L.Aggregate) -> Optional[Tuple[str, L.SampleClause]]:
        """The (table, block-sample) pair when ``plan`` takes the dist
        route; None -> monolithic execution (shard-count-independent)."""
        if not self.use_compiled or not self._sharded:
            return None
        scans = plan.scans()
        hits = [s for s in scans
                if s.table in self._sharded and s.sample is not None
                and s.sample.method == "block" and s.sample.rate < 1.0]
        if len(hits) != 1:
            return None
        target = hits[0]
        for s in scans:
            if s is not target and s.sample is not None and s.sample.rate < 1.0:
                return None  # multi-table sampling: monolithic fallback
        return target.table, target.sample

    def _shard_snapshot(self, table: str):
        """One consistent (ShardedTable, executors) pair, taken under the
        lock: a concurrent re-registration must never pair one generation's
        shard ranges with another's executors (wrong blocks scanned), nor
        KeyError a query that routed before the sharding was dropped —
        such a query runs against the consistent OLD snapshot and the
        session-level generation guard decides whether its answer is
        deliverable."""
        with self._shard_lock:
            sharded = self._sharded.get(table)
            if sharded is None:
                return None
            return sharded, self._shard_executors[table]

    # -- execution ------------------------------------------------------------
    def execute(self, plan: L.Aggregate) -> QueryResult:
        route = self._dist_route(plan)
        snap = self._shard_snapshot(route[0]) if route is not None else None
        if snap is None:  # unsharded plan, or sharding dropped concurrently
            return super().execute(plan)
        self._count("queries_run")
        return self._execute_dist(plan, route[0], route[1], *snap)

    def execute_batch(self, plans: List[L.Aggregate],
                      on_result=None) -> List[object]:
        """Dist-routed members run as per-shard dispatches (bit-identical
        to their solo execution by construction); the rest batch as usual.
        ``on_result`` keeps the base contract: dist members announce per
        member, the rest via the forwarded (index-remapped) callback."""
        dist_idx = {i for i, p in enumerate(plans)
                    if self._dist_route(p) is not None}
        if not dist_idx:
            return super().execute_batch(plans, on_result=on_result)
        results: List[object] = [None] * len(plans)
        rest = [i for i in range(len(plans)) if i not in dist_idx]
        if rest:
            remap = (None if on_result is None
                     else (lambda j, r: on_result(rest[j], r)))
            for i, r in zip(rest, super().execute_batch(
                    [plans[i] for i in rest], on_result=remap)):
                results[i] = r
        for i in sorted(dist_idx):
            results[i] = self._execute_captured(plans[i])
            if on_result is not None:
                try:
                    on_result(i, results[i])
                except Exception:
                    pass
        return results

    def _replicated_infos(self, plan: L.Aggregate, table: str) -> Dict[str, SampleInfo]:
        infos: Dict[str, SampleInfo] = {}
        for s in plan.scans():
            if s.table == table or s.table in infos:
                continue
            tab = self.catalog[s.table]
            infos[s.table] = SampleInfo(
                "none", 1.0, 0, tab.num_blocks, tab.num_blocks,
                np.arange(tab.num_blocks),
                scanned_bytes=scan_cost_bytes(tab, "none"))
        return infos

    def _staged_dist_rung(self, table: str, rate: float, sharded):
        """(ladder, rung) when the dist draw of ``table`` at ``rate`` can be
        served from per-shard staged rungs; (ladder, None) when the table
        has a ladder but must draw fresh (under the ladder's pinned seed)."""
        lad = self.staged.ladder(table)
        if lad is None:
            return None, None
        if lad.sharded is not sharded or self.physical._use_pallas():
            return lad, None
        return lad, lad.rung_for(rate)

    def _execute_dist(self, plan: L.Aggregate, table: str,
                      sample: L.SampleClause, sharded: ShardedTable,
                      executors: List[Executor]) -> QueryResult:
        t0 = time.perf_counter()
        lad, rung = self._staged_dist_rung(table, sample.rate, sharded)
        seed = sample.seed if lad is None else lad.seed
        stripped = L.strip_samples(plan)
        with _trace.span("shard_fanout", table=table,
                         shards=sharded.num_shards,
                         staged=rung is not None) as sp:
            if rung is not None:
                self.staged.note_hit()
                global_ids, splits = prepare_dist_subdraw(lad, rung,
                                                          sample.rate)
                if len(global_ids) == 0:
                    raise EmptySampleError(table, "block", sample.rate)
                parts = self._dispatch_staged_shards(stripped, table, sharded,
                                                     splits)
            else:
                if lad is not None:
                    self.staged.note_miss()
                global_ids, parts_ids = shard_block_ids(
                    sharded.num_blocks, sample.rate, seed, sharded)
                if len(global_ids) == 0:
                    raise EmptySampleError(table, "block", sample.rate)
                parts = self._dispatch_shards(stripped, table, sharded,
                                              executors, parts_ids)
            sp.set(shards_hit=len(parts),
                   scanned_bytes=sum(p.scanned_bytes for p in parts))
        _, block_sums = merge.merge_block_stats(parts)
        sums, counts = merge.reduce_group_totals(block_sums)

        infos = self._replicated_infos(plan, table)
        infos[table] = SampleInfo(
            "block", sample.rate, seed, int(len(global_ids)),
            sharded.num_blocks, global_ids,
            scanned_bytes=sum(p.scanned_bytes for p in parts))
        values = self._compose_values(plan, sums, counts, self._upscale(infos))
        return QueryResult(
            agg_names=[a.name for a in plan.aggs],
            values=values,
            raw_sums=sums,
            group_counts=counts,
            # counts is the f64-summed "__rows" channel of the same merged
            # matrix: counts > 0 IS the presence bitmap (monolithic form)
            group_present=counts > 0,
            scanned_bytes=sum(i.scanned_bytes for i in infos.values()),
            sample_infos=infos,
            wall_time_s=time.perf_counter() - t0,
        )

    def _dispatch_shards(self, stripped: L.Aggregate, table: str,
                         sharded: ShardedTable, executors: List[Executor],
                         parts_ids: List[Tuple[Shard, np.ndarray]],
                         pair_table: Optional[str] = None) -> List[merge.ShardPart]:
        """One device dispatch per shard holding sampled blocks; results are
        converted to host arrays only after every shard was dispatched, so
        multi-device placements overlap their executions.  ``sharded`` and
        ``executors`` come from one :meth:`_shard_snapshot` — never re-read
        here (see the snapshot's consistency contract)."""
        params = plan_constants(stripped)
        raw = []
        for s, local_ids in parts_ids:
            ex = executors[s.index]
            phys, n_real, _ = pad_block_ids(local_ids, s.num_blocks)
            runtime = ScanRuntime("block", n_real, len(phys), phys)
            compiled = ex.physical.compile_pilot(stripped, table, runtime,
                                                 pair_table)
            raw.append((s, local_ids, n_real,
                        compiled({table: runtime}, params)))
        parts = []
        for s, local_ids, n_real, (bs_d, _present, pair_d) in raw:
            nbytes = n_real * sharded.block_rows * sharded.row_bytes
            self._note_shard_scan(table, s.index, nbytes)
            parts.append(merge.ShardPart(
                shard_index=s.index,
                global_ids=local_ids.astype(np.int64) + s.start_block,
                block_sums=np.asarray(bs_d, np.float64)[:n_real],
                pair_sums=(None if pair_d is None
                           else np.asarray(pair_d, np.float64)[:n_real]),
                scanned_bytes=nbytes))
        return parts

    def _dispatch_staged_shards(self, stripped: L.Aggregate, table: str,
                                sharded: ShardedTable,
                                splits: List[ShardSubdraw],
                                pair_table: Optional[str] = None
                                ) -> List[merge.ShardPart]:
        """The staged twin of :meth:`_dispatch_shards`: each shard's sampled
        blocks are addressed by POSITION within its staged rung and gathered
        from the pre-staged shard-rung arrays, with the physical block count
        forced to the fresh per-shard value — same rows, same shapes, same
        reduction order, so the merged answer is bitwise the fresh one."""
        params = plan_constants(stripped)
        raw = []
        for sd in splits:
            part = sd.part
            runtime = ScanRuntime("block", sd.n_real, sd.n_phys, sd.phys,
                                  ids_dev=sd.phys_dev,
                                  nreal_dev=sd.nreal_dev)
            compiled = part.compiler.compile_pilot(stripped, table, runtime,
                                                   pair_table)
            raw.append((part, sd.local_ids, sd.n_real,
                        compiled({table: runtime}, params)))
        parts = []
        for part, local_ids, n_real, (bs_d, _present, pair_d) in raw:
            nbytes = n_real * sharded.block_rows * sharded.row_bytes
            self._note_shard_scan(table, part.shard_index, nbytes)
            parts.append(merge.ShardPart(
                shard_index=part.shard_index,
                global_ids=local_ids.astype(np.int64) + part.start_block,
                block_sums=np.asarray(bs_d, np.float64)[:n_real],
                pair_sums=(None if pair_d is None
                           else np.asarray(pair_d, np.float64)[:n_real]),
                scanned_bytes=nbytes))
        return parts

    # -- pilot ----------------------------------------------------------------
    def execute_pilot(self, plan: L.Aggregate, pilot_table: str,
                      theta_p: float, seed: int,
                      pair_tables: Tuple[str, ...] = ()) -> PilotStats:
        snap = (self._shard_snapshot(pilot_table)
                if self.use_compiled and len(pair_tables) <= 1 else None)
        if snap is None:
            return super().execute_pilot(plan, pilot_table, theta_p, seed,
                                         pair_tables)
        sharded, executors = snap
        t0 = time.perf_counter()
        lad, rung = self._staged_dist_rung(pilot_table, theta_p, sharded)
        seed = seed if lad is None else lad.seed
        names = [a.name for a in plan.aggs] + ["__rows"]
        pair_table = pair_tables[0] if pair_tables else None
        replicated = sum(
            self.catalog[t].total_bytes()
            for t in {s.table for s in plan.scans()} if t != pilot_table)
        with _trace.span("shard_fanout", table=pilot_table, pilot=True,
                         shards=sharded.num_shards,
                         staged=rung is not None) as sp:
            if rung is not None:
                self.staged.note_hit()
                global_ids, splits = prepare_dist_subdraw(lad, rung, theta_p)
                parts = (self._dispatch_staged_shards(
                    L.strip_samples(plan), pilot_table, sharded, splits,
                    pair_table) if len(global_ids) else [])
            else:
                if lad is not None:
                    self.staged.note_miss()
                global_ids, parts_ids = shard_block_ids(
                    sharded.num_blocks, theta_p, seed, sharded)
                parts = (self._dispatch_shards(L.strip_samples(plan),
                                               pilot_table, sharded,
                                               executors, parts_ids,
                                               pair_table)
                         if len(global_ids) else [])
            sp.set(shards_hit=len(parts),
                   scanned_bytes=sum(p.scanned_bytes for p in parts))
        has_pair = bool(parts) and parts[0].pair_sums is not None
        return merge.merge_pilot_stats(
            table=pilot_table,
            theta_p=theta_p,
            n_total_blocks=sharded.num_blocks,
            block_rows=sharded.block_rows,
            agg_names=names,
            max_groups=plan.max_groups,
            parts=parts,
            pair_table=pair_table if has_pair else None,
            n_right_blocks=(self.catalog[pair_table].num_blocks
                            if pair_table else 0),
            replicated_bytes=replicated,
            wall_time_s=time.perf_counter() - t0,
        )
