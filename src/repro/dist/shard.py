"""Partitioned tables: disjoint block-range shards of a :class:`BlockTable`.

A :class:`ShardedTable` splits a block table into N contiguous block-range
partitions.  Blocks — the paper's minimum unit of data accessing — are the
atomic placement unit and are never split across shards, which is what makes
every per-block BSAP statistic *mergeable*: block sampling commutes with
selection/join/union (Props. 4.4-4.6), so pilot and final aggregation state
computed independently per shard combines by concatenation/summation without
weakening the a-priori error guarantees (the same observation VerdictDB and
BlinkDB exploit to scale out).

Placement.  Each shard's column slices are materialized as their own device
arrays; with more than one JAX device available they are placed round-robin
(``jax.device_put``), otherwise they stay host-local (the CPU-hosts case).
Shard rows keep their GLOBAL origin ``block_id`` labels, so merged per-block
statistics index the same block space as the monolithic table.

Sampling.  ``shard_block_ids`` restricts the table's ONE content-derived
Bernoulli realization (``sampling.draw_block_ids`` — a pure function of the
query-content seed) to each shard's block range.  Every shard can compute
its own sub-draw locally from the shared seed, and the union of the
sub-draws *is* the monolithic draw — so the sampled block set is
bit-identical regardless of shard count.  (Independent per-shard seeds
would also yield a valid Bernoulli sample but a *different* realization per
shard count, silently breaking equal-seed replay.)
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.engine.sampling import draw_block_ids, restrict_block_ids
from repro.engine.table import BlockTable


@dataclasses.dataclass(frozen=True)
class Shard:
    """One block-range partition: blocks ``[start_block, end_block)`` of the
    base table, materialized as a standalone :class:`BlockTable` whose
    ``block_id`` column carries the *global* origin block indices."""

    index: int
    start_block: int
    end_block: int
    table: BlockTable

    @property
    def num_blocks(self) -> int:
        return self.end_block - self.start_block

    def local_ids(self, global_ids: np.ndarray) -> np.ndarray:
        """Global sampled block ids restricted to this shard, re-based to
        the shard's local block space (see ``sampling.restrict_block_ids``
        for why restriction — not independent seeding — is load-bearing)."""
        return restrict_block_ids(global_ids, self.start_block,
                                  self.end_block)


@dataclasses.dataclass
class ShardedTable:
    """N disjoint, contiguous block-range partitions of one block table."""

    name: str
    shards: List[Shard]
    num_blocks: int          # global block count (== base table's)
    block_rows: int
    row_bytes: int

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    @staticmethod
    def from_table(table: BlockTable, num_shards: int,
                   devices: Optional[Sequence] = None) -> "ShardedTable":
        """Partition ``table`` into ``num_shards`` contiguous block ranges.

        ``devices`` (default: ``jax.devices()``) receive the shard arrays
        round-robin when more than one is available; on a single-device
        host every shard stays local and "distribution" degenerates to
        independent dispatches over disjoint slices — the semantics (and
        the bit-identity guarantees) are placement-independent.
        """
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        n_blocks = table.num_blocks
        if num_shards > n_blocks:
            raise ValueError(
                f"cannot split {n_blocks} blocks into {num_shards} shards "
                "(blocks are the atomic placement unit)")
        if devices is None:
            import jax
            devices = jax.devices()
        bounds = _shard_bounds(n_blocks, num_shards)
        shards: List[Shard] = []
        for i, (lo, hi) in enumerate(bounds):
            dev = devices[i % len(devices)] if len(devices) > 1 else None
            shards.append(Shard(index=i, start_block=lo, end_block=hi,
                                table=_slice_blocks(table, lo, hi, dev)))
        return ShardedTable(name=table.name, shards=shards,
                            num_blocks=n_blocks, block_rows=table.block_rows,
                            row_bytes=table.row_bytes())

    def partition_ids(self, global_ids: np.ndarray) -> List[Tuple[Shard, np.ndarray]]:
        """Split a global sampled-id set into non-empty per-shard sub-draws
        (ascending shard order; ascending local ids within each shard —
        concatenating the per-shard results therefore recovers the global
        ascending order, which the merge layer relies on)."""
        out: List[Tuple[Shard, np.ndarray]] = []
        for shard in self.shards:
            local = shard.local_ids(global_ids)
            if len(local):
                out.append((shard, local))
        return out


def _shard_bounds(n_blocks: int, num_shards: int) -> List[Tuple[int, int]]:
    """Contiguous near-even block ranges (``np.array_split`` semantics)."""
    base, extra = divmod(n_blocks, num_shards)
    bounds, lo = [], 0
    for i in range(num_shards):
        hi = lo + base + (1 if i < extra else 0)
        bounds.append((lo, hi))
        lo = hi
    return bounds


def _slice_blocks(table: BlockTable, lo: int, hi: int, device) -> BlockTable:
    """Materialize blocks ``[lo, hi)`` as a standalone BlockTable with
    GLOBAL ``block_id`` labels (optionally placed on ``device``)."""
    import jax

    br = table.block_rows
    sl = slice(lo * br, hi * br)

    def place(arr):
        piece = arr[sl]
        return jax.device_put(piece, device) if device is not None else piece

    n_rows = min(hi * br, table.num_rows) - min(lo * br, table.num_rows)
    return BlockTable(
        name=table.name,
        columns={c: place(v) for c, v in table.columns.items()},
        block_rows=br,
        num_rows=max(n_rows, 0),
        valid=place(table.valid),
        block_id=np.repeat(np.arange(lo, hi, dtype=np.int32), br),
        # origin ids are global: merged per-block statistics index the
        # monolithic block space
        num_origin_blocks=table.num_origin_blocks,
    )


def shard_block_ids(num_blocks: int, rate: float, seed: int,
                    sharded: ShardedTable) -> Tuple[np.ndarray, List[Tuple[Shard, np.ndarray]]]:
    """The distributed TABLESAMPLE decision: ONE global Bernoulli
    realization (the same stream the monolithic samplers consume — see
    :func:`repro.engine.sampling.draw_block_ids`), restricted per shard.

    Returns ``(global_ids, [(shard, local_ids), ...])`` with empty shards
    omitted.  The union of the per-shard sub-draws equals the monolithic
    draw exactly, for any shard count — the cornerstone of the dist layer's
    bit-identity guarantees.
    """
    global_ids = draw_block_ids(num_blocks, rate, seed)
    return global_ids, sharded.partition_ids(global_ids)
