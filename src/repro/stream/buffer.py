"""Thread-safe per-query frame buffer behind ``QueryHandle.stream()``.

One buffer per streaming handle.  Emission happens on whatever thread
executes the query (the caller's for synchronous paths, a runtime worker
for async drains); consumption happens on client threads through the
blocking iterator (:meth:`FrameBuffer.stream`) or registered callbacks
(:meth:`FrameBuffer.add_callback` — the gateway's server-push hook).

Contracts:

* frames are delivered in emission order with monotonically increasing
  ``seq``; the stream ends at the first terminal frame (exactly one is ever
  pushed — the emitting sites guarantee it, the buffer enforces it);
* a callback registered *after* frames were emitted is replayed the backlog
  first, in order, so late subscription never loses frames;
* iteration over a finished stream terminates without blocking; iteration
  over a live one blocks (up to ``timeout`` per frame) until the next frame
  or the terminal arrives.

Callbacks run under the buffer lock: they stay cheap (the gateway appends to
a bounded deque) and MUST NOT call back into the buffer.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Iterator, List, Optional

from repro.stream.frames import Frame


class FrameBuffer:
    def __init__(self, query_id: int, t0: Optional[float] = None):
        self.query_id = query_id
        # the zero point of every frame's relative `emitted_at` stamp —
        # handles pass their submission instant (QueryHandle.t_submit);
        # default: buffer creation
        self.t0 = time.perf_counter() if t0 is None else t0
        self._cond = threading.Condition()
        self._frames: List[Frame] = []
        self._callbacks: List[Callable[[Frame], None]] = []
        self._closed = False

    # -- emission (runtime side) ----------------------------------------------
    def push(self, frame: Frame) -> Frame:
        """Emit one frame: stamps ``seq``/``t_emit``, wakes iterators,
        invokes callbacks in registration order.  Pushing after the terminal
        frame is a no-op (the stream already ended — a late duplicate
        completion must not grow a closed stream)."""
        with self._cond:
            if self._closed:
                return frame
            frame.seq = len(self._frames)
            frame.t_emit = time.perf_counter()
            # submit-relative latency stamp, monotone in seq (one clock)
            frame.emitted_at = frame.t_emit - self.t0
            self._frames.append(frame)
            if frame.terminal:
                self._closed = True
            for cb in self._callbacks:
                cb(frame)
            self._cond.notify_all()
        return frame

    # -- consumption (client side) --------------------------------------------
    @property
    def closed(self) -> bool:
        with self._cond:
            return self._closed

    def frames(self) -> List[Frame]:
        """Snapshot of everything emitted so far (no blocking)."""
        with self._cond:
            return list(self._frames)

    def add_callback(self, cb: Callable[[Frame], None]) -> None:
        """Register ``cb`` for every frame; already-emitted frames are
        replayed to it first (in order, under the lock) so registration
        time never changes what a subscriber observes."""
        with self._cond:
            for frame in self._frames:
                cb(frame)
            if not self._closed:
                self._callbacks.append(cb)

    def stream(self, timeout: Optional[float] = None) -> Iterator[Frame]:
        """Blocking frame iterator: yields every frame in order and stops
        after the terminal one.  ``timeout`` bounds each *wait for the next
        frame* (not the whole stream); expiry raises :class:`TimeoutError`.
        """
        i = 0
        while True:
            with self._cond:
                while i >= len(self._frames):
                    if self._closed:
                        return
                    if not self._cond.wait(timeout):
                        raise TimeoutError(
                            f"no frame for query {self.query_id} within "
                            f"{timeout}s (stream still open)")
                frame = self._frames[i]
            i += 1
            yield frame

    __iter__ = stream

    # -- drain accounting (scheduler side) ------------------------------------
    def emit_times(self) -> List[float]:
        with self._cond:
            return [f.t_emit for f in self._frames]

    def terminal_emit_time(self) -> Optional[float]:
        with self._cond:
            if self._frames and self._frames[-1].terminal:
                return self._frames[-1].t_emit
            return None
