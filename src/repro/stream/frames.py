"""Typed answer frames — the progressive-streaming wire format.

Every streamed query is a *monotone* sequence of frames: zero or more
advisory frames followed by exactly one terminal frame.

* :class:`PilotFrame` — the pilot-stage point estimate with a *provisional*
  confidence interval, emitted the moment TAQA's stage 1 returns (before any
  stage-2 dispatch).  ADVISORY ONLY: its CI comes from the pilot sample's
  t-statistics plus the Table-2 propagation rules, not from the §4 BSAP
  machinery — it carries no a-priori guarantee and is flagged
  ``advisory=True`` so no client can mistake it for one.
* :class:`FinalFrame` — the guaranteed TAQA answer, carrying the §4 error
  report.  BITWISE identical to the non-streaming ``handle.answer`` for the
  same query on an equal-seed session (it IS the delivered answer object,
  post-HAVING/LIMIT), for every configuration: solo, shared-pilot herd,
  batched finals, cached re-issues, staged ladders, and every shard count.
* :class:`ExactFrame` — the :class:`FinalFrame` subtype delivered when TAQA
  fell back to exact execution (``report.fallback`` set) or exact execution
  was requested; the answer is exact, hence trivially guaranteed.
* :class:`ErrorFrame` — terminal failure: execution failures are captured as
  a frame, never raised through a streaming client (mirroring
  ``QueryHandle``'s failure-capture contract).

``seq``, ``t_emit`` and ``emitted_at`` are assigned by the
:class:`repro.stream.FrameBuffer` at emission (monotone per query); frames
are immutable by convention after that point.
"""

from __future__ import annotations

import dataclasses
from typing import ClassVar, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Frame:
    """Common frame header; ``seq``/``t_emit`` are buffer-assigned."""

    query_id: int = -1
    seq: int = -1                 # 0-based emission index within the stream
    t_emit: float = 0.0           # time.perf_counter() at emission
    # seconds since the query was SUBMITTED (the buffer's t0, which handles
    # pin to QueryHandle.t_submit): a client-computable latency stamp —
    # TTFF is the first frame's emitted_at, time-to-final the terminal
    # frame's — monotone in seq by construction (one emission clock)
    emitted_at: float = 0.0

    advisory: ClassVar[bool] = False
    terminal: ClassVar[bool] = False
    kind: ClassVar[str] = "frame"


@dataclasses.dataclass
class PilotFrame(Frame):
    """Pilot-stage advisory estimate (see :func:`repro.core.taqa.advisory_estimate`).

    ``values``/``half_widths`` are ``(num_aggs, max_groups)`` float64: the
    Hájek point estimate of every user-facing aggregate per group, and the
    absolute half-width of its provisional ``confidence``-level interval
    (``inf`` where the pilot cannot bound a channel, e.g. zero estimates).
    ``shared=True`` marks an estimate fanned out from a pilot stage shared
    with other herd members; ``from_cache=True`` marks a replay of the
    compact pilot summary recorded on a cached answer.
    """

    names: Tuple[str, ...] = ()
    values: Optional[np.ndarray] = None        # (num_aggs, max_groups)
    half_widths: Optional[np.ndarray] = None   # absolute, same shape
    group_present: Optional[np.ndarray] = None  # (max_groups,) bool
    confidence: float = 0.0
    theta_pilot: float = 0.0
    n_pilot_blocks: int = 0
    shared: bool = False
    from_cache: bool = False

    advisory: ClassVar[bool] = True
    terminal: ClassVar[bool] = False
    kind: ClassVar[str] = "pilot"

    def scalar(self, name: str, group: int = 0) -> float:
        return float(self.values[self.names.index(name), group])

    def half_width(self, name: str, group: int = 0) -> float:
        return float(self.half_widths[self.names.index(name), group])


@dataclasses.dataclass
class FinalFrame(Frame):
    """The guaranteed answer: ``answer`` is the very object the handle
    delivers (``handle.answer``), §4 error report included — bitwise
    identity with the non-streaming path holds by construction."""

    answer: Optional[object] = None    # repro.core.taqa.ApproxAnswer
    cached: bool = False               # served from the session result cache

    advisory: ClassVar[bool] = False
    terminal: ClassVar[bool] = True
    kind: ClassVar[str] = "final"

    @property
    def report(self):
        return self.answer.report if self.answer is not None else None

    def scalar(self, name: str, group: int = 0) -> float:
        return self.answer.scalar(name, group)


@dataclasses.dataclass
class ExactFrame(FinalFrame):
    """Terminal frame whose answer came from exact execution (TAQA fallback
    or requested exact) — same payload as :class:`FinalFrame`, distinct type
    so clients can tell the guarantee's provenance at a glance."""

    kind: ClassVar[str] = "exact"


@dataclasses.dataclass
class ErrorFrame(Frame):
    """Terminal failure frame: the captured execution error, never raised."""

    error: str = ""

    advisory: ClassVar[bool] = False
    terminal: ClassVar[bool] = True
    kind: ClassVar[str] = "error"


def final_frame_for(query_id: int, answer, cached: bool = False) -> FinalFrame:
    """The terminal frame for a delivered answer: :class:`ExactFrame` when
    the report records a fallback (or exact was requested), else
    :class:`FinalFrame`."""
    report = getattr(answer, "report", None)
    cls = ExactFrame if (report is not None
                         and report.fallback is not None) else FinalFrame
    return cls(query_id=query_id, answer=answer, cached=cached)


def pilot_frame_for(query_id: int, est, *, shared: bool = False,
                    from_cache: bool = False) -> PilotFrame:
    """Wrap a :class:`repro.core.taqa.PilotEstimate` into a frame."""
    return PilotFrame(query_id=query_id, names=tuple(est.names),
                      values=est.values, half_widths=est.half_widths,
                      group_present=est.group_present,
                      confidence=est.confidence,
                      theta_pilot=est.theta_pilot,
                      n_pilot_blocks=est.n_pilot_blocks,
                      shared=shared, from_cache=from_cache)
