# Progressive answer streaming (online-aggregation serving, ROADMAP item 2):
# every query can be observed as a monotone stream of typed frames — advisory
# PilotFrames the moment TAQA's stage 1 returns, then exactly one terminal
# frame (FinalFrame with the §4 guarantee, ExactFrame on fallback, ErrorFrame
# on captured failure).  The FrameBuffer is the thread-safe plumbing behind
# QueryHandle.stream()/on_frame() and the gateway's server-push tickets.
from repro.stream.buffer import FrameBuffer
from repro.stream.frames import (ErrorFrame, ExactFrame, FinalFrame, Frame,
                                 PilotFrame, final_frame_for, pilot_frame_for)

__all__ = [
    "Frame",
    "PilotFrame",
    "FinalFrame",
    "ExactFrame",
    "ErrorFrame",
    "FrameBuffer",
    "final_frame_for",
    "pilot_frame_for",
]
