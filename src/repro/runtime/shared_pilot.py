"""One pilot, many finals: group execution with shared pilot statistics.

A drain group holds queries with equal structural signatures (sampling-
stripped plan, predicate constants included).  Within such a group, the
pilot stage — scan theta_p of the pilot table, per-block statistics — is
identical for every member whose ErrorSpec agrees on the *pilot-stage*
tunables (:func:`repro.core.taqa.pilot_params`); error/confidence targets
only enter at stage 2.  So the group runs ONE pilot and fans its block
statistics out: each member solves its own sampling-plan optimization from
its own ErrorSpec and draws its own final sample from its own seed.

Bit-identity.  The pilot seed derives from (session seed, structural
signature, pilot params) — not from any member's per-query seed — and the
session uses the *same* derivation when a query runs solo.  A query answered
from a shared pilot is therefore bit-identical to the same query run alone
on an equal-seed session: same pilot sample, same constraints, same chosen
plan, same final sample.

Failure capture.  A member whose stage 2 raises fails alone; a pilot-stage
exception fails every member that would have used that pilot (each would
have raised identically solo).  Nothing propagates out of the group — the
worker pool relies on that.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Dict, List, Tuple

from repro.core.taqa import pilot_params

if TYPE_CHECKING:  # runtime layering: session owns the runtime
    from repro.api.session import QueryHandle, Session


def subgroup_by_pilot(handles: List["QueryHandle"]) -> List[List["QueryHandle"]]:
    """Split a signature group into pilot-sharing subgroups.

    Exact-mode members (no ErrorSpec) run no pilot and each form their own
    singleton; approximate members subgroup by pilot params, keeping
    submission order within and across subgroups (first-arrival order).
    """
    subgroups: Dict[Tuple, List["QueryHandle"]] = {}
    for h in handles:
        key = ("exact", h.query_id) if h.spec is None \
            else ("pilot",) + pilot_params(h.spec)
        subgroups.setdefault(key, []).append(h)
    return list(subgroups.values())


def execute_group(session: "Session", handles: List["QueryHandle"]) -> None:
    """Run one signature group: cached members answer immediately, each
    pilot-sharing subgroup runs one pilot, members finish independently."""
    for members in subgroup_by_pilot(handles):
        live = [h for h in members
                if not h.done and not session._serve_cached(h)]
        if not live:
            continue
        if (live[0].spec is None or len(live) == 1
                or not session.config.share_pilots):
            for h in live:
                session._run_handle(h)
            continue
        _run_shared(session, live)


def _run_shared(session: "Session", live: List["QueryHandle"]) -> None:
    leader = live[0]
    pilot_seed = session._pilot_seed_for(leader)
    gen = session._scan_generations(leader.query)
    for h in live:
        h._mark_running()
    try:
        outcome = session.db.run_pilot(leader.query, leader.spec, pilot_seed)
    except Exception as e:
        # every member's solo pilot would have raised identically
        for h in live:
            h._mark_failed(f"{type(e).__name__}: {e}")
        return
    # the first member actually COMPUTED (not cache-served) owns the pilot
    # stage in its report (pilot_shared=False) — drain stats count pilot
    # stages by that flag, so it must land on a computed answer
    owns_pilot = True
    for h in live:
        # an earlier member's completion may have populated the result
        # cache with this member's exact (query, spec, seed) answer — the
        # within-batch herd case — so re-check before paying a final stage
        if session._serve_cached(h):
            continue
        try:
            ans = session.db.finish_from_pilot(h.query, h.spec, outcome,
                                               seed=h.seed,
                                               shared=not owns_pilot)
            # ownership sticks only to a COMPLETED answer: if completion
            # fails (mid-flight table replacement), the next member carries
            # the non-shared report so drain stats still see the stage.
            # (If every member fails, the stage shows only in
            # executor.pilots_run — drain stats count completed answers.)
            if session._complete_handle(h, ans, gen):
                owns_pilot = False
        except Exception as e:  # a member failing alone must not sink peers
            h._mark_failed(f"{type(e).__name__}: {e}")
