"""One pilot, many finals: group execution with shared pilot statistics and
batched final launches.

A drain group holds queries with equal *template* signatures (sampling- and
constant-stripped plan — the compile-cache grouping key).  Within it, pilot
work re-splits on the FULL constant-bearing structural signature plus the
pilot-stage tunables (:func:`repro.core.taqa.pilot_params`): pilot block
statistics depend on predicate selectivity, so two queries differing in a
WHERE constant must never share a pilot — sharing across constants would
silently break the §4 error guarantees.  Members agreeing on both run ONE
pilot and fan its block statistics out: each solves its own sampling-plan
optimization from its own ErrorSpec and draws its own final sample from its
own seed.

Pilot fan-out.  A template group's pilot subgroups are mutually independent
(one per constant/pilot-params combination), so their stage-1 pilots run
concurrently on the runtime's dedicated pilot pool
(:meth:`repro.runtime.AsyncRuntime.map_pilot_subgroups`) and re-join here
before any final launches — a constant-varied herd no longer serializes its
N pilot stages on the group's single worker.  The pool records (wall,
serial-sum) pairs per fan-out; scheduler drains surface them as
``DrainStats.pilot_fanout_*``.

Batched finals.  Stage 2 is split into planning (``PilotDB.prepare_final``)
and execution: every subgroup first plans its members' finals, then the
whole drain group's pending final scans run through
``PilotDB.run_finals_batched`` — same-signature buckets stack their block-id
matrices and hoisted-constant params rows into ONE ``lax.map`` dispatch, so
N finals cost one launch instead of N.  Lanes execute each member's solo XLA
graph, keeping batched answers bit-identical to solo runs.

Bit-identity.  The pilot seed derives from (session seed, structural
signature, pilot params) — not from any member's per-query seed — and the
session uses the *same* derivation when a query runs solo.  A query answered
from a shared pilot and/or a batched final is therefore bit-identical to the
same query run alone on an equal-seed session: same pilot sample, same
constraints, same chosen plan, same final sample, same f32 reduction order.

Failure capture.  A member whose stage 2 raises fails alone; a pilot-stage
exception fails every member that would have used that pilot (each would
have raised identically solo).  Nothing propagates out of the group — the
worker pool relies on that.
"""

from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

from repro.core.taqa import (FinalStage, PilotOutcome, advisory_estimate,
                             pilot_params)
from repro.obs import trace as _trace
from repro.stream import pilot_frame_for

if TYPE_CHECKING:  # runtime layering: session owns the runtime
    from repro.api.session import QueryHandle, Session


def subgroup_by_pilot(handles: List["QueryHandle"]) -> List[List["QueryHandle"]]:
    """Split a drain group into pilot-sharing subgroups.

    Exact-mode members (no ErrorSpec) run no pilot and each form their own
    singleton; approximate members subgroup by (full constant-bearing
    signature, pilot params) — the template-grouped scheduler may put
    constant-varied queries in one drain group, and those must NOT share
    pilot statistics.  Submission order is kept within and across subgroups
    (first-arrival order).
    """
    subgroups: Dict[Tuple, List["QueryHandle"]] = {}
    for h in handles:
        key = ("exact", h.query_id) if h.spec is None \
            else ("pilot", h.signature) + pilot_params(h.spec)
        subgroups.setdefault(key, []).append(h)
    return list(subgroups.values())


@dataclasses.dataclass
class _Pending:
    """One group member between stage-2 planning and completion."""

    handle: "QueryHandle"
    gen: tuple                              # table-generation snapshot
    outcome: PilotOutcome
    stage: Optional[FinalStage] = None      # None: deferred duplicate
    failed: Optional[str] = None
    est: Optional[object] = None            # advisory PilotEstimate (or None)


def execute_group(session: "Session", handles: List["QueryHandle"]) -> None:
    """Run one drain group: cached members answer immediately, each
    pilot-sharing subgroup runs one pilot — subgroups fan out concurrently
    on the runtime's pilot pool and re-join here — pending finals batch
    into per-bucket single dispatches, members complete independently in
    submission order."""
    shared: List[List["QueryHandle"]] = []
    for members in subgroup_by_pilot(handles):
        live = []
        for h in members:
            if h.done:
                continue
            # per-member trace activation: the cache probe's span must land
            # on ITS handle's tree, not a neighbor's
            token = _trace.activate(h._trace)
            try:
                if not session._serve_cached(h):
                    live.append(h)
            finally:
                _trace.deactivate(token)
        if not live:
            continue
        if live[0].spec is None or not session.config.share_pilots:
            # exact members, or sharing disabled: the legacy solo path
            # (its own pilot, its own final dispatch)
            for h in live:
                session._run_handle(h)
            continue
        if session.config.fused_taqa and len(live) == 1 \
                and _try_fused(session, live[0]):
            continue  # single-launch program delivered the answer
        shared.append(live)

    # Batched pilots: when the group holds several pilot subgroups, their
    # stage-1 scans dispatch FIRST through PilotDB.run_pilots_batched —
    # same-shape pilot scans (same pilot table, same plan signature under
    # the drawn geometry) stack into ONE device launch; ineligible members
    # run their bit-identical solo pilots inside the same call.  Each
    # subgroup's precomputed outcome (or captured exception) then threads
    # into the fan-out below, which keeps only the stage-2 planning.
    # Generation snapshots are taken BEFORE the batched dispatch so the
    # mid-flight table-replacement guard keeps covering the pilot stage.
    pre: List[Optional[object]] = [None] * len(shared)
    gens: List[Optional[tuple]] = [None] * len(shared)
    if len(shared) >= 2:
        for live in shared:
            for h in live:
                h._mark_running()
        gens = [session._scan_generations(live[0].query) for live in shared]
        pre = session.db.run_pilots_batched(
            [(live[0].query, live[0].spec, session._pilot_seed_for(live[0]))
             for live in shared])

    # Stage-1 fan-out: a template group may hold MANY pilot subgroups (a
    # constant-varied herd runs one pilot per constant — selectivity shapes
    # the §4 bounds), and those stages are independent: fan them out across
    # the pilot pool and re-join before the group-wide batched final
    # launch.  Results come back in submission order, completions below run
    # in submission order, and every subgroup's pilot seed is
    # content-derived — concurrency changes wall-clock, never answers.
    durations: List[float] = []

    def _stage1(args: Tuple[List["QueryHandle"], Optional[object],
                            Optional[tuple]]) -> List[_Pending]:
        live, outcome, gen = args
        t0 = time.perf_counter()
        try:
            return _pilot_and_prepare(session, live, pre=outcome, gen=gen)
        finally:
            durations.append(time.perf_counter() - t0)

    t0 = time.perf_counter()
    pend_lists = session.runtime.map_pilot_subgroups(
        _stage1, list(zip(shared, pre, gens)))
    if len(shared) >= 2:
        session.runtime.record_pilot_fanout(
            time.perf_counter() - t0, sum(durations))
    subgroups = [p for p in pend_lists if p]

    # one batched launch per same-signature bucket across the WHOLE group;
    # each subgroup's pilot-ownership box is shared between the per-bucket
    # early completions and the serial sweep below, so exactly one COMPLETED
    # member per subgroup carries pilot_shared=False whichever path lands it
    boxes = [{"owns": True} for _ in subgroups]
    if session.config.batch_finals:
        by_stage: Dict[int, Tuple[_Pending, dict]] = {}
        for pend, box in zip(subgroups, boxes):
            for p in pend:
                if p.stage is not None and p.failed is None \
                        and p.stage.answer is None:
                    by_stage[id(p.stage)] = (p, box)
        if len(by_stage) >= 2:
            def _on_answer(stage: FinalStage) -> None:
                # a bucket landed: complete its members NOW — streaming
                # clients see their FinalFrames while later buckets are
                # still dispatching (the serial sweep skips done handles)
                p, box = by_stage[id(stage)]
                _complete_one(session, p, box)

            try:
                session.db.run_finals_batched(list(
                    pb[0].stage for pb in by_stage.values()),
                    on_answer=_on_answer)
            except Exception:
                # batching is an optimization, never a failure mode: stages
                # left unanswered execute solo in the completion loop below
                # (run_final), under its per-member exception capture
                pass

    for pend, box in zip(subgroups, boxes):
        _complete_subgroup(session, pend, box)


def _pilot_and_prepare(session: "Session", live: List["QueryHandle"],
                       pre: Optional[object] = None,
                       gen: Optional[tuple] = None) -> List[_Pending]:
    """Run the subgroup's one pilot stage and plan every member's final.

    ``pre`` threads a pilot already executed by the group-wide batched
    dispatch (``PilotDB.run_pilots_batched``) into this subgroup: a
    :class:`PilotOutcome` skips the pilot stage here (the leader gets a
    retroactive summary span), a captured exception fails every member —
    exactly what the solo pilot's except-branch below would have done —
    and None runs the pilot as before.  ``gen`` carries the
    table-generation snapshot taken before that batched dispatch.
    """
    leader = live[0]
    pilot_seed = session._pilot_seed_for(leader)
    if gen is None:
        gen = session._scan_generations(leader.query)
    for h in live:
        h._mark_running()
    shared = len(live) > 1
    if isinstance(pre, Exception):
        # every member's solo pilot would have raised identically
        for h in live:
            h._mark_failed(f"{type(pre).__name__}: {pre}")
        return []
    if pre is not None:
        outcome = pre
        rep = outcome.report
        if leader._trace is not None:
            leader._trace.record(
                "pilot", duration_s=rep.pilot_time_s, shared=shared,
                owner=True, members=len(live), batched=True,
                table=rep.pilot_table, theta_pilot=rep.theta_pilot,
                n_pilot_blocks=rep.n_pilot_blocks,
                scanned_bytes=rep.pilot_scanned_bytes,
                fallback=rep.fallback)
    else:
        # the shared pilot executes ONCE, on the leader's trace: deep tags
        # (staged rung, shard fan-out, compile hit/miss) annotate the
        # leader's open "pilot" span; members get a retroactive summary
        # span below
        token = _trace.activate(leader._trace)
        try:
            with _trace.span("pilot", shared=shared, owner=True,
                             members=len(live)) as sp:
                outcome = session.db.run_pilot(leader.query, leader.spec,
                                               pilot_seed)
                rep = outcome.report
                sp.set(table=rep.pilot_table, theta_pilot=rep.theta_pilot,
                       n_pilot_blocks=rep.n_pilot_blocks,
                       scanned_bytes=rep.pilot_scanned_bytes,
                       fallback=rep.fallback)
        except Exception as e:
            # every member's solo pilot would have raised identically
            for h in live:
                h._mark_failed(f"{type(e).__name__}: {e}")
            return []
        finally:
            _trace.deactivate(token)
    # one flight-recorder record per pilot STAGE (not per member): the
    # leader's qid plus the member count it fanned out to
    session._emit_event("pilot", qid=leader.query_id, shared=shared,
                        members=len(live), table=rep.pilot_table,
                        scanned_bytes=rep.pilot_scanned_bytes,
                        wall_s=round(rep.pilot_time_s, 6),
                        fallback=rep.fallback)
    for h in live[1:]:
        if h._trace is not None:
            h._trace.record(
                "pilot", duration_s=rep.pilot_time_s, shared=True,
                owner=False, table=rep.pilot_table,
                theta_pilot=rep.theta_pilot,
                n_pilot_blocks=rep.n_pilot_blocks,
                scanned_bytes=rep.pilot_scanned_bytes,
                fallback=rep.fallback)
    # fan the shared pilot's advisory estimate out to EVERY member the
    # moment stage 1 returns — before any stage-2 planning or dispatch.
    # Members share pilot statistics but not necessarily confidence, so
    # the t-interval is computed per distinct confidence level.
    ests: Dict[float, Optional[object]] = {}
    for h in live:
        conf = h.spec.confidence
        if conf not in ests:
            ests[conf] = advisory_estimate(h.query, outcome, conf)
        if ests[conf] is not None:
            h._emit(pilot_frame_for(h.query_id, ests[conf], shared=shared))
    pend: List[_Pending] = []
    seen_keys = set()
    for h in live:
        token = _trace.activate(h._trace)
        try:
            # an earlier drain's completion may have populated the result
            # cache with this member's exact (query, spec, seed) answer
            if session._serve_cached(h):
                continue
            p = _Pending(handle=h, gen=gen, outcome=outcome,
                         est=ests.get(h.spec.confidence))
            key = session._cache_key(h)
            if session.result_cache.enabled and key in seen_keys:
                # identical re-issue inside one drain: the earlier member's
                # completion will cache the answer — defer instead of paying
                # a duplicate final execution
                pend.append(p)
                continue
            seen_keys.add(key)
            try:
                with _trace.span("rate_solve") as sp:
                    p.stage = session.db.prepare_final(h.query, h.spec,
                                                       outcome, seed=h.seed)
                    srep = p.stage.report
                    sp.set(candidates=srep.candidates,
                           fallback=srep.fallback,
                           rates=dict(srep.plan.rates)
                           if srep.plan is not None else None)
                session._emit_event("rate_solve", qid=h.query_id,
                                    candidates=srep.candidates,
                                    fallback=srep.fallback)
            except Exception as e:  # a failing member must not sink peers
                p.failed = f"{type(e).__name__}: {e}"
            pend.append(p)
        finally:
            _trace.deactivate(token)
    return pend


def _try_fused(session: "Session", h: "QueryHandle") -> bool:
    """Attempt the single-launch fused TAQA program for a singleton
    subgroup.  True when the handle completed (answer delivered, or failed
    on the completion guard); False when the query's shape is ineligible —
    the caller then falls through to the shared-pilot path having executed
    nothing (``Session._run_fused`` swallows fused-path exceptions, so a
    False return really means "nothing happened")."""
    token = _trace.activate(h._trace)
    try:
        h._mark_running()
        gen = session._scan_generations(h.query)
        ans = session._run_fused(h)
        if ans is None:
            return False
        with _trace.span("deliver"):
            session._complete_handle(h, ans, gen)
        return True
    finally:
        _trace.deactivate(token)


def _complete_one(session: "Session", p: _Pending, box: dict) -> None:
    """Finish ONE member (idempotent): called early by the batched launch's
    per-bucket callback, and again by the subgroup's serial sweep — whoever
    runs first delivers; the other sees ``handle.done`` and returns.

    ``box["owns"]`` is the subgroup's pilot-ownership flag: the first member
    that actually COMPUTES (not cache-serves) a completed answer owns the
    pilot stage in its report (pilot_shared=False) — drain stats count pilot
    stages by that flag.  Both callers run on the group's worker thread, so
    the box needs no lock.
    """
    h = p.handle
    if h.done:
        return
    token = _trace.activate(h._trace)
    try:
        if p.failed is not None:
            h._mark_failed(p.failed)
            return
        # a peer's completion may have cached this member's answer already
        if session._serve_cached(h):
            return
        try:
            if p.stage is None:  # deferred duplicate whose peer failed
                with _trace.span("rate_solve", deferred=True):
                    p.stage = session.db.prepare_final(h.query, h.spec,
                                                       p.outcome, seed=h.seed)
            # a stage answered before this sweep means the group's batched
            # lax.map dispatch landed it (or a rate-solve fallback
            # short-circuited to exact) — run_final just returns it
            pre_answered = p.stage.answer is not None
            with _trace.span("final") as sp:
                ans = session.db.run_final(p.stage)
                sp.set(batched=pre_answered and ans.report.fallback is None,
                       scanned_bytes=ans.report.final_scanned_bytes,
                       fallback=ans.report.fallback)
            session._emit_event(
                "final", qid=h.query_id,
                batched=pre_answered and ans.report.fallback is None,
                scanned_bytes=ans.report.final_scanned_bytes,
                wall_s=round(ans.report.final_time_s, 6),
                fallback=ans.report.fallback)
            ans.report.pilot_shared = not box["owns"]
            # ownership sticks only to a COMPLETED answer: if completion
            # fails (mid-flight table replacement), the next member carries
            # the non-shared report so drain stats still see the stage.
            # (If every member fails, the stage shows only in
            # executor.pilots_run — drain stats count completed answers.)
            with _trace.span("deliver"):
                if session._complete_handle(h, ans, p.gen, pilot_est=p.est):
                    box["owns"] = False
        except Exception as e:  # a member failing alone must not sink peers
            h._mark_failed(f"{type(e).__name__}: {e}")
    finally:
        _trace.deactivate(token)


def _complete_subgroup(session: "Session", pend: List[_Pending],
                       box: Optional[dict] = None) -> None:
    if box is None:
        box = {"owns": True}
    for p in pend:
        _complete_one(session, p, box)
