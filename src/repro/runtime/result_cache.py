"""Session-level LRU cache of finished approximate answers.

The many-users workload re-issues *identical* dashboards: same plan
structure, same predicate constants, same ErrorSpec.  Because the session
derives sampling seeds from query *content* (see
``repro.api.Session._derive_seed``), an identical re-issue maps to an
identical ``(query, spec, seed)`` triple — so its answer (values AND the
a-priori error report, which stays valid while the data is unchanged) can be
returned straight from this cache without touching the executor.  This is
the BlinkDB stance at the serving layer: a bounded-error answer is reusable
state, not a one-shot.

Keying.  The key is ``(query, spec, seed)`` where ``query`` is the frozen
:class:`repro.core.taqa.Query` dataclass.  That embeds the structural
signature *and* the predicate constants *and* the user-facing aggregate
names, while ``spec``/``seed`` pin the guarantee target and the sampling
realization — i.e. the (structural signature, predicate constants,
ErrorSpec, seed) key, carried by the dataclasses that already exist.

Invalidation.  ``invalidate_table(name)`` evicts every entry whose plan
scans ``name``; :meth:`repro.api.Session.register_table` calls it, so a
table replacement can never serve answers computed against the old data.
All operations are lock-guarded — runtime workers consult the cache
concurrently.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Hashable, Optional, Tuple


@dataclasses.dataclass
class ResultCacheInfo:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    invalidations: int = 0
    size: int = 0
    capacity: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class ResultCache:
    """A thread-safe LRU of (key -> (answer, scanned table names))."""

    def __init__(self, capacity: int = 128):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.capacity = capacity
        self._lock = threading.Lock()
        self._entries: "OrderedDict[Hashable, Tuple[object, frozenset]]" = \
            OrderedDict()
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._invalidations = 0

    @property
    def enabled(self) -> bool:
        return self.capacity > 0

    def get(self, key: Hashable):
        """The cached answer for ``key``, refreshed to most-recently-used,
        or None (a miss)."""
        if not self.enabled:
            return None
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self._misses += 1
                return None
            self._entries.move_to_end(key)
            self._hits += 1
            return entry[0]

    def put(self, key: Hashable, answer, tables, guard=None) -> None:
        """Insert an answer; ``tables`` are the scanned table names used for
        targeted invalidation.

        ``guard`` (optional, called under the cache lock) must return True
        for the insert to happen.  Sessions pass a table-generation check:
        an answer computed against data that ``register_table`` has since
        replaced would otherwise race past the invalidation — the guard runs
        under the same lock as ``invalidate_table``, so either the stale
        entry is skipped here or it lands first and the invalidation evicts
        it.
        """
        if not self.enabled:
            return
        with self._lock:
            if guard is not None and not guard():
                return
            self._entries[key] = (answer, frozenset(tables))
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self._evictions += 1

    def invalidate_table(self, name: str) -> int:
        """Evict every entry whose plan scanned ``name``; returns the count."""
        with self._lock:
            stale = [k for k, (_, tables) in self._entries.items()
                     if name in tables]
            for k in stale:
                del self._entries[k]
            self._invalidations += len(stale)
            return len(stale)

    def clear(self) -> None:
        with self._lock:
            self._invalidations += len(self._entries)
            self._entries.clear()

    def info(self) -> ResultCacheInfo:
        with self._lock:
            return ResultCacheInfo(
                hits=self._hits, misses=self._misses,
                evictions=self._evictions,
                invalidations=self._invalidations,
                size=len(self._entries), capacity=self.capacity)
