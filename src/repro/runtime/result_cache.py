"""Session-level LRU cache of finished approximate answers.

The many-users workload re-issues *identical* dashboards: same plan
structure, same predicate constants, same ErrorSpec.  Because the session
derives sampling seeds from query *content* (see
``repro.api.Session._derive_seed``), an identical re-issue maps to an
identical ``(query, spec, seed)`` triple — so its answer (values AND the
a-priori error report, which stays valid while the data is unchanged) can be
returned straight from this cache without touching the executor.  This is
the BlinkDB stance at the serving layer: a bounded-error answer is reusable
state, not a one-shot.

Keying.  The key is ``(query, spec, seed)`` where ``query`` is the frozen
:class:`repro.core.taqa.Query` dataclass.  That embeds the structural
signature *and* the predicate constants *and* the user-facing aggregate
names, while ``spec``/``seed`` pin the guarantee target and the sampling
realization — i.e. the (structural signature, predicate constants,
ErrorSpec, seed) key, carried by the dataclasses that already exist.

Entries.  Sessions store :class:`CachedAnswer` records, not full
``ApproxAnswer`` object graphs: the per-group values, the error report, and
the group-present bitmap *packed* (``np.packbits``, 8 groups per byte).  At
many-dashboard scale that is what lets the cache hold thousands of grouped
answers; ``max_bytes`` adds an explicit byte budget on top of the entry
count, evicting LRU-first once either bound is hit.

Invalidation.  ``invalidate_table(name)`` evicts every entry whose plan
scans ``name``; :meth:`repro.api.Session.register_table` calls it, so a
table replacement can never serve answers computed against the old data.
All operations are lock-guarded — runtime workers consult the cache
concurrently.
"""

from __future__ import annotations

import dataclasses
import sys
import threading
from collections import OrderedDict
from typing import Hashable, List, Optional, Tuple

import numpy as np

# Fixed per-entry overhead estimate (key tuple, report object, OrderedDict
# slot) charged against the byte budget so "many tiny entries" cannot blow
# past it on container overhead alone.
_ENTRY_OVERHEAD_BYTES = 512


@dataclasses.dataclass
class CachedAnswer:
    """A finished answer in cache-resident form.

    ``group_present`` is bit-packed; ``to_answer()`` rebuilds a fresh
    :class:`repro.core.taqa.ApproxAnswer` on every hit (values/report are
    shared read-only, the bitmap is unpacked per hit).

    ``pilot`` optionally records the query's compact advisory
    :class:`repro.core.taqa.PilotEstimate` (point estimates + CI half-widths
    only, never the per-block matrix) so a *streaming* cached re-issue can
    replay a provisional frame before its terminal one; its bytes are
    charged to the cache budget like everything else.
    """

    names: List[str]
    values: np.ndarray           # (num_composites, max_groups) float64
    present_bits: np.ndarray     # packbits(group_present) uint8
    n_groups: int
    report: object               # the TaqaReport guaranteed at compute time
    pilot: Optional[object] = None  # PilotEstimate (duck-typed: .nbytes())

    @classmethod
    def from_answer(cls, answer, pilot=None) -> "CachedAnswer":
        present = np.asarray(answer.group_present, dtype=bool)
        return cls(names=list(answer.names),
                   values=np.asarray(answer.values),
                   present_bits=np.packbits(present),
                   n_groups=present.shape[0],
                   report=answer.report,
                   pilot=pilot)

    def to_answer(self):
        from repro.core.taqa import ApproxAnswer  # session-layer dependency
        present = np.unpackbits(self.present_bits,
                                count=self.n_groups).astype(bool)
        return ApproxAnswer(names=list(self.names), values=self.values,
                            group_present=present, report=self.report)

    def nbytes(self) -> int:
        pilot_bytes = 0 if self.pilot is None else self.pilot.nbytes()
        return (self.values.nbytes + self.present_bits.nbytes
                + sum(len(n) for n in self.names) + pilot_bytes
                + _ENTRY_OVERHEAD_BYTES)


def _entry_bytes(value) -> int:
    """Byte charge of a cached value: CachedAnswer knows its size; foreign
    objects are charged their shallow footprint."""
    if isinstance(value, CachedAnswer):
        return value.nbytes()
    nbytes = getattr(value, "nbytes", None)
    if isinstance(nbytes, (int, np.integer)):
        return int(nbytes) + _ENTRY_OVERHEAD_BYTES
    return sys.getsizeof(value) + _ENTRY_OVERHEAD_BYTES


@dataclasses.dataclass
class ResultCacheInfo:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    invalidations: int = 0
    size: int = 0
    capacity: int = 0
    bytes_used: int = 0
    max_bytes: Optional[int] = None

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class ResultCache:
    """A thread-safe LRU of (key -> (answer, scanned table names)), bounded
    by entry count and optionally by total bytes."""

    def __init__(self, capacity: int = 128, max_bytes: Optional[int] = None):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        if max_bytes is not None and max_bytes < 0:
            raise ValueError(f"max_bytes must be >= 0, got {max_bytes}")
        self.capacity = capacity
        self.max_bytes = max_bytes
        self._lock = threading.Lock()
        # key -> (answer, scanned tables, byte charge)
        self._entries: "OrderedDict[Hashable, Tuple[object, frozenset, int]]" \
            = OrderedDict()
        self._bytes = 0
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._invalidations = 0

    @property
    def enabled(self) -> bool:
        return self.capacity > 0 and (self.max_bytes is None or self.max_bytes > 0)

    def get(self, key: Hashable):
        """The cached answer for ``key``, refreshed to most-recently-used,
        or None (a miss)."""
        if not self.enabled:
            return None
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self._misses += 1
                return None
            self._entries.move_to_end(key)
            self._hits += 1
            return entry[0]

    def _evict_lru(self) -> None:
        _, (_, _, freed) = self._entries.popitem(last=False)
        self._bytes -= freed
        self._evictions += 1

    def put(self, key: Hashable, answer, tables, guard=None) -> None:
        """Insert an answer; ``tables`` are the scanned table names used for
        targeted invalidation.

        ``guard`` (optional, called under the cache lock) must return True
        for the insert to happen.  Sessions pass a table-generation check:
        an answer computed against data that ``register_table`` has since
        replaced would otherwise race past the invalidation — the guard runs
        under the same lock as ``invalidate_table``, so either the stale
        entry is skipped here or it lands first and the invalidation evicts
        it.
        """
        if not self.enabled:
            return
        cost = _entry_bytes(answer)
        if self.max_bytes is not None and cost > self.max_bytes:
            return  # larger than the whole budget: never resident
        with self._lock:
            if guard is not None and not guard():
                return
            old = self._entries.pop(key, None)
            if old is not None:
                self._bytes -= old[2]
            self._entries[key] = (answer, frozenset(tables), cost)
            self._bytes += cost
            while len(self._entries) > self.capacity:
                self._evict_lru()
            while self.max_bytes is not None and self._bytes > self.max_bytes:
                self._evict_lru()

    def invalidate_table(self, name: str) -> int:
        """Evict every entry whose plan scanned ``name``; returns the count."""
        with self._lock:
            stale = [k for k, (_, tables, _) in self._entries.items()
                     if name in tables]
            for k in stale:
                self._bytes -= self._entries[k][2]
                del self._entries[k]
            self._invalidations += len(stale)
            return len(stale)

    def clear(self) -> None:
        with self._lock:
            self._invalidations += len(self._entries)
            self._entries.clear()
            self._bytes = 0

    def info(self) -> ResultCacheInfo:
        """One consistent counter snapshot (single lock acquisition).  The
        session metrics registry's ``result_cache`` collector reads this —
        the numbers surfaced by ``gateway.stats_payload()["result_cache"]``
        and the Prometheus exposition are exactly these fields."""
        with self._lock:
            return ResultCacheInfo(
                hits=self._hits, misses=self._misses,
                evictions=self._evictions,
                invalidations=self._invalidations,
                size=len(self._entries), capacity=self.capacity,
                bytes_used=self._bytes, max_bytes=self.max_bytes)
