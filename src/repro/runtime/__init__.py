# The concurrent query runtime (serving-scale execution behind QueryHandle):
# a worker pool overlapping host-side sampling decisions with device
# execution across drain groups, one-pilot-per-group statistic sharing, and
# a session-level LRU of finished answers.  The synchronous scheduler drain
# is the degenerate case (workers=0, sharing off, cache size 0).
from repro.runtime.pool import AsyncRuntime, BackpressureError
from repro.runtime.result_cache import (CachedAnswer, ResultCache,
                                        ResultCacheInfo)
from repro.runtime.shared_pilot import execute_group, subgroup_by_pilot

__all__ = [
    "AsyncRuntime",
    "BackpressureError",
    "CachedAnswer",
    "ResultCache",
    "ResultCacheInfo",
    "execute_group",
    "subgroup_by_pilot",
]
