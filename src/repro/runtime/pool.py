"""Thread-pool execution of drain groups — async behind QueryHandle.

JAX dispatch releases the GIL while the device executes, so a thread pool
genuinely overlaps one group's host-side work (sampling decisions, plan
optimization, tracing) with another group's device execution — the
serving-scale step past the synchronous-cooperative ``drain()`` loop.
Groups, not individual queries, are the unit of work: a group shares one
pilot (see ``shared_pilot``) and must stay on one worker so its members
finish from the same outcome without cross-thread hand-off.

Every failure is captured on the affected handles (``shared_pilot`` per
member, a last-resort net here for bugs in the group machinery itself) —
nothing raises through ``run_groups`` and no worker death loses a handle.

Backpressure is the admission side's job: :class:`BackpressureError` is
raised by callers (the SQL gateway's bounded queue and per-client caps)
when ``in_flight`` + queued work exceeds their bounds; the pool itself
never drops or blocks submissions.
"""

from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor, wait
from typing import TYPE_CHECKING, List, Optional

if TYPE_CHECKING:
    from repro.api.session import QueryHandle, Session


class BackpressureError(RuntimeError):
    """Admission refused: the queue is full or a per-client cap is hit.

    Deliberately NOT a query failure — the request was never admitted, so
    no ticket exists and no seed was consumed; the client should retry
    after draining results.
    """


class AsyncRuntime:
    """Executes drain groups on a bounded worker pool for one session."""

    def __init__(self, session: "Session", workers: int = 4):
        if workers < 0:
            raise ValueError(f"workers must be >= 0, got {workers}")
        self._session = session
        self.workers = workers
        self._pool: Optional[ThreadPoolExecutor] = None
        self._lock = threading.Lock()
        self._in_flight = 0          # handles dispatched, not yet finished
        self._futures: List[Future] = []
        self.total_groups = 0

    @property
    def is_async(self) -> bool:
        return self.workers > 0

    @property
    def in_flight(self) -> int:
        """Handles currently dispatched to workers and not yet finished —
        the admission-control signal gateways bound against."""
        with self._lock:
            return self._in_flight

    def _ensure_pool(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.workers,
                    thread_name_prefix="pilotdb-runtime")
            return self._pool

    # -- execution -----------------------------------------------------------
    def run_groups(self, groups: List[List["QueryHandle"]],
                   block: bool = True) -> None:
        """Execute signature groups; with ``block=False`` they run in the
        background and callers observe completion via handle.poll()/wait()."""
        groups = [g for g in groups if g]
        if not groups:
            return
        self.total_groups += len(groups)
        if not self.is_async:
            for g in groups:
                self._run_group_captured(g)
            return
        pool = self._ensure_pool()
        futures = []
        for g in groups:
            with self._lock:
                self._in_flight += len(g)
            fut = pool.submit(self._worker, g)
            futures.append(fut)
        with self._lock:
            self._futures = [f for f in self._futures if not f.done()]
            self._futures.extend(futures)
        if block:
            wait(futures)

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        """Block until every dispatched group finished; False on timeout."""
        with self._lock:
            outstanding = list(self._futures)
        done, not_done = wait(outstanding, timeout=timeout)
        with self._lock:
            self._futures = [f for f in self._futures if not f.done()]
        return not not_done

    def shutdown(self) -> None:
        with self._lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=True)

    # -- worker side ---------------------------------------------------------
    def _worker(self, group: List["QueryHandle"]) -> None:
        try:
            self._run_group_captured(group)
        finally:
            with self._lock:
                self._in_flight -= len(group)

    def _run_group_captured(self, group: List["QueryHandle"]) -> None:
        try:
            self._session._execute_group(group)
        except Exception as e:  # group-machinery bug: fail handles, not pool
            for h in group:
                if not h.done:
                    h._mark_failed(
                        f"runtime worker error: {type(e).__name__}: {e}")
