"""Thread-pool execution of drain groups — async behind QueryHandle.

JAX dispatch releases the GIL while the device executes, so a thread pool
genuinely overlaps one group's host-side work (sampling decisions, plan
optimization, tracing) with another group's device execution — the
serving-scale step past the synchronous-cooperative ``drain()`` loop.
Groups, not individual queries, are the unit of work: a group shares one
pilot (see ``shared_pilot``) and must stay on one worker so its members
finish from the same outcome without cross-thread hand-off.

Every failure is captured on the affected handles (``shared_pilot`` per
member, a last-resort net here for bugs in the group machinery itself) —
nothing raises through ``run_groups`` and no worker death loses a handle.
The same capture path closes every *streaming* handle's frame stream with a
terminal :class:`repro.stream.ErrorFrame` (``QueryHandle._mark_failed``
emits it), so a blocked ``stream()`` iterator always terminates — a failure
becomes a frame, never a hung client.

Backpressure is the admission side's job: :class:`BackpressureError` is
raised by callers (the SQL gateway's bounded queue and per-client caps)
when ``in_flight`` + queued work exceeds their bounds; the pool itself
never drops or blocks submissions.
"""

from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor, wait
from typing import TYPE_CHECKING, List, Optional

if TYPE_CHECKING:
    from repro.api.session import QueryHandle, Session


class BackpressureError(RuntimeError):
    """Admission refused: the queue is full or a per-client cap is hit.

    Deliberately NOT a query failure — the request was never admitted, so
    no ticket exists and no seed was consumed; the client should retry
    after draining results.
    """


class AsyncRuntime:
    """Executes drain groups on a bounded worker pool for one session.

    Two pools, deliberately separate: the GROUP pool runs whole drain
    groups (a group stays on one worker so its members finish from one
    shared pilot outcome), and the PILOT pool fans a single group's
    pilot-sharing *subgroups* out concurrently — the constant-varied herd
    whose N per-constant pilot stages would otherwise serialize on the
    group's one worker.  Group workers block on pilot futures; the pilot
    pool never submits back to the group pool, so the fan-out cannot
    deadlock however saturated either pool is (the failure mode that ruled
    out nested submission into one shared ThreadPoolExecutor).
    """

    def __init__(self, session: "Session", workers: int = 4,
                 pilot_workers: int = 0):
        if workers < 0:
            raise ValueError(f"workers must be >= 0, got {workers}")
        if pilot_workers < 0:
            raise ValueError(
                f"pilot_workers must be >= 0, got {pilot_workers}")
        self._session = session
        self.workers = workers
        self.pilot_workers = pilot_workers
        self._pool: Optional[ThreadPoolExecutor] = None
        self._pilot_pool: Optional[ThreadPoolExecutor] = None
        self._lock = threading.Lock()
        self._in_flight = 0          # handles dispatched, not yet finished
        self._futures: List[Future] = []
        self.total_groups = 0
        # pilot fan-out accounting (scheduler drains diff these): wall is
        # the concurrent span, serial the sum of the per-subgroup stage
        # durations it overlapped — wall < serial is the concurrency win
        self.pilot_fanouts = 0
        self.pilot_fanout_wall_s = 0.0
        self.pilot_fanout_serial_s = 0.0

    @property
    def is_async(self) -> bool:
        return self.workers > 0

    @property
    def in_flight(self) -> int:
        """Handles currently dispatched to workers and not yet finished —
        the admission-control signal gateways bound against."""
        with self._lock:
            return self._in_flight

    def _ensure_pool(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.workers,
                    thread_name_prefix="pilotdb-runtime")
            return self._pool

    def _ensure_pilot_pool(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._pilot_pool is None:
                self._pilot_pool = ThreadPoolExecutor(
                    max_workers=self.pilot_workers,
                    thread_name_prefix="pilotdb-pilot")
            return self._pilot_pool

    # -- pilot-subgroup fan-out ----------------------------------------------
    def map_pilot_subgroups(self, fn, items: list) -> list:
        """Run ``fn`` over a drain group's pilot subgroups, concurrently on
        the pilot pool when it exists, and return results in input order.

        ``fn`` must capture per-member failures itself (shared_pilot does);
        an escaping exception propagates to the caller exactly as it would
        on the serial path.
        """
        if self.pilot_workers <= 1 or len(items) <= 1:
            return [fn(x) for x in items]
        pool = self._ensure_pilot_pool()
        return [f.result() for f in [pool.submit(fn, x) for x in items]]

    def record_pilot_fanout(self, wall_s: float, serial_s: float) -> None:
        with self._lock:
            self.pilot_fanouts += 1
            self.pilot_fanout_wall_s += wall_s
            self.pilot_fanout_serial_s += serial_s

    def pilot_fanout_totals(self):
        with self._lock:
            return (self.pilot_fanouts, self.pilot_fanout_wall_s,
                    self.pilot_fanout_serial_s)

    def totals(self) -> dict:
        """One consistent snapshot of the runtime's cumulative counters —
        the metrics registry's "runtime" collector reads this (one lock
        acquisition, no torn reads across fields)."""
        with self._lock:
            return {
                "workers": self.workers,
                "pilot_workers": self.pilot_workers,
                "in_flight": self._in_flight,
                "groups_total": self.total_groups,
                "pilot_fanouts": self.pilot_fanouts,
                "pilot_fanout_wall_s": self.pilot_fanout_wall_s,
                "pilot_fanout_serial_s": self.pilot_fanout_serial_s,
            }

    # -- execution -----------------------------------------------------------
    def run_groups(self, groups: List[List["QueryHandle"]],
                   block: bool = True) -> None:
        """Execute signature groups; with ``block=False`` they run in the
        background and callers observe completion via handle.poll()/wait()."""
        groups = [g for g in groups if g]
        if not groups:
            return
        self.total_groups += len(groups)
        if not self.is_async:
            for g in groups:
                self._run_group_captured(g)
            return
        pool = self._ensure_pool()
        futures = []
        for g in groups:
            with self._lock:
                self._in_flight += len(g)
            fut = pool.submit(self._worker, g)
            futures.append(fut)
        with self._lock:
            self._futures = [f for f in self._futures if not f.done()]
            self._futures.extend(futures)
        if block:
            wait(futures)

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        """Block until every dispatched group finished; False on timeout."""
        with self._lock:
            outstanding = list(self._futures)
        done, not_done = wait(outstanding, timeout=timeout)
        with self._lock:
            self._futures = [f for f in self._futures if not f.done()]
        return not not_done

    def shutdown(self) -> None:
        with self._lock:
            pool, self._pool = self._pool, None
            pilot_pool, self._pilot_pool = self._pilot_pool, None
        if pool is not None:
            pool.shutdown(wait=True)
        if pilot_pool is not None:
            pilot_pool.shutdown(wait=True)

    # -- worker side ---------------------------------------------------------
    def _worker(self, group: List["QueryHandle"]) -> None:
        try:
            self._run_group_captured(group)
        finally:
            with self._lock:
                self._in_flight -= len(group)

    def _run_group_captured(self, group: List["QueryHandle"]) -> None:
        try:
            self._session._execute_group(group)
        except Exception as e:  # group-machinery bug: fail handles, not pool
            for h in group:
                if not h.done:
                    h._mark_failed(
                        f"runtime worker error: {type(e).__name__}: {e}")
