"""Distribution percentiles used by TAQA/BSAP (Appendix B.1 of the paper).

TAQA needs three percentile functions: standard normal ``z``, Student's t, and
chi-squared.  We use scipy when available (it is a pure-host dependency — the
planner runs on host, never inside a jitted graph) and fall back to published
closed-form approximations otherwise, so the middleware deploys with only
jax+numpy installed.

Accuracy of the fallbacks (validated in tests/test_distributions.py):
  * normal_ppf: Acklam's rational approximation, |err| < 1.2e-8.
  * student_t_ppf: Hill (1970) Cornish-Fisher expansion, rel err < 1e-3 for
    df >= 5 (TAQA requires pilot samples of n >= 30, see §3.1).
  * chi2_ppf: Wilson–Hilferty cube approximation, rel err < 1e-2 for df >= 20.
"""

from __future__ import annotations

import math

import numpy as np

try:  # pragma: no cover - environment dependent
    from scipy import stats as _sps

    _HAVE_SCIPY = True
except Exception:  # pragma: no cover
    _sps = None
    _HAVE_SCIPY = False


# ---------------------------------------------------------------------------
# Normal
# ---------------------------------------------------------------------------

# Acklam's inverse-normal-CDF coefficients.
_A = (-3.969683028665376e01, 2.209460984245205e02, -2.759285104469687e02,
      1.383577518672690e02, -3.066479806614716e01, 2.506628277459239e00)
_B = (-5.447609879822406e01, 1.615858368580409e02, -1.556989798598866e02,
      6.680131188771972e01, -1.328068155288572e01)
_C = (-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e00,
      -2.549732539343734e00, 4.374664141464968e00, 2.938163982698783e00)
_D = (7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e00,
      3.754408661907416e00)


def _acklam(p: float) -> float:
    if not 0.0 < p < 1.0:
        raise ValueError(f"p must be in (0, 1), got {p}")
    p_low, p_high = 0.02425, 1 - 0.02425
    if p < p_low:
        q = math.sqrt(-2 * math.log(p))
        return (((((_C[0] * q + _C[1]) * q + _C[2]) * q + _C[3]) * q + _C[4]) * q + _C[5]) / \
            ((((_D[0] * q + _D[1]) * q + _D[2]) * q + _D[3]) * q + 1)
    if p > p_high:
        q = math.sqrt(-2 * math.log(1 - p))
        return -(((((_C[0] * q + _C[1]) * q + _C[2]) * q + _C[3]) * q + _C[4]) * q + _C[5]) / \
            ((((_D[0] * q + _D[1]) * q + _D[2]) * q + _D[3]) * q + 1)
    q = p - 0.5
    r = q * q
    return (((((_A[0] * r + _A[1]) * r + _A[2]) * r + _A[3]) * r + _A[4]) * r + _A[5]) * q / \
        (((((_B[0] * r + _B[1]) * r + _B[2]) * r + _B[3]) * r + _B[4]) * r + 1)


def normal_ppf(p: float) -> float:
    """Percentile of the standard normal distribution (z_{p})."""
    if _HAVE_SCIPY:
        return float(_sps.norm.ppf(p))
    return _acklam(p)


# ---------------------------------------------------------------------------
# Student's t
# ---------------------------------------------------------------------------

def student_t_ppf(p: float, df: float) -> float:
    """Percentile t_{df, p} of Student's t distribution."""
    if df <= 0:
        raise ValueError("df must be positive")
    if _HAVE_SCIPY:
        return float(_sps.t.ppf(p, df))
    # Hill's Cornish-Fisher style expansion around the normal percentile.
    z = _acklam(p)
    g1 = (z ** 3 + z) / 4.0
    g2 = (5 * z ** 5 + 16 * z ** 3 + 3 * z) / 96.0
    g3 = (3 * z ** 7 + 19 * z ** 5 + 17 * z ** 3 - 15 * z) / 384.0
    g4 = (79 * z ** 9 + 776 * z ** 7 + 1482 * z ** 5 - 1920 * z ** 3 - 945 * z) / 92160.0
    return float(z + g1 / df + g2 / df ** 2 + g3 / df ** 3 + g4 / df ** 4)


# ---------------------------------------------------------------------------
# Chi-squared
# ---------------------------------------------------------------------------

def chi2_ppf(p: float, df: float) -> float:
    """Percentile chi2_{df, p}."""
    if df <= 0:
        raise ValueError("df must be positive")
    if _HAVE_SCIPY:
        return float(_sps.chi2.ppf(p, df))
    # Wilson–Hilferty: chi2 ~ df * (1 - 2/(9 df) + z sqrt(2/(9 df)))^3
    z = _acklam(p)
    k = 2.0 / (9.0 * df)
    return float(df * (1.0 - k + z * math.sqrt(k)) ** 3)


# ---------------------------------------------------------------------------
# Binomial / population-size bounds (Lemma B.1 machinery)
# ---------------------------------------------------------------------------

def binomial_lower_bound(n_units: float, theta: float, delta: float) -> float:
    """Probabilistic lower bound on a Bin(n_units, theta) sample size.

    Normal approximation (Ineq. 12 of the paper):
      P[n >= N*theta - z_{1-delta} sqrt(N theta (1-theta))] >= 1 - delta.
    Clamped below at 0.
    """
    if n_units <= 0:
        return 0.0
    z = normal_ppf(1.0 - delta)
    lo = n_units * theta - z * math.sqrt(max(n_units * theta * (1.0 - theta), 0.0))
    return max(lo, 0.0)


def population_lower_bound(n_pilot: float, theta_p: float, delta: float) -> float:
    """Probabilistic lower bound L_N of the population size N (Ineq. 13).

    From n_p <= N*theta_p + z sqrt(N theta_p (1-theta_p)) w.p. >= 1-delta,
      sqrt(N) >= sqrt(n_p/theta_p + z^2 (1-theta_p)/(4 theta_p))
                 - sqrt(z^2 (1-theta_p)/(4 theta_p)).
    """
    if n_pilot <= 0:
        return 0.0
    z = normal_ppf(1.0 - delta)
    c = z * z * (1.0 - theta_p) / (4.0 * theta_p)
    root = math.sqrt(n_pilot / theta_p + c) - math.sqrt(c)
    return max(root * root, 0.0)
