from repro.stats.distributions import (
    normal_ppf,
    student_t_ppf,
    chi2_ppf,
    binomial_lower_bound,
    population_lower_bound,
)

__all__ = [
    "normal_ppf",
    "student_t_ppf",
    "chi2_ppf",
    "binomial_lower_bound",
    "population_lower_bound",
]
