"""Session — the stateful front door of the PilotDB middleware.

A :class:`Session` owns everything that must persist across queries for the
many-users scenario to pay off:

* the registered tables (the catalog, plus optional per-column string
  dictionaries) and the :class:`Executor` whose physical compile cache makes
  repeated structurally-identical queries run warm (see
  ``engine/physical.py``),
* the concurrent query runtime (:mod:`repro.runtime`): a worker pool that
  overlaps drain groups, one-pilot-per-group statistic sharing, and the
  session result cache,
* deterministic seed derivation (below), and a
  :class:`repro.api.QueryScheduler` for batched submission.

Seed derivation.  Every query's sampling seed is a pure function of
``(session seed, lowered query, ErrorSpec)`` — not of submission order — and
the *pilot* seed is a pure function of ``(session seed, structural
signature, pilot-stage tunables)``.  Consequences, all load-bearing for the
runtime:

* equal-seed sessions replay bit-identical answers for the same queries, in
  ANY submission order and under any scheduler/runtime interleaving;
* a query answered from a group's shared pilot is bit-identical to the same
  query run solo (solo runs derive the identical pilot seed);
* a repeated identical query re-derives the identical ``(query, spec,
  seed)`` triple, which is exactly the result cache's key — repeats are
  cache hits with their original error reports.

Result-cache invalidation contract: see :meth:`Session.register_table`.

``session.sql(...)`` / ``builder.run()`` return a :class:`QueryHandle`
carrying status, the :class:`ApproxAnswer`, the :class:`TaqaReport` and any
fallback reason — execution failures are captured on the handle instead of
raising through the client (`EmptySampleError` in particular is already an
*internal* signal: TAQA answers it with an explicit exact fallback).
Handles are pollable (`poll()`) and waitable (`wait(timeout)`), so clients
of the async runtime never need to block on a drain.
"""

from __future__ import annotations

import bisect
import collections
import dataclasses
import hashlib
import os
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.api.builder import QueryBuilder
from repro.api.scheduler import QueryScheduler
from repro.api.sql import (HavingClause, LimitClause, UnsupportedSqlError,
                           parse_sql, resolve_string_literals)
from repro.core.spec import ErrorSpec
from repro.dist import DistExecutor
from repro.core.taqa import (ApproxAnswer, PilotDB, Query, TaqaReport,
                             advisory_estimate, pilot_params,
                             structural_signature)
from repro.engine.executor import Executor
from repro.engine.physical import plan_template
from repro.engine.staged import DEFAULT_STAGED_RATES, validate_rates
from repro.engine.table import BlockTable
from repro.obs import audit as _audit
from repro.obs import events as _events
from repro.obs import metrics as _metrics
from repro.obs import slo as _slo
from repro.obs import timeseries as _timeseries
from repro.obs import trace as _trace
from repro.runtime import (AsyncRuntime, CachedAnswer, ResultCache,
                           ResultCacheInfo)
from repro.runtime import shared_pilot as _shared_pilot
from repro.stream import (ErrorFrame, FrameBuffer, final_frame_for,
                          pilot_frame_for)


class QueryStatus:
    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"


class QueryFailedError(RuntimeError):
    """Raised by :meth:`QueryHandle.result` when execution failed."""


@dataclasses.dataclass
class _Dictionary:
    """A column's string dictionary: code lookup plus order metadata."""

    codes: Dict[str, int]       # value -> integer code
    values: List[str]           # code -> value (registration order)
    is_sorted: bool             # strictly ascending => code order == lex order


def _content_hash(*parts) -> int:
    """Deterministic 64-bit hash of frozen-dataclass content (their reprs
    are complete and stable — plans, exprs and specs hold only scalars)."""
    digest = hashlib.blake2b(repr(parts).encode(), digest_size=8).digest()
    return int.from_bytes(digest, "big")


@dataclasses.dataclass
class QueryHandle:
    """One submitted query: its lowered form, derived seed, and outcome."""

    query_id: int
    query: Optional[Query]            # None only for parse-failed handles
    spec: Optional[ErrorSpec]         # None -> exact execution was requested
    seed: int
    sql: Optional[str] = None
    # post-aggregation HAVING filter: applied to every delivered answer
    # (fresh or cache-served) but never part of the plan, the seed, or the
    # cache key — the cache stores the unfiltered base answer
    having: Optional[HavingClause] = None
    # post-aggregation [ORDER BY agg] LIMIT n selection: same contract as
    # HAVING (applied after it, never keyed) — LIMIT-varied re-issues all
    # share one cached base answer
    limit: Optional[LimitClause] = None
    status: str = QueryStatus.PENDING
    error: Optional[str] = None
    cached: bool = False              # answered from the session result cache
    _answer: Optional[ApproxAnswer] = None
    # full constant-bearing structural signature, computed once at
    # submission (pilot-seed derivation and pilot-sharing subgroups key off
    # it — pilot statistics depend on predicate constants)
    signature: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False)
    # constant-stripped template signature: the scheduler's grouping key —
    # constant-varied queries share compilations and batched final launches
    group_key: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False)
    _done_event: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False)
    # progressive streaming (repro.stream): None until enable_streaming();
    # the lock serializes terminal-frame emission against late enabling so
    # every stream ends in EXACTLY one terminal frame
    _frames: Optional[FrameBuffer] = dataclasses.field(
        default=None, repr=False, compare=False)
    _frame_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)
    # submission instant (perf_counter): the zero point for every frame's
    # relative `emitted_at` stamp and for the trace's span times
    t_submit: float = dataclasses.field(
        default_factory=time.perf_counter, repr=False, compare=False)
    # query-lifecycle span tree (repro.obs.trace); None unless the session
    # was configured with tracing=True
    _trace: Optional[_trace.QueryTrace] = dataclasses.field(
        default=None, repr=False, compare=False)
    # observed-vs-promised outcome (repro.obs.audit); None unless the
    # session runs in audit mode and this query completed
    audit_record: Optional[_audit.AuditRecord] = dataclasses.field(
        default=None, repr=False, compare=False)
    # the fused single-launch program delivered this answer (set by
    # Session._run_fused; provenance reporting and telemetry read it — the
    # fused span carries the same fact only when tracing is on)
    _fused: bool = dataclasses.field(default=False, repr=False, compare=False)
    # this handle was picked by deterministic trace sampling
    # (SessionConfig.trace_sample); sampled traces land in the flight
    # recorder and the session's recent-traces ring at completion
    _trace_sampled: bool = dataclasses.field(
        default=False, repr=False, compare=False)
    # continuous-telemetry delivery hook (Session._observe_delivery); fired
    # exactly once from _mark_done/_mark_failed, AFTER the done event —
    # None (the default) keeps the completion path byte-for-byte the
    # pre-telemetry code
    _on_complete: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False)
    # 12-hex hash of the constant-stripped template signature: the
    # time-series / SLO / flight-recorder key (computed at submission only
    # when telemetry is armed; None otherwise)
    _template_key: Optional[str] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def done(self) -> bool:
        return self.status in (QueryStatus.DONE, QueryStatus.FAILED)

    @property
    def answer(self) -> Optional[ApproxAnswer]:
        return self._answer

    @property
    def report(self) -> Optional[TaqaReport]:
        return self._answer.report if self._answer is not None else None

    @property
    def fallback(self) -> Optional[str]:
        """Reason exact execution was used, if TAQA fell back (else None)."""
        r = self.report
        return r.fallback if r is not None else None

    # -- async observation ----------------------------------------------------
    def poll(self) -> str:
        """Non-blocking status probe: pending / running / done / failed."""
        return self.status

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the query finished (done OR failed); False on
        timeout.  Returns immediately for handles that never entered a
        runtime (synchronous paths complete before returning)."""
        if self.done:
            return True
        return self._done_event.wait(timeout)

    # -- progressive streaming (repro.stream) ---------------------------------
    @property
    def streaming(self) -> bool:
        return self._frames is not None

    def enable_streaming(self) -> "QueryHandle":
        """Attach a frame buffer to this handle (idempotent).

        Queries submitted with ``stream=True`` arrive pre-enabled; enabling
        later still works — frames emitted before the buffer existed are
        simply not observed (they are advisory), and enabling on an
        already-finished handle synthesizes its terminal frame so late
        subscribers always observe a complete stream.
        """
        with self._frame_lock:
            if self._frames is None:
                self._frames = FrameBuffer(self.query_id, t0=self.t_submit)
                if self.status == QueryStatus.DONE:
                    self._frames.push(final_frame_for(
                        self.query_id, self._answer, cached=self.cached))
                elif self.status == QueryStatus.FAILED:
                    self._frames.push(ErrorFrame(
                        query_id=self.query_id,
                        error=self.error or "query failed"))
        return self

    def stream(self, timeout: Optional[float] = None):
        """Blocking frame iterator: advisory :class:`repro.stream.PilotFrame`
        estimates as they materialize, then exactly one terminal frame — a
        :class:`FinalFrame` carrying the SAME answer object ``result()``
        returns (bitwise identity with the non-streaming path is structural),
        an :class:`ExactFrame` on fallback, or an :class:`ErrorFrame` on
        captured failure.  Implicitly enables streaming; ``timeout`` bounds
        each wait for the next frame."""
        return self.enable_streaming()._frames.stream(timeout)

    def on_frame(self, cb) -> "QueryHandle":
        """Register ``cb(frame)`` for every frame of this query; frames
        already emitted are replayed first, in order (late subscription
        never loses frames).  Implicitly enables streaming."""
        self.enable_streaming()._frames.add_callback(cb)
        return self

    def frames(self) -> list:
        """Snapshot of the frames emitted so far ([] when not streaming)."""
        return [] if self._frames is None else self._frames.frames()

    def _emit(self, frame) -> None:
        """Push an advisory frame if this handle streams (no-op otherwise);
        terminal frames go through _mark_done/_mark_failed instead."""
        if self._frames is not None:
            self._frames.push(frame)

    # -- observability (repro.obs) --------------------------------------------
    def trace(self, fmt: str = "json"):
        """The query's span tree: a JSON-able dict (``fmt="json"``) or a
        Chrome trace-event list (``fmt="chrome"``, load in chrome://tracing).
        None when the session ran with tracing off."""
        if self._trace is None:
            return None
        if fmt == "chrome":
            return self._trace.to_chrome()
        if fmt == "json":
            return self._trace.to_dict()
        raise ValueError(f"unknown trace format {fmt!r} "
                         "(expected 'json' or 'chrome')")

    def explain(self) -> str:
        """EXPLAIN-style report: promised guarantee, solved rates, pilot
        inputs, scanned bytes, provenance (see :mod:`repro.obs.audit`)."""
        return _audit.explain(self)

    # -- completion (runtime-internal) ----------------------------------------
    def _mark_running(self) -> None:
        if not self.done:
            self.status = QueryStatus.RUNNING
            if self._trace is not None:
                # the cross-thread wait-in-queue span submit() opened
                self._trace.close_span("schedule")

    def _mark_done(self, answer: ApproxAnswer, cached: bool = False) -> None:
        with self._frame_lock:
            self._answer = answer
            self.cached = cached
            self.status = QueryStatus.DONE
            if self._frames is not None:
                self._frames.push(final_frame_for(
                    self.query_id, answer, cached=cached))
        if self._trace is not None:
            self._trace.finish(
                "ok", cached=cached,
                fallback=answer.report.fallback if answer is not None else None)
        self._done_event.set()
        self._fire_on_complete()

    def _fire_on_complete(self) -> None:
        """Run the telemetry delivery hook exactly once; it observes only
        (time-series row, SLO evaluation, flight-recorder event) and must
        never raise into the completion path."""
        cb, self._on_complete = self._on_complete, None
        if cb is not None:
            try:
                cb(self)
            except Exception:
                pass

    def _mark_failed(self, error: str) -> None:
        with self._frame_lock:
            self.status = QueryStatus.FAILED
            self.error = error
            if self._frames is not None:
                # the failure-capture contract extends to streams: execution
                # failures become a terminal frame, never an exception
                # raised through a streaming client
                self._frames.push(ErrorFrame(query_id=self.query_id,
                                             error=error))
        if self._trace is not None:
            self._trace.finish("error", error=error)
        self._done_event.set()
        self._fire_on_complete()

    def result(self) -> ApproxAnswer:
        """The answer; raises if the query failed or has not run yet."""
        if self.status == QueryStatus.FAILED:
            raise QueryFailedError(self.error or "query failed")
        if self._answer is None:
            raise RuntimeError(
                f"query {self.query_id} is {self.status}; drain the "
                "scheduler it was submitted to (session.drain(), or "
                "gateway.run() for gateway tickets) — or wait() on the "
                "handle after an async drain — before reading results")
        return self._answer

    def scalar(self, name: str, group: int = 0) -> float:
        return self.result().scalar(name, group)


@dataclasses.dataclass(frozen=True)
class SessionConfig:
    large_table_rows: int = 50_000     # sampling threshold (§3.1)
    default_error: float = 0.05        # builder .error() defaults
    default_confidence: float = 0.95
    use_compiled: bool = True
    kernel_mode: str = "auto"
    spec_kwargs: Optional[Dict] = None  # TAQA tunable overrides for SQL specs
    # The physical layer sizes dense per-(block, group) buffers by
    # max_groups; an id-cardinality GROUP BY through the public front door
    # would otherwise allocate process-killing buffers in a shared server.
    max_groups_limit: int = 4096
    # -- concurrent runtime (repro.runtime) ----------------------------------
    # Worker threads draining signature groups concurrently; 0 restores the
    # synchronous-cooperative loop (groups run inline on the draining
    # thread).  None sizes the pool from os.cpu_count(): capped at the core
    # count (a pool wider than the machine only contends on jit dispatch —
    # the BENCH_runtime.json async regression was 4 workers on 2 cores) with
    # a serial fallback on single-core hosts where no overlap exists to
    # win.  Answers never depend on this — only wall-clock does.
    async_workers: Optional[int] = None
    # One pilot per (full signature, pilot-params) subgroup, statistics
    # fanned out to every member (off: each query runs its own —
    # bit-identical — pilot; the switch trades pilot scans for nothing
    # else).  Never shared across predicate constants: selectivity shapes
    # the pilot statistics the §4 guarantees are computed from.
    share_pilots: bool = True
    # Stack a drain group's same-bucket final scans into ONE batched device
    # dispatch (lax.map over member lanes — bit-identical to solo runs).
    # Rides the shared-pilot group path, so share_pilots=False also
    # disables it.
    batch_finals: bool = True
    # Worker threads fanning a drain group's pilot SUBGROUPS out (the
    # constant-varied herd whose N per-constant pilot stages previously ran
    # serially on the group's one worker — see runtime/shared_pilot.py).
    # The pilot pool is separate from the group pool, so group workers
    # blocking on pilot futures can never deadlock it.  None auto-sizes
    # (min(4, cores), serial on one core); 0 restores serial pilot stages.
    pilot_workers: Optional[int] = None
    # Session result-cache capacity in answers; 0 disables caching.
    result_cache_size: int = 128
    # Optional byte budget for the result cache: entries are stored compact
    # (values + error report + packed group-present bitmap, never the full
    # ApproxAnswer graph) and evicted LRU-first once the budget is hit.
    # None = entry-count bound only.
    result_cache_bytes: Optional[int] = None
    # Optional byte budget for the staged sample catalog (tables registered
    # with staged_rates=...): rung arrays of cold ladders are evicted
    # LRU-first past the budget; the ladder's pinned staging seed survives
    # eviction, so answers stay bit-identical across the hit/miss boundary.
    # None = unbounded residency.
    staged_bytes: Optional[int] = None
    # -- observability (repro.obs) -------------------------------------------
    # Per-query span trees (handle.trace()).  Off by default: the untraced
    # path carries no trace objects and is byte-for-byte the pre-tracing
    # code; ON only observes (never touches seeds, plans, or reductions),
    # so answers stay bit-identical either way.
    tracing: bool = False
    # Audit mode: after each approximate answer is DELIVERED, run the exact
    # query alongside and record observed vs promised error into the
    # session metrics registry (see repro.obs.audit — never perturbs seeds,
    # cache keys, or delivered answers; adds exact scan cost per query).
    audit: bool = False
    # -- continuous telemetry (repro.obs.timeseries / slo / events) ----------
    # Per-template time-series + SLO evaluation on every delivery: bounded
    # ring buffers keyed by the constant-stripped template signature record
    # latency / pilot wall / scanned bytes / provenance / audit error ratio
    # with streaming windowed p50/p95/p99 (stats_payload()["timeseries"]).
    # Off (default): no store exists, handles carry no completion hook, and
    # the delivery path is byte-for-byte the pre-telemetry code; ON only
    # observes finished handles, so answers stay bit-identical either way.
    telemetry: bool = False
    # Ring-buffer capacity per template series (and the drain-level
    # streaming-latency rings) when telemetry is on.
    timeseries_window: int = 256
    # Initial SLO targets (tuple of repro.obs.slo.SloTarget); more can be
    # added at runtime via session.slo.set_target(...).  Requires
    # telemetry=True (targets evaluate against the time-series).
    slo_targets: Optional[Tuple] = None
    # Flight recorder: path of an append-only JSONL event log (submit /
    # pilot / rate_solve / final / deliver / fallback / fail / audit /
    # slo_breach / sampled-trace records; see repro.obs.events).  The
    # recorder never raises into the query path — an unwritable target
    # only counts drops.  None (default) records nothing.
    flight_recorder: Optional[str] = None
    flight_recorder_max_bytes: int = 1 << 20   # rotate past this size
    flight_recorder_max_files: int = 3         # live file + rotated .1/.2
    # Always-on sampled tracing: attach a full span tree to this fraction
    # of queries, chosen by a content-derived hash of (structural
    # signature, session seed) — never wall-clock RNG, so equal-seed
    # sessions sample the IDENTICAL query set and replay stays
    # deterministic.  Sampled traces land in the flight recorder (when
    # armed) and the session's recent-traces ring.  0.0 (default) samples
    # nothing; tracing=True still traces everything.
    trace_sample: float = 0.0
    # Fuse both TAQA stages into ONE device program per query (pilot scan
    # -> rate solve -> final aggregation with no host sync between stages;
    # see engine/physical.py compile_fused).  Answers stay bit-identical
    # to the two-stage path: the fused program replays the same
    # content-derived draws in the same reduction order, and delivery
    # verifies the device-side final draw against the host oracle before
    # trusting fused sums — any mismatch, fallback decision, or
    # ineligible query shape (groups, joins, kernels, shards) re-routes
    # to the two-stage path.  Off (default) is byte-for-byte today's
    # two-launch execution.
    fused_taqa: bool = False

    def resolve_workers(self) -> int:
        """The worker count ``async_workers=None`` auto-sizes to.

        On <= 2 cores the pool measurably LOSES to the serial loop (GIL-bound
        planning + jit-dispatch contention — the BENCH_runtime.json `async`
        regression), so toy hosts fall back to serial; larger machines get a
        pool one narrower than the core count, capped at 8.
        """
        if self.async_workers is not None:
            return self.async_workers
        cpus = os.cpu_count() or 1
        if cpus <= 2:
            return 0
        return min(8, cpus - 1)  # leave a core for the draining thread

    def resolve_pilot_workers(self) -> int:
        """Pilot-stage fan-out width (``pilot_workers=None`` auto-size).

        Unlike the group pool, pilot stages are device-execution-heavy
        (the scan releases the GIL), so even 2-core hosts profit from a
        2-wide pilot pool; single-core hosts stay serial.
        """
        if self.pilot_workers is not None:
            return self.pilot_workers
        cpus = os.cpu_count() or 1
        return 0 if cpus <= 1 else min(4, cpus)


class Session:
    """A client session against a catalog of block tables."""

    def __init__(self, catalog: Optional[Dict[str, BlockTable]] = None, *,
                 seed: int = 0, config: SessionConfig = SessionConfig(),
                 executor: Optional[Executor] = None):
        self.config = config
        if config.spec_kwargs:
            # fail at construction, not on every client's ERROR clause
            dataclasses.replace(
                ErrorSpec(error=config.default_error,
                          confidence=config.default_confidence),
                **config.spec_kwargs)
        if executor is not None:
            if catalog is not None:
                raise ValueError(
                    "pass either catalog or executor, not both: an explicit "
                    "executor brings its own catalog, and the catalog "
                    "argument would be silently ignored")
            self.executor = executor
        else:
            # DistExecutor behaves exactly like Executor until a table is
            # registered with shards= (see register_table)
            self.executor = DistExecutor(catalog or {},
                                         use_compiled=config.use_compiled,
                                         kernel_mode=config.kernel_mode,
                                         staged_bytes=config.staged_bytes)
        self.db = PilotDB(self.executor,
                          large_table_rows=config.large_table_rows)
        self._entropy = int(seed)
        self._next_id = 0
        self._max_groups_cache: Dict[tuple, int] = {}
        self._dictionaries: Dict[str, "_Dictionary"] = {}
        # Bumped by register_table; snapshotted when a query starts
        # executing so an answer computed against since-replaced data can
        # never be delivered or (re-)enter the result cache.  The lock makes
        # bump+swap atomic with respect to snapshots: a snapshot is taken
        # either wholly before a replacement (the completion check then sees
        # the bump) or wholly after (the query runs on the new data).
        self._table_gen: Dict[str, int] = {}
        self._gen_lock = threading.Lock()
        self.result_cache = ResultCache(config.result_cache_size,
                                        max_bytes=config.result_cache_bytes)
        self.runtime = AsyncRuntime(self, workers=config.resolve_workers(),
                                    pilot_workers=config.resolve_pilot_workers())
        self.scheduler = QueryScheduler(self)
        # unified metrics registry: first-class instruments plus collector
        # views over the caches/runtime this session already tracks
        self.metrics = _metrics.MetricsRegistry()
        # -- continuous telemetry (repro.obs.timeseries / slo / events) ------
        if not 0.0 <= config.trace_sample <= 1.0:
            raise ValueError(
                f"trace_sample must be in [0, 1], got {config.trace_sample}")
        self.recorder = (_events.FlightRecorder(
            config.flight_recorder,
            max_bytes=config.flight_recorder_max_bytes,
            max_files=config.flight_recorder_max_files)
            if config.flight_recorder else None)
        self.timeseries = (_timeseries.TemplateTimeSeries(
            window=config.timeseries_window)
            if config.telemetry else None)
        self.slo = (_slo.SloMonitor(
            self.metrics, self.timeseries, recorder=self.recorder,
            targets=tuple(config.slo_targets or ()))
            if config.telemetry else None)
        if config.slo_targets and not config.telemetry:
            raise ValueError(
                "slo_targets requires telemetry=True (targets evaluate "
                "against the per-template time-series)")
        # last N sampled span trees (dict form), for the ops dashboard
        self.recent_traces: "collections.deque" = collections.deque(maxlen=16)
        # whether handles get the completion hook: any continuous-telemetry
        # surface is on — False (the default config) arms NOTHING, keeping
        # submission and completion byte-for-byte the pre-telemetry path
        self._telemetry_armed = (self.timeseries is not None
                                 or self.recorder is not None
                                 or config.trace_sample > 0.0)
        _metrics.register_session_collectors(self.metrics, self)
        self.auditor = (_audit.GuaranteeAuditor(self.db, self.metrics)
                        if config.audit else None)

    def close(self) -> None:
        """Shut the runtime's worker pool down and close the flight
        recorder (idempotent)."""
        self.runtime.shutdown()
        if self.recorder is not None:
            self.recorder.close()

    # -- catalog -------------------------------------------------------------
    def register_table(self, name: str, table: BlockTable, *,
                       dictionaries: Optional[Dict[str, Sequence[str]]] = None,
                       shards: Optional[int] = None,
                       staged_rates: Optional[Sequence[float]] = None,
                       ) -> None:
        """Add (or replace) a catalog table.

        ``staged_rates=[...]`` additionally materializes a staged
        block-sample ladder for the table (``staged_rates=True`` uses the
        default 1%/4%/16% ladder; per shard for sharded registrations): a
        sampled scan whose rate a rung covers executes against the
        pre-gathered staged arrays as a sub-draw of the table's ONE
        content-derived staging realization — bit-identical to a fresh
        draw, for pilots and finals — skipping the per-query full-table
        gather.  ``staged_rates=None`` (default) stages nothing and
        reproduces the unstaged behavior exactly.  Re-registration always
        drops the old ladder first, so staged arrays can never outlive
        their data.

        ``shards=N`` registers the table *partitioned* into N disjoint
        block ranges (placed round-robin across JAX devices when more than
        one is available): block-sampled scans then execute one dispatch
        per shard, merged through per-block statistics (:mod:`repro.dist`)
        — and answers are bit-identical for EVERY shard count, so
        re-sharding never perturbs equal-seed replay, shared pilots, or the
        result cache.  ``shards=None`` (default) registers monolithic.
        Memory cost: a sharded registration keeps the monolithic arrays
        (exact / row-sample / multi-table fallback paths run on them) AND
        materializes every shard's slices — about 2x the table's bytes
        resident until the plain registration is dropped.

        Cache-invalidation contract: registering ``name`` synchronously
        evicts (a) the cached MAXGROUPS statistics of its columns and
        (b) every result-cache entry whose plan scanned ``name`` — including
        join queries that merely touch it — so no later lookup can return an
        answer (or an error report) computed against the replaced data.
        Entries over other tables survive; compiled *executables* need no
        invalidation (see :meth:`Executor.register_table`: data enters as
        runtime arguments, geometry changes re-key the compile cache).
        A query of ``name`` still in flight on the runtime when the
        replacement lands fails with a retryable error rather than
        delivering a possibly-torn answer (see :meth:`_complete_handle`).

        ``dictionaries`` maps dictionary-encoded column names to their value
        lists (code = list index), enabling string literals for those
        columns in WHERE clauses: ``WHERE l_returnflag = 'A'`` lowers to the
        integer code before planning.
        """
        if shards is not None:
            if not hasattr(self.executor, "register_sharded"):
                raise ValueError(
                    "shards= needs a dist-capable executor (repro.dist."
                    "DistExecutor — the session default); the explicit "
                    "executor passed to this session does not support "
                    "sharding")
            # validate BEFORE the generation bump: a rejected registration
            # must not fail in-flight queries over unchanged data
            if not 1 <= shards <= table.num_blocks:
                raise ValueError(
                    f"shards must be in [1, {table.num_blocks}] (blocks are "
                    f"the atomic placement unit), got {shards}")
        if staged_rates is not None:
            if not hasattr(self.executor, "register_staged"):
                raise ValueError(
                    "staged_rates= needs a staging-capable executor (the "
                    "session default); the explicit executor passed to this "
                    "session does not support staged sample ladders")
            # validate BEFORE the generation bump, like shards= above
            staged_rates = DEFAULT_STAGED_RATES if staged_rates is True \
                else validate_rates(staged_rates)
        # bump+swap under the generation lock: no snapshot can interleave
        # between the new generation and the new data (see _gen_lock above)
        with self._gen_lock:
            self._table_gen[name] = self._table_gen.get(name, 0) + 1
            if shards is None:
                self.executor.register_table(name, table)
            else:
                self.executor.register_sharded(name, table, shards)
            if staged_rates is not None:
                # stage inside the lock: the ladder (and its seed pinning)
                # becomes visible atomically with the table swap, so no
                # query can observe the table staged-rates-on but unstaged
                self.executor.register_staged(
                    name, staged_rates, seed=self._staged_seed_for(name))
        # replacing a table invalidates its cached statistics
        self._max_groups_cache = {k: v for k, v in
                                  self._max_groups_cache.items()
                                  if k[0] != name}
        # eviction after the bump: an in-flight query's cache insert either
        # sees the bump in its put guard (skipped) or lands before this
        # eviction (removed) — the only two orders under the cache lock
        self.result_cache.invalidate_table(name)
        if dictionaries:
            for column, values in dictionaries.items():
                self.register_dictionary(column, values)

    def register_dictionary(self, column: str, values: Sequence[str]) -> None:
        """Declare ``column`` as dictionary-encoded: ``values[i]`` is the
        string for integer code ``i``.  String equality literals comparing
        against ``column`` then lower to the code (see ``api/sql.py``).

        When ``values`` is lexicographically sorted (a *sorted dictionary*
        encoding: code order == string order), order comparisons
        (``WHERE col < 'N'``) lower too, via the bisection boundary — even
        for literals outside the dictionary.  Unsorted dictionaries keep
        rejecting order comparisons: their code order is meaningless.
        """
        values = list(values)
        self._dictionaries[column] = _Dictionary(
            codes={v: i for i, v in enumerate(values)},
            values=values,
            is_sorted=all(a < b for a, b in zip(values, values[1:])))

    def tables(self) -> List[str]:
        return sorted(self.executor.catalog)

    def infer_max_groups(self, tables, column: str) -> int:
        """Group-id domain size for integer-coded group columns, from the
        catalog (the "DBMS statistics" a middleware would consult).

        ``tables`` is the table name — or every table in the query's FROM/
        JOIN chain, since GROUP BY may name a joined table's column.  An
        unknown table or column resolves to 1 rather than raising: the
        inference is advisory, and the real error surfaces at execution
        where it is captured on the handle.
        """
        if isinstance(tables, str):
            tables = (tables,)
        for name in tables:
            tab = self.executor.catalog.get(name)
            if tab is None or column not in tab.columns:
                continue
            key = (name, column)
            if key not in self._max_groups_cache:
                col = np.asarray(tab.columns[column])[np.asarray(tab.valid)]
                if col.size == 0:
                    self._max_groups_cache[key] = 1
                else:
                    # grouping requires non-negative integer group codes;
                    # a float/negative column would silently collapse groups
                    if not (np.issubdtype(col.dtype, np.integer)
                            or np.all(col == np.floor(col))):
                        raise UnsupportedSqlError(
                            f"GROUP BY {column}: column is not integer-coded "
                            f"(dtype {col.dtype}); group columns must hold "
                            "non-negative integer group ids")
                    if col.min() < 0:
                        raise UnsupportedSqlError(
                            f"GROUP BY {column}: negative group ids "
                            "(min {:g}) are not supported".format(col.min()))
                    self._max_groups_cache[key] = int(col.max()) + 1
            return self._max_groups_cache[key]
        return 1

    def compile_cache_info(self):
        return self.executor.compile_cache_info()

    def result_cache_info(self) -> ResultCacheInfo:
        return self.result_cache.info()

    # -- seed derivation ------------------------------------------------------
    def _derive_seed(self, query: Query, spec: Optional[ErrorSpec]) -> int:
        """Per-query seed as a pure function of session seed and query
        content.  Identical resubmissions re-derive the identical seed
        (making them result-cache hits), distinct queries get independent
        streams, and replay is submission-order-independent."""
        seq = np.random.SeedSequence(
            [self._entropy, _content_hash(query, spec)])
        return int(seq.generate_state(1, dtype=np.uint32)[0])

    def _pilot_seed_for(self, handle: QueryHandle) -> int:
        """Pilot seed from (session seed, structural signature, pilot-stage
        tunables) — NOT from the per-query seed.  Every query that could
        share a pilot derives the same value, so a shared pilot's statistics
        are bit-identical to the pilot each member would have run solo."""
        params = None if handle.spec is None else pilot_params(handle.spec)
        seq = np.random.SeedSequence(
            [self._entropy, 0x9E3779B9,
             _content_hash(handle.signature, params)])
        return int(seq.generate_state(1, dtype=np.uint32)[0])

    def _staged_seed_for(self, name: str) -> int:
        """The staging seed pinning table ``name``'s one staged realization.

        Derived from (session seed, table name) ONLY — not from the ladder
        rates — so every ladder configuration of a table stages the same
        realization and answers are bit-identical across re-staging with
        different rungs.  Its own domain constant keeps it off the
        per-query and pilot seed streams."""
        seq = np.random.SeedSequence(
            [self._entropy, 0x5A3D1ED, _content_hash(name)])
        return int(seq.generate_state(1, dtype=np.uint32)[0])

    # -- continuous telemetry (repro.obs.timeseries / slo / events) -----------
    def _trace_sampled(self, signature) -> bool:
        """Deterministic trace-sampling decision: a content-derived hash of
        (session seed, structural signature) against ``trace_sample`` —
        never wall-clock RNG, so equal-seed sessions sample the IDENTICAL
        query set (pinned by tests/test_obs.py).  Its own domain constant
        keeps the hash independent of the per-query/pilot/staged seed
        streams."""
        p = self.config.trace_sample
        if p <= 0.0:
            return False
        if p >= 1.0:
            return True
        h = _content_hash(self._entropy, 0x7E1E5C0F, signature)
        return (h / 2.0 ** 64) < p

    def template_key(self, sql: str) -> str:
        """The 12-hex time-series/SLO key of ``sql``'s constant-stripped
        template — what ``stats_payload()["timeseries"]["templates"]`` and
        :class:`repro.obs.slo.SloTarget.template` key by.  Constant-varied
        re-issues of one dashboard query map to one key."""
        parsed = parse_sql(sql, max_groups_resolver=self.infer_max_groups,
                           spec_kwargs=self.config.spec_kwargs)
        return _trace.sig_hash(
            plan_template(structural_signature(parsed.query)))

    def _emit_event(self, etype: str, **fields) -> None:
        """Append one flight-recorder record (no-op when unarmed; the
        recorder itself never raises into the query path)."""
        if self.recorder is not None:
            self.recorder.emit(etype, **fields)

    def _observe_delivery(self, handle: QueryHandle) -> None:
        """The completion hook (``handle._on_complete``): one time-series
        row, the SLO evaluation, and the flight-recorder terminal event for
        a just-finished handle.  Read-only over the handle — runs AFTER the
        done event, never raises (the hook firer swallows), and never
        touches seeds, answers, or caches."""
        latency = max(0.0, time.perf_counter() - handle.t_submit)
        key = handle._template_key or "_unkeyed"
        rep = handle.report
        failed = handle.status == QueryStatus.FAILED
        fallback = bool(rep.fallback) if rep is not None else False
        pilot_wall = rep.pilot_time_s if rep is not None else 0.0
        if handle.cached or rep is None:
            scanned = 0  # a cache-served delivery scanned nothing now
        elif rep.fallback:
            scanned = rep.pilot_scanned_bytes + rep.exact_scanned_bytes
        else:
            scanned = rep.pilot_scanned_bytes + rep.final_scanned_bytes
        shared = bool(rep.pilot_shared) if rep is not None else False
        staged = False
        if handle._trace is not None:  # staged rungs tag scan spans only
            staged = any(sp.attrs.get("staged")
                         for sp in handle._trace.find("scan"))
        if self.timeseries is not None:
            self.timeseries.record_delivery(
                key, sql=handle.sql, latency_s=latency,
                pilot_wall_s=pilot_wall, scanned_bytes=scanned,
                cached=handle.cached, shared=shared, fused=handle._fused,
                staged=staged, fallback=fallback, failed=failed)
        if self.recorder is not None:
            if failed:
                self._emit_event("fail", qid=handle.query_id, template=key,
                                 latency_s=round(latency, 6),
                                 error=handle.error)
            else:
                self._emit_event(
                    "deliver", qid=handle.query_id, template=key,
                    latency_s=round(latency, 6),
                    pilot_wall_s=round(pilot_wall, 6),
                    scanned_bytes=int(scanned), cached=handle.cached,
                    shared=shared, fused=handle._fused, staged=staged,
                    fallback=fallback)
                if fallback:
                    self._emit_event("fallback", qid=handle.query_id,
                                     template=key, reason=rep.fallback)
        if handle._trace_sampled and handle._trace is not None:
            tree = handle._trace.to_dict()
            self.recent_traces.append(tree)
            self._emit_event("trace", qid=handle.query_id, template=key,
                             trace=tree)
        if self.slo is not None:
            self.slo.evaluate(key)

    def _observe_audit(self, handle: QueryHandle,
                       rec: _audit.AuditRecord) -> None:
        """Feed one audit outcome into the time-series / recorder / SLO
        (called by :meth:`_complete_handle` after the auditor ran)."""
        key = handle._template_key or "_unkeyed"
        if self.timeseries is not None and rec.skipped is None:
            self.timeseries.record_audit(key, rec.error_ratio, rec.passed)
        self._emit_event("audit", qid=handle.query_id, template=key,
                         ratio=round(rec.error_ratio, 6), passed=rec.passed,
                         observed=round(rec.observed_error, 6),
                         promised=rec.promised_error, skipped=rec.skipped)
        if self.slo is not None and rec.skipped is None:
            self.slo.evaluate(key)  # violation-rate targets see the record

    # -- front doors ----------------------------------------------------------
    def table(self, name: str) -> QueryBuilder:
        if name not in self.executor.catalog:
            raise KeyError(f"unknown table {name!r}; registered: "
                           f"{self.tables()}")
        return QueryBuilder(self, name)

    def sql(self, text: str, *, stream: bool = False) -> QueryHandle:
        """Parse and execute dialect SQL synchronously.

        Parse-stage rejections — :class:`repro.api.SqlSyntaxError`, and
        :class:`repro.api.UnsupportedSqlError` for semantic violations such
        as GROUP BY on a non-integer-coded column or an unresolvable string
        literal — raise immediately (the query never existed); execution
        failures are captured on the returned handle.

        ``stream=True`` attaches a frame buffer before execution, so the
        handle's :meth:`QueryHandle.stream` / :meth:`QueryHandle.on_frame`
        observe the advisory pilot estimate as well as the terminal frame;
        the default is byte-for-byte today's non-streaming behavior.
        """
        handle = self._parse_to_handle(text, stream=stream)
        self._run_handle(handle)
        return handle

    def prepare(self, text: str, *, stream: bool = False) -> QueryHandle:
        """Parse dialect SQL into a pending handle without scheduling it —
        for callers that run their own :class:`QueryScheduler` (e.g. a
        gateway keeping its queue separate from the session's)."""
        return self._parse_to_handle(text, stream=stream)

    def submit(self, text: str, *, stream: bool = False) -> QueryHandle:
        """Parse dialect SQL and enqueue it on the session scheduler."""
        return self.scheduler.submit(self.prepare(text, stream=stream))

    def execute(self, query: Query, spec: Optional[ErrorSpec] = None, *,
                stream: bool = False) -> QueryHandle:
        """Execute an already-lowered query synchronously (builder path)."""
        handle = self._make_handle(query, spec, stream=stream)
        self._run_handle(handle)
        return handle

    def submit_query(self, query: Query,
                     spec: Optional[ErrorSpec] = None, *,
                     having: Optional[HavingClause] = None,
                     limit: Optional[LimitClause] = None,
                     stream: bool = False) -> QueryHandle:
        return self.scheduler.submit(
            self._make_handle(query, spec, having=having, limit=limit,
                              stream=stream))

    def drain(self, max_queries: Optional[int] = None) -> List[QueryHandle]:
        return self.scheduler.drain(max_queries)

    def drain_async(self) -> List[QueryHandle]:
        """Dispatch every pending query to the runtime without waiting;
        observe completion per handle via ``poll()`` / ``wait()``."""
        return self.scheduler.drain_async()

    # -- plumbing -------------------------------------------------------------
    def _parse_to_handle(self, text: str, *, stream: bool = False) -> QueryHandle:
        t0 = time.perf_counter()
        parsed = parse_sql(text, max_groups_resolver=self.infer_max_groups,
                           spec_kwargs=self.config.spec_kwargs)
        t_parsed = time.perf_counter()
        # t0 (pre-parse) is the submit epoch: the parse span and every
        # frame's emitted_at stay non-negative relative to it
        handle = self._make_handle(parsed.query, parsed.spec, sql=text,
                                   having=parsed.having, limit=parsed.limit,
                                   stream=stream, t_submit=t0)
        if handle._trace is not None:
            handle._trace.record("parse", duration_s=t_parsed - t0)
        return handle

    def _resolve_dictionary(self, column: str, literal: str) -> int:
        d = self._dictionaries.get(column)
        if d is None:
            raise UnsupportedSqlError(
                f"string literal {literal!r} compares against {column!r}, "
                "which has no registered dictionary (see "
                "Session.register_dictionary)")
        if literal not in d.codes:
            raise UnsupportedSqlError(
                f"{literal!r} is not in the dictionary of {column!r} "
                f"(values: {sorted(d.codes)})")
        return d.codes[literal]

    def _resolve_dictionary_order(self, column: str, literal: str,
                                  op: str) -> Tuple[str, int]:
        """Lower an order comparison ``column <op> literal`` against a
        SORTED dictionary to an integer-code comparison.

        Sortedness makes code order equal string order, so the comparison
        becomes a bisection boundary — valid even for literals not in the
        dictionary: ``col < 'N'`` holds exactly for codes below
        ``bisect_left(values, 'N')``.  Returns the lowered ``(op, code)``
        with the column on the left.
        """
        d = self._dictionaries.get(column)
        if d is None:
            raise UnsupportedSqlError(
                f"string literal {literal!r} compares against {column!r}, "
                "which has no registered dictionary (see "
                "Session.register_dictionary)")
        if not d.is_sorted:
            raise UnsupportedSqlError(
                f"dictionary-encoded column {column!r} supports = and != "
                f"only, got {op!r}: its dictionary is not lexicographically "
                "sorted, so code order does not reflect string order "
                "(register a sorted dictionary to enable order comparisons)")
        if op in ("<", ">="):
            boundary = bisect.bisect_left(d.values, literal)
        else:  # "<=", ">": strict/inclusive flip at the right bisection
            boundary = bisect.bisect_right(d.values, literal)
        lowered = {"<": "<", "<=": "<", ">": ">=", ">=": ">="}[op]
        return lowered, boundary

    def _validate_group_domain(self, query: Query) -> None:
        """Reject GROUP BY shapes that would silently misbehave: a
        max_groups above the buffer-size cap (OOM in a shared server) or
        below the column's observed domain (the engine clips overflow group
        ids, silently merging those rows into the last group)."""
        if query.group_by is None:
            return
        limit = self.config.max_groups_limit
        if query.max_groups > limit:
            raise UnsupportedSqlError(
                f"GROUP BY {query.group_by}: max_groups={query.max_groups} "
                f"exceeds the session limit {limit} (per-block group "
                "buffers scale with max_groups)")
        tables = tuple(s.table for s in query.child.scans())
        domain = self.infer_max_groups(tables, query.group_by)
        if domain > query.max_groups:
            raise UnsupportedSqlError(
                f"GROUP BY {query.group_by}: MAXGROUPS {query.max_groups} "
                f"is below the observed group domain ({domain}); overflow "
                "groups would be silently merged into the last group")

    def _make_handle(self, query: Query, spec: Optional[ErrorSpec],
                     sql: Optional[str] = None,
                     having: Optional[HavingClause] = None,
                     limit: Optional[LimitClause] = None,
                     stream: bool = False,
                     t_submit: Optional[float] = None) -> QueryHandle:
        # resolve + validate before deriving a seed: rejected queries never
        # enter the seed/cache keyspace
        query = resolve_string_literals(query, self._resolve_dictionary,
                                        self._resolve_dictionary_order)
        self._validate_group_domain(query)
        if having is not None and having.agg not in {c.name for c in query.aggs}:
            raise UnsupportedSqlError(
                f"HAVING references unknown aggregate {having.agg!r} "
                f"(outputs: {[c.name for c in query.aggs]})")
        if limit is not None and limit.order_by is not None \
                and limit.order_by not in {c.name for c in query.aggs}:
            raise UnsupportedSqlError(
                f"ORDER BY references unknown aggregate {limit.order_by!r} "
                f"(outputs: {[c.name for c in query.aggs]})")
        # one lowering: the group key is the (memoized) constant-stripped
        # template of the signature just computed, not a second lowering
        t_lower0 = time.perf_counter()
        signature = structural_signature(query)
        handle = QueryHandle(query_id=self._next_id, query=query, spec=spec,
                             seed=self._derive_seed(query, spec), sql=sql,
                             having=having, limit=limit, signature=signature,
                             group_key=plan_template(signature),
                             t_submit=(time.perf_counter()
                                       if t_submit is None else t_submit))
        self._next_id += 1
        handle._trace_sampled = self._trace_sampled(signature)
        if self.config.tracing or handle._trace_sampled:
            handle._trace = _trace.QueryTrace(
                handle.query_id, sql=sql, t_start=handle.t_submit)
            handle._trace.record(
                "lower", duration_s=time.perf_counter() - t_lower0,
                seed=handle.seed,
                template=_trace.sig_hash(handle.group_key),
                signature=_trace.sig_hash(signature))
        if self._telemetry_armed:
            handle._template_key = _trace.sig_hash(handle.group_key)
            handle._on_complete = self._observe_delivery
            if self.recorder is not None:
                self.recorder.emit(
                    "submit", qid=handle.query_id,
                    template=handle._template_key, sql=sql,
                    sampled=handle._trace_sampled)
        if stream:
            handle.enable_streaming()
        return handle

    def failed_handle(self, sql: str, error: str) -> QueryHandle:
        """A pre-failed handle for requests that never parsed (gateways use
        this to reject one client's bad SQL without dropping the batch)."""
        handle = QueryHandle(query_id=self._next_id, query=None, spec=None,
                             seed=0, sql=sql, status=QueryStatus.FAILED,
                             error=error)
        handle._done_event.set()
        self._next_id += 1
        return handle

    # -- execution core (shared by sync paths and runtime workers) ------------
    def _cache_key(self, handle: QueryHandle):
        # (structural signature, predicate constants, ErrorSpec, seed): the
        # frozen Query embeds the first two (constants live in its plan) and
        # additionally pins user-facing aggregate names.
        return (handle.query, handle.spec, handle.seed)

    def _serve_cached(self, handle: QueryHandle) -> bool:
        """Answer ``handle`` from the result cache if possible.  A hit
        rebuilds the answer from the compact cached record — values and the
        error report that was guaranteed when it was computed (still valid:
        register_table would have evicted the entry if the data had
        changed)."""
        if handle.query is None:
            return False
        with _trace.span("cache_lookup") as sp:
            entry = self.result_cache.get(self._cache_key(handle))
            sp.set(hit=entry is not None)
        if entry is None:
            return False
        if handle.streaming and isinstance(entry, CachedAnswer) \
                and entry.pilot is not None:
            # replay the compact pilot summary recorded at insert as an
            # advisory frame, so cached re-issues stream the same shape
            # (pilot then final); entries without one stream single-frame
            handle._emit(pilot_frame_for(handle.query_id, entry.pilot,
                                         from_cache=True))
        answer = entry.to_answer() if isinstance(entry, CachedAnswer) else entry
        if handle.having is not None:
            # the cache holds the unfiltered base answer (HAVING is not in
            # the key), so HAVING-varied re-issues all hit one entry
            answer = handle.having.apply(answer)
        if handle.limit is not None:  # same contract; after HAVING
            answer = handle.limit.apply(answer)
        handle._mark_done(answer, cached=True)
        return True

    def _scan_generations(self, query: Query) -> Tuple[int, ...]:
        with self._gen_lock:
            return tuple(self._table_gen.get(s.table, 0)
                         for s in query.child.scans())

    def _complete_handle(self, handle: QueryHandle, answer: ApproxAnswer,
                         gen_snapshot: Optional[tuple] = None,
                         pilot_est=None) -> bool:
        """Finish a handle, guarding against mid-flight table replacement.

        If :meth:`register_table` replaced any scanned table after execution
        started (``gen_snapshot`` mismatch), the answer may be *torn* —
        e.g. pilot statistics from the old data scaling a final scan of the
        new — so its error report is no longer a guarantee.  PilotDB never
        returns an unguaranteed estimate: the handle fails with a retryable
        error instead (a resubmission re-derives the same seed and runs
        cleanly against the new data).  The result-cache insert is guarded
        by the same generation check, under the cache lock.  Returns True
        when the handle completed with the answer.

        ``pilot_est`` (the query's advisory :class:`PilotEstimate`, when its
        pilot produced one) is recorded on the cache entry so cached
        re-issues can replay a provisional frame (see :meth:`_serve_cached`).
        """
        current = self._scan_generations(handle.query)
        if gen_snapshot is not None and gen_snapshot != current:
            handle._mark_failed(
                "table replaced while the query was in flight "
                f"({sorted({s.table for s in handle.query.child.scans()})}); "
                "resubmit to run against the new data")
            return False
        self.result_cache.put(
            self._cache_key(handle),
            CachedAnswer.from_answer(answer, pilot=pilot_est),
            (s.table for s in handle.query.child.scans()),
            guard=None if gen_snapshot is None else
            (lambda: gen_snapshot == self._scan_generations(handle.query)))
        base = answer  # the guarantee covers the pre-HAVING/LIMIT answer
        if handle.having is not None:  # cache keeps the unfiltered answer
            answer = handle.having.apply(answer)
        if handle.limit is not None:   # after HAVING, like _serve_cached
            answer = handle.limit.apply(answer)
        handle._mark_done(answer)
        if self.auditor is not None:
            # AFTER delivery (the client already has its answer; the trace
            # is finished, so the exact run traces nothing) and against the
            # base answer — every group the guarantee covered gets checked
            rec = self.auditor.check(handle, base)
            if rec is not None and self._telemetry_armed:
                try:  # telemetry observes; it must never raise into delivery
                    self._observe_audit(handle, rec)
                except Exception:
                    pass
        return True

    def _run_fused(self, handle: QueryHandle) -> Optional[ApproxAnswer]:
        """Attempt the single-launch fused TAQA program for ``handle``.

        Returns the answer (bit-identical to the two-stage path by the
        fused-path verification contract — see :meth:`PilotDB.run_fused`)
        or None when the query's shape is ineligible, in which case the
        caller falls through to the two-stage path having executed
        nothing."""
        with _trace.span("fused") as sp:
            try:
                ans = self.db.run_fused(
                    handle.query, handle.spec, seed=handle.seed,
                    pilot_seed=self._pilot_seed_for(handle))
            except Exception:
                # fusion is an optimization, never a failure mode: the
                # two-stage path re-runs the query from scratch and captures
                # any genuine execution failure on the handle itself
                ans = None
            sp.set(engaged=ans is not None,
                   fallback=None if ans is None else ans.report.fallback)
        if ans is not None:
            handle._fused = True  # provenance + telemetry read this flag
            rep = ans.report
            self._emit_event("pilot", qid=handle.query_id, fused=True,
                             table=rep.pilot_table,
                             scanned_bytes=rep.pilot_scanned_bytes,
                             wall_s=round(rep.pilot_time_s, 6),
                             fallback=rep.fallback)
            self._emit_event("rate_solve", qid=handle.query_id, fused=True,
                             candidates=rep.candidates, fallback=rep.fallback)
            self._emit_event("final", qid=handle.query_id, fused=True,
                             scanned_bytes=rep.final_scanned_bytes,
                             wall_s=round(rep.final_time_s, 6),
                             fallback=rep.fallback)
        return ans

    def _run_handle(self, handle: QueryHandle) -> QueryHandle:
        if handle.done:
            return handle
        token = _trace.activate(handle._trace)
        try:
            if self._serve_cached(handle):
                return handle
            handle._mark_running()
            gen = self._scan_generations(handle.query)
            try:
                pilot_est = None
                if handle.spec is None:
                    with _trace.span("exact") as sp:
                        ans = self.db.exact(handle.query)
                        sp.set(scanned_bytes=ans.report.exact_scanned_bytes)
                elif self.config.fused_taqa and (
                        fused := self._run_fused(handle)) is not None:
                    ans = fused
                else:
                    # run the two TAQA stages separately (instead of
                    # db.query) so the advisory estimate streams the moment
                    # stage 1 returns — before any stage-2 dispatch
                    with _trace.span("pilot", shared=False) as sp:
                        outcome = self.db.run_pilot(
                            handle.query, handle.spec,
                            self._pilot_seed_for(handle))
                        rep = outcome.report
                        sp.set(table=rep.pilot_table,
                               theta_pilot=rep.theta_pilot,
                               n_pilot_blocks=rep.n_pilot_blocks,
                               scanned_bytes=rep.pilot_scanned_bytes,
                               fallback=rep.fallback)
                    self._emit_event(
                        "pilot", qid=handle.query_id, shared=False,
                        table=rep.pilot_table,
                        scanned_bytes=rep.pilot_scanned_bytes,
                        wall_s=round(rep.pilot_time_s, 6),
                        fallback=rep.fallback)
                    pilot_est = advisory_estimate(handle.query, outcome,
                                                  handle.spec.confidence)
                    if pilot_est is not None:
                        handle._emit(pilot_frame_for(handle.query_id,
                                                     pilot_est))
                    # finish_from_pilot == run_final(prepare_final(...));
                    # split here only so each stage gets its own span
                    with _trace.span("rate_solve") as sp:
                        stage = self.db.prepare_final(
                            handle.query, handle.spec, outcome, handle.seed)
                        rep = stage.report
                        sp.set(candidates=rep.candidates,
                               fallback=rep.fallback,
                               rates=dict(rep.plan.rates)
                               if rep.plan is not None else None)
                    self._emit_event("rate_solve", qid=handle.query_id,
                                     candidates=rep.candidates,
                                     fallback=rep.fallback)
                    with _trace.span("final", batched=False) as sp:
                        ans = self.db.run_final(stage)
                        sp.set(scanned_bytes=ans.report.final_scanned_bytes,
                               fallback=ans.report.fallback)
                    self._emit_event(
                        "final", qid=handle.query_id,
                        scanned_bytes=ans.report.final_scanned_bytes,
                        wall_s=round(ans.report.final_time_s, 6),
                        fallback=ans.report.fallback)
                with _trace.span("deliver"):
                    self._complete_handle(handle, ans, gen,
                                          pilot_est=pilot_est)
            except Exception as e:  # capture, don't raise through the client
                handle._mark_failed(f"{type(e).__name__}: {e}")
            return handle
        finally:
            # worker threads are pooled: a leaked context var would
            # misattribute the next query's spans
            _trace.deactivate(token)

    def _execute_group(self, handles: List[QueryHandle]) -> None:
        """Run one signature group (runtime workers land here): cached
        members answer immediately, the rest share a pilot per
        pilot-params subgroup and finish independently."""
        _shared_pilot.execute_group(self, handles)
