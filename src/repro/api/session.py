"""Session — the stateful front door of the PilotDB middleware.

A :class:`Session` owns everything that must persist across queries for the
many-users scenario to pay off:

* the registered tables (the catalog) and the :class:`Executor` whose
  physical compile cache makes repeated structurally-identical queries run
  warm (see ``engine/physical.py``),
* a session PRNG (:class:`numpy.random.SeedSequence`) from which every
  query's sampling seed is derived at *submission* time — two sessions
  created with the same seed replay bit-identical answers for the same
  query sequence, with no global RNG state anywhere,
* a :class:`repro.api.QueryScheduler` for batched submission.

``session.sql(...)`` / ``builder.run()`` return a :class:`QueryHandle`
carrying status, the :class:`ApproxAnswer`, the :class:`TaqaReport` and any
fallback reason — execution failures are captured on the handle instead of
raising through the client (`EmptySampleError` in particular is already an
*internal* signal: TAQA answers it with an explicit exact fallback).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.api.builder import QueryBuilder
from repro.api.scheduler import QueryScheduler
from repro.api.sql import UnsupportedSqlError, parse_sql
from repro.core.spec import ErrorSpec
from repro.core.taqa import ApproxAnswer, PilotDB, Query, TaqaReport
from repro.engine.executor import Executor
from repro.engine.table import BlockTable


class QueryStatus:
    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"


class QueryFailedError(RuntimeError):
    """Raised by :meth:`QueryHandle.result` when execution failed."""


@dataclasses.dataclass
class QueryHandle:
    """One submitted query: its lowered form, derived seed, and outcome."""

    query_id: int
    query: Optional[Query]            # None only for parse-failed handles
    spec: Optional[ErrorSpec]         # None -> exact execution was requested
    seed: int
    sql: Optional[str] = None
    status: str = QueryStatus.PENDING
    error: Optional[str] = None
    _answer: Optional[ApproxAnswer] = None

    @property
    def done(self) -> bool:
        return self.status in (QueryStatus.DONE, QueryStatus.FAILED)

    @property
    def answer(self) -> Optional[ApproxAnswer]:
        return self._answer

    @property
    def report(self) -> Optional[TaqaReport]:
        return self._answer.report if self._answer is not None else None

    @property
    def fallback(self) -> Optional[str]:
        """Reason exact execution was used, if TAQA fell back (else None)."""
        r = self.report
        return r.fallback if r is not None else None

    def result(self) -> ApproxAnswer:
        """The answer; raises if the query failed or has not run yet."""
        if self.status == QueryStatus.FAILED:
            raise QueryFailedError(self.error or "query failed")
        if self._answer is None:
            raise RuntimeError(
                f"query {self.query_id} is {self.status}; drain the "
                "scheduler it was submitted to (session.drain(), or "
                "gateway.run() for gateway tickets) before reading results")
        return self._answer

    def scalar(self, name: str, group: int = 0) -> float:
        return self.result().scalar(name, group)


@dataclasses.dataclass(frozen=True)
class SessionConfig:
    large_table_rows: int = 50_000     # sampling threshold (§3.1)
    default_error: float = 0.05        # builder .error() defaults
    default_confidence: float = 0.95
    use_compiled: bool = True
    kernel_mode: str = "auto"
    spec_kwargs: Optional[Dict] = None  # TAQA tunable overrides for SQL specs
    # The physical layer sizes dense per-(block, group) buffers by
    # max_groups; an id-cardinality GROUP BY through the public front door
    # would otherwise allocate process-killing buffers in a shared server.
    max_groups_limit: int = 4096


class Session:
    """A client session against a catalog of block tables."""

    def __init__(self, catalog: Optional[Dict[str, BlockTable]] = None, *,
                 seed: int = 0, config: SessionConfig = SessionConfig(),
                 executor: Optional[Executor] = None):
        self.config = config
        if config.spec_kwargs:
            # fail at construction, not on every client's ERROR clause
            dataclasses.replace(
                ErrorSpec(error=config.default_error,
                          confidence=config.default_confidence),
                **config.spec_kwargs)
        if executor is not None:
            if catalog is not None:
                raise ValueError(
                    "pass either catalog or executor, not both: an explicit "
                    "executor brings its own catalog, and the catalog "
                    "argument would be silently ignored")
            self.executor = executor
        else:
            self.executor = Executor(catalog or {},
                                     use_compiled=config.use_compiled,
                                     kernel_mode=config.kernel_mode)
        self.db = PilotDB(self.executor,
                          large_table_rows=config.large_table_rows)
        self._seed_seq = np.random.SeedSequence(seed)
        self._next_id = 0
        self._max_groups_cache: Dict[tuple, int] = {}
        self.scheduler = QueryScheduler(self)

    # -- catalog -------------------------------------------------------------
    def register_table(self, name: str, table: BlockTable) -> None:
        self.executor.register_table(name, table)
        # replacing a table invalidates its cached statistics
        self._max_groups_cache = {k: v for k, v in
                                  self._max_groups_cache.items()
                                  if k[0] != name}

    def tables(self) -> List[str]:
        return sorted(self.executor.catalog)

    def infer_max_groups(self, tables, column: str) -> int:
        """Group-id domain size for integer-coded group columns, from the
        catalog (the "DBMS statistics" a middleware would consult).

        ``tables`` is the table name — or every table in the query's FROM/
        JOIN chain, since GROUP BY may name a joined table's column.  An
        unknown table or column resolves to 1 rather than raising: the
        inference is advisory, and the real error surfaces at execution
        where it is captured on the handle.
        """
        if isinstance(tables, str):
            tables = (tables,)
        for name in tables:
            tab = self.executor.catalog.get(name)
            if tab is None or column not in tab.columns:
                continue
            key = (name, column)
            if key not in self._max_groups_cache:
                col = np.asarray(tab.columns[column])[np.asarray(tab.valid)]
                if col.size == 0:
                    self._max_groups_cache[key] = 1
                else:
                    # grouping requires non-negative integer group codes;
                    # a float/negative column would silently collapse groups
                    if not (np.issubdtype(col.dtype, np.integer)
                            or np.all(col == np.floor(col))):
                        raise UnsupportedSqlError(
                            f"GROUP BY {column}: column is not integer-coded "
                            f"(dtype {col.dtype}); group columns must hold "
                            "non-negative integer group ids")
                    if col.min() < 0:
                        raise UnsupportedSqlError(
                            f"GROUP BY {column}: negative group ids "
                            "(min {:g}) are not supported".format(col.min()))
                    self._max_groups_cache[key] = int(col.max()) + 1
            return self._max_groups_cache[key]
        return 1

    def compile_cache_info(self):
        return self.executor.compile_cache_info()

    # -- seed derivation ------------------------------------------------------
    def _derive_seed(self) -> int:
        """Per-query seed from the session PRNG key.  Spawning advances the
        SeedSequence deterministically, so seeds depend only on the session
        seed and the submission index — never on global state or on how the
        scheduler later reorders execution."""
        child = self._seed_seq.spawn(1)[0]
        return int(child.generate_state(1, dtype=np.uint32)[0])

    # -- front doors ----------------------------------------------------------
    def table(self, name: str) -> QueryBuilder:
        if name not in self.executor.catalog:
            raise KeyError(f"unknown table {name!r}; registered: "
                           f"{self.tables()}")
        return QueryBuilder(self, name)

    def sql(self, text: str) -> QueryHandle:
        """Parse and execute dialect SQL synchronously.

        Parse-stage rejections — :class:`repro.api.SqlSyntaxError`, and
        :class:`repro.api.UnsupportedSqlError` for semantic violations such
        as GROUP BY on a non-integer-coded column — raise immediately (the
        query never existed); execution failures are captured on the
        returned handle.
        """
        handle = self._parse_to_handle(text)
        self._run_handle(handle)
        return handle

    def prepare(self, text: str) -> QueryHandle:
        """Parse dialect SQL into a pending handle without scheduling it —
        for callers that run their own :class:`QueryScheduler` (e.g. a
        gateway keeping its queue separate from the session's)."""
        return self._parse_to_handle(text)

    def submit(self, text: str) -> QueryHandle:
        """Parse dialect SQL and enqueue it on the session scheduler."""
        return self.scheduler.submit(self.prepare(text))

    def execute(self, query: Query, spec: Optional[ErrorSpec] = None) -> QueryHandle:
        """Execute an already-lowered query synchronously (builder path)."""
        handle = self._make_handle(query, spec)
        self._run_handle(handle)
        return handle

    def submit_query(self, query: Query,
                     spec: Optional[ErrorSpec] = None) -> QueryHandle:
        return self.scheduler.submit(self._make_handle(query, spec))

    def drain(self, max_queries: Optional[int] = None) -> List[QueryHandle]:
        return self.scheduler.drain(max_queries)

    # -- plumbing -------------------------------------------------------------
    def _parse_to_handle(self, text: str) -> QueryHandle:
        parsed = parse_sql(text, max_groups_resolver=self.infer_max_groups,
                           spec_kwargs=self.config.spec_kwargs)
        return self._make_handle(parsed.query, parsed.spec, sql=text)

    def _validate_group_domain(self, query: Query) -> None:
        """Reject GROUP BY shapes that would silently misbehave: a
        max_groups above the buffer-size cap (OOM in a shared server) or
        below the column's observed domain (the engine clips overflow group
        ids, silently merging those rows into the last group)."""
        if query.group_by is None:
            return
        limit = self.config.max_groups_limit
        if query.max_groups > limit:
            raise UnsupportedSqlError(
                f"GROUP BY {query.group_by}: max_groups={query.max_groups} "
                f"exceeds the session limit {limit} (per-block group "
                "buffers scale with max_groups)")
        tables = tuple(s.table for s in query.child.scans())
        domain = self.infer_max_groups(tables, query.group_by)
        if domain > query.max_groups:
            raise UnsupportedSqlError(
                f"GROUP BY {query.group_by}: MAXGROUPS {query.max_groups} "
                f"is below the observed group domain ({domain}); overflow "
                "groups would be silently merged into the last group")

    def _make_handle(self, query: Query, spec: Optional[ErrorSpec],
                     sql: Optional[str] = None) -> QueryHandle:
        # validate before deriving a seed: rejected queries never consume
        # from the session PRNG, keeping replay deterministic
        self._validate_group_domain(query)
        handle = QueryHandle(query_id=self._next_id, query=query, spec=spec,
                             seed=self._derive_seed(), sql=sql)
        self._next_id += 1
        return handle

    def failed_handle(self, sql: str, error: str) -> QueryHandle:
        """A pre-failed handle for requests that never parsed (gateways use
        this to reject one client's bad SQL without dropping the batch)."""
        handle = QueryHandle(query_id=self._next_id, query=None, spec=None,
                             seed=0, sql=sql, status=QueryStatus.FAILED,
                             error=error)
        self._next_id += 1
        return handle

    def _run_handle(self, handle: QueryHandle) -> QueryHandle:
        if handle.done:
            return handle
        handle.status = QueryStatus.RUNNING
        try:
            if handle.spec is None:
                ans = self.db.exact(handle.query)
            else:
                ans = self.db.query(handle.query, handle.spec,
                                    seed=handle.seed)
            handle._answer = ans
            handle.status = QueryStatus.DONE
        except Exception as e:  # capture, don't raise through the client
            handle.status = QueryStatus.FAILED
            handle.error = f"{type(e).__name__}: {e}"
        return handle
