# The user-facing front door (§2.4): plain SQL extended with
# `ERROR e% CONFIDENCE p%`, a typed fluent builder, and a Session that owns
# tables, the compile cache, and seed derivation.  The raw dataclass surface
# (core.taqa.Query + CompositeAgg) remains available as the internal
# representation these lower to.
from repro.api.builder import QueryBuilder, avg_, count_, sum_
from repro.api.scheduler import DrainStats, QueryScheduler
from repro.api.session import (QueryFailedError, QueryHandle, QueryStatus,
                               Session, SessionConfig)
from repro.api.sql import (HavingClause, LimitClause, ParsedQuery,
                           SqlSyntaxError, UnsupportedSqlError, parse_sql,
                           render_sql, resolve_string_literals)
from repro.runtime import BackpressureError, ResultCacheInfo
from repro.stream import (ErrorFrame, ExactFrame, FinalFrame, Frame,
                          PilotFrame)

__all__ = [
    "Session",
    "SessionConfig",
    "QueryHandle",
    "QueryStatus",
    "QueryFailedError",
    "QueryScheduler",
    "DrainStats",
    "QueryBuilder",
    "sum_",
    "count_",
    "avg_",
    "parse_sql",
    "render_sql",
    "resolve_string_literals",
    "HavingClause",
    "LimitClause",
    "ParsedQuery",
    "SqlSyntaxError",
    "UnsupportedSqlError",
    "BackpressureError",
    "ResultCacheInfo",
    "Frame",
    "PilotFrame",
    "FinalFrame",
    "ExactFrame",
    "ErrorFrame",
]
