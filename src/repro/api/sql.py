"""The PilotDB SQL dialect (§2.4): parser and renderer.

Grammar (case-insensitive keywords)::

    query   := SELECT item (',' item)*
               FROM ident (JOIN ident ON column '=' column)*
               (WHERE pred)?
               (GROUP BY column (MAXGROUPS int)?)?
               (HAVING ident cmp num)?
               ((ORDER BY ident (ASC|DESC)?)? LIMIT int)?
               (ERROR num '%' CONFIDENCE num '%')?
    item    := composite (AS ident)?
    composite := wterm '+' wterm          -- addition rule (Table 2)
               | aggcall '/' aggcall      -- division rule: SUM/SUM ratio
               | aggcall '*' aggcall      -- multiplication rule
               | aggcall
    wterm   := (num '*')? aggcall         -- weighted SUM, only under '+'
    aggcall := SUM '(' expr ')' | AVG '(' expr ')' | COUNT '(' '*' ')'
    pred    := or-chain of AND-chains of comparisons / BETWEEN / NOT (...)
    expr    := arithmetic over columns and numeric literals (+ - * /)
    column  := ident | ident '.' ident    -- optional table qualifier
    string  := "'" chars "'"              -- '' escapes a quote; strings may
                                          -- appear as comparison operands

`MAXGROUPS n` is a dialect extension fixing the group-id domain
(``Query.max_groups``); when omitted the caller may supply a resolver that
infers it from catalog statistics (see :meth:`repro.api.Session.sql`).

Column names are globally unique in this schema family (TPC-H style), so a
``t.col`` qualifier is presentation sugar: the parser strips it, and
:func:`render_sql` emits the canonical unqualified form.  String literals
parse to :class:`repro.engine.expr.Str` nodes, which
:func:`resolve_string_literals` lowers to dictionary codes before a plan
reaches the engine (sessions call it with their registered dictionaries).

Lowering targets the existing internal representation unchanged:
:class:`repro.core.taqa.Query` (+ :class:`repro.core.spec.ErrorSpec`), i.e.
the same frozen dataclasses tests hand-build.  AND/OR chains fold *right*
(``a AND b AND c`` -> ``And(a, And(b, c))``) and arithmetic folds left,
matching the hand-built idiom, so parse -> lower reproduces those plans
bit-for-bit and :func:`render_sql` round-trips through :func:`parse_sql`.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Callable, List, Optional, Tuple

from repro.core.spec import CompositeAgg, ErrorSpec
from repro.core.taqa import Query
from repro.engine import logical as L
from repro.engine.expr import (And, Between, BinOp, Cmp, Col, Const, Expr, Not,
                               Or, Str)


class SqlSyntaxError(ValueError):
    """The query text does not parse in the PilotDB dialect."""


class UnsupportedSqlError(ValueError):
    """A plan/query outside the dialect surface (rendering direction)."""


@dataclasses.dataclass(frozen=True)
class HavingClause:
    """``HAVING <agg> <cmp> <number>``: a post-aggregation filter.

    The comparison references an output aggregate by its SELECT alias and
    is applied AFTER the approximate aggregation, to the returned groups of
    an :class:`repro.core.taqa.ApproxAnswer` (or one rebuilt from a cached
    record): groups whose estimated value fails the comparison are cleared
    from ``group_present``.  It never reaches the engine plan — the plan
    signature, pilot sharing, seeds, and the result-cache key are all
    HAVING-agnostic, so HAVING-varied re-issues of one query share the same
    pilot, compilation, and cached base answer.
    """

    agg: str
    op: str       # normalized: == != < <= > >=
    value: float

    def apply(self, answer):
        """A copy of ``answer`` with failing groups cleared (the values
        array is untouched — HAVING filters group membership, not
        estimates).  NaN estimates (absent groups) never pass."""
        import numpy as np
        if self.agg not in answer.names:
            raise UnsupportedSqlError(
                f"HAVING references unknown aggregate {self.agg!r} "
                f"(outputs: {answer.names})")
        vals = np.asarray(answer.values[answer.names.index(self.agg)])
        with np.errstate(invalid="ignore"):
            ok = _HAVING_OPS[self.op](vals, self.value)
        present = np.asarray(answer.group_present, dtype=bool) & ok
        return dataclasses.replace(answer, group_present=present)


_HAVING_OPS = {
    "==": lambda v, c: v == c,
    "!=": lambda v, c: v != c,
    "<": lambda v, c: v < c,
    "<=": lambda v, c: v <= c,
    ">": lambda v, c: v > c,
    ">=": lambda v, c: v >= c,
}


@dataclasses.dataclass(frozen=True)
class LimitClause:
    """``[ORDER BY <agg> [ASC|DESC]] LIMIT n``: post-aggregation top-n.

    Same contract as :class:`HavingClause` (and applied after it): the
    selection acts on the delivered answer's present groups — ranked by the
    named output aggregate's estimates when ORDER BY is given, by group id
    otherwise — and never reaches the engine plan.  Signatures, pilot
    sharing, seeds, and the result-cache key are all LIMIT-agnostic, so
    LIMIT-varied re-issues of one query share the same pilot, compilation,
    and cached base answer.  ORDER BY without LIMIT is rejected at parse:
    answers are unordered group sets, so ordering only exists to select.
    """

    n: int
    order_by: Optional[str] = None
    desc: bool = False

    def apply(self, answer):
        """A copy of ``answer`` keeping at most ``n`` present groups (the
        values array is untouched — LIMIT selects group membership, not
        estimates).  Ties and NaN-last ranking follow numpy stable argsort,
        so repeated applications are deterministic."""
        import numpy as np
        present = np.asarray(answer.group_present, dtype=bool)
        idx = np.nonzero(present)[0]
        if len(idx) <= self.n:
            return dataclasses.replace(answer, group_present=present)
        if self.order_by is not None:
            if self.order_by not in answer.names:
                raise UnsupportedSqlError(
                    f"ORDER BY references unknown aggregate "
                    f"{self.order_by!r} (outputs: {answer.names})")
            vals = np.asarray(
                answer.values[answer.names.index(self.order_by)])[idx]
            key = -vals if self.desc else vals
            keep = idx[np.argsort(key, kind="stable")[:self.n]]
        else:
            keep = idx[:self.n]
        new_present = np.zeros_like(present)
        new_present[keep] = True
        return dataclasses.replace(answer, group_present=new_present)


@dataclasses.dataclass(frozen=True)
class ParsedQuery:
    query: Query
    spec: Optional[ErrorSpec]   # None: no ERROR clause -> exact execution
    having: Optional[HavingClause] = None
    limit: Optional[LimitClause] = None

    @property
    def is_approximate(self) -> bool:
        return self.spec is not None


# ---------------------------------------------------------------------------
# Tokenizer
# ---------------------------------------------------------------------------

_KEYWORDS = {
    "SELECT", "FROM", "WHERE", "GROUP", "BY", "JOIN", "ON", "AS", "AND",
    "OR", "NOT", "BETWEEN", "SUM", "COUNT", "AVG", "ERROR", "CONFIDENCE",
    "MAXGROUPS", "HAVING", "ORDER", "LIMIT", "ASC", "DESC",
}

_TOKEN_RE = re.compile(
    r"\s*(?:"
    r"(?P<num>\d+(?:\.\d+)?(?:[eE][+-]?\d+)?)"
    r"|(?P<str>'(?:[^']|'')*')"
    r"|(?P<ident>[A-Za-z_][A-Za-z_0-9]*)"
    r"|(?P<op><=|>=|<>|!=|==|[-+*/(),%=<>.])"
    r")")


def _tokenize(text: str) -> List[Tuple[str, object]]:
    toks: List[Tuple[str, object]] = []
    pos = 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if m is None:
            rest = text[pos:].strip()
            if not rest:
                break
            raise SqlSyntaxError(f"cannot tokenize near {rest[:20]!r}")
        pos = m.end()
        if m.lastgroup == "num":
            toks.append(("num", float(m.group("num"))))
        elif m.lastgroup == "str":
            toks.append(("str", m.group("str")[1:-1].replace("''", "'")))
        elif m.lastgroup == "ident":
            word = m.group("ident")
            if word.upper() in _KEYWORDS:
                toks.append(("kw", word.upper()))
            else:
                toks.append(("ident", word))
        else:
            toks.append(("op", m.group("op")))
    toks.append(("end", None))
    return toks


# ---------------------------------------------------------------------------
# Parser
# ---------------------------------------------------------------------------

_CMP_OPS = {"<": "<", "<=": "<=", ">": ">", ">=": ">=",
            "=": "==", "==": "==", "!=": "!=", "<>": "!="}


class _Parser:
    def __init__(self, toks: List[Tuple[str, object]]):
        self.toks = toks
        self.pos = 0

    # -- token helpers -------------------------------------------------------
    def peek(self) -> Tuple[str, object]:
        return self.toks[self.pos]

    def advance(self) -> Tuple[str, object]:
        t = self.toks[self.pos]
        self.pos += 1
        return t

    def accept_kw(self, *words: str) -> Optional[str]:
        k, v = self.peek()
        if k == "kw" and v in words:
            self.advance()
            return v  # type: ignore[return-value]
        return None

    def accept_op(self, *ops: str) -> Optional[str]:
        k, v = self.peek()
        if k == "op" and v in ops:
            self.advance()
            return v  # type: ignore[return-value]
        return None

    def expect_kw(self, word: str) -> None:
        if self.accept_kw(word) is None:
            raise SqlSyntaxError(f"expected {word}, got {self.peek()[1]!r}")

    def expect_op(self, op: str) -> None:
        if self.accept_op(op) is None:
            raise SqlSyntaxError(f"expected {op!r}, got {self.peek()[1]!r}")

    def expect_ident(self) -> str:
        k, v = self.advance()
        if k != "ident":
            raise SqlSyntaxError(f"expected identifier, got {v!r}")
        return v  # type: ignore[return-value]

    def expect_column(self) -> str:
        """A column reference, optionally table-qualified (``t.col``).
        Column names are globally unique, so the qualifier is stripped."""
        name = self.expect_ident()
        if self.accept_op("."):
            return self.expect_ident()
        return name

    def expect_num(self) -> float:
        k, v = self.advance()
        if k != "num":
            raise SqlSyntaxError(f"expected number, got {v!r}")
        return v  # type: ignore[return-value]

    def expect_signed_num(self) -> float:
        if self.accept_op("-"):
            return -self.expect_num()
        return self.expect_num()

    # -- arithmetic expressions (left-assoc, matching operator overloads) ----
    def parse_arith(self) -> Expr:
        e = self.parse_term()
        while True:
            op = self.accept_op("+", "-")
            if op is None:
                return e
            e = BinOp(op, e, self.parse_term())

    def parse_term(self) -> Expr:
        e = self.parse_factor()
        while True:
            op = self.accept_op("*", "/")
            if op is None:
                return e
            e = BinOp(op, e, self.parse_factor())

    def parse_factor(self) -> Expr:
        if self.accept_op("("):
            e = self.parse_arith()
            self.expect_op(")")
            return e
        if self.accept_op("-"):
            return Const(-self.expect_num())
        k, v = self.peek()
        if k == "num":
            self.advance()
            return Const(float(v))  # type: ignore[arg-type]
        if k == "ident":
            self.advance()
            if self.accept_op("."):  # qualified column: t.col -> col
                return Col(self.expect_ident())
            return Col(v)  # type: ignore[arg-type]
        raise SqlSyntaxError(f"expected expression, got {v!r}")

    # -- predicates ----------------------------------------------------------
    def parse_pred(self) -> Expr:
        return self._parse_or()

    def _parse_or(self) -> Expr:
        # Right fold: a OR b OR c -> Or(a, Or(b, c)).
        left = self._parse_and()
        if self.accept_kw("OR"):
            return Or(left, self._parse_or())
        return left

    def _parse_and(self) -> Expr:
        left = self._parse_not()
        if self.accept_kw("AND"):
            return And(left, self._parse_and())
        return left

    def _parse_not(self) -> Expr:
        if self.accept_kw("NOT"):
            return Not(self._parse_not())
        return self._parse_cmp()

    def _parse_cmp(self) -> Expr:
        # '(' may open either a predicate group or an arithmetic group;
        # try the predicate reading first and backtrack on failure.
        if self.peek() == ("op", "("):
            mark = self.pos
            try:
                self.advance()
                inner = self.parse_pred()
                self.expect_op(")")
                if isinstance(inner, (Cmp, Between, And, Or, Not)):
                    return inner
            except SqlSyntaxError:
                pass
            self.pos = mark
        if self.peek()[0] == "str":
            left: Expr = Str(self.advance()[1])  # type: ignore[arg-type]
        else:
            left = self.parse_arith()
        if self.accept_kw("BETWEEN"):
            if isinstance(left, Str):
                raise SqlSyntaxError(
                    "string literals cannot be BETWEEN operands "
                    "(dictionary order is not lexicographic)")
            lo = self.expect_signed_num()
            self.expect_kw("AND")
            hi = self.expect_signed_num()
            return Between(left, float(lo), float(hi))
        for tok, op in _CMP_OPS.items():
            if self.accept_op(tok):
                if self.peek()[0] == "str":
                    return Cmp(op, left, Str(self.advance()[1]))  # type: ignore[arg-type]
                return Cmp(op, left, self.parse_arith())
        raise SqlSyntaxError(f"expected comparison, got {self.peek()[1]!r}")

    # -- aggregates ----------------------------------------------------------
    def parse_aggcall(self) -> Tuple[str, Optional[Expr]]:
        kw = self.accept_kw("SUM", "AVG", "COUNT")
        if kw is None:
            raise SqlSyntaxError(
                f"expected SUM/AVG/COUNT, got {self.peek()[1]!r}")
        self.expect_op("(")
        if kw == "COUNT":
            self.expect_op("*")
            self.expect_op(")")
            return "count", None
        e = self.parse_arith()
        self.expect_op(")")
        return kw.lower(), e

    def _parse_weighted_sum(self) -> Tuple[float, Expr]:
        weight, sign = 1.0, 1.0
        if self.accept_op("-"):
            sign = -1.0
        k, _ = self.peek()
        if k == "num":
            weight = self.expect_num()
            self.expect_op("*")
        elif sign < 0:
            raise SqlSyntaxError("expected a numeric weight after '-'")
        kind, expr = self.parse_aggcall()
        if kind != "sum":
            raise SqlSyntaxError("composite aggregates combine SUM parts only")
        return sign * float(weight), expr  # type: ignore[return-value]

    def parse_select_item(self, index: int) -> CompositeAgg:
        # a (possibly negative) weight can only open an 'add' composite
        if self.peek()[0] == "num" or self.peek() == ("op", "-"):
            w1, e1 = self._parse_weighted_sum()
            self.expect_op("+")
            w2, e2 = self._parse_weighted_sum()
            kind, expr, expr2, weights = "add", e1, e2, (w1, w2)
        else:
            kind, expr = self.parse_aggcall()
            expr2, weights = None, (1.0, 1.0)
            op = self.accept_op("/", "*", "+")
            if op is not None:
                if kind != "sum":
                    raise SqlSyntaxError(
                        "composite aggregates combine SUM parts only")
                if op == "+":
                    w2, expr2 = self._parse_weighted_sum()
                    kind, weights = "add", (1.0, w2)
                else:
                    kind2, expr2 = self.parse_aggcall()
                    if kind2 != "sum":
                        raise SqlSyntaxError(
                            "composite aggregates combine SUM parts only")
                    kind = "ratio" if op == "/" else "product"
        name = self.expect_ident() if self.accept_kw("AS") else f"agg{index}"
        return CompositeAgg(name, kind, expr, expr2=expr2, weights=weights)

    # -- full query ----------------------------------------------------------
    def parse_query(
        self,
        max_groups_resolver: Optional[Callable[[Tuple[str, ...], str], int]] = None,
        spec_kwargs: Optional[dict] = None,
    ) -> ParsedQuery:
        self.expect_kw("SELECT")
        aggs = [self.parse_select_item(0)]
        while self.accept_op(","):
            aggs.append(self.parse_select_item(len(aggs)))

        self.expect_kw("FROM")
        base = self.expect_ident()
        child: L.Plan = L.Scan(base)
        while self.accept_kw("JOIN"):
            right = self.expect_ident()
            self.expect_kw("ON")
            lk = self.expect_column()
            self.expect_op("=")
            rk = self.expect_column()
            child = L.Join(child, L.Scan(right), lk, rk)

        if self.accept_kw("WHERE"):
            child = L.Filter(child, self.parse_pred())

        group_by, max_groups = None, 1
        if self.accept_kw("GROUP"):
            self.expect_kw("BY")
            group_by = self.expect_column()
            if self.accept_kw("MAXGROUPS"):
                n = self.expect_num()
                if n != int(n):
                    raise SqlSyntaxError(f"MAXGROUPS must be an integer, "
                                         f"got {n!r}")
                max_groups = int(n)
            elif max_groups_resolver is not None:
                tables = tuple(s.table for s in child.scans())
                max_groups = int(max_groups_resolver(tables, group_by))
            if max_groups < 1:
                raise SqlSyntaxError("MAXGROUPS must be >= 1")

        having = None
        if self.accept_kw("HAVING"):
            name = self.expect_ident()
            if name not in {a.name for a in aggs}:
                raise SqlSyntaxError(
                    f"HAVING references {name!r}, which is not a SELECT "
                    f"output (outputs: {[a.name for a in aggs]}); HAVING "
                    "compares an aggregate alias against a number")
            for tok, op in _CMP_OPS.items():
                if self.accept_op(tok):
                    having = HavingClause(name, op, self.expect_signed_num())
                    break
            if having is None:
                raise SqlSyntaxError(
                    f"expected comparison after HAVING {name}, got "
                    f"{self.peek()[1]!r}")

        limit = None
        order_by = None
        desc = False
        if self.accept_kw("ORDER"):
            self.expect_kw("BY")
            order_by = self.expect_ident()
            if order_by not in {a.name for a in aggs}:
                raise SqlSyntaxError(
                    f"ORDER BY references {order_by!r}, which is not a "
                    f"SELECT output (outputs: {[a.name for a in aggs]}); "
                    "ORDER BY ranks by an aggregate alias")
            if self.accept_kw("DESC"):
                desc = True
            else:
                self.accept_kw("ASC")
            if not self.accept_kw("LIMIT"):
                raise SqlSyntaxError(
                    "ORDER BY requires LIMIT: answers are unordered group "
                    "sets, so ordering only exists to select the top n")
            limit = self._finish_limit(order_by, desc)
        elif self.accept_kw("LIMIT"):
            limit = self._finish_limit(None, False)

        spec = None
        if self.accept_kw("ERROR"):
            err = self.expect_num()
            self.expect_op("%")
            self.expect_kw("CONFIDENCE")
            conf = self.expect_num()
            self.expect_op("%")
            try:
                spec = ErrorSpec(error=err / 100.0, confidence=conf / 100.0)
            except ValueError as e:
                # out-of-range targets (ERROR 150%) are dialect violations,
                # not internal errors — reject at the parse stage
                raise SqlSyntaxError(f"invalid ERROR/CONFIDENCE clause: {e}")
            if spec_kwargs:
                # caller-config tunables are applied OUTSIDE the client-error
                # wrapping: a bad server-side override must fail loudly, not
                # masquerade as the client's syntax error
                spec = dataclasses.replace(spec, **spec_kwargs)

        if self.peek()[0] != "end":
            raise SqlSyntaxError(f"trailing input at {self.peek()[1]!r}")
        q = Query(child=child, aggs=tuple(aggs), group_by=group_by,
                  max_groups=max_groups)
        return ParsedQuery(query=q, spec=spec, having=having, limit=limit)

    def _finish_limit(self, order_by: Optional[str],
                      desc: bool) -> LimitClause:
        n = self.expect_num()
        if n != int(n) or int(n) < 1:
            raise SqlSyntaxError(
                f"LIMIT must be a positive integer, got {n!r}")
        return LimitClause(n=int(n), order_by=order_by, desc=desc)


def parse_sql(
    text: str,
    *,
    max_groups_resolver: Optional[Callable[[Tuple[str, ...], str], int]] = None,
    spec_kwargs: Optional[dict] = None,
) -> ParsedQuery:
    """Parse dialect SQL into the internal (Query, ErrorSpec) representation.

    ``max_groups_resolver(tables, column)`` — called with every table in the
    FROM/JOIN chain — supplies ``max_groups`` for GROUP BY queries that omit
    MAXGROUPS; ``spec_kwargs`` overrides TAQA tunables
    on the lowered :class:`ErrorSpec` (e.g. ``{"min_pilot_blocks": 50}``).
    """
    return _Parser(_tokenize(text)).parse_query(max_groups_resolver,
                                                spec_kwargs)


# ---------------------------------------------------------------------------
# String-literal lowering (dictionary-encoded columns)
# ---------------------------------------------------------------------------

# Mirrored comparison for literal-on-the-left spellings: 'N' < col == col > 'N'
_MIRROR_CMP = {"<": ">", "<=": ">=", ">": "<", ">=": "<="}


def _resolve_strings_expr(e: Expr, resolver, order_resolver=None) -> Expr:
    if isinstance(e, Cmp):
        ls, rs = isinstance(e.left, Str), isinstance(e.right, Str)
        if not (ls or rs):
            return e
        if ls and rs:
            raise UnsupportedSqlError(
                "comparing two string literals is not a table predicate")
        col, lit = (e.right, e.left) if ls else (e.left, e.right)
        if not isinstance(col, Col):
            raise UnsupportedSqlError(
                f"string literal {lit.value!r} must compare against a "
                "column, not an expression")
        if e.op in ("==", "!="):
            code = Const(float(resolver(col.name, lit.value)))
            return Cmp(e.op, code, col) if ls else Cmp(e.op, col, code)
        # Order comparison: valid only against a SORTED dictionary (code
        # order == string order); the order resolver owns that check and
        # returns the bisection boundary as the lowered (op, code).
        if order_resolver is None:
            raise UnsupportedSqlError(
                f"dictionary-encoded columns support = and != only, "
                f"got {e.op!r} (no sorted-dictionary order resolver)")
        op = _MIRROR_CMP[e.op] if ls else e.op
        lowered_op, code = order_resolver(col.name, lit.value, op)
        return Cmp(lowered_op, col, Const(float(code)))
    if isinstance(e, And):
        return And(_resolve_strings_expr(e.left, resolver, order_resolver),
                   _resolve_strings_expr(e.right, resolver, order_resolver))
    if isinstance(e, Or):
        return Or(_resolve_strings_expr(e.left, resolver, order_resolver),
                  _resolve_strings_expr(e.right, resolver, order_resolver))
    if isinstance(e, Not):
        return Not(_resolve_strings_expr(e.arg, resolver, order_resolver))
    if isinstance(e, Between) and isinstance(e.arg, Str):
        # unreachable from the parser (rejected there); guards hand-built
        # plans so no Str survives to execution
        raise UnsupportedSqlError(
            "string literals cannot be BETWEEN operands")
    return e


def _resolve_strings_plan(p: L.Plan, resolver, order_resolver=None) -> L.Plan:
    if isinstance(p, L.Filter):
        return dataclasses.replace(
            p, child=_resolve_strings_plan(p.child, resolver, order_resolver),
            pred=_resolve_strings_expr(p.pred, resolver, order_resolver))
    if isinstance(p, L.Join):
        return dataclasses.replace(
            p, left=_resolve_strings_plan(p.left, resolver, order_resolver),
            right=_resolve_strings_plan(p.right, resolver, order_resolver))
    if isinstance(p, L.Union):
        return dataclasses.replace(
            p, inputs=tuple(_resolve_strings_plan(c, resolver, order_resolver)
                            for c in p.inputs))
    return p


def resolve_string_literals(query: Query, resolver,
                            order_resolver=None) -> Query:
    """Lower every string-literal comparison to integer dictionary codes.

    ``resolver(column, literal) -> int`` handles equality (``=`` / ``!=``);
    ``order_resolver(column, literal, op) -> (op, code)`` handles order
    comparisons over *sorted* dictionaries, returning the bisection-boundary
    code and the (possibly strictness-adjusted) operator — omit it to keep
    the historical equality-only behaviour.

    The engine is numeric; this is the only path by which a :class:`Str`
    node may reach execution, and it removes them all.  Resolvers raise
    :class:`UnsupportedSqlError` for columns without a dictionary, literals
    outside it, or order comparisons against unsorted dictionaries (see
    :meth:`repro.api.Session.register_dictionary`).  Queries without string
    literals are returned unchanged.
    """
    child = _resolve_strings_plan(query.child, resolver, order_resolver)
    if child == query.child:
        return query
    return dataclasses.replace(query, child=child)


# ---------------------------------------------------------------------------
# Renderer (the inverse direction, for round-trip tests and logging)
# ---------------------------------------------------------------------------

_PREC = {"+": 1, "-": 1, "*": 2, "/": 2}


def _num(v: float) -> str:
    f = float(v)
    if f.is_integer() and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _pct(frac: float) -> str:
    """Shortest percent literal p with float(p)/100 == frac (exact re-parse);
    naive ``frac * 100`` drifts (0.05 * 100 == 5.000000000000001)."""
    for digits in range(0, 18):
        s = f"{frac * 100:.{digits}f}"
        if "." in s:
            s = s.rstrip("0").rstrip(".")
        if s and float(s) / 100.0 == frac:
            return s
    return repr(frac * 100)


def _render_arith(e: Expr, parent_prec: int = 0, right: bool = False) -> str:
    if isinstance(e, Col):
        return e.name
    if isinstance(e, Const):
        return _num(e.value)
    if isinstance(e, Str):
        return "'" + e.value.replace("'", "''") + "'"
    if isinstance(e, BinOp):
        p = _PREC[e.op]
        s = (f"{_render_arith(e.left, p, False)} {e.op} "
             f"{_render_arith(e.right, p, True)}")
        # Parenthesize when re-parsing (left-assoc, precedence-climbing)
        # would otherwise reassociate the tree.
        if p < parent_prec or (p == parent_prec and right):
            return f"({s})"
        return s
    raise UnsupportedSqlError(f"not an arithmetic expression: {e!r}")


_SQL_CMP = {"==": "=", "!=": "!=", "<": "<", "<=": "<=", ">": ">", ">=": ">="}


def _conjunction_terms(e: Expr) -> List[Expr]:
    """Flatten a top-level AND chain (any association) into its terms."""
    if isinstance(e, And):
        return _conjunction_terms(e.left) + _conjunction_terms(e.right)
    return [e]


def _render_pred(e: Expr) -> str:
    if isinstance(e, Or):
        left = _render_pred(e.left)
        if isinstance(e.left, Or):  # left-nested Or needs explicit grouping
            left = f"({left})"
        return f"{left} OR {_render_pred(e.right)}"
    if isinstance(e, And):
        def side(x: Expr, is_left: bool) -> str:
            s = _render_pred(x)
            if isinstance(x, Or) or (is_left and isinstance(x, And)):
                return f"({s})"
            return s
        return f"{side(e.left, True)} AND {side(e.right, False)}"
    if isinstance(e, Not):
        return f"NOT ({_render_pred(e.arg)})"
    if isinstance(e, Cmp):
        return (f"{_render_arith(e.left)} {_SQL_CMP[e.op]} "
                f"{_render_arith(e.right)}")
    if isinstance(e, Between):
        return (f"{_render_arith(e.arg)} BETWEEN {_num(e.lo)} AND "
                f"{_num(e.hi)}")
    raise UnsupportedSqlError(f"not a predicate: {e!r}")


def _render_agg(a: CompositeAgg) -> str:
    if a.kind == "sum":
        body = f"SUM({_render_arith(a.expr)})"
    elif a.kind == "count":
        body = "COUNT(*)"
    elif a.kind == "avg":
        body = f"AVG({_render_arith(a.expr)})"
    elif a.kind == "ratio":
        body = f"SUM({_render_arith(a.expr)}) / SUM({_render_arith(a.expr2)})"
    elif a.kind == "product":
        body = f"SUM({_render_arith(a.expr)}) * SUM({_render_arith(a.expr2)})"
    elif a.kind == "add":
        w1, w2 = a.weights
        s1, s2 = (f"SUM({_render_arith(a.expr)})",
                  f"SUM({_render_arith(a.expr2)})")
        if w1 != 1.0:
            s1 = f"{_num(w1)} * {s1}"
        if w2 != 1.0:
            s2 = f"{_num(w2)} * {s2}"
        body = f"{s1} + {s2}"
    else:
        raise UnsupportedSqlError(f"composite kind {a.kind!r}")
    return f"{body} AS {a.name}"


def render_sql(query: Query, spec: Optional[ErrorSpec] = None,
               having: Optional[HavingClause] = None,
               limit: Optional[LimitClause] = None) -> str:
    """Render the internal representation back to dialect SQL.

    Only the dialect surface is expressible: a single optional Filter over a
    left-deep Join chain over plain Scans.  TABLESAMPLE clauses and Unions
    raise :class:`UnsupportedSqlError` — those are TAQA's rewriting
    intermediates, not user queries.  ``having`` and ``limit`` re-emit the
    post-aggregation :class:`HavingClause` / :class:`LimitClause`
    (round-trip through :func:`parse_sql`; ASC, the default direction, is
    left implicit).
    """
    preds: List[Expr] = []
    node: L.Plan = query.child
    while isinstance(node, L.Filter):
        preds.append(node.pred)
        node = node.child
    joins: List[Tuple[str, str, str]] = []
    while isinstance(node, L.Join):
        if not isinstance(node.right, L.Scan):
            raise UnsupportedSqlError("join right side must be a plain Scan")
        if node.right.sample is not None:
            raise UnsupportedSqlError("TABLESAMPLE is not renderable SQL")
        joins.append((node.right.table, node.left_key, node.right_key))
        node = node.left
    if not isinstance(node, L.Scan):
        raise UnsupportedSqlError(f"unsupported plan shape at {node!r}")
    if node.sample is not None:
        raise UnsupportedSqlError("TABLESAMPLE is not renderable SQL")

    parts = ["SELECT " + ", ".join(_render_agg(a) for a in query.aggs),
             f"FROM {node.table}"]
    for table, lk, rk in reversed(joins):
        parts.append(f"JOIN {table} ON {lk} = {rk}")
    if preds:
        # Canonical WHERE: flatten every nested Filter's top-level AND chain
        # into one deterministic term list — application order, i.e.
        # innermost filter first, left-to-right within each chain — and
        # re-fold RIGHT exactly as the parser folds, so render∘parse is a
        # fixpoint and nested-Filter plans collapse to one stable clause.
        terms = [t for p in reversed(preds) for t in _conjunction_terms(p)]
        pred = terms[-1]
        for t in reversed(terms[:-1]):
            pred = And(t, pred)
        parts.append(f"WHERE {_render_pred(pred)}")
    if query.group_by is not None:
        clause = f"GROUP BY {query.group_by}"
        if query.max_groups != 1:
            clause += f" MAXGROUPS {query.max_groups}"
        parts.append(clause)
    if having is not None:
        if having.agg not in {a.name for a in query.aggs}:
            raise UnsupportedSqlError(
                f"HAVING references {having.agg!r}, not a query output")
        parts.append(f"HAVING {having.agg} {_SQL_CMP[having.op]} "
                     f"{_num(having.value)}")
    if limit is not None:
        if limit.order_by is not None:
            if limit.order_by not in {a.name for a in query.aggs}:
                raise UnsupportedSqlError(
                    f"ORDER BY references {limit.order_by!r}, "
                    "not a query output")
            parts.append(f"ORDER BY {limit.order_by}"
                         + (" DESC" if limit.desc else ""))
        parts.append(f"LIMIT {limit.n}")
    if spec is not None:
        parts.append(f"ERROR {_pct(spec.error)}% "
                     f"CONFIDENCE {_pct(spec.confidence)}%")
    return " ".join(parts)
