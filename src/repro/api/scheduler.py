"""Concurrent query scheduler: signature-grouped, submission-fair draining.

The millions-of-users scenario sends streams of structurally identical
queries — the same dashboard refreshed by many users, often with *shifted
predicate constants* (a sliding date range).  Constants are hoisted out of
the physical layer's compile keys (``engine/physical.plan_signature``) and
ride as runtime operands, so constant-varied queries share one executable;
this scheduler groups by the same constant-stripped *template* signature so
those queries also drain as one group and their finals can launch as one
batched dispatch:

* submissions queue as :class:`QueryHandle`\\ s (seeds derive from query
  content at submission, so scheduling order never changes sampling),
* draining groups pending handles by their template signature
  (``core.taqa.template_signature``, computed once at submission and
  carried on the handle) and hands the groups to the session's
  :class:`repro.runtime.AsyncRuntime` — groups run concurrently on the
  worker pool, one pilot is shared within each group's (full
  constant-bearing signature, pilot-params) subgroup — pilot statistics
  depend on predicate selectivity, so sharing across constants would void
  the error guarantees — cached answers short-circuit execution entirely,
  and same-bucket finals stack into one device launch,
* groups are *admitted* in order of their earliest submission and members
  in submission order, so no query starves behind an unrelated hot group
  (submission-fair batches); ``max_queries`` caps one drain call.

``drain()`` blocks until its batch finished and returns handles in the
fair admission order (regardless of worker completion order);
``drain_async()`` dispatches everything pending and returns immediately —
callers observe completion via ``handle.poll()`` / ``handle.wait()``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Dict, List, Optional

from repro.core.taqa import structural_signature, template_signature

if TYPE_CHECKING:  # circular at runtime: session owns the scheduler
    from repro.api.session import QueryHandle, Session


@dataclasses.dataclass
class DrainStats:
    """What one ``drain()`` call did to the caches and the queue.

    ``pilots_run`` and ``result_hits`` are attributed per handle (from the
    batch's own reports/flags), so concurrent activity elsewhere on the
    session never leaks in.  ``compile_misses``/``compile_hits`` diff the
    session-global compile cache around the drain — exact when nothing else
    executes concurrently, which is the single-drainer serving loop.

    Accumulation contract: every field is PER DRAIN.  A fresh ``DrainStats``
    is built for each ``drain()`` call (``scheduler.last_drain`` is replaced
    wholesale; counters never carry over between drains).  Cumulative
    session totals live elsewhere: ``scheduler.total_drained``, the
    session-global cache infos, and the session metrics registry
    (``session.metrics``).  Pinned by
    ``tests/test_runtime.py::test_drain_stats_reset_per_drain``.
    """

    n_queries: int = 0
    n_groups: int = 0
    compile_misses: int = 0   # new physical compilations this drain
    compile_hits: int = 0     # warm executions this drain
    pilots_run: int = 0       # pilot stages executed for this batch
    result_hits: int = 0      # batch answers served from the result cache
    wall_time_s: float = 0.0
    group_sizes: List[int] = dataclasses.field(default_factory=list)
    # pilot-subgroup fan-outs this drain (groups with >= 2 pilot
    # subgroups): concurrent span vs the sum of the per-subgroup stage
    # durations it overlapped — wall < serial means the previously
    # serialized per-constant pilot stages genuinely ran concurrently
    pilot_fanouts: int = 0
    pilot_fanout_wall_s: float = 0.0
    pilot_fanout_serial_s: float = 0.0
    # the runtime pool widths this drain actually ran on — the session
    # auto-sizes both, so reports must read the resolved values here, not
    # echo the (possibly 0 = "auto") configuration knob back
    workers: int = 0
    pilot_workers: int = 0
    # progressive streaming (repro.stream), over this drain's STREAMING
    # handles: frames emitted, drain-relative time of the first frame of
    # any kind (the first advisory estimate a client could render), and of
    # the last terminal frame (every guarantee delivered).  All 0.0 when no
    # handle in the batch streamed.
    frames_emitted: int = 0
    time_to_first_frame_s: float = 0.0
    time_to_final_s: float = 0.0

    @property
    def cache_hit_rate(self) -> float:
        total = self.compile_hits + self.compile_misses
        return self.compile_hits / total if total else 0.0


class QueryScheduler:
    def __init__(self, session: "Session"):
        self._session = session
        self._pending: List["QueryHandle"] = []
        self._queued: set = set()  # query ids, for idempotent resubmits
        # dispatched-but-unfinished handles: a retried submit() during an
        # async drain must not re-queue a handle a worker is executing
        self._in_flight: Dict[int, "QueryHandle"] = {}
        self.last_drain: Optional[DrainStats] = None
        self.total_drained = 0

    def _prune_in_flight(self) -> None:
        self._in_flight = {qid: h for qid, h in self._in_flight.items()
                           if not h.done}

    def submit(self, handle: "QueryHandle") -> "QueryHandle":
        if handle.done:
            return handle  # pre-failed (e.g. parse rejection) — nothing to run
        self._prune_in_flight()
        if handle.query_id in self._queued \
                or handle.query_id in self._in_flight:
            return handle  # idempotent: a retried submit must not double-
                           # queue the handle (it would double-count stats,
                           # or double-execute one already on a worker)
        if handle.signature is None:  # hand-built handles from older callers
            handle.signature = structural_signature(handle.query)
        if handle.group_key is None:
            handle.group_key = template_signature(handle.query)
        self._queued.add(handle.query_id)
        self._pending.append(handle)
        if handle._trace is not None:
            # cross-thread span: opened here on the client thread, closed by
            # whichever worker starts the query (_mark_running) — the
            # wait-in-queue time
            handle._trace.open_span("schedule")
        return handle

    @property
    def pending_count(self) -> int:
        return len(self._pending)

    def _grouped(self) -> List[List["QueryHandle"]]:
        groups: Dict[object, List["QueryHandle"]] = {}
        for h in self._pending:
            # Constant-stripped template: constant-varied herds drain as one
            # group (shared compilations, batched finals); pilot sharing
            # re-splits on the full signature inside the group.
            groups.setdefault(h.group_key or h.signature, []).append(h)
        # Submission-fair: a group runs no earlier than its first member's
        # arrival; members keep submission order within the group.
        return sorted(groups.values(), key=lambda g: g[0].query_id)

    def _take_batch(self, max_queries: Optional[int]) -> List[List["QueryHandle"]]:
        """Dequeue up to ``max_queries`` handles as signature-grouped batches
        in fair order; the remainder stays pending."""
        batches: List[List["QueryHandle"]] = []
        taken = 0
        for group in self._grouped():
            if max_queries is not None and taken >= max_queries:
                break
            batch = group if max_queries is None else \
                group[: max_queries - taken]
            batches.append(batch)
            taken += len(batch)
        dispatched = {h.query_id for b in batches for h in b}
        self._pending = [h for h in self._pending
                         if h.query_id not in dispatched]
        self._queued -= dispatched
        self._prune_in_flight()
        for b in batches:
            for h in b:
                self._in_flight[h.query_id] = h
        return batches

    def drain(self, max_queries: Optional[int] = None) -> List["QueryHandle"]:
        """Run pending queries grouped by plan signature; return completed
        handles in fair admission order.  ``max_queries`` bounds one batch —
        the remainder stays queued for the next call."""
        if max_queries is not None and max_queries < 1:
            raise ValueError(f"max_queries must be >= 1, got {max_queries}")
        t0 = time.perf_counter()
        info0 = self._session.compile_cache_info()
        fan0 = self._session.runtime.pilot_fanout_totals()
        batches = self._take_batch(max_queries)
        self._session.runtime.run_groups(batches, block=True)
        completed = [h for b in batches for h in b]

        stats = DrainStats()
        stats.workers = self._session.runtime.workers
        stats.pilot_workers = self._session.runtime.pilot_workers
        stats.n_groups = len(batches)
        stats.group_sizes = [len(b) for b in batches]
        info1 = self._session.compile_cache_info()
        stats.n_queries = len(completed)
        stats.compile_misses = info1.misses - info0.misses
        stats.compile_hits = info1.hits - info0.hits
        # per-handle attribution: a pilot stage belongs to this batch when a
        # non-cached member's report records its own (non-shared) pilot run
        stats.result_hits = sum(1 for h in completed if h.cached)
        stats.pilots_run = sum(
            1 for h in completed
            if not h.cached and h.report is not None
            and h.report.pilot_ran and not h.report.pilot_shared)
        fan1 = self._session.runtime.pilot_fanout_totals()
        stats.pilot_fanouts = fan1[0] - fan0[0]
        stats.pilot_fanout_wall_s = fan1[1] - fan0[1]
        stats.pilot_fanout_serial_s = fan1[2] - fan0[2]
        # streaming latency, drain-relative: emission stamps predating this
        # drain (replayed/synthesized frames of pre-enabled handles) clamp
        # to 0 rather than going negative
        emits: List[float] = []
        finals: List[float] = []
        for h in completed:
            if not h.streaming:
                continue
            for f in h.frames():
                emits.append(f.t_emit)
                if f.terminal:
                    finals.append(f.t_emit)
        stats.frames_emitted = len(emits)
        if emits:
            stats.time_to_first_frame_s = max(0.0, min(emits) - t0)
        if finals:
            stats.time_to_final_s = max(0.0, max(finals) - t0)
        stats.wall_time_s = time.perf_counter() - t0
        self.last_drain = stats
        self.total_drained += len(completed)
        metrics = getattr(self._session, "metrics", None)
        if metrics is not None:  # cumulative totals live in the registry
            metrics.counter("pilotdb_drains_total",
                            "drain() calls completed").inc()
            metrics.counter("pilotdb_drained_queries_total",
                            "Queries completed via drain()").inc(
                                len(completed))
            metrics.histogram("pilotdb_drain_wall_seconds",
                              "Wall time per drain() call").observe(
                                  stats.wall_time_s)
            # streaming latency histograms: observed only when the batch
            # actually streamed (fields stay 0.0 otherwise — observing
            # zeros would poison the quantiles)
            if emits:
                metrics.histogram(
                    "pilotdb_time_to_first_frame_seconds",
                    "Drain-relative time of the first streamed frame"
                ).observe(stats.time_to_first_frame_s)
            if finals:
                metrics.histogram(
                    "pilotdb_time_to_final_seconds",
                    "Drain-relative time of the last terminal frame"
                ).observe(stats.time_to_final_s)
        ts = getattr(self._session, "timeseries", None)
        if ts is not None:
            ts.record_drain(
                stats.time_to_first_frame_s if emits else None,
                stats.time_to_final_s if finals else None)
        return completed

    def drain_async(self) -> List["QueryHandle"]:
        """Dispatch everything pending to the runtime and return the
        dispatched handles immediately (they finish in the background; with
        ``async_workers=0`` this degenerates to a blocking drain).  No
        :class:`DrainStats` are recorded — concurrent completions have no
        well-defined batch boundary."""
        batches = self._take_batch(None)
        handles = [h for b in batches for h in b]
        self._session.runtime.run_groups(batches, block=False)
        self.total_drained += len(handles)
        return handles
