"""Concurrent query scheduler: signature-grouped, submission-fair draining.

The millions-of-users scenario sends streams of structurally identical
queries (the same dashboard refreshed by many users — fresh sampling seeds,
same plan *including predicate constants*: the kernels bake constants in as
compile-time bounds, so queries differing in a WHERE constant compile
separately, exactly as ``engine/physical.plan_signature`` keys them).  The
physical layer already compiles one executable per plan signature; this
scheduler makes the serving side exploit it:

* submissions queue as :class:`QueryHandle`\\ s (seeds were already derived
  at submission, so scheduling order never changes sampling),
* ``drain()`` groups pending handles by :func:`repro.core.taqa.
  structural_signature` and runs each group back-to-back — the first member
  pays the (cached) compilation, the rest run warm,
* groups are visited in order of their earliest submission and members in
  submission order, so no query starves behind an unrelated hot group
  (submission-fair batches); ``max_queries`` caps one drain call.
"""

from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Dict, List, Optional

from repro.core.taqa import structural_signature

if TYPE_CHECKING:  # circular at runtime: session owns the scheduler
    from repro.api.session import QueryHandle, Session


@dataclasses.dataclass
class DrainStats:
    """What one ``drain()`` call did to the compile cache and the queue."""

    n_queries: int = 0
    n_groups: int = 0
    compile_misses: int = 0   # new physical compilations this drain
    compile_hits: int = 0     # warm executions this drain
    wall_time_s: float = 0.0
    group_sizes: List[int] = dataclasses.field(default_factory=list)

    @property
    def cache_hit_rate(self) -> float:
        total = self.compile_hits + self.compile_misses
        return self.compile_hits / total if total else 0.0


class QueryScheduler:
    def __init__(self, session: "Session"):
        self._session = session
        self._pending: List["QueryHandle"] = []
        self._signatures: Dict[int, object] = {}  # query_id -> structural key
        self.last_drain: Optional[DrainStats] = None
        self.total_drained = 0

    def submit(self, handle: "QueryHandle") -> "QueryHandle":
        if handle.done:
            return handle  # pre-failed (e.g. parse rejection) — nothing to run
        if handle.query_id in self._signatures:
            return handle  # idempotent: a retried submit must not double-
                           # queue the handle (it would double-count stats)
        # the signature is immutable per handle: compute once at submission,
        # not on every drain pass over the queue
        self._signatures[handle.query_id] = structural_signature(handle.query)
        self._pending.append(handle)
        return handle

    @property
    def pending_count(self) -> int:
        return len(self._pending)

    def _grouped(self) -> List[List["QueryHandle"]]:
        groups: Dict[object, List["QueryHandle"]] = {}
        for h in self._pending:
            groups.setdefault(self._signatures[h.query_id], []).append(h)
        # Submission-fair: a group runs no earlier than its first member's
        # arrival; members keep submission order within the group.
        return sorted(groups.values(), key=lambda g: g[0].query_id)

    def drain(self, max_queries: Optional[int] = None) -> List["QueryHandle"]:
        """Run pending queries grouped by plan signature; return completed
        handles in execution order.  ``max_queries`` bounds one batch — the
        remainder stays queued for the next call."""
        if max_queries is not None and max_queries < 1:
            raise ValueError(f"max_queries must be >= 1, got {max_queries}")
        t0 = time.perf_counter()
        info0 = self._session.compile_cache_info()
        stats = DrainStats()
        completed: List["QueryHandle"] = []
        for group in self._grouped():
            if max_queries is not None and len(completed) >= max_queries:
                break
            batch = group if max_queries is None else \
                group[: max_queries - len(completed)]
            stats.n_groups += 1
            stats.group_sizes.append(len(batch))
            for h in batch:
                self._session._run_handle(h)
                completed.append(h)
        done_ids = {h.query_id for h in completed}
        self._pending = [h for h in self._pending
                         if h.query_id not in done_ids]
        for qid in done_ids:
            self._signatures.pop(qid, None)
        info1 = self._session.compile_cache_info()
        stats.n_queries = len(completed)
        stats.compile_misses = info1.misses - info0.misses
        stats.compile_hits = info1.hits - info0.hits
        stats.wall_time_s = time.perf_counter() - t0
        self.last_drain = stats
        self.total_drained += len(completed)
        return completed
