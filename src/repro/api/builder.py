"""Typed fluent query builder — the programmatic twin of the SQL dialect.

    from repro.api import sum_, avg_, count_
    from repro.engine.expr import Col

    handle = (session.table("lineitem")
              .where(Col("l_quantity") < 24)
              .agg(sum_(Col("l_extendedprice") * Col("l_discount")).as_("rev"),
                   count_().as_("n"))
              .error(0.05, 0.95)
              .run())

Aggregate terms compose with Python arithmetic exactly along the paper's
Table-2 propagation rules: ``sum_(a) / sum_(b)`` is a ratio composite,
``sum_(a) * sum_(b)`` a product, ``0.5 * sum_(a) + 2 * sum_(b)`` a weighted
addition.  Everything lowers to the same frozen dataclasses
(:class:`repro.core.taqa.Query` + :class:`CompositeAgg`) the SQL path
produces, so the two front doors are interchangeable.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.core.spec import CompositeAgg, ErrorSpec
from repro.core.taqa import Query
from repro.engine import logical as L
from repro.engine.expr import And, Expr


@dataclasses.dataclass(frozen=True)
class Agg:
    """A (possibly composite) aggregate under construction."""

    kind: str
    expr: Optional[Expr] = None
    expr2: Optional[Expr] = None
    weights: Tuple[float, float] = (1.0, 1.0)
    name: Optional[str] = None
    _weight: float = 1.0  # pending scalar coefficient, consumed by '+'

    def as_(self, name: str) -> "Agg":
        return dataclasses.replace(self, name=name)

    # -- Table-2 composition rules ------------------------------------------
    def _require_sum(self, op: str) -> None:
        if self.kind != "sum":
            raise TypeError(f"{op} composites combine SUM terms only, "
                            f"got {self.kind}")
        if self._weight != 1.0:
            # refusing beats silently dropping the coefficient
            raise TypeError(f"scalar weights only apply to '+' composites; "
                            f"a {op} term cannot carry weight {self._weight}")

    def __truediv__(self, other: "Agg") -> "Agg":
        if not isinstance(other, Agg):
            raise TypeError(
                f"cannot divide an aggregate by {type(other).__name__}: "
                "Table-2 ratios are SUM/SUM (scale the inner expression "
                "instead, e.g. sum_(expr / 2))")
        self._require_sum("/")
        other._require_sum("/")
        return Agg("ratio", self.expr, other.expr,
                   name=self.name or other.name)

    def __mul__(self, other):
        if isinstance(other, Agg):
            self._require_sum("*")
            other._require_sum("*")
            return Agg("product", self.expr, other.expr,
                       name=self.name or other.name)
        return dataclasses.replace(self, _weight=self._weight * float(other))

    def __rmul__(self, other) -> "Agg":
        return self.__mul__(other)

    def __add__(self, other: "Agg") -> "Agg":
        if not isinstance(other, Agg):
            raise TypeError(
                f"cannot add {type(other).__name__} to an aggregate: "
                "Table-2 additions combine weighted SUM terms, e.g. "
                "sum_(a) + 2 * sum_(b)")
        for side in (self, other):
            if side.kind != "sum":
                raise TypeError(f"+ composites combine SUM terms only, "
                                f"got {side.kind}")
        return Agg("add", self.expr, other.expr,
                   weights=(self._weight, other._weight),
                   name=self.name or other.name)

    def to_composite(self, default_name: str) -> CompositeAgg:
        if self._weight != 1.0:
            raise TypeError("a scalar-weighted SUM term is only meaningful "
                            "inside an addition composite")
        return CompositeAgg(self.name or default_name, self.kind, self.expr,
                            expr2=self.expr2, weights=self.weights)


def sum_(expr: Expr) -> Agg:
    return Agg("sum", expr)


def count_() -> Agg:
    return Agg("count")


def avg_(expr: Expr) -> Agg:
    return Agg("avg", expr)


class QueryBuilder:
    """Fluent builder bound to a :class:`repro.api.Session`.

    Each method returns ``self``; ``build()`` lowers to the internal
    representation, ``run()`` executes synchronously through the session and
    ``submit()`` enqueues on the session's scheduler.
    """

    def __init__(self, session, table: str):
        self._session = session
        self._table = table
        self._joins: List[Tuple[str, str, str]] = []
        self._preds: List[Expr] = []
        self._aggs: List[Agg] = []
        self._group_by: Optional[str] = None
        self._max_groups: Optional[int] = None
        self._spec: Optional[ErrorSpec] = None

    def join(self, table: str, left_key: str, right_key: str) -> "QueryBuilder":
        self._joins.append((table, left_key, right_key))
        return self

    def where(self, pred: Expr) -> "QueryBuilder":
        self._preds.append(pred)
        return self

    def agg(self, *aggs: Agg) -> "QueryBuilder":
        self._aggs.extend(aggs)
        return self

    def group_by(self, column: str,
                 max_groups: Optional[int] = None) -> "QueryBuilder":
        self._group_by = column
        self._max_groups = max_groups
        return self

    def error(self, error: Optional[float] = None,
              confidence: Optional[float] = None, **spec_kwargs) -> "QueryBuilder":
        """Attach an ERROR/CONFIDENCE target; defaults (and TAQA tunable
        overrides, ``SessionConfig.spec_kwargs``) come from the session
        config, exactly as for the SQL front door.  Explicit kwargs here win.
        Omitting this clause entirely means exact execution."""
        cfg = self._session.config
        kwargs = dict(cfg.spec_kwargs or {})
        kwargs.update(spec_kwargs)
        self._spec = ErrorSpec(
            error=cfg.default_error if error is None else error,
            confidence=(cfg.default_confidence if confidence is None
                        else confidence),
            **kwargs)
        return self

    def spec(self, spec: ErrorSpec) -> "QueryBuilder":
        self._spec = spec
        return self

    # -- lowering ------------------------------------------------------------
    def build(self) -> Tuple[Query, Optional[ErrorSpec]]:
        if not self._aggs:
            raise ValueError("no aggregates: call .agg(...) before build/run")
        child: L.Plan = L.Scan(self._table)
        for table, lk, rk in self._joins:
            child = L.Join(child, L.Scan(table), lk, rk)
        if self._preds:
            pred = self._preds[-1]
            for p in reversed(self._preds[:-1]):  # right fold, SQL-identical
                pred = And(p, pred)
            child = L.Filter(child, pred)
        max_groups = 1
        if self._group_by is not None:
            tables = (self._table,) + tuple(t for t, _, _ in self._joins)
            max_groups = (self._max_groups
                          if self._max_groups is not None
                          else self._session.infer_max_groups(
                              tables, self._group_by))
        q = Query(
            child=child,
            aggs=tuple(a.to_composite(f"agg{i}")
                       for i, a in enumerate(self._aggs)),
            group_by=self._group_by,
            max_groups=max_groups)
        return q, self._spec

    def run(self):
        q, spec = self.build()
        return self._session.execute(q, spec)

    def submit(self):
        q, spec = self.build()
        return self._session.submit_query(q, spec)
