"""Model configuration covering all assigned architecture families.

One config type drives dense GQA decoders, MoE decoders, attention-free
linear-attention (RWKV6), hybrid attn+SSM (hymba), encoder-decoder audio
(whisper) and VLM (llava) backbones.  Frontends for [audio]/[vlm] are stubs
per the assignment: input_specs feed precomputed frame/patch embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    mlp: str = "swiglu"         # swiglu | geglu
    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # linear attention / SSM
    ssm_state: int = 0          # key/state dim per linear-attention head
    num_ssm_heads: int = 0
    gla_impl: str = "dif"       # dif | subblock (see models.linear_attn)
    moe_chunk: int = 0          # >0: process MoE FFN in token chunks (memory)
    moe_dense_train: bool = False  # dense-all-experts compute (no dispatch)
    remat_groups: int = 0       # >1: two-level (sqrt) remat over layer groups
    # hybrid (hymba): parallel attention + SSM heads; sliding-window attn
    sliding_window: int = 0     # 0 = full attention
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    enc_seq: int = 0            # stub frontend length (precomputed frames)
    # VLM (llava)
    num_patches: int = 0        # stub frontend patch-embedding count
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    # serving
    max_decode_len: int = 32768

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 500k-token contexts?  SSM state is O(1); a
        sliding window bounds the cache.  Pure full attention cannot."""
        return self.family == "ssm" or (
            self.family == "hybrid" and self.sliding_window > 0)

    def validate(self):
        assert self.num_layers > 0 and self.d_model > 0
        if self.has_attention:
            assert self.num_heads % max(self.num_kv_heads, 1) == 0
        if self.is_moe:
            assert 0 < self.top_k <= self.num_experts
        if self.family == "encdec":
            assert self.encoder_layers > 0 and self.enc_seq > 0
        if self.family == "vlm":
            assert self.num_patches > 0
        if self.has_ssm:
            assert self.ssm_state > 0 and self.num_ssm_heads > 0
        return self

    def reduced(self, **overrides) -> "ModelConfig":
        """A small same-family config for CPU smoke tests."""
        small = dict(
            num_layers=2,
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            num_experts=min(self.num_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            num_ssm_heads=4 if self.num_ssm_heads else 0,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            enc_seq=24 if self.enc_seq else 0,
            num_patches=8 if self.num_patches else 0,
            dtype="float32",
            max_decode_len=64,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small).validate()
