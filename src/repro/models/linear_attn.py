"""Gated linear attention (RWKV6 "Finch" family) — pure-JAX chunked form.

Mirrors kernels/gla_chunk exactly (same recurrence, same chunked math) so the
Pallas kernel can be swapped in on TPU; this XLA path is what pjit lowers on
any backend.  The recurrence family

    S_t = diag(exp(g_t)) S_{t-1} + k_t v_t^T ,   o_t = S_t^T q_t

covers RWKV-6 (data-dependent per-channel decay g_t = f(x_t)) and SSD/Mamba-2
style SSMs (scalar per-head decay broadcast over channels).  Training/prefill
use the chunked parallel form (MXU GEMMs); decode carries the (dk, dv) state —
this is what makes `long_500k` servable with O(1) memory.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

G_CLAMP = -8.0


def gla_chunked_xla(q, k, v, g, *, chunk: int = 32, impl: str = "dif",
                    initial_state: Optional[jax.Array] = None):
    """q,k,g: (B, H, T, dk); v: (B, H, T, dv).  Returns (o, final_state).

    Chunked scan: intra-chunk uses exponent-safe relative decays (all
    exponents <= 0), inter-chunk carries the state.

    impl="dif": reference formulation — materializes the (C, C, dk) relative
    decay tensor per chunk.  Simple, but its HBM traffic scales with C²·dk.
    impl="subblock": the gla_chunk Pallas kernel's two-level scheme in XLA —
    off-diagonal sub-block pairs use re-based GEMMs (MXU work, no 5-D
    tensor), only SUB-wide diagonal blocks materialize relative decays.
    Traffic drops ~C/SUB× on the elementwise term; chunks can then be
    larger (fewer, bigger GEMMs per scan step).
    """
    if impl == "subblock":
        return _gla_subblock_xla(q, k, v, g, chunk=max(chunk, 64),
                                 initial_state=initial_state)
    b, h, t, dk = q.shape
    dv = v.shape[-1]
    g = jnp.clip(g.astype(jnp.float32), G_CLAMP, 0.0)
    pad = (-t) % chunk
    if pad:
        zq = ((0, 0), (0, 0), (0, pad), (0, 0))
        q, k, v = (jnp.pad(x, zq) for x in (q, k, v))
        g = jnp.pad(g, zq)
    tt = t + pad
    nc = tt // chunk

    def to_chunks(x):
        return x.reshape(b, h, nc, chunk, -1).transpose(2, 0, 1, 3, 4)

    qc, kc, vc, gc = (to_chunks(x) for x in (q, k, v, g))
    s0 = (jnp.zeros((b, h, dk, dv), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    rows = jnp.arange(chunk)[:, None]
    cols = jnp.arange(chunk)[None, :]
    tri = cols <= rows

    def step(S, xs):
        qi, ki, vi, gi = xs  # (b, h, C, d*)
        L = jnp.cumsum(gi, axis=2)                       # (b,h,C,dk) decreasing
        L_last = L[:, :, -1:, :]
        q_in = qi.astype(jnp.float32) * jnp.exp(L)
        inter = jnp.einsum("bhck,bhkv->bhcv", q_in, S)
        # intra-chunk, exponent-safe: mask BEFORE exp
        dif = L[:, :, :, None, :] - L[:, :, None, :, :]  # (b,h,C,C,dk)
        dif = jnp.where(tri[None, None, :, :, None], dif, -jnp.inf)
        attn = jnp.einsum("bhik,bhjk,bhijk->bhij",
                          qi.astype(jnp.float32), ki.astype(jnp.float32),
                          jnp.exp(dif))
        intra = jnp.einsum("bhij,bhjv->bhiv", attn, vi.astype(jnp.float32))
        k_carry = ki.astype(jnp.float32) * jnp.exp(L_last - L)
        S_new = S * jnp.exp(L_last).transpose(0, 1, 3, 2) + jnp.einsum(
            "bhck,bhcv->bhkv", k_carry, vi.astype(jnp.float32))
        return S_new, (inter + intra).astype(q.dtype)

    S, o = jax.lax.scan(step, s0, (qc, kc, vc, gc))
    o = o.transpose(1, 2, 0, 3, 4).reshape(b, h, tt, dv)
    return o[:, :, :t, :], S


SUB = 16


def _gla_subblock_xla(q, k, v, g, *, chunk: int = 64,
                      initial_state: Optional[jax.Array] = None):
    """Two-level chunked GLA (mirrors kernels/gla_chunk exactly)."""
    b, h, t, dk = q.shape
    dv = v.shape[-1]
    g = jnp.clip(g.astype(jnp.float32), G_CLAMP, 0.0)
    pad = (-t) % chunk
    if pad:
        zq = ((0, 0), (0, 0), (0, pad), (0, 0))
        q, k, v = (jnp.pad(x, zq) for x in (q, k, v))
        g = jnp.pad(g, zq)
    tt = t + pad
    nc = tt // chunk
    ns = chunk // SUB

    def to_chunks(x):
        return x.reshape(b, h, nc, chunk, -1).transpose(2, 0, 1, 3, 4)

    qc, kc, vc, gc = (to_chunks(x) for x in (q, k, v, g))
    s0 = (jnp.zeros((b, h, dk, dv), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    tri = jnp.arange(SUB)[:, None] >= jnp.arange(SUB)[None, :]

    def step(S, xs):
        qi, ki, vi, gi = (x.astype(jnp.float32) for x in xs)
        L = jnp.cumsum(gi, axis=2)                        # (b,h,C,dk)
        L_last = L[:, :, -1:, :]
        inter = jnp.einsum("bhck,bhkv->bhcv", qi * jnp.exp(L), S)

        out_rows = []
        for r in range(ns):
            sl_r = slice(r * SUB, (r + 1) * SUB)
            qr, Lr = qi[:, :, sl_r], L[:, :, sl_r]
            acc = jnp.zeros((qi.shape[0], qi.shape[1], SUB, dv), jnp.float32)
            for c in range(r + 1):
                sl_c = slice(c * SUB, (c + 1) * SUB)
                vcb = vi[:, :, sl_c]
                if c < r:
                    base = L[:, :, (c + 1) * SUB - 1:(c + 1) * SUB]
                    qq = qr * jnp.exp(Lr - base)           # exponents <= 0
                    kk = ki[:, :, sl_c] * jnp.exp(base - L[:, :, sl_c])
                    attn = jnp.einsum("bhik,bhjk->bhij", qq, kk)
                else:
                    Lc = L[:, :, sl_c]
                    dif = Lr[:, :, :, None, :] - Lc[:, :, None, :, :]
                    dif = jnp.where(tri[None, None, :, :, None], dif, -jnp.inf)
                    attn = jnp.einsum("bhik,bhjk,bhijk->bhij", qr,
                                      ki[:, :, sl_c], jnp.exp(dif))
                acc = acc + jnp.einsum("bhij,bhjv->bhiv", attn, vcb)
            out_rows.append(acc)
        intra = jnp.concatenate(out_rows, axis=2)
        k_carry = ki * jnp.exp(L_last - L)
        S_new = S * jnp.exp(L_last).transpose(0, 1, 3, 2) + jnp.einsum(
            "bhck,bhcv->bhkv", k_carry, vi)
        return S_new, (inter + intra).astype(q.dtype)

    S, o = jax.lax.scan(step, s0, (qc, kc, vc, gc))
    o = o.transpose(1, 2, 0, 3, 4).reshape(b, h, tt, dv)
    return o[:, :, :t, :], S


def gla_decode_step(q, k, v, g, state) -> Tuple[jax.Array, jax.Array]:
    """One recurrent step.  q,k,g: (B, H, dk); v: (B, H, dv);
    state: (B, H, dk, dv).  Returns (o (B,H,dv), new_state)."""
    g = jnp.clip(g.astype(jnp.float32), G_CLAMP, 0.0)
    state = state * jnp.exp(g)[..., None] + k.astype(jnp.float32)[..., None] \
        * v.astype(jnp.float32)[..., None, :]
    o = jnp.einsum("bhkv,bhk->bhv", state, q.astype(jnp.float32))
    return o.astype(q.dtype), state
