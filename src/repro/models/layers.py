"""Core layers: RMSNorm, RoPE, memory-efficient (flash-style) attention, MLPs.

Attention is implemented as a two-level chunked scan with online softmax —
the pure-XLA equivalent of the Pallas flash_attn kernel (kernels/flash_attn
is the TPU hot path; this path is what jit/pjit lowers everywhere, keeping
peak memory O(q_chunk × kv_chunk) instead of O(S²)).  GQA is computed in
grouped form (no materialized KV repetition).  Sliding-window and causal
masks are applied with global positions so the same code serves training,
prefill, and cross-attention.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rms_norm(x, weight, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return ((xf * inv) * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta: float = 1e4):
    """x: (..., S, d) with d even; positions: (..., S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _online_update(carry, s, v):
    """One online-softmax accumulation step.  s: (..., q, kc); v: (..., kc, d)."""
    m_prev, l_prev, acc = carry
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc * alpha + jnp.einsum("...qk,...kd->...qd", p,
                                       v.astype(jnp.float32))
    return m_new, l_new, acc_new


def mea_attention(q, k, v, *, causal: bool = True, window: int = 0,
                  q_offset: int = 0, q_chunk: int = 512, kv_chunk: int = 1024,
                  scale: Optional[float] = None):
    """Memory-efficient attention.

    q: (B, Hq, Sq, d); k, v: (B, Hkv, Skv, d); Hq % Hkv == 0.
    window > 0 limits attention to the last `window` key positions (and self).
    q_offset is the global position of q[...,0,:] (for decode/prefill resume).
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    rep = hq // hkv
    scale = float(scale if scale is not None else 1.0 / (d ** 0.5))

    qc = min(q_chunk, sq)
    kc = min(kv_chunk, skv)
    pad_q = (-sq) % qc
    pad_k = (-skv) % kc
    qg = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0))).reshape(
        b, hkv, rep, (sq + pad_q) // qc, qc, d).transpose(3, 0, 1, 2, 4, 5)
    kg = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0))).reshape(
        b, hkv, (skv + pad_k) // kc, kc, d).transpose(2, 0, 1, 3, 4)
    vg = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0))).reshape(
        b, hkv, (skv + pad_k) // kc, kc, d).transpose(2, 0, 1, 3, 4)
    nq, nk = qg.shape[0], kg.shape[0]

    def q_step(_, qi_with_idx):
        qi, iq = qi_with_idx
        q_pos = q_offset + iq * qc + jnp.arange(qc)

        def kv_step(carry, ki_vi_idx):
            ki, vi, jk = ki_vi_idx
            k_pos = jk * kc + jnp.arange(kc)
            s = jnp.einsum("bhrqd,bhkd->bhrqk", qi.astype(jnp.float32),
                           ki.astype(jnp.float32)) * scale
            mask = k_pos[None, :] < skv  # unpadded keys only
            if causal:
                mask = mask & (k_pos[None, :] <= q_pos[:, None])
            if window:
                mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            return _online_update(carry, s, vi[:, :, None]), None

        init = (jnp.full((b, hkv, rep, qc, 1), NEG_INF, jnp.float32),
                jnp.zeros((b, hkv, rep, qc, 1), jnp.float32),
                jnp.zeros((b, hkv, rep, qc, d), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, (kg, vg, jnp.arange(nk)))
        o = acc / jnp.where(l > 0, l, 1.0)
        return None, o.astype(q.dtype)

    _, out = jax.lax.scan(q_step, None, (qg, jnp.arange(nq)))
    # (nq, b, hkv, rep, qc, d) -> (b, hq, sq, d)
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, sq + pad_q, d)
    return out[:, :, :sq, :]


def decode_attention(q, k_cache, v_cache, *, pos, window: int = 0,
                     scale: Optional[float] = None):
    """Single-token attention against a cache.

    q: (B, Hq, d); caches: (B, Hkv, S, d); pos: (B,) per-sequence position
    (index of the token being generated) — per-sequence so that slot-based
    continuous batching can run sequences at different depths in one graph.
    """
    b, hq, d = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    rep = hq // hkv
    scale = float(scale if scale is not None else 1.0 / (d ** 0.5))
    qg = q.reshape(b, hkv, rep, d)
    # keep the CACHE in its storage dtype and accumulate in f32 via the MXU:
    # an explicit .astype(f32) materializes a full f32 copy of the per-layer
    # cache slice (2x cache bytes of temp per layer — measured as the 18 GiB
    # gemma decode_32k peak); preferred_element_type gets f32 accuracy free.
    sc = jnp.einsum("bhrd,bhsd->bhrs", qg, k_cache,
                    preferred_element_type=jnp.float32) * scale
    k_pos = jnp.arange(s)
    mask = k_pos[None, :] <= pos[:, None]                 # (B, S)
    if window:
        mask = mask & (k_pos[None, :] > (pos - window)[:, None])
    sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhrs,bhsd->bhrd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, hq, d).astype(q.dtype)


def mlp_block(x, w1, w2, w3, kind: str = "swiglu"):
    """Gated MLP: swiglu (SiLU gate) or geglu (GELU gate, gemma)."""
    h = x @ w1
    g = x @ w3
    act = jax.nn.silu(h) if kind == "swiglu" else jax.nn.gelu(h, approximate=True)
    return (act * g) @ w2
