"""Config-driven composable model: one builder for all 10 assigned archs.

Design decisions that matter at scale:

* **Scan-over-layers**: layer parameters are stacked on a leading L axis and
  the block is applied with `jax.lax.scan` (+ optional `jax.checkpoint`), so
  compile time and HLO size are depth-independent — 88-layer Mistral-Large
  compiles as fast as 2 layers.  Heterogeneous per-layer behaviour (e.g.
  sliding/global mix) is expressed as scanned per-layer data, not structure.
* **Padded vocab**: embedding/head vocab is padded to a multiple of 128 so
  the `model` axis always divides it (MaxText practice); loss masks padding.
* **Frontend stubs**: whisper gets precomputed frame embeddings (B, enc_seq,
  D), llava gets patch embeddings (B, P, D) — per the assignment spec.
* **Decode caches**: attention archs carry (L, B, kvH, S, hd) KV caches
  (ring-buffered when sliding-window); SSM/hybrid archs carry O(1) per-layer
  (dk, dv) states — that is what makes `long_500k` servable.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (decode_attention, mea_attention, mlp_block,
                                 rms_norm, rope)
from repro.models.linear_attn import gla_chunked_xla, gla_decode_step
from repro.models.moe import moe_ffn, moe_ffn_dense

Params = Dict[str, Any]

VOCAB_PAD = 128


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def padded_vocab(cfg: ModelConfig) -> int:
    return ((cfg.vocab_size + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD


def _ssm_dv(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return cfg.d_model // cfg.num_ssm_heads
    return cfg.head_dim


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    # Activation-sharding hints (set by the launcher before lowering, None on
    # single-device paths).  Without explicit constraints GSPMD may satisfy
    # FSDP contractions by resharding *activations* instead of gathering
    # *weights* — measured: global-batch all-reduces inside the layer scan
    # and a 23 GiB logits all-gather at train_4k scale.  Constraining hidden
    # states to (batch→dp, ·, ·) at block boundaries pins the intended
    # data-parallel dataflow.  {"dp": axes tuple|None, "tp": axis|None,
    # "dp_ok": batch divisible by dp}.
    shard_hints: Optional[Dict[str, Any]] = None

    def _c(self, x, kind: str):
        """Apply an activation sharding constraint if hints are set."""
        h = self.shard_hints
        if not h:
            return x
        from jax.sharding import PartitionSpec as P

        dp = h.get("dp") if h.get("dp_ok", True) else None
        tp = h.get("tp")
        # sequence-parallel TP (Megatron-SP): the residual stream between
        # blocks is sharded over the model axis along SEQ, so the per-block
        # boundary collectives become reduce-scatter/all-gather pairs (half
        # the all-reduce wire bytes) and the scan-saved residuals shrink by
        # the TP degree — the lever that fits mistral-large into HBM.
        sp = tp if h.get("sp") else None
        spec = {
            "hidden3": P(dp, sp, None),            # (B, S, D)
            "hidden2": P(dp, None),                # (B, D)
            "logits3": P(dp, sp, tp if not sp else None),  # (B, S, V)
            "logits2": P(dp, tp),                  # (B, V)
        }[kind]
        return jax.lax.with_sharding_constraint(x, spec)

    # ------------------------------------------------------------------ init
    def _layer_shapes(self, cross: bool) -> Dict[str, Tuple[int, ...]]:
        cfg = self.cfg
        d, qd, kvd, f = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.d_ff
        shapes: Dict[str, Tuple[int, ...]] = {"ln1": (d,), "ln2": (d,)}
        if cfg.has_attention:
            shapes.update(wq=(d, qd), wk=(d, kvd), wv=(d, kvd), wo=(qd, d))
        if cfg.has_ssm:
            nh, dk = cfg.num_ssm_heads, cfg.ssm_state
            dv = _ssm_dv(cfg)
            shapes.update(s_wq=(d, nh * dk), s_wk=(d, nh * dk),
                          s_wv=(d, nh * dv), s_wg=(d, nh * dk),
                          s_gbias=(nh * dk,), s_wo=(nh * dv, d))
        if cross:
            shapes.update(ln_x=(d,), xwq=(d, qd), xwk=(d, kvd), xwv=(d, kvd),
                          xwo=(qd, d))
        if cfg.is_moe:
            e = cfg.num_experts
            shapes.update(router=(d, e), e_w1=(e, d, f), e_w3=(e, d, f),
                          e_w2=(e, f, d))
        else:
            shapes.update(w1=(d, f), w3=(d, f), w2=(f, d))
        return shapes

    def _init_stack(self, rng, n_layers: int, cross: bool):
        cfg = self.cfg
        shapes = self._layer_shapes(cross)
        out = {}
        keys = jax.random.split(rng, len(shapes))
        for k, (name, shp) in zip(keys, sorted(shapes.items())):
            full = (n_layers,) + shp if cfg.scan_layers else shp
            if name.startswith("ln"):
                out[name] = jnp.zeros(full, _dt(cfg))
            elif name == "s_gbias":
                out[name] = jnp.full(full, -1.0, _dt(cfg))
            else:
                fan_in = shp[-2] if len(shp) >= 2 else shp[-1]
                out[name] = (jax.random.normal(k, full, _dt(cfg))
                             * (0.02 if len(shp) < 2 else fan_in ** -0.5))
        return out

    def init(self, rng) -> Params:
        cfg = self.cfg
        r_embed, r_layers, r_enc, r_head = jax.random.split(rng, 4)
        vp = padded_vocab(cfg)
        params: Params = {
            "embed": jax.random.normal(r_embed, (vp, cfg.d_model), _dt(cfg)) * 0.02,
            "layers": self._init_stack(r_layers, cfg.num_layers,
                                       cross=cfg.family == "encdec"),
            "final_norm": jnp.zeros((cfg.d_model,), _dt(cfg)),
            "head": jax.random.normal(r_head, (cfg.d_model, vp), _dt(cfg))
            * cfg.d_model ** -0.5,
        }
        if cfg.family == "encdec":
            params["enc_layers"] = self._init_stack(r_enc, cfg.encoder_layers,
                                                    cross=False)
            params["enc_norm"] = jnp.zeros((cfg.d_model,), _dt(cfg))
        return params

    def init_abstract(self) -> Params:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # ------------------------------------------------------------- the block
    def _attn_branch(self, p, x, layer_idx, *, q_offset, window):
        cfg = self.cfg
        b, s, d = x.shape
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        q = (h @ p["wq"]).reshape(b, s, cfg.num_heads, cfg.head_dim)
        k = (h @ p["wk"]).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
        v = (h @ p["wv"]).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
        pos = q_offset + jnp.arange(s)
        q = rope(q.transpose(0, 2, 1, 3), pos, cfg.rope_theta)
        k = rope(k.transpose(0, 2, 1, 3), pos, cfg.rope_theta)
        v = v.transpose(0, 2, 1, 3)
        o = mea_attention(q, k, v, causal=True, window=window, q_offset=q_offset)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.q_dim)
        return o @ p["wo"], (k, v)

    def _ssm_branch(self, p, x, *, state=None):
        cfg = self.cfg
        b, s, d = x.shape
        nh, dk, dv = cfg.num_ssm_heads, cfg.ssm_state, _ssm_dv(cfg)
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        q = (h @ p["s_wq"]).reshape(b, s, nh, dk).transpose(0, 2, 1, 3)
        k = (h @ p["s_wk"]).reshape(b, s, nh, dk).transpose(0, 2, 1, 3)
        v = (h @ p["s_wv"]).reshape(b, s, nh, dv).transpose(0, 2, 1, 3)
        # data-dependent log-decay (RWKV6-style): -softplus(xW + b)
        g = -jax.nn.softplus((h @ p["s_wg"]) + p["s_gbias"])
        g = g.reshape(b, s, nh, dk).transpose(0, 2, 1, 3)
        o, new_state = gla_chunked_xla(q, k, v, g, impl=cfg.gla_impl,
                                       initial_state=state)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, nh * dv)
        return o @ p["s_wo"], new_state

    def _cross_branch(self, p, x, enc_kv):
        cfg = self.cfg
        b, s, d = x.shape
        h = rms_norm(x, p["ln_x"], cfg.norm_eps)
        q = (h @ p["xwq"]).reshape(b, s, cfg.num_heads, cfg.head_dim)
        q = q.transpose(0, 2, 1, 3)
        ek, ev = enc_kv
        o = mea_attention(q, ek, ev, causal=False)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.q_dim)
        return o @ p["xwo"]

    def _ffn_branch(self, p, x):
        cfg = self.cfg
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            b, s, d = h.shape
            flat = h.reshape(b * s, d)

            def run(tokens):
                return moe_ffn(tokens, p["router"], p["e_w1"],
                               p["e_w3"], p["e_w2"], top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor,
                               mlp_kind=cfg.mlp)

            if cfg.moe_dense_train:
                # dense-all-experts: every token through every expert, sparse
                # gates applied at combine.  8x expert FLOPs for ZERO dispatch
                # collectives — wins when the cell is collective-bound and
                # experts are small (olmoe/granite-moe; see EXPERIMENTS §Perf)
                y = moe_ffn_dense(flat, p["router"], p["e_w1"], p["e_w3"],
                                  p["e_w2"], top_k=cfg.top_k, mlp_kind=cfg.mlp)
                return y.reshape(b, s, d), jnp.float32(0.0)

            t = b * s
            if cfg.moe_chunk and t > cfg.moe_chunk and t % cfg.moe_chunk == 0:
                # token-chunked MoE: dispatch buffers scale with the chunk,
                # not the full sequence (prefill_32k memory lever)
                nc = t // cfg.moe_chunk
                ys, auxs = jax.lax.map(run, flat.reshape(nc, cfg.moe_chunk, d))
                return ys.reshape(b, s, d), auxs.mean()
            y, aux = run(flat)
            return y.reshape(b, s, d), aux
        return mlp_block(h, p["w1"], p["w2"], p["w3"], cfg.mlp), jnp.float32(0.0)

    def _decoder_block(self, p, x, *, q_offset, enc_kv=None, ssm_state=None):
        cfg = self.cfg
        aux = jnp.float32(0.0)
        kv = None
        new_state = None
        if cfg.family == "hybrid":
            a, kv = self._attn_branch(p, x, 0, q_offset=q_offset,
                                      window=cfg.sliding_window)
            sso, new_state = self._ssm_branch(p, x, state=ssm_state)
            x = x + (a + sso) / 2.0
        elif cfg.has_ssm:  # pure SSM (rwkv)
            sso, new_state = self._ssm_branch(p, x, state=ssm_state)
            x = x + sso
        else:
            a, kv = self._attn_branch(p, x, 0, q_offset=q_offset, window=0)
            x = x + a
        if enc_kv is not None:
            x = x + self._cross_branch(p, x, enc_kv)
        f, aux = self._ffn_branch(p, x)
        return x + f, kv, new_state, aux

    def _encoder_block(self, p, x):
        cfg = self.cfg
        b, s, d = x.shape
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        q = (h @ p["wq"]).reshape(b, s, cfg.num_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        k = (h @ p["wk"]).reshape(b, s, cfg.num_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        v = (h @ p["wv"]).reshape(b, s, cfg.num_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        pos = jnp.arange(s)
        q, k = rope(q, pos, cfg.rope_theta), rope(k, pos, cfg.rope_theta)
        o = mea_attention(q, k, v, causal=False)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.q_dim)
        x = x + o @ p["wo"]
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + mlp_block(h2, p["w1"], p["w2"], p["w3"], cfg.mlp)

    # ------------------------------------------------------------ full passes
    def _scan_stack(self, stack, x, body):
        """Apply `body(layer_params, x) -> x` over stacked layers."""
        cfg = self.cfg

        def f(carry, lp):
            return body(lp, carry), None

        if cfg.remat:
            f = jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(f, x, stack)
        return x

    def _embed_inputs(self, params, batch) -> Tuple[jax.Array, Optional[Tuple]]:
        """Token (+ stub-frontend) embedding; returns (x, enc_kv)."""
        cfg = self.cfg
        x = params["embed"][batch["tokens"]]
        if cfg.family == "vlm":
            patches = batch["patch_embeds"].astype(x.dtype)
            x = jnp.concatenate([patches, x], axis=1)
        enc_kv = None
        if cfg.family == "encdec":
            enc = batch["frames"].astype(x.dtype)
            enc = self._scan_stack(params["enc_layers"], enc,
                                   lambda lp, h: self._encoder_block(lp, h))
            enc = rms_norm(enc, params["enc_norm"], cfg.norm_eps)
            # encoder K/V projected once per decoder layer at run time; here
            # we pass the encoded sequence and project inside the block scan.
            enc_kv = enc
        return x, enc_kv

    def forward(self, params: Params, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, jax.Array]:
        """Training/prefill logits.  Returns (logits (B, S, Vp), aux_loss)."""
        cfg = self.cfg
        x, enc = self._embed_inputs(params, batch)

        aux_total = jnp.float32(0.0)

        x = self._c(x, "hidden3")

        def body(carry, lp):
            h, aux = carry
            enc_kv = None
            if enc is not None:
                b, se, d = enc.shape
                ek = (enc @ lp["xwk"]).reshape(b, se, cfg.num_kv_heads,
                                               cfg.head_dim).transpose(0, 2, 1, 3)
                ev = (enc @ lp["xwv"]).reshape(b, se, cfg.num_kv_heads,
                                               cfg.head_dim).transpose(0, 2, 1, 3)
                enc_kv = (ek, ev)
            h, _, _, a = self._decoder_block(lp, h, q_offset=0, enc_kv=enc_kv)
            return (self._c(h, "hidden3"), aux + a), None

        f = body
        if cfg.remat:
            f = jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)
        G = cfg.remat_groups
        if G > 1 and cfg.num_layers % G == 0:
            # sqrt-remat: outer scan over G groups saves G carries; the inner
            # scan re-materializes its L/G carries one group at a time during
            # backward, so residual-stream memory is O(G + L/G), not O(L)
            grouped = jax.tree.map(
                lambda a: a.reshape(G, cfg.num_layers // G, *a.shape[1:]),
                params["layers"])

            def group_body(carry, group_params):
                out, _ = jax.lax.scan(f, carry, group_params)
                return out, None

            gb = jax.checkpoint(group_body,
                                policy=jax.checkpoint_policies.nothing_saveable)
            (x, aux_total), _ = jax.lax.scan(gb, (x, aux_total), grouped)
        else:
            (x, aux_total), _ = jax.lax.scan(f, (x, aux_total), params["layers"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._c(x @ params["head"], "logits3")
        return logits, aux_total

    # --------------------------------------------------------------- serving
    def cache_spec(self, batch: int, cache_len: int) -> Dict[str, Any]:
        """Abstract cache layout for a decode session."""
        cfg = self.cfg
        dt = _dt(cfg)
        L = cfg.num_layers
        # per-sequence positions: continuous batching runs sequences at
        # different depths through one compiled decode graph
        spec: Dict[str, Any] = {"pos": jax.ShapeDtypeStruct((batch,), jnp.int32)}
        if cfg.has_attention:
            window = cfg.sliding_window
            s = min(cache_len, window) if window else cache_len
            spec["k"] = jax.ShapeDtypeStruct(
                (L, batch, cfg.num_kv_heads, s, cfg.head_dim), dt)
            spec["v"] = jax.ShapeDtypeStruct(
                (L, batch, cfg.num_kv_heads, s, cfg.head_dim), dt)
        if cfg.has_ssm:
            spec["ssm"] = jax.ShapeDtypeStruct(
                (L, batch, cfg.num_ssm_heads, cfg.ssm_state, _ssm_dv(cfg)),
                jnp.float32)
        if cfg.family == "encdec":
            spec["cross_k"] = jax.ShapeDtypeStruct(
                (L, batch, cfg.num_kv_heads, cfg.enc_seq, cfg.head_dim), dt)
            spec["cross_v"] = jax.ShapeDtypeStruct(
                (L, batch, cfg.num_kv_heads, cfg.enc_seq, cfg.head_dim), dt)
        return spec

    def init_cache(self, batch: int, cache_len: int) -> Dict[str, Any]:
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_spec(batch, cache_len))

    def decode_step(self, params: Params, cache: Dict[str, Any],
                    token: jax.Array) -> Tuple[jax.Array, Dict[str, Any]]:
        """One decoding step.  token: (B,) int32.  Returns (logits (B, Vp), cache)."""
        cfg = self.cfg
        pos = cache["pos"]  # (B,)
        x = params["embed"][token]  # (B, D)
        b = x.shape[0]
        window = cfg.sliding_window
        cache_len = cache["k"].shape[3] if cfg.has_attention else 0
        if cfg.has_attention:
            slot = (pos % cache_len) if window else pos  # (B,) ring vs linear
        else:
            slot = pos

        def body(carry, xs):
            h = carry
            lp = xs[0]
            kc = vc = ssm = xk = xv = None
            i = 1
            if cfg.has_attention:
                kc, vc = xs[i], xs[i + 1]
                i += 2
            if cfg.has_ssm:
                ssm = xs[i]
                i += 1
            if cfg.family == "encdec":
                xk, xv = xs[i], xs[i + 1]

            hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
            new_kc, new_vc, new_ssm = kc, vc, ssm
            attn_out = None
            if cfg.has_attention:
                q = (hn @ lp["wq"]).reshape(b, cfg.num_heads, cfg.head_dim)
                k = (hn @ lp["wk"]).reshape(b, cfg.num_kv_heads, cfg.head_dim)
                v = (hn @ lp["wv"]).reshape(b, cfg.num_kv_heads, cfg.head_dim)
                posv = pos.reshape(b, 1, 1)  # broadcast over heads
                q = rope(q[:, :, None, :], posv, cfg.rope_theta)[:, :, 0, :]
                k = rope(k[:, :, None, :], posv, cfg.rope_theta)
                upd = jax.vmap(functools.partial(
                    jax.lax.dynamic_update_slice_in_dim, axis=1))
                new_kc = upd(kc, k, slot)
                new_vc = upd(vc, v[:, :, None, :], slot)
                if window:
                    # ring buffer: every written slot is within the window
                    o = decode_attention(q, new_kc, new_vc,
                                         pos=jnp.minimum(pos, cache_len - 1),
                                         window=0)
                else:
                    o = decode_attention(q, new_kc, new_vc, pos=pos, window=0)
                attn_out = o.reshape(b, cfg.q_dim) @ lp["wo"]
            ssm_out = None
            if cfg.has_ssm:
                nh, dk, dv = cfg.num_ssm_heads, cfg.ssm_state, _ssm_dv(cfg)
                sq = (hn @ lp["s_wq"]).reshape(b, nh, dk)
                sk = (hn @ lp["s_wk"]).reshape(b, nh, dk)
                sv = (hn @ lp["s_wv"]).reshape(b, nh, dv)
                sg = -jax.nn.softplus((hn @ lp["s_wg"]) + lp["s_gbias"]).reshape(b, nh, dk)
                so, new_ssm = gla_decode_step(sq, sk, sv, sg, ssm)
                ssm_out = so.reshape(b, nh * dv) @ lp["s_wo"]

            if cfg.family == "hybrid":
                h = h + (attn_out + ssm_out) / 2.0
            elif cfg.has_ssm:
                h = h + ssm_out
            else:
                h = h + attn_out

            if cfg.family == "encdec":
                hx = rms_norm(h, lp["ln_x"], cfg.norm_eps)
                q = (hx @ lp["xwq"]).reshape(b, cfg.num_heads, cfg.head_dim)
                enc_pos = jnp.full((b,), xk.shape[2] - 1, jnp.int32)
                o = decode_attention(q, xk, xv, pos=enc_pos, window=0)
                h = h + o.reshape(b, cfg.q_dim) @ lp["xwo"]

            hf = rms_norm(h, lp["ln2"], cfg.norm_eps)
            if cfg.is_moe:
                # dropless dense-combine: exact routing, no sort/scatter in
                # the latency-critical decode graph (see moe.moe_ffn_dense)
                y = moe_ffn_dense(hf, lp["router"], lp["e_w1"], lp["e_w3"],
                                  lp["e_w2"], top_k=cfg.top_k, mlp_kind=cfg.mlp)
            else:
                y = mlp_block(hf, lp["w1"], lp["w2"], lp["w3"], cfg.mlp)
            h = self._c(h + y, "hidden2")

            ys = []
            if cfg.has_attention:
                ys += [new_kc, new_vc]
            if cfg.has_ssm:
                ys += [new_ssm]
            return h, tuple(ys)

        xs = [params["layers"]]
        if cfg.has_attention:
            xs += [cache["k"], cache["v"]]
        if cfg.has_ssm:
            xs += [cache["ssm"]]
        if cfg.family == "encdec":
            xs += [cache["cross_k"], cache["cross_v"]]

        x, ys = jax.lax.scan(body, x, tuple(xs))
        new_cache = dict(cache)
        i = 0
        if cfg.has_attention:
            new_cache["k"], new_cache["v"] = ys[i], ys[i + 1]
            i += 2
        if cfg.has_ssm:
            new_cache["ssm"] = ys[i]
        new_cache["pos"] = pos + 1

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._c(x @ params["head"], "logits2")
        return logits, new_cache

    def prefill(self, params: Params, batch: Dict[str, jax.Array],
                cache_len: Optional[int] = None) -> Tuple[jax.Array, Dict[str, Any]]:
        """Prefill: forward over the prompt, building the decode cache.

        Returns (last-token logits (B, Vp), cache).
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        cache_len = cache_len or max(s, 1)
        x, enc = self._embed_inputs(params, batch)

        def body(carry, lp):
            h = carry
            enc_kv = None
            if enc is not None:
                bb, se, _ = enc.shape
                ek = (enc @ lp["xwk"]).reshape(bb, se, cfg.num_kv_heads,
                                               cfg.head_dim).transpose(0, 2, 1, 3)
                ev = (enc @ lp["xwv"]).reshape(bb, se, cfg.num_kv_heads,
                                               cfg.head_dim).transpose(0, 2, 1, 3)
                enc_kv = (ek, ev)
            h, kv, ssm_state, _ = self._decoder_block(lp, h, q_offset=0,
                                                      enc_kv=enc_kv,
                                                      ssm_state=None)
            h = self._c(h, "hidden3")
            ys = []
            if kv is not None:
                ys += [kv[0], kv[1]]
            if ssm_state is not None:
                ys += [ssm_state]
            if enc_kv is not None:
                ys += [enc_kv[0], enc_kv[1]]
            return h, tuple(ys)

        f = body
        if cfg.remat:
            f = jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)
        x, ys = jax.lax.scan(f, x, params["layers"])

        cache: Dict[str, Any] = {
            "pos": jnp.full((tokens.shape[0],), x.shape[1], jnp.int32)}
        i = 0
        if cfg.has_attention:
            k_all, v_all = ys[i], ys[i + 1]  # (L, B, kvH, S, hd)
            i += 2
            window = cfg.sliding_window
            store = min(cache_len, window) if window else cache_len
            pad = store - k_all.shape[3]
            if pad > 0:
                k_all = jnp.pad(k_all, ((0, 0),) * 3 + ((0, pad), (0, 0)))
                v_all = jnp.pad(v_all, ((0, 0),) * 3 + ((0, pad), (0, 0)))
            elif pad < 0:
                # keep the last `store` keys and rotate them into ring order:
                # position p must live at slot p % store (s, store static)
                k_all = jnp.roll(k_all[:, :, :, -store:, :], s % store, axis=3)
                v_all = jnp.roll(v_all[:, :, :, -store:, :], s % store, axis=3)
            cache["k"], cache["v"] = k_all, v_all
        if cfg.has_ssm:
            cache["ssm"] = ys[i]
            i += 1
        if cfg.family == "encdec":
            cache["cross_k"], cache["cross_v"] = ys[i], ys[i + 1]

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._c(x[:, -1, :] @ params["head"], "logits2")
        return logits, cache


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg.validate())
