"""Top-k MoE with sort-based dispatch into static-capacity expert buffers.

The dispatch path is the jit-friendly formulation that scales to 64 experts
(olmoe) without materializing a (tokens, E, capacity) mask:

  1. route: top-k softmax gates per token;
  2. sort the (token, expert-slot) pairs by expert id;
  3. compute each pair's position within its expert via a cumulative count;
  4. scatter token activations into an (E * capacity, D) buffer (overflow
     beyond capacity is dropped — standard capacity-factor semantics);
  5. batched expert FFN: einsum over the expert axis (EP-shardable: the
     expert dimension is sharded over the `model` mesh axis, so the scatter/
     gather become the MoE all-to-all under pjit);
  6. gather back and combine with gate weights.

An auxiliary load-balancing loss (Switch-style) is returned for training.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def moe_ffn(x, router_w, w1, w3, w2, *, top_k: int, capacity_factor: float,
            mlp_kind: str = "swiglu") -> Tuple[jax.Array, jax.Array]:
    """x: (T, D); router_w: (D, E); w1/w3: (E, D, F); w2: (E, F, D).

    Returns (out (T, D), aux_loss ()).
    """
    t, d = x.shape
    e = router_w.shape[1]
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)              # (T, K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e f_e * p_e
    me = probs.mean(axis=0)
    ce = jnp.zeros(e, jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (t * top_k)
    aux = e * jnp.sum(me * ce)

    capacity = max(int(t * top_k * capacity_factor / e), top_k)
    flat_expert = expert_idx.reshape(-1)                              # (T*K,)
    flat_token = jnp.repeat(jnp.arange(t), top_k)
    flat_gate = gate_vals.reshape(-1)

    order = jnp.argsort(flat_expert)
    se, st_tok, sg = flat_expert[order], flat_token[order], flat_gate[order]
    counts = jnp.zeros(e, jnp.int32).at[se].add(1)
    starts = jnp.cumsum(counts) - counts                              # (E,)
    pos_in_expert = jnp.arange(t * top_k) - starts[se]
    keep = pos_in_expert < capacity
    dest = jnp.where(keep, se * capacity + pos_in_expert, e * capacity)

    buf = jnp.zeros((e * capacity + 1, d), x.dtype)
    buf = buf.at[dest].set(x[st_tok] * keep[:, None].astype(x.dtype))
    buf = buf[:-1].reshape(e, capacity, d)

    h = jnp.einsum("ecd,edf->ecf", buf, w1)
    g = jnp.einsum("ecd,edf->ecf", buf, w3)
    act = jax.nn.silu(h) if mlp_kind == "swiglu" else jax.nn.gelu(h, approximate=True)
    out_buf = jnp.einsum("ecf,efd->ecd", act * g, w2).reshape(e * capacity, d)
    out_buf = jnp.concatenate([out_buf, jnp.zeros((1, d), out_buf.dtype)], axis=0)

    y_pairs = out_buf[dest] * (sg * keep)[:, None].astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[st_tok].add(y_pairs)
    return y, aux


def moe_ffn_dense(x, router_w, w1, w3, w2, *, top_k: int,
                  mlp_kind: str = "swiglu") -> jax.Array:
    """Dropless decode path: evaluate ALL experts and combine with the sparse
    top-k gates.  At decode batch sizes the MoE layer is weight-streaming
    bound (every expert's weights cross HBM regardless), so the extra MXU
    work is free — and routing becomes exactly dropless, with no sort/scatter
    in the latency-critical graph."""
    t, d = x.shape
    e = router_w.shape[1]
    probs = jax.nn.softmax(x.astype(jnp.float32) @ router_w.astype(jnp.float32), -1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
    gates = jnp.zeros((t, e), jnp.float32)
    gates = jax.vmap(lambda g, i, gv: g.at[i].set(gv))(gates, expert_idx, gate_vals)

    h = jnp.einsum("td,edf->tef", x, w1)
    g = jnp.einsum("td,edf->tef", x, w3)
    act = jax.nn.silu(h) if mlp_kind == "swiglu" else jax.nn.gelu(h, approximate=True)
    y_e = jnp.einsum("tef,efd->ted", act * g, w2)
    return jnp.einsum("ted,te->td", y_e, gates.astype(x.dtype))
