"""Eager vs compiled-physical execution of the pilot + final query pair.

The pair is TAQA's hot path: ``execute_pilot`` (block-sample at θ_p, per-block
channel stats) followed by ``execute`` of the final block-sampled plan.  The
eager interpreter dispatches jnp ops per operator with host round-trips per
expression; the compiled physical layer runs each stage as one cached jitted
executable (``engine/physical.py``) with a single device→host boundary.

Reported per variant: first-call time (includes lowering + XLA compile),
steady-state wall time over repeated structurally-identical queries with
fresh seeds (the serve-layer scenario — these hit the compile cache, which we
assert via the hit counters), and scanned bytes (identical by construction:
both paths draw the same Bernoulli samples and charge θ·bytes for
block-sampled scans).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import catalog, csv_row, save_results
from repro.engine import logical as L
from repro.engine.executor import Executor
from repro.engine.expr import And, Col

THETA_PILOT = 0.01
THETA_FINAL = 0.05
REPS = 5
SWEEP_LEN = 10  # distinct constant sets in the constant-hoisting sweep


def _q6_plan():
    pred = And(Col("l_shipdate").between(100, 1500),
               And(Col("l_discount").between(0.02, 0.08), Col("l_quantity") < 24))
    return L.Aggregate(
        child=L.Filter(L.Scan("lineitem"), pred),
        aggs=(L.AggSpec("sum", Col("l_extendedprice") * Col("l_discount"), "rev"),
              L.AggSpec("count", None, "cnt")))


def _grouped_plan():
    return L.Aggregate(
        child=L.Filter(L.Scan("lineitem"), Col("l_shipdate") < 2400),
        aggs=(L.AggSpec("sum", Col("l_quantity"), "qty"),
              L.AggSpec("sum", Col("l_extendedprice"), "price"),
              L.AggSpec("count", None, "cnt")),
        group_by="l_returnflag", max_groups=3)


def _pair(ex: Executor, plan: L.Aggregate, seed: int):
    pilot = ex.execute_pilot(plan, "lineitem", THETA_PILOT, seed)
    final = ex.execute(L.rewrite_scans(
        plan, {"lineitem": L.SampleClause("block", THETA_FINAL, seed + 977)}))
    return pilot, final


def _measure(ex: Executor, plan: L.Aggregate) -> dict:
    t0 = time.perf_counter()
    pilot, final = _pair(ex, plan, seed=0)
    first_s = time.perf_counter() - t0
    times = []
    for seed in range(1, REPS + 1):
        t0 = time.perf_counter()
        _pair(ex, plan, seed=seed)
        times.append(time.perf_counter() - t0)
    return {
        "first_call_s": first_s,
        "steady_state_s": float(np.median(times)),
        "best_s": float(min(times)),
        "pilot_scanned_bytes": pilot.scanned_bytes,
        "final_scanned_bytes": final.scanned_bytes,
    }


def _q6_variant(i: int):
    """The Q6 shape with shifted constants — a dashboard's sliding range."""
    pred = And(Col("l_shipdate").between(100 + 25 * i, 1500 + 20 * i),
               And(Col("l_discount").between(0.02, 0.08 + 0.002 * i),
                   Col("l_quantity") < 24 + i))
    return L.Aggregate(
        child=L.Filter(L.Scan("lineitem"), pred),
        aggs=(L.AggSpec("sum", Col("l_extendedprice") * Col("l_discount"), "rev"),
              L.AggSpec("count", None, "cnt")))


def _measure_constant_sweep(baseline_steady_s: float) -> dict:
    """Sweep SWEEP_LEN constant sets over one shape: compile misses must be
    independent of sweep length (constants are runtime operands — one
    executable for the pilot stage and one for the final stage), and the
    per-constant steady latency must track the repeated-identical baseline.
    """
    ex = Executor(catalog())
    t0 = time.perf_counter()
    _pair(ex, _q6_variant(0), seed=0)  # pays the (only) two compilations
    first_s = time.perf_counter() - t0
    times = []
    for i in range(1, SWEEP_LEN):
        t0 = time.perf_counter()
        # fixed seed: the sweep isolates the CONSTANT axis.  A fresh seed
        # per step would also vary the Binomial block draw, which near a
        # bucket_blocks boundary (e.g. 200k rows: mean 62.5 vs the 64
        # bucket) legitimately compiles a second shape — a shape miss, not
        # a constant miss, and not what this smoke bound is about.
        _pair(ex, _q6_variant(i), seed=0)
        times.append(time.perf_counter() - t0)
    info = ex.compile_cache_info()
    assert info.misses <= 2, (
        f"a {SWEEP_LEN}-constant sweep must compile at most one pilot and "
        f"one final executable, got {info.misses} misses")
    steady = float(np.median(times))
    return {
        "sweep_len": SWEEP_LEN,
        "compile_misses": info.misses,
        "compile_hits": info.hits,
        "first_call_s": first_s,
        "per_query_steady_s": steady,
        "baked_baseline_steady_s": baseline_steady_s,
        "steady_vs_baseline": steady / baseline_steady_s
        if baseline_steady_s else float("nan"),
    }


def run() -> dict:
    cat = catalog()
    payload = {}
    for name, plan in (("q6_pair", _q6_plan()), ("grouped_pair", _grouped_plan())):
        eager = _measure(Executor(cat, use_compiled=False), plan)
        ex_c = Executor(cat)
        compiled = _measure(ex_c, plan)
        info = ex_c.compile_cache_info()
        assert info.hits > 0, "steady-state queries must hit the compile cache"
        payload[name] = {
            "eager": eager,
            "compiled": compiled,
            "compile_overhead_s": compiled["first_call_s"] - compiled["steady_state_s"],
            "steady_speedup": eager["steady_state_s"] / compiled["steady_state_s"],
            "cache": {"hits": info.hits, "misses": info.misses, "size": info.size,
                      "hit_rate": info.hits / max(info.hits + info.misses, 1)},
            "scanned_bytes": {
                "pilot": compiled["pilot_scanned_bytes"],
                "final": compiled["final_scanned_bytes"],
            },
            "scanned_bytes_equal": (
                eager["pilot_scanned_bytes"] == compiled["pilot_scanned_bytes"]
                and eager["final_scanned_bytes"] == compiled["final_scanned_bytes"]),
        }
    # Constant-hoisting sweep: compile misses independent of sweep length.
    payload["constant_sweep"] = _measure_constant_sweep(
        payload["q6_pair"]["compiled"]["steady_state_s"])
    save_results("bench_compiled", payload)
    q6 = payload["q6_pair"]
    print(csv_row("compiled_vs_eager", q6["compiled"]["steady_state_s"] * 1e6,
                  f"speedup={q6['steady_speedup']:.2f}x;"
                  f"compile={q6['compile_overhead_s']:.2f}s;"
                  f"cache_hits={q6['cache']['hits']}"))
    sweep = payload["constant_sweep"]
    print(csv_row("constant_sweep", sweep["per_query_steady_s"] * 1e6,
                  f"misses={sweep['compile_misses']};"
                  f"sweep={sweep['sweep_len']};"
                  f"vs_baseline={sweep['steady_vs_baseline']:.2f}x"))
    return payload


if __name__ == "__main__":
    run()
