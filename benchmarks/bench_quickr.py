"""Fig. 11/12 + Table 5: BSAP vs row-level sampling (Quickr-style, PilotDB-R).

Quickr-style row-uniform plans need one full pass (row Bernoulli cannot skip
blocks); replacing its sampler with BSAP's block sampling (same two-stage
planner) yields the Fig. 12 acceleration.  Identical queries, identical
error targets (10%, the Quickr paper's setting).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (csv_row, geomean, make_db, make_row_db,
                               query_suite, rel_errors, save_results)
from repro.core import ErrorSpec


def run(trials: int = 2) -> dict:
    db = make_db()
    rdb = make_row_db()
    spec = ErrorSpec(error=0.10, confidence=0.95)
    t_all = time.perf_counter()
    per_query = {}
    for bq in query_suite():
        if bq.name.startswith("join_grouped"):
            continue  # row path identical shape; keep the bench tight
        exact = db.exact(bq.query)
        b_wall, r_wall, b_bytes, r_bytes = [], [], [], []
        errs_ok = True
        for s in range(trials):
            t0 = time.perf_counter()
            a_blk = db.query(bq.query, spec, seed=77 * s + 1)
            b_wall.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            a_row = rdb.query(bq.query, spec, seed=77 * s + 1)
            r_wall.append(time.perf_counter() - t0)
            if a_blk.report.fallback is None:
                b_bytes.append(a_blk.report.pilot_scanned_bytes
                               + a_blk.report.final_scanned_bytes)
            if a_row.report.fallback is None:
                r_bytes.append(a_row.report.pilot_scanned_bytes
                               + a_row.report.final_scanned_bytes)
            for a in (a_blk, a_row):
                e = rel_errors(a, exact)
                if len(e) and e.max() > spec.error and a.report.fallback is None:
                    errs_ok = False
        per_query[bq.name] = {
            "bsap_vs_row_wall": float(np.mean(r_wall) / np.mean(b_wall)),
            "bsap_vs_row_bytes": (float(np.mean(r_bytes) / np.mean(b_bytes))
                                  if b_bytes and r_bytes else None),
            "both_within_target": errs_ok,
        }
    wall = time.perf_counter() - t_all
    speedups = [q["bsap_vs_row_bytes"] for q in per_query.values()
                if q["bsap_vs_row_bytes"]]
    payload = {"per_query": per_query,
               "gm_bytes_speedup": geomean(speedups),
               "max_bytes_speedup": max(speedups) if speedups else None}
    save_results("bench_quickr", payload)
    print(csv_row("quickr_bsap_fig11_12", wall * 1e6,
                  f"gm={payload['gm_bytes_speedup']:.1f}x;"
                  f"max={payload['max_bytes_speedup']:.0f}x"))
    return payload


if __name__ == "__main__":
    run()
