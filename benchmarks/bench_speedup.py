"""Fig. 8/9/10: PilotDB speedups over exact execution.

Per query: wall-clock speedup (exact / PilotDB-total incl. pilot+planning)
and the scale-free scan-bytes fraction.  Also sweeps target errors (Fig. 9)
on the Q6 family and reports the skewed-data queries separately (Fig. 10).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (csv_row, geomean, make_db, query_suite,
                               rel_errors, save_results)
from repro.core import ErrorSpec


def _run_once(db, bq, spec, seed):
    t0 = time.perf_counter()
    exact = db.exact(bq.query)
    t_exact = time.perf_counter() - t0
    t0 = time.perf_counter()
    ans = db.query(bq.query, spec, seed=seed)
    t_aqp = time.perf_counter() - t0
    scan_frac = (ans.report.pilot_scanned_bytes + ans.report.final_scanned_bytes) \
        / max(ans.report.exact_scanned_bytes, 1)
    err = rel_errors(ans, exact)
    return {
        "speedup": t_exact / max(t_aqp, 1e-9),
        "scan_frac": scan_frac,
        "bytes_speedup": 1.0 / max(scan_frac, 1e-9),
        "fallback": ans.report.fallback,
        "max_err": float(err.max()) if len(err) else None,
    }


def run(trials: int = 3) -> dict:
    db = make_db()
    spec = ErrorSpec(error=0.05, confidence=0.95)
    t_all = time.perf_counter()

    per_query = {}
    for bq in query_suite():
        for ws in (3, 4):  # warm the shape-bucket caches (adjacent buckets)
            _run_once(db, bq, spec, seed=ws)
        runs = [_run_once(db, bq, spec, seed=100 * s + 7) for s in range(trials)]
        ok = [r for r in runs if r["fallback"] is None]
        per_query[bq.name] = {
            "wall_speedup_gm": geomean([r["speedup"] for r in ok]) if ok else None,
            "bytes_speedup_gm": geomean([r["bytes_speedup"] for r in ok]) if ok else None,
            "scan_frac": float(np.mean([r["scan_frac"] for r in ok])) if ok else None,
            "fallbacks": len(runs) - len(ok),
            "max_err": max((r["max_err"] or 0) for r in runs),
        }

    # Fig. 9: error-target sweep on the Q6 family
    q6 = query_suite()[0]
    err_sweep = {}
    for e in (0.01, 0.02, 0.05, 0.10):
        r = _run_once(db, q6, ErrorSpec(error=e, confidence=0.95), seed=5)
        err_sweep[str(e)] = {"bytes_speedup": r["bytes_speedup"],
                             "wall_speedup": r["speedup"],
                             "fallback": r["fallback"]}
    wall = time.perf_counter() - t_all

    accel = [q for q in per_query.values() if q["wall_speedup_gm"]]
    payload = {
        "per_query": per_query,
        "error_sweep_q6": err_sweep,
        "gm_wall_speedup": geomean([q["wall_speedup_gm"] for q in accel]),
        "gm_bytes_speedup": geomean([q["bytes_speedup_gm"] for q in accel]),
        "max_bytes_speedup": max(q["bytes_speedup_gm"] for q in accel),
    }
    save_results("bench_speedup", payload)
    print(csv_row("speedup_fig8_9_10", wall * 1e6,
                  f"gm_wall={payload['gm_wall_speedup']:.1f}x;"
                  f"gm_bytes={payload['gm_bytes_speedup']:.1f}x;"
                  f"max_bytes={payload['max_bytes_speedup']:.0f}x"))
    return payload


if __name__ == "__main__":
    run()
