"""Kernel-layer benchmark: the BSAP scan primitives + LM hot paths.

On this CPU container the Pallas kernels run in interpret mode (Python), so
wall-clock there is meaningless; what we measure is the *system model* the
kernels implement:

  * block-gather aggregation (XLA path, == kernels/block_agg semantics)
    vs full-column scan — bytes touched and wall time at several rates;
  * fused filter+aggregate (kernels/filtered_agg semantics) vs the unfused
    two-pass engine pipeline;
  * chunked GLA (kernels/gla_chunk XLA twin) vs naive recurrence — step
    count collapse (T sequential steps -> T/chunk GEMM steps).

Kernel-vs-ref numerical equivalence is covered by tests/test_kernels.py.
End-to-end eager-vs-compiled query execution (the physical layer that routes
plans through these kernels) is measured in bench_compiled.py.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import catalog, csv_row, save_results
from repro.models.linear_attn import gla_chunked_xla
from repro.kernels.gla_chunk.ref import gla_recurrent_ref


def _time(f, *args, reps=3):
    f(*args)  # warm/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        r = f(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps


def run() -> dict:
    li = catalog()["lineitem"]
    col = li.columns["l_extendedprice"]
    valid = li.valid.astype(jnp.float32)
    n_blocks, br = li.num_blocks, li.block_rows

    @jax.jit
    def full_scan_agg(c, v):
        return jnp.stack([jnp.sum(v), jnp.sum(c * v), jnp.sum(c * c * v)])

    def block_gather_agg(c, v, ids):
        cb = c.reshape(n_blocks, br)[ids]
        vb = v.reshape(n_blocks, br)[ids]
        return jnp.stack([vb.sum(), (cb * vb).sum(), (cb * cb * vb).sum()])

    rng = np.random.default_rng(0)
    t_full = _time(full_scan_agg, col, valid)
    gather_rows = {}
    for rate in (0.001, 0.01, 0.1):
        ids = jnp.asarray(np.nonzero(rng.random(n_blocks) < rate)[0], jnp.int32)
        fn = jax.jit(block_gather_agg)
        t = _time(fn, col, valid, ids)
        gather_rows[str(rate)] = {"time_s": t, "speedup_vs_full": t_full / t,
                                  "bytes_frac": float(len(ids)) / n_blocks}

    # chunked GLA vs naive recurrence
    B, H, T, dk, dv = 1, 4, 2048, 32, 32
    q = jnp.asarray(rng.normal(0, 1, (B, H, T, dk)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (B, H, T, dk)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (B, H, T, dv)).astype(np.float32))
    g = jnp.asarray(-rng.uniform(0.001, 0.1, (B, H, T, dk)).astype(np.float32))
    chunked = jax.jit(lambda *a: gla_chunked_xla(*a)[0])
    naive = jax.jit(lambda qq, kk, vv, gg: jax.vmap(jax.vmap(
        lambda a, b, c, d: gla_recurrent_ref(a, b, c, d)[0]))(qq, kk, vv, gg))
    t_chunk = _time(chunked, q, k, v, g)
    t_naive = _time(naive, q, k, v, g)

    payload = {
        "full_scan_s": t_full,
        "block_gather": gather_rows,
        "gla_chunked_s": t_chunk,
        "gla_recurrent_s": t_naive,
        "gla_cpu_wall_ratio": t_naive / t_chunk,
        # the TPU-relevant quantity: sequential dependency chain length
        "gla_sequential_steps_naive": T,
        "gla_sequential_steps_chunked": T // 32,
    }
    save_results("bench_kernels", payload)
    # note: on CPU the recurrence can win wall-clock (no MXU to feed); the
    # chunked form trades elementwise work for GEMMs + a 32x shorter serial
    # chain, which is the TPU win (kernels/gla_chunk).
    print(csv_row("kernels_scan_gla", t_full * 1e6,
                  f"gather@1%={gather_rows['0.01']['speedup_vs_full']:.0f}x;"
                  f"gla_serial_chain={T}->{T//32}"))
    return payload


if __name__ == "__main__":
    run()
