"""Shared benchmark fixtures: catalogs, the query suite, timing helpers.

The query suite mirrors the paper's workload mix (Table 3): filtered simple
aggregates (TPC-H Q6 family), grouped multi-aggregates (Q1 family), ratio
composites (Q14 family), PK-FK joins, and DSB-like skewed data — at
CPU-container scale (§DESIGN.md "benchmark scale": speedups are additionally
reported as scan fractions, which are scale-free).
"""

from __future__ import annotations

import dataclasses
import functools
import json
import os
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import CompositeAgg, ErrorSpec, PilotDB, Query, RowSamplingAQP
from repro.engine import logical as L
from repro.engine.datagen import make_lineitem, make_orders, make_skewed
from repro.engine.executor import Executor
from repro.engine.expr import And, Col

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

SCALE_ROWS = int(os.environ.get("BENCH_ROWS", 2_000_000))
BLOCK_ROWS = 32


@functools.lru_cache(maxsize=4)
def catalog(clustered: bool = False):
    n_orders = SCALE_ROWS // 4
    return {
        "lineitem": make_lineitem(SCALE_ROWS, BLOCK_ROWS, num_orders=n_orders,
                                  clustered=clustered, seed=0),
        "orders": make_orders(n_orders, BLOCK_ROWS, seed=1),
        "skewed": make_skewed(SCALE_ROWS // 2, BLOCK_ROWS, num_groups=4, seed=7),
    }


@dataclasses.dataclass
class BenchQuery:
    name: str
    query: Query
    has_join: bool = False
    groups: int = 1


def query_suite() -> List[BenchQuery]:
    q6_pred = And(Col("l_shipdate").between(100, 1500),
                  And(Col("l_discount").between(0.02, 0.08),
                      Col("l_quantity") < 24))
    rev = Col("l_extendedprice") * Col("l_discount")
    return [
        BenchQuery("q6_filtered_sum", Query(
            child=L.Filter(L.Scan("lineitem"), q6_pred),
            aggs=(CompositeAgg("revenue", "sum", rev),))),
        BenchQuery("q1_grouped_multi", Query(
            child=L.Filter(L.Scan("lineitem"), Col("l_shipdate") < 2400),
            aggs=(CompositeAgg("sum_qty", "sum", Col("l_quantity")),
                  CompositeAgg("sum_price", "sum", Col("l_extendedprice")),
                  CompositeAgg("avg_price", "avg", Col("l_extendedprice")),
                  CompositeAgg("cnt", "count")),
            group_by="l_returnflag", max_groups=3), groups=3),
        BenchQuery("q14_ratio", Query(
            child=L.Filter(L.Scan("lineitem"), Col("l_shipdate").between(400, 2200)),
            aggs=(CompositeAgg("promo_share", "ratio",
                               rev * Col("l_linestatus"), expr2=rev),))),
        BenchQuery("join_sum", Query(
            child=L.Filter(L.Join(L.Scan("lineitem"), L.Scan("orders"),
                                  "l_orderkey", "o_orderkey"),
                           Col("o_orderdate") < 1200),
            aggs=(CompositeAgg("rev", "sum", Col("l_extendedprice")),)),
            has_join=True),
        BenchQuery("join_grouped", Query(
            child=L.Join(L.Scan("lineitem"), L.Scan("orders"),
                         "l_orderkey", "o_orderkey"),
            aggs=(CompositeAgg("qty", "sum", Col("l_quantity")),),
            group_by="o_orderpriority", max_groups=5),
            has_join=True, groups=5),
        BenchQuery("skew_agg", Query(
            child=L.Filter(L.Scan("skewed"), Col("s_filter") < 0.6),
            aggs=(CompositeAgg("m", "sum", Col("s_measure")),))),
        BenchQuery("skew_grouped", Query(
            child=L.Scan("skewed"),
            aggs=(CompositeAgg("m", "sum", Col("s_measure")),
                  CompositeAgg("avg_m", "avg", Col("s_measure"))),
            group_by="s_group", max_groups=4), groups=4),
    ]


def make_db(clustered: bool = False) -> PilotDB:
    return PilotDB(Executor(catalog(clustered)), large_table_rows=100_000)


def make_row_db(clustered: bool = False) -> RowSamplingAQP:
    return RowSamplingAQP(Executor(catalog(clustered)), large_table_rows=100_000)


def rel_errors(ans, exact) -> np.ndarray:
    errs = []
    for i in range(len(ans.names)):
        for g in range(ans.values.shape[1]):
            t = exact.values[i, g]
            if exact.group_present[g] and np.isfinite(t) and abs(t) > 1e-9:
                errs.append(abs(ans.values[i, g] - t) / abs(t))
    return np.asarray(errs)


def save_results(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def geomean(xs) -> float:
    xs = np.asarray([x for x in xs if x > 0], dtype=float)
    return float(np.exp(np.log(xs).mean())) if len(xs) else float("nan")


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
