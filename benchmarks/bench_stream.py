"""Progressive-streaming benchmark: time-to-first-frame vs time-to-final.

Workload: a dashboard herd — a block of verbatim re-issues of one template
plus a sliding WHERE constant — submitted as STREAMING queries and drained
through the full concurrent runtime (shared pilots + batched finals).  The
herd shares one pilot stage, so the moment that pilot lands every member
receives its advisory :class:`~repro.stream.PilotFrame`; the guaranteed
:class:`~repro.stream.FinalFrame`\\ s arrive as each batched final bucket
materializes.  The gap between those two is the whole point of streaming —
a dashboard paints a provisional number long before the guarantee.

Contract checks run BEFORE any timing is reported (each raises, so
``run.py --only stream`` exits nonzero on violation):

* every streamed FinalFrame is BITWISE identical to the answer an
  equal-seed NON-streaming session produces for the same SQL — streaming
  may only change observability, never values;
* every member emits exactly one terminal frame, preceded by its advisory
  PilotFrame;
* on the herd drain, ALL PilotFrames are emitted before ANY FinalFrame —
  the shared pilot fans out before the first stage-2 bucket lands.

Reported: median time-to-first-frame vs time-to-final per drain (from
``DrainStats``), their ratio, and frame counts.  Emits the
machine-readable ``BENCH_stream.json`` at the repo root.

  PYTHONPATH=src python -m benchmarks.run --only stream
  BENCH_ROWS=200000 PYTHONPATH=src python -m benchmarks.bench_stream
"""

from __future__ import annotations

import json
import os
import sys

import numpy as np

from benchmarks.common import SCALE_ROWS, catalog, csv_row, save_results
from repro.api import Session, SessionConfig

BENCH_STREAM_PATH = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_stream.json")

HERD_N = int(os.environ.get("BENCH_HERD_N", 12))
REPS = int(os.environ.get("BENCH_STREAM_REPS", 3))  # median-of over drains

# Tight error target => finals scan a real block fraction, so the pilot
# fan-out visibly precedes the stage-2 work it prices.
HERD_SQL = ("SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
            "WHERE l_quantity < {cap} ERROR 5% CONFIDENCE 95%")

# result cache off: every drain (and every rep) re-executes both stages, so
# TTFF/TTF measure the pilot fan-out against real final work, not a replay
CFG = SessionConfig(async_workers=None, share_pilots=True, batch_finals=True,
                    result_cache_size=0, large_table_rows=100_000)


def _workload():
    sqls = [HERD_SQL.format(cap=24)] * (HERD_N // 2)
    sqls += [HERD_SQL.format(cap=18 + 2 * i) for i in range(HERD_N - len(sqls))]
    return sqls


def _reference_answers(tables) -> dict:
    """Equal-seed NON-streaming drain: sql -> answer values (the identity
    oracle; answers are a pure function of session seed + query content)."""
    session = Session(tables, seed=17, config=CFG)
    handles = [session.submit(s) for s in _workload()]
    session.drain()
    out = {}
    for h in handles:
        ans = h.result()
        out.setdefault(h.sql, (np.asarray(ans.values), ans.report.fallback))
    session.close()
    return out


def run() -> dict:
    tables = {k: v for k, v in catalog().items() if k != "skewed"}
    reference = _reference_answers(tables)

    session = Session(tables, seed=17, config=CFG)
    # Warm the jit caches (pilot + every final bucket shape) so the measured
    # drains time the steady-state serving loop, not first-touch XLA.
    for s in dict.fromkeys(_workload()):
        session.sql(s)
    for s in _workload():
        session.submit(s, stream=True)
    session.drain()

    ttffs, ttfs, frame_counts = [], [], []
    pilot_before_final = True
    for _ in range(REPS):
        handles = [session.submit(s, stream=True) for s in _workload()]
        session.drain()
        stats = session.scheduler.last_drain

        # -- contract checks (before any timing is trusted) ----------------
        pilot_emits, final_emits = [], []
        for h in handles:
            frames = h.frames()
            terminals = [f for f in frames if f.terminal]
            assert len(terminals) == 1, \
                f"query {h.query_id}: expected exactly one terminal frame"
            final = terminals[0]
            ref_values, ref_fallback = reference[h.sql]
            # a member the planner sends exact (e.g. "no feasible plan
            # cheaper than exact" at small BENCH_ROWS) must stream an
            # ExactFrame — and the reference must have gone exact too
            want_kind = "exact" if ref_fallback is not None else "final"
            assert final.kind == want_kind, \
                f"query {h.query_id}: terminal kind {final.kind!r}, " \
                f"reference says {want_kind!r}"
            assert np.array_equal(np.asarray(final.answer.values),
                                  ref_values), \
                "streamed FinalFrame must be bitwise identical to the " \
                "non-streaming answer"
            assert final.answer is h.answer, \
                "FinalFrame must carry the very answer object the handle " \
                "delivers"
            pilots = [f for f in frames if f.kind == "pilot"]
            if ref_fallback is None:
                assert pilots and pilots[0].advisory, \
                    f"query {h.query_id}: missing advisory PilotFrame"
            pilot_emits += [f.t_emit for f in pilots]
            final_emits.append(final.t_emit)
        assert pilot_emits, "herd drain produced no advisory PilotFrames"
        if max(pilot_emits) >= min(final_emits):
            pilot_before_final = False

        assert stats.time_to_first_frame_s > 0.0
        assert stats.time_to_first_frame_s < stats.time_to_final_s, \
            "time-to-first-frame must be strictly below time-to-final"
        ttffs.append(stats.time_to_first_frame_s)
        ttfs.append(stats.time_to_final_s)
        frame_counts.append(stats.frames_emitted)

    assert pilot_before_final, \
        "every PilotFrame must be emitted before any FinalFrame on a " \
        "shared-pilot herd drain"
    session.close()

    ttff, ttf = float(np.median(ttffs)), float(np.median(ttfs))
    doc = {"bench": "stream", "rows": SCALE_ROWS, "herd_n": HERD_N,
           "reps": REPS, "cpu_count": os.cpu_count(),
           "time_to_first_frame_s": ttff,
           "time_to_final_s": ttf,
           "first_frame_speedup": ttf / ttff if ttff else float("nan"),
           "frames_per_drain": int(np.median(frame_counts)),
           "bit_identical_to_nonstreaming": True,
           "pilot_frames_precede_finals": pilot_before_final}

    with open(BENCH_STREAM_PATH, "w") as f:
        json.dump(doc, f, indent=1, default=float)
    print(f"# wrote {os.path.normpath(BENCH_STREAM_PATH)}", file=sys.stderr)
    save_results("stream", doc)

    print(csv_row("stream_first_frame", ttff * 1e6,
                  f"ttf={ttf * 1e6:.1f}us;"
                  f"speedup={doc['first_frame_speedup']:.2f}x;"
                  f"frames={doc['frames_per_drain']}"))
    return doc


if __name__ == "__main__":
    run()
