"""Herd benchmark for the concurrent query runtime (repro.runtime).

Workload: a dashboard herd — N structurally identical queries (the many-
users case) plus M distinct queries — pushed through the session scheduler
under four runtime configurations:

  serial       workers=0, sharing off, cache off  (the old drain() loop)
  async        worker pool only
  async+share  + one pilot per signature group
  full         + session result cache (the default configuration)

Reported per configuration: wall time, pilot stages executed, physical
compilations, result-cache hits — and a bit-identity check across all four
(answers are a pure function of session seed and query content; the runtime
may only change wall-clock, never values).  Emits the machine-readable
``BENCH_runtime.json`` at the repo root for trajectory tracking.

  PYTHONPATH=src python -m benchmarks.run --only runtime
  BENCH_ROWS=200000 PYTHONPATH=src python -m benchmarks.bench_runtime
"""

from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from benchmarks.common import SCALE_ROWS, catalog, csv_row, save_results
from repro.api import Session, SessionConfig

BENCH_RUNTIME_PATH = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_runtime.json")

HERD_N = int(os.environ.get("BENCH_HERD_N", 12))
DISTINCT_M = int(os.environ.get("BENCH_DISTINCT_M", 4))

HERD_SQL = ("SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
            "WHERE l_quantity < 24 ERROR 8% CONFIDENCE 95%")
DISTINCT_SQLS = [
    "SELECT SUM(l_quantity) AS q FROM lineitem ERROR 10% CONFIDENCE 90%",
    "SELECT COUNT(*) AS n FROM lineitem WHERE l_shipdate < 2000 "
    "ERROR 10% CONFIDENCE 90%",
    "SELECT AVG(l_extendedprice) AS p FROM lineitem "
    "WHERE l_discount BETWEEN 0.02 AND 0.08 ERROR 10% CONFIDENCE 90%",
    "SELECT SUM(l_extendedprice) AS rev FROM lineitem "
    "WHERE l_shipdate BETWEEN 400 AND 2200 ERROR 10% CONFIDENCE 90%",
]

CONFIGS = {
    "serial": SessionConfig(async_workers=0, share_pilots=False,
                            result_cache_size=0, large_table_rows=100_000),
    "async": SessionConfig(async_workers=4, share_pilots=False,
                           result_cache_size=0, large_table_rows=100_000),
    "async_share": SessionConfig(async_workers=4, share_pilots=True,
                                 result_cache_size=0,
                                 large_table_rows=100_000),
    "full": SessionConfig(async_workers=4, share_pilots=True,
                          result_cache_size=128, large_table_rows=100_000),
}


def _workload():
    sqls = [HERD_SQL] * HERD_N
    for i in range(DISTINCT_M):
        sqls.append(DISTINCT_SQLS[i % len(DISTINCT_SQLS)])
    return sqls


def _run_config(cfg: SessionConfig, tables) -> dict:
    session = Session(tables, seed=17, config=cfg)
    # Warm the jit caches on every unique query first, so the measured
    # window is the steady-state serving loop, not first-touch XLA
    # compilation (identical across configurations; the result cache — when
    # enabled — is warm too, which is exactly its serving-state semantics).
    for s in dict.fromkeys(_workload()):
        session.sql(s)
    ex = session.executor
    info0 = session.compile_cache_info()
    p0, m0, h0 = ex.pilots_run, info0.misses, info0.hits
    rc0 = session.result_cache_info().hits
    handles = [session.submit(s) for s in _workload()]
    t0 = time.perf_counter()
    session.drain()
    wall = time.perf_counter() - t0
    info = session.compile_cache_info()
    out = {
        "wall_s": wall,
        "queries": len(handles),
        "pilots_run": ex.pilots_run - p0,
        "compile_misses": info.misses - m0,
        "compile_hits": info.hits - h0,
        "result_hits": session.result_cache_info().hits - rc0,
        "failed": sum(h.status != "done" for h in handles),
        "values": {h.query_id: np.asarray(h.result().values)
                   for h in handles},
        "sqls": {h.query_id: h.sql for h in handles},
    }
    session.close()
    return out


def run() -> dict:
    tables = {k: v for k, v in catalog().items() if k != "skewed"}
    results = {}
    for name, cfg in CONFIGS.items():
        results[name] = _run_config(cfg, tables)

    # bit-identity across configurations, matched by query content
    base = results["serial"]
    by_sql = {}
    for qid, sql in base["sqls"].items():
        by_sql.setdefault(sql, base["values"][qid])
    identical = True
    for name, res in results.items():
        for qid, sql in res["sqls"].items():
            if not np.array_equal(res["values"][qid], by_sql[sql]):
                identical = False
    for res in results.values():
        res.pop("values"), res.pop("sqls")

    doc = {"bench": "runtime", "rows": SCALE_ROWS,
           "herd_n": HERD_N, "distinct_m": DISTINCT_M,
           "bit_identical_across_configs": identical}
    doc.update({name: res for name, res in results.items()})
    for name in ("async", "async_share", "full"):
        doc[name]["speedup_vs_serial"] = (
            results["serial"]["wall_s"] / results[name]["wall_s"]
            if results[name]["wall_s"] else float("nan"))

    with open(BENCH_RUNTIME_PATH, "w") as f:
        json.dump(doc, f, indent=1, default=float)
    print(f"# wrote {os.path.normpath(BENCH_RUNTIME_PATH)}", file=sys.stderr)
    save_results("runtime", doc)

    n = HERD_N + DISTINCT_M
    for name, res in results.items():
        print(csv_row(
            f"runtime_{name}", res["wall_s"] / n * 1e6,
            f"pilots={res['pilots_run']};misses={res['compile_misses']};"
            f"result_hits={res['result_hits']};"
            f"speedup={doc[name].get('speedup_vs_serial', 1.0):.2f}x"))
    assert identical, "runtime configurations must be bit-identical"
    assert all(res["failed"] == 0 for res in results.values())
    return doc


if __name__ == "__main__":
    run()
