"""Herd benchmark for the concurrent query runtime (repro.runtime).

Workload: a dashboard herd — N queries of one *template* (a block of
identical re-issues plus a sliding WHERE constant, the many-users case) plus
M distinct queries — pushed through the session scheduler under five
runtime configurations:

  serial       workers=0, sharing off, batching off, cache off
  async        auto-sized worker pool only (os.cpu_count()-derived)
  async_share  + one pilot per (signature, pilot-params) subgroup
  batched      + same-bucket finals stacked into one device launch
  full         + session result cache (the default configuration)

Per-query work is scaled so the measured window is device execution (the
part that releases the GIL and can actually overlap), not host-side
planning: the herd uses a tight error target, so finals scan a meaningful
block fraction — at toy scale the async pool is otherwise lock-bound on jit
dispatch and *loses* to the serial loop, which is exactly the regression
the auto-sized pool (never wider than the machine, serial on one core)
guards against.

Reported per configuration: wall time, pilot stages executed, physical
compilations, result-cache hits — and a bit-identity check across ALL
configurations (answers are a pure function of session seed and query
content; the runtime may only change wall-clock, never values — the
``batched`` config's lax.map lanes included).  Emits the machine-readable
``BENCH_runtime.json`` at the repo root for trajectory tracking.

  PYTHONPATH=src python -m benchmarks.run --only runtime
  BENCH_ROWS=200000 PYTHONPATH=src python -m benchmarks.bench_runtime
"""

from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from benchmarks.common import SCALE_ROWS, catalog, csv_row, save_results
from repro.api import Session, SessionConfig

BENCH_RUNTIME_PATH = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_runtime.json")

HERD_N = int(os.environ.get("BENCH_HERD_N", 12))
DISTINCT_M = int(os.environ.get("BENCH_DISTINCT_M", 4))
REPS = int(os.environ.get("BENCH_RUNTIME_REPS", 3))  # median-of over drains

# Tight error targets => the final stage scans a real block fraction: the
# measured window is device work, which is what async/batched can win on.
HERD_SQL = ("SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
            "WHERE l_quantity < {cap} ERROR 5% CONFIDENCE 95%")
DISTINCT_SQLS = [
    "SELECT SUM(l_quantity) AS q FROM lineitem ERROR 6% CONFIDENCE 90%",
    "SELECT COUNT(*) AS n FROM lineitem WHERE l_shipdate < 2000 "
    "ERROR 6% CONFIDENCE 90%",
    "SELECT AVG(l_extendedprice) AS p FROM lineitem "
    "WHERE l_discount BETWEEN 0.02 AND 0.08 ERROR 6% CONFIDENCE 90%",
    "SELECT SUM(l_extendedprice) AS rev FROM lineitem "
    "WHERE l_shipdate BETWEEN 400 AND 2200 ERROR 6% CONFIDENCE 90%",
]

_COMMON = dict(result_cache_size=0, large_table_rows=100_000)
CONFIGS = {
    "serial": SessionConfig(async_workers=0, share_pilots=False,
                            batch_finals=False, **_COMMON),
    "async": SessionConfig(async_workers=None, share_pilots=False,
                           batch_finals=False, **_COMMON),
    "async_share": SessionConfig(async_workers=None, share_pilots=True,
                                 batch_finals=False, **_COMMON),
    "batched": SessionConfig(async_workers=None, share_pilots=True,
                             batch_finals=True, **_COMMON),
    "full": SessionConfig(async_workers=None, share_pilots=True,
                          batch_finals=True, result_cache_size=128,
                          large_table_rows=100_000),
}


def _workload():
    # half the herd re-issues one dashboard verbatim, half slides its WHERE
    # constant — one template group either way (constants are hoisted), but
    # only the verbatim block may share pilots/result-cache entries
    sqls = [HERD_SQL.format(cap=24)] * (HERD_N // 2)
    sqls += [HERD_SQL.format(cap=18 + 2 * i) for i in range(HERD_N - len(sqls))]
    for i in range(DISTINCT_M):
        sqls.append(DISTINCT_SQLS[i % len(DISTINCT_SQLS)])
    return sqls


def _run_config(cfg: SessionConfig, tables) -> dict:
    session = Session(tables, seed=17, config=cfg)
    # Warm the jit caches first — every unique query solo, then one full
    # drain (which also compiles the config's batch executables) — so the
    # measured window is the steady-state serving loop, not first-touch XLA
    # compilation (identical across configurations; the result cache — when
    # enabled — is warm too, which is exactly its serving-state semantics).
    for s in dict.fromkeys(_workload()):
        session.sql(s)
    for s in _workload():
        session.submit(s)
    session.drain()
    ex = session.executor
    walls = []
    for rep in range(REPS):  # median-of-REPS: 2-core hosts are noisy
        if rep == REPS - 1:  # counters are attributed to the last drain
            info0 = session.compile_cache_info()
            p0, m0, h0 = ex.pilots_run, info0.misses, info0.hits
            rc0 = session.result_cache_info().hits
        handles = [session.submit(s) for s in _workload()]
        t0 = time.perf_counter()
        session.drain()
        walls.append(time.perf_counter() - t0)
    wall = float(np.median(walls))
    info = session.compile_cache_info()
    out = {
        "wall_s": wall,
        # the ACTUAL pool widths the drains ran on (the runtime auto-sizes
        # both), not the config knob — which is 0/None for "auto"
        "workers": session.runtime.workers,
        "pilot_workers": session.runtime.pilot_workers,
        "queries": len(handles),
        "pilots_run": ex.pilots_run - p0,
        "compile_misses": info.misses - m0,
        "compile_hits": info.hits - h0,
        "result_hits": session.result_cache_info().hits - rc0,
        "failed": sum(h.status != "done" for h in handles),
        "values": {h.query_id: np.asarray(h.result().values)
                   for h in handles},
        "sqls": {h.query_id: h.sql for h in handles},
    }
    session.close()
    return out


def _measure_final_dispatch(tables, n: int = 8, reps: int = 7, *,
                            kernel_mode: str = "auto",
                            rate: float = 0.07) -> dict:
    """The batching headline, isolated: n warmed constant-varied finals as n
    solo dispatches vs one chunked batch launch (bit-identity asserted).

    ``kernel_mode="pallas"`` times the same shape through the Pallas route
    (solo filtered_agg kernels vs the megacore-style batched grid); off-TPU
    that runs in interpret mode, so its absolute numbers are structural, not
    production — the bit-identity assert is the load-bearing part there.
    """
    import jax

    from repro.engine import logical as L
    from repro.engine.executor import Executor
    from repro.engine.expr import And, Col

    ex = Executor(tables, kernel_mode=kernel_mode)

    def final(i):
        pred = And(Col("l_shipdate").between(100, 1500),
                   Col("l_quantity") < 18 + i)
        plan = L.Aggregate(
            child=L.Filter(L.Scan("lineitem"), pred),
            aggs=(L.AggSpec("sum",
                            Col("l_extendedprice") * Col("l_discount"), "rev"),
                  L.AggSpec("count", None, "cnt")))
        return L.rewrite_scans(
            plan, {"lineitem": L.SampleClause("block", rate, seed=i)})

    plans = [final(i) for i in range(n)]
    solo_ref = [ex.execute(p) for p in plans]          # warm + reference
    for out, ref in zip(ex.execute_batch(plans), solo_ref):  # warm batch
        assert np.array_equal(out.values, ref.values), \
            "batched lanes must be bit-identical to solo dispatches"
    solo_t, batch_t = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        for p in plans:
            ex.execute(p)
        solo_t.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        ex.execute_batch(plans)
        batch_t.append(time.perf_counter() - t0)
    solo_s, batch_s = float(np.median(solo_t)), float(np.median(batch_t))
    return {"n_finals": n, "solo_s": solo_s, "batched_s": batch_s,
            "dispatch_speedup": solo_s / batch_s if batch_s else float("nan"),
            "bit_identical": True, "kernel_mode": kernel_mode,
            "interpret": jax.default_backend() != "tpu",
            "routes": sorted({c.route
                              for c in ex.physical._cache.values()})}


def run() -> dict:
    tables = {k: v for k, v in catalog().items() if k != "skewed"}
    results = {}
    for name, cfg in CONFIGS.items():
        results[name] = _run_config(cfg, tables)

    # bit-identity across configurations, matched by query content
    base = results["serial"]
    by_sql = {}
    for qid, sql in base["sqls"].items():
        by_sql.setdefault(sql, base["values"][qid])
    identical = True
    for name, res in results.items():
        for qid, sql in res["sqls"].items():
            if not np.array_equal(res["values"][qid], by_sql[sql]):
                identical = False
    for res in results.values():
        res.pop("values"), res.pop("sqls")

    doc = {"bench": "runtime", "rows": SCALE_ROWS,
           "herd_n": HERD_N, "distinct_m": DISTINCT_M,
           "cpu_count": os.cpu_count(),
           "bit_identical_across_configs": identical,
           "final_dispatch": _measure_final_dispatch(tables),
           # the same micro-shape through the Pallas kernel route: solo
           # filtered_agg launches vs one batched grid.  Interpret mode
           # off-TPU => small n / low rate to bound the wall clock; the
           # bit-identity assert inside is the contract being smoked.
           "final_dispatch_kernel": _measure_final_dispatch(
               tables, n=4, reps=3, kernel_mode="pallas", rate=0.02)}
    doc.update({name: res for name, res in results.items()})
    for name in ("async", "async_share", "batched", "full"):
        doc[name]["speedup_vs_serial"] = (
            results["serial"]["wall_s"] / results[name]["wall_s"]
            if results[name]["wall_s"] else float("nan"))

    with open(BENCH_RUNTIME_PATH, "w") as f:
        json.dump(doc, f, indent=1, default=float)
    print(f"# wrote {os.path.normpath(BENCH_RUNTIME_PATH)}", file=sys.stderr)
    save_results("runtime", doc)

    n = HERD_N + DISTINCT_M
    for name, res in results.items():
        print(csv_row(
            f"runtime_{name}", res["wall_s"] / n * 1e6,
            f"pilots={res['pilots_run']};misses={res['compile_misses']};"
            f"result_hits={res['result_hits']};"
            f"speedup={doc[name].get('speedup_vs_serial', 1.0):.2f}x"))
    for key in ("final_dispatch", "final_dispatch_kernel"):
        fd = doc[key]
        print(csv_row(f"runtime_{key}",
                      fd["batched_s"] / fd["n_finals"] * 1e6,
                      f"n={fd['n_finals']};mode={fd['kernel_mode']};"
                      f"dispatch_speedup={fd['dispatch_speedup']:.2f}x"))
    assert identical, "runtime configurations must be bit-identical"
    assert all(res["failed"] == 0 for res in results.values())
    return doc


if __name__ == "__main__":
    run()
