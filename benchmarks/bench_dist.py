"""Shard-parallel distributed execution benchmark (repro.dist).

For 1/2/4-shard registrations of the fact table, measures

* the raw sampled SCAN+aggregate dispatch (one dispatch per shard, merged
  per-block statistics),
* the PILOT stage (per-shard pilot dispatches, merged block statistics),
* a full serving drain: a constant-varied dashboard herd (one pilot
  subgroup per constant, fanned out concurrently on the runtime's pilot
  pool) plus verbatim re-issues (shared pilot) and a cache re-issue —

and asserts the dist layer's contracts hard (the CI smoke gate):

* every answer is BIT-IDENTICAL across shard counts (sampled finals,
  shared pilots, cached results),
* per-shard scanned-bytes attribution sums to the single-shard total,
* the multi-shard drain executed its pilot subgroups CONCURRENTLY:
  pilot wall-clock < the serial sum of the per-subgroup stage times
  (the previously-serialized per-constant pilot stages of one template
  group).

Emits the machine-readable ``BENCH_dist.json`` at the repo root.

  BENCH_ROWS=200000 PYTHONPATH=src python -m benchmarks.run --only dist
"""

from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from benchmarks.common import SCALE_ROWS, catalog, csv_row, save_results
from repro.api import Session, SessionConfig
from repro.engine import logical as L
from repro.engine.expr import And, Col

BENCH_DIST_PATH = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_dist.json")

SHARD_COUNTS = (1, 2, 4)
HERD_K = int(os.environ.get("BENCH_DIST_HERD_K", 4))   # constant-varied pilots
REPS = int(os.environ.get("BENCH_DIST_REPS", 3))

HERD_SQL = ("SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
            "WHERE l_quantity < {cap} ERROR 5% CONFIDENCE 95%")
EXTRA_SQLS = [
    "SELECT COUNT(*) AS n, AVG(l_quantity) AS aq FROM lineitem "
    "GROUP BY l_returnflag ERROR 6% CONFIDENCE 90%",
    "SELECT SUM(l_extendedprice) AS rev FROM lineitem "
    "JOIN orders ON l_orderkey = o_orderkey WHERE o_orderdate < 1200 "
    "ERROR 8% CONFIDENCE 90%",
]


def _workload():
    sqls = [HERD_SQL.format(cap=24)] * 3                       # verbatim herd
    sqls += [HERD_SQL.format(cap=18 + 2 * i) for i in range(HERD_K - 1)]
    sqls += EXTRA_SQLS
    return sqls


def _scan_plan(seed, rate=0.1):
    pred = And(Col("l_shipdate").between(100, 1500), Col("l_quantity") < 24)
    plan = L.Aggregate(
        child=L.Filter(L.Scan("lineitem"), pred),
        aggs=(L.AggSpec("sum", Col("l_extendedprice") * Col("l_discount"),
                        "rev"),
              L.AggSpec("count", None, "cnt")))
    return L.rewrite_scans(plan,
                           {"lineitem": L.SampleClause("block", rate, seed)})


def _median_time(fn, reps=REPS):
    fn()  # warm (compiles)
    walls = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        walls.append(time.perf_counter() - t0)
    return float(np.median(walls))


def _run_shards(tables, n_shards: int) -> dict:
    # measurement session: result cache OFF so every measured drain really
    # executes its pilot subgroups (the fan-out under test) and finals
    session = Session(seed=29,
                      config=SessionConfig(large_table_rows=100_000,
                                           result_cache_size=0))
    session.register_table("orders", tables["orders"])
    session.register_table("lineitem", tables["lineitem"], shards=n_shards)
    ex = session.executor

    # raw dispatch timings: sampled scan+aggregate, and the pilot stage
    scan_s = _median_time(lambda: ex.execute(_scan_plan(seed=41)))
    pilot_plan = L.strip_samples(_scan_plan(0))
    pilot_s = _median_time(
        lambda: ex.execute_pilot(pilot_plan, "lineitem", 0.02, 43))
    scan_res = ex.execute(_scan_plan(seed=41))

    # serving drain: warm every unique query's compilations, then measure
    for s in dict.fromkeys(_workload()):
        session.sql(s)
    fan = []
    walls = []
    for _ in range(REPS):
        handles = [session.submit(s) for s in _workload()]
        t0 = time.perf_counter()
        session.drain()
        walls.append(time.perf_counter() - t0)
        d = session.scheduler.last_drain
        if d.pilot_fanouts:
            fan.append((d.pilot_fanout_wall_s, d.pilot_fanout_serial_s))
    shard_bytes = ex.shard_scan_info()["lineitem"]
    values = {h.query_id: np.asarray(h.result().values) for h in handles}
    failed = sum(h.status != "done" for h in handles)
    pilots_run = ex.pilots_run
    session.close()

    # cache-contract session (cache ON): an identical re-issue answers from
    # the result cache, bit-identically at every shard count
    cached_session = Session(seed=29,
                             config=SessionConfig(large_table_rows=100_000))
    cached_session.register_table("orders", tables["orders"])
    cached_session.register_table("lineitem", tables["lineitem"],
                                  shards=n_shards)
    for s in _workload():
        cached_session.submit(s)
    cached_session.drain()
    reissue = cached_session.submit(_workload()[0])
    cached_session.drain()
    reissue_values = np.asarray(reissue.result().values)
    reissue_cached = reissue.cached
    cached_session.close()

    best = int(np.argmin([w for w, _ in fan])) if fan else -1
    return {
        "shards": n_shards,
        "scan_dispatch_s": scan_s,
        "pilot_dispatch_s": pilot_s,
        "drain_wall_s": float(np.median(walls)),
        "pilots_run": pilots_run,
        "queries": len(handles),
        "failed": failed,
        "reissue_cached": reissue_cached,
        "shard_scanned_bytes": list(shard_bytes),
        "scan_scanned_bytes": scan_res.scanned_bytes,
        "pilot_fanout_wall_s": fan[best][0] if fan else None,
        "pilot_fanout_serial_s": fan[best][1] if fan else None,
        "pilot_workers": session.config.resolve_pilot_workers(),
        "values": values,
        "reissue_values": reissue_values,
    }


def run() -> dict:
    tables = {k: v for k, v in catalog().items() if k != "skewed"}
    results = {n: _run_shards(tables, n) for n in SHARD_COUNTS}

    # contract 1: bit-identity across shard counts, cached re-issue included
    base = results[SHARD_COUNTS[0]]
    identical = True
    for n in SHARD_COUNTS[1:]:
        for qid, v in results[n]["values"].items():
            if not np.array_equal(v, base["values"][qid]):
                identical = False
        if not np.array_equal(results[n]["reissue_values"],
                              base["reissue_values"]):
            identical = False
    for res in results.values():
        res.pop("values"), res.pop("reissue_values")

    # contract 2: per-shard attribution sums to the single-shard total
    attribution_ok = all(
        sum(results[n]["shard_scanned_bytes"])
        == sum(base["shard_scanned_bytes"]) for n in SHARD_COUNTS)

    # contract 3: the multi-shard drain fanned its pilot subgroups out
    # concurrently — wall < serial sum of the per-subgroup stages
    multi = results[SHARD_COUNTS[-1]]
    fan_wall, fan_serial = (multi["pilot_fanout_wall_s"],
                            multi["pilot_fanout_serial_s"])
    concurrent = (fan_wall is not None and fan_serial is not None
                  and fan_wall < fan_serial)

    doc = {"bench": "dist", "rows": SCALE_ROWS, "herd_k": HERD_K,
           "cpu_count": os.cpu_count(),
           "bit_identical_across_shards": identical,
           "shard_bytes_attribution_ok": attribution_ok,
           "pilot_subgroups_concurrent": concurrent,
           "pilot_fanout_speedup": (fan_serial / fan_wall
                                    if concurrent else None)}
    for n in SHARD_COUNTS:
        doc[f"shards_{n}"] = results[n]

    with open(BENCH_DIST_PATH, "w") as f:
        json.dump(doc, f, indent=1, default=float)
    print(f"# wrote {os.path.normpath(BENCH_DIST_PATH)}", file=sys.stderr)
    save_results("dist", doc)

    for n in SHARD_COUNTS:
        res = results[n]
        print(csv_row(
            f"dist_{n}shard", res["scan_dispatch_s"] * 1e6,
            f"pilot_us={res['pilot_dispatch_s'] * 1e6:.0f};"
            f"drain_s={res['drain_wall_s']:.3f};"
            f"pilots={res['pilots_run']}"))
    print(csv_row(
        "dist_pilot_fanout",
        (fan_wall or 0.0) * 1e6,
        f"serial_us={(fan_serial or 0.0) * 1e6:.0f};"
        f"concurrent={concurrent}"))

    assert identical, "dist answers must be bit-identical across shard counts"
    assert attribution_ok, \
        "per-shard scanned bytes must sum to the single-shard total"
    assert all(res["failed"] == 0 for res in results.values())
    assert all(res["reissue_cached"] for res in results.values())
    if (os.cpu_count() or 1) >= 2 and multi["pilot_workers"] >= 2:
        assert concurrent, (
            "multi-shard drain must fan pilot subgroups out concurrently "
            f"(wall {fan_wall}s vs serial {fan_serial}s)")
    return doc


if __name__ == "__main__":
    run()
