"""Observability benchmark: tracing overhead, bit-identity, audit honesty.

Contract checks run BEFORE any timing is reported (each raises, so
``run.py --only obs`` exits nonzero on violation):

* every answer of a traced warm herd drain is BITWISE identical to the
  equal-seed untraced session's answer — tracing observes, never steers;
* every traced query ends with a CLOSED span tree covering the full
  lifecycle (pilot → rate_solve → final → deliver, or exact);
* audit mode records observed <= promised error for the whole seeded
  workload (zero violations) without perturbing a single answer.

Continuous-telemetry section (same pre-timing contract discipline):

* a warm herd drain with FULL telemetry on — per-template time-series,
  flight recorder, ``trace_sample=0.05`` — is bitwise identical to the
  plain session AND its overhead stays below the same budget;
* an injected absurd SLO target round-trips: breach counter, recorder
  ``slo_breach`` event, and a breached ``SloMonitor.report()`` row;
* trace-sampling decisions are identical across equal-seed sessions.

Reported: warm herd drain wall time with tracing OFF vs ON and the
relative overhead — asserted below ``BENCH_OBS_MAX_OVERHEAD`` (default
5%).  Emits the machine-readable ``BENCH_obs.json`` at the repo root plus
three workflow artifacts: one sample Chrome trace
(``BENCH_obs_trace.json``, loadable in ``chrome://tracing`` / Perfetto),
the rendered ops dashboard (``BENCH_obs_dashboard.html``), and the
telemetry run's flight-recorder log (``BENCH_obs_flightrec.jsonl``).

  PYTHONPATH=src python -m benchmarks.run --only obs
  BENCH_ROWS=200000 PYTHONPATH=src python -m benchmarks.bench_obs
"""

from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from benchmarks.common import SCALE_ROWS, catalog, csv_row, save_results
from repro.api import Session, SessionConfig

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
BENCH_OBS_PATH = os.path.join(_ROOT, "BENCH_obs.json")
SAMPLE_TRACE_PATH = os.path.join(_ROOT, "BENCH_obs_trace.json")
DASHBOARD_PATH = os.path.join(_ROOT, "BENCH_obs_dashboard.html")
FLIGHTREC_PATH = os.path.join(_ROOT, "BENCH_obs_flightrec.jsonl")

HERD_N = int(os.environ.get("BENCH_HERD_N", 12))
REPS = int(os.environ.get("BENCH_OBS_REPS", 9))  # best-of interleaved drains
MAX_OVERHEAD = float(os.environ.get("BENCH_OBS_MAX_OVERHEAD", 0.05))

HERD_SQL = ("SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
            "WHERE l_quantity < {cap} ERROR 5% CONFIDENCE 95%")

# result cache off: every measured drain re-executes both stages, so the
# overhead figure prices the instrumented hot path, not a cache replay
CFG = SessionConfig(async_workers=None, share_pilots=True, batch_finals=True,
                    result_cache_size=0, large_table_rows=100_000)
TRACE_CFG = SessionConfig(async_workers=None, share_pilots=True,
                          batch_finals=True, result_cache_size=0,
                          large_table_rows=100_000, tracing=True)
AUDIT_CFG = SessionConfig(async_workers=0, share_pilots=False,
                          result_cache_size=0, large_table_rows=100_000,
                          tracing=True, audit=True)
# full continuous telemetry: time-series + flight recorder + 5% sampled
# tracing — the always-on serving posture the overhead budget prices
TELEMETRY_CFG = SessionConfig(async_workers=None, share_pilots=True,
                              batch_finals=True, result_cache_size=0,
                              large_table_rows=100_000, telemetry=True,
                              trace_sample=0.05,
                              flight_recorder=FLIGHTREC_PATH)


def _workload():
    sqls = [HERD_SQL.format(cap=24)] * (HERD_N // 2)
    sqls += [HERD_SQL.format(cap=18 + 2 * i) for i in range(HERD_N - len(sqls))]
    return sqls


def _warm_session(cfg) -> Session:
    tables = {k: v for k, v in catalog().items() if k != "skewed"}
    session = Session(tables, seed=17, config=cfg)
    # warm the jit caches (pilot + every final bucket shape) so measured
    # drains time the steady-state serving loop, not first-touch XLA
    for s in dict.fromkeys(_workload()):
        session.sql(s)
    return session


def _timed_drains_interleaved(sessions: dict) -> tuple:
    """Best warm-drain wall time per session over REPS INTERLEAVED rounds
    (round-robin across the sessions, min per session): back-to-back
    medians confound the comparison with thermal/background drift on a
    busy host — interleaving exposes every session to the same drift, and
    the min is the standard noise-robust point estimate for a
    deterministic workload.  Returns ({name: best_s}, {name: last-rep
    handles})."""
    walls = {k: [] for k in sessions}
    handles = {}
    for _ in range(REPS):
        for k, session in sessions.items():
            hs = [session.submit(s) for s in _workload()]
            t0 = time.perf_counter()
            session.drain()
            walls[k].append(time.perf_counter() - t0)
            handles[k] = hs
    return {k: float(np.min(v)) for k, v in walls.items()}, handles


def run() -> dict:
    for p in (FLIGHTREC_PATH, f"{FLIGHTREC_PATH}.1", f"{FLIGHTREC_PATH}.2"):
        if os.path.exists(p):
            os.remove(p)
    plain = _warm_session(CFG)
    traced = _warm_session(TRACE_CFG)
    telemetry = _warm_session(TELEMETRY_CFG)

    best, reps_handles = _timed_drains_interleaved(
        {"off": plain, "on": traced, "telemetry": telemetry})
    off_s, on_s, tele_s = best["off"], best["on"], best["telemetry"]
    off_handles = reps_handles["off"]
    on_handles = reps_handles["on"]
    tele_handles = reps_handles["telemetry"]

    # -- contract checks (before any timing is trusted) --------------------
    for hp, ht in zip(off_handles, on_handles):
        ap, at = hp.result(), ht.result()
        assert np.array_equal(np.asarray(ap.values), np.asarray(at.values)), \
            "traced answers must be bitwise identical to untraced ones"
        assert np.array_equal(np.asarray(ap.group_present),
                              np.asarray(at.group_present))
        tr = ht._trace
        assert tr is not None and tr.finished and tr.open_spans() == [], \
            f"query {ht.query_id}: span tree not closed"
        names = set(tr.span_names())
        want = {"final", "deliver"} if at.report.fallback is None \
            else {"exact"}
        assert want <= names or at.report.fallback is not None, \
            f"query {ht.query_id}: lifecycle spans missing ({names})"
    assert all(h._trace is None for h in off_handles), \
        "tracing OFF must carry no trace objects"

    overhead = (on_s - off_s) / off_s if off_s > 0 else 0.0
    assert overhead < MAX_OVERHEAD, \
        f"tracing-ON overhead {overhead:.1%} exceeds the " \
        f"{MAX_OVERHEAD:.0%} budget (off={off_s * 1e3:.2f}ms " \
        f"on={on_s * 1e3:.2f}ms)"

    # one sample Chrome trace for the workflow artifact: the first traced
    # member that genuinely sampled (pilot + final spans)
    sampled = [h for h in on_handles
               if h.answer is not None and h.report.fallback is None]
    sample = (sampled or on_handles)[0]
    with open(SAMPLE_TRACE_PATH, "w") as f:
        json.dump(sample.trace("chrome"), f, indent=1)
    print(f"# wrote {os.path.normpath(SAMPLE_TRACE_PATH)}", file=sys.stderr)

    # -- audit mode: runtime Figure-9 check over the seeded workload -------
    audit_session = _warm_session(AUDIT_CFG)
    for s in dict.fromkeys(_workload()):
        audit_session.sql(s)
    summary = audit_session.auditor.summary()
    assert summary["violations"] == 0, \
        f"audit recorded guarantee violations: {summary}"
    assert summary["errors"] == 0
    assert summary["audited"] > 0 or summary["skipped_exact"] > 0
    assert summary["max_error_ratio"] <= 1.0 or summary["audited"] == 0

    # -- continuous telemetry: bit-identity, overhead, SLO round-trip ------
    from repro.obs.events import replay
    from repro.obs.slo import SloTarget
    from repro.serve.dashboard import write_dashboard

    # bit-identity BEFORE timing is trusted: full telemetry ON must match
    # the plain session's answers exactly
    for hp, ht in zip(off_handles, tele_handles):
        ap, at = hp.result(), ht.result()
        assert np.array_equal(np.asarray(ap.values), np.asarray(at.values)), \
            "telemetry-ON answers must be bitwise identical to OFF"
        assert np.array_equal(np.asarray(ap.group_present),
                              np.asarray(at.group_present))
    tele_overhead = (tele_s - off_s) / off_s if off_s > 0 else 0.0
    assert tele_overhead < MAX_OVERHEAD, \
        f"telemetry-ON overhead {tele_overhead:.1%} exceeds the " \
        f"{MAX_OVERHEAD:.0%} budget (off={off_s * 1e3:.2f}ms " \
        f"on={tele_s * 1e3:.2f}ms)"

    # sampling determinism: an equal-seed session makes the IDENTICAL
    # trace-sampling decision for every workload query
    twin = _warm_session(TELEMETRY_CFG)
    decisions = [telemetry._trace_sampled(h.signature)
                 for h in tele_handles]
    twin_handles = [twin.submit(s) for s in _workload()]
    twin.drain()
    twin_decisions = [h._trace_sampled for h in twin_handles]
    assert decisions == twin_decisions, \
        "equal-seed sessions must sample the identical query set"
    twin.close()

    # SLO round-trip: an absurd injected target breaches on the very next
    # delivery — counter, recorder event, and report row all see it
    telemetry.slo.set_target(SloTarget(p95_latency_s=1e-9))
    for s in _workload():
        telemetry.submit(s)
    telemetry.drain()
    n_breaches = telemetry.metrics.counter(
        "pilotdb_slo_breaches_total").value
    assert n_breaches >= 1, "injected SLO target did not breach"
    slo_rows = telemetry.slo.report()
    assert any(r["breached"] and r["metric"] == "p95_latency_s"
               for r in slo_rows), "breach missing from slo report"

    # time-series landed every delivery; the recorder logged the breach
    ts_snap = telemetry.timeseries.snapshot()
    total_deliveries = sum(t["deliveries"]
                           for t in ts_snap["templates"].values())
    assert total_deliveries >= (REPS + 1) * HERD_N
    rec_stats = telemetry.recorder.stats()
    assert rec_stats["emitted"] > 0 and rec_stats["dropped"] == 0
    events = list(replay(FLIGHTREC_PATH))
    assert any(e["ev"] == "slo_breach" for e in events), \
        "slo_breach event missing from the flight recorder"
    assert any(e["ev"] == "deliver" for e in events)

    # workflow artifacts: the rendered ops dashboard + the recorder log
    assert write_dashboard(DASHBOARD_PATH, telemetry,
                           title="bench_obs telemetry run") is not None
    print(f"# wrote {os.path.normpath(DASHBOARD_PATH)}", file=sys.stderr)
    print(f"# wrote {os.path.normpath(FLIGHTREC_PATH)}", file=sys.stderr)

    telemetry.close()
    plain.close()
    traced.close()
    audit_session.close()

    doc = {"bench": "obs", "rows": SCALE_ROWS, "herd_n": HERD_N,
           "reps": REPS, "cpu_count": os.cpu_count(),
           "drain_off_s": off_s,
           "drain_on_s": on_s,
           "tracing_overhead": overhead,
           "max_overhead_budget": MAX_OVERHEAD,
           "bit_identical_on_vs_off": True,
           "span_trees_closed": True,
           "audit": {k: summary[k] for k in
                     ("runs", "audited", "skipped_exact", "violations",
                      "errors", "max_error_ratio", "mean_error_ratio")},
           "telemetry": {
               "drain_on_s": tele_s,
               "overhead": tele_overhead,
               "max_overhead_budget": MAX_OVERHEAD,
               "bit_identical_on_vs_off": True,
               "sampling_deterministic": True,
               "trace_sample": TELEMETRY_CFG.trace_sample,
               "deliveries_recorded": total_deliveries,
               "templates_tracked": len(ts_snap["templates"]),
               "slo_breaches": n_breaches,
               "slo_round_trip": True,
               "flight_recorder": {k: rec_stats[k] for k in
                                   ("emitted", "dropped", "rotations")},
           }}

    with open(BENCH_OBS_PATH, "w") as f:
        json.dump(doc, f, indent=1, default=float)
    print(f"# wrote {os.path.normpath(BENCH_OBS_PATH)}", file=sys.stderr)
    save_results("obs", doc)

    print(csv_row("obs_tracing_overhead", on_s * 1e6,
                  f"off={off_s * 1e6:.1f}us;overhead={overhead:.2%};"
                  f"audit_max_ratio={summary['max_error_ratio']:.3f}"))
    print(csv_row("obs_telemetry_overhead", tele_s * 1e6,
                  f"off={off_s * 1e6:.1f}us;overhead={tele_overhead:.2%};"
                  f"deliveries={total_deliveries};breaches={n_breaches:g}"))
    return doc


if __name__ == "__main__":
    run()
