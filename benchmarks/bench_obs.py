"""Observability benchmark: tracing overhead, bit-identity, audit honesty.

Contract checks run BEFORE any timing is reported (each raises, so
``run.py --only obs`` exits nonzero on violation):

* every answer of a traced warm herd drain is BITWISE identical to the
  equal-seed untraced session's answer — tracing observes, never steers;
* every traced query ends with a CLOSED span tree covering the full
  lifecycle (pilot → rate_solve → final → deliver, or exact);
* audit mode records observed <= promised error for the whole seeded
  workload (zero violations) without perturbing a single answer.

Reported: warm herd drain wall time with tracing OFF vs ON and the
relative overhead — asserted below ``BENCH_OBS_MAX_OVERHEAD`` (default
5%).  Emits the machine-readable ``BENCH_obs.json`` at the repo root plus
one sample Chrome trace (``BENCH_obs_trace.json``, loadable in
``chrome://tracing`` / Perfetto) as a workflow artifact.

  PYTHONPATH=src python -m benchmarks.run --only obs
  BENCH_ROWS=200000 PYTHONPATH=src python -m benchmarks.bench_obs
"""

from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from benchmarks.common import SCALE_ROWS, catalog, csv_row, save_results
from repro.api import Session, SessionConfig

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
BENCH_OBS_PATH = os.path.join(_ROOT, "BENCH_obs.json")
SAMPLE_TRACE_PATH = os.path.join(_ROOT, "BENCH_obs_trace.json")

HERD_N = int(os.environ.get("BENCH_HERD_N", 12))
REPS = int(os.environ.get("BENCH_OBS_REPS", 5))  # median-of over drains
MAX_OVERHEAD = float(os.environ.get("BENCH_OBS_MAX_OVERHEAD", 0.05))

HERD_SQL = ("SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
            "WHERE l_quantity < {cap} ERROR 5% CONFIDENCE 95%")

# result cache off: every measured drain re-executes both stages, so the
# overhead figure prices the instrumented hot path, not a cache replay
CFG = SessionConfig(async_workers=None, share_pilots=True, batch_finals=True,
                    result_cache_size=0, large_table_rows=100_000)
TRACE_CFG = SessionConfig(async_workers=None, share_pilots=True,
                          batch_finals=True, result_cache_size=0,
                          large_table_rows=100_000, tracing=True)
AUDIT_CFG = SessionConfig(async_workers=0, share_pilots=False,
                          result_cache_size=0, large_table_rows=100_000,
                          tracing=True, audit=True)


def _workload():
    sqls = [HERD_SQL.format(cap=24)] * (HERD_N // 2)
    sqls += [HERD_SQL.format(cap=18 + 2 * i) for i in range(HERD_N - len(sqls))]
    return sqls


def _warm_session(cfg) -> Session:
    tables = {k: v for k, v in catalog().items() if k != "skewed"}
    session = Session(tables, seed=17, config=cfg)
    # warm the jit caches (pilot + every final bucket shape) so measured
    # drains time the steady-state serving loop, not first-touch XLA
    for s in dict.fromkeys(_workload()):
        session.sql(s)
    return session


def _timed_drains(session) -> tuple:
    """Median warm-drain wall time over REPS; returns (median_s, handles of
    the last rep)."""
    walls, handles = [], []
    for _ in range(REPS):
        handles = [session.submit(s) for s in _workload()]
        t0 = time.perf_counter()
        session.drain()
        walls.append(time.perf_counter() - t0)
    return float(np.median(walls)), handles


def run() -> dict:
    plain = _warm_session(CFG)
    traced = _warm_session(TRACE_CFG)

    off_s, off_handles = _timed_drains(plain)
    on_s, on_handles = _timed_drains(traced)

    # -- contract checks (before any timing is trusted) --------------------
    for hp, ht in zip(off_handles, on_handles):
        ap, at = hp.result(), ht.result()
        assert np.array_equal(np.asarray(ap.values), np.asarray(at.values)), \
            "traced answers must be bitwise identical to untraced ones"
        assert np.array_equal(np.asarray(ap.group_present),
                              np.asarray(at.group_present))
        tr = ht._trace
        assert tr is not None and tr.finished and tr.open_spans() == [], \
            f"query {ht.query_id}: span tree not closed"
        names = set(tr.span_names())
        want = {"final", "deliver"} if at.report.fallback is None \
            else {"exact"}
        assert want <= names or at.report.fallback is not None, \
            f"query {ht.query_id}: lifecycle spans missing ({names})"
    assert all(h._trace is None for h in off_handles), \
        "tracing OFF must carry no trace objects"

    overhead = (on_s - off_s) / off_s if off_s > 0 else 0.0
    assert overhead < MAX_OVERHEAD, \
        f"tracing-ON overhead {overhead:.1%} exceeds the " \
        f"{MAX_OVERHEAD:.0%} budget (off={off_s * 1e3:.2f}ms " \
        f"on={on_s * 1e3:.2f}ms)"

    # one sample Chrome trace for the workflow artifact: the first traced
    # member that genuinely sampled (pilot + final spans)
    sampled = [h for h in on_handles
               if h.answer is not None and h.report.fallback is None]
    sample = (sampled or on_handles)[0]
    with open(SAMPLE_TRACE_PATH, "w") as f:
        json.dump(sample.trace("chrome"), f, indent=1)
    print(f"# wrote {os.path.normpath(SAMPLE_TRACE_PATH)}", file=sys.stderr)

    # -- audit mode: runtime Figure-9 check over the seeded workload -------
    audit_session = _warm_session(AUDIT_CFG)
    for s in dict.fromkeys(_workload()):
        audit_session.sql(s)
    summary = audit_session.auditor.summary()
    assert summary["violations"] == 0, \
        f"audit recorded guarantee violations: {summary}"
    assert summary["errors"] == 0
    assert summary["audited"] > 0 or summary["skipped_exact"] > 0
    assert summary["max_error_ratio"] <= 1.0 or summary["audited"] == 0

    plain.close()
    traced.close()
    audit_session.close()

    doc = {"bench": "obs", "rows": SCALE_ROWS, "herd_n": HERD_N,
           "reps": REPS, "cpu_count": os.cpu_count(),
           "drain_off_s": off_s,
           "drain_on_s": on_s,
           "tracing_overhead": overhead,
           "max_overhead_budget": MAX_OVERHEAD,
           "bit_identical_on_vs_off": True,
           "span_trees_closed": True,
           "audit": {k: summary[k] for k in
                     ("runs", "audited", "skipped_exact", "violations",
                      "errors", "max_error_ratio", "mean_error_ratio")}}

    with open(BENCH_OBS_PATH, "w") as f:
        json.dump(doc, f, indent=1, default=float)
    print(f"# wrote {os.path.normpath(BENCH_OBS_PATH)}", file=sys.stderr)
    save_results("obs", doc)

    print(csv_row("obs_tracing_overhead", on_s * 1e6,
                  f"off={off_s * 1e6:.1f}us;overhead={overhead:.2%};"
                  f"audit_max_ratio={summary['max_error_ratio']:.3f}"))
    return doc


if __name__ == "__main__":
    run()
