"""Fig. 16/17 (Appendix A.1): naive row-level CLT under block sampling fails.

The ablation replaces BSAP with the standard row-level Lemma-B.1 machinery
while STILL executing block sampling.  On block-homogeneous (clustered) data
the row-level bounds ignore intra-block correlation, undersample, and blow
through the target error (the paper measures up to 52×).
"""

from __future__ import annotations

import math
import time

import numpy as np

from benchmarks.common import catalog, csv_row, make_db, save_results
from repro.core import CompositeAgg, ErrorSpec, Query, bsap
from repro.core.allocation import allocate
from repro.engine import logical as L
from repro.engine.executor import Executor
from repro.engine.expr import Col


def _naive_block_plan(ex, plan, table, theta_p, spec, seed):
    """Row-level CLT planning (invalid under block sampling)."""
    pplan = L.rewrite_scans(plan, {table: L.SampleClause("block", theta_p, seed)})
    pres = ex.execute(pplan)
    sq = L.Aggregate(pplan.child,
                     tuple(L.AggSpec("sum", a.expr * a.expr, a.name + "_sq")
                           for a in plan.aggs), plan.group_by, plan.max_groups)
    sqres = ex.execute(sq)
    info = pres.sample_infos[table]
    n_rows_sampled = (info.n_sampled_blocks or 0) * ex.block_rows(table)
    if n_rows_sampled < 2:
        return None
    budget = allocate(spec.confidence, 1, spec.error)
    mean = pres.raw_sums[0, 0] / n_rows_sampled
    var = max(sqres.raw_sums[0, 0] / n_rows_sampled - mean ** 2, 0.0)
    L_mu, U_V = bsap.naive_row_bounds(mean, var, n_rows_sampled, theta_p,
                                      budget.delta1, budget.delta2,
                                      exact_N=float(ex.table_rows(table)))
    if L_mu <= 0:
        return None
    z = bsap.z_for(budget.p_prime)
    # rel err of the MEAN equals rel err of the TOTAL
    lo, hi = 1e-6, 0.1
    if not bsap.phi_satisfied(z, U_V(hi), L_mu, budget.error):
        return None
    for _ in range(48):
        mid = math.sqrt(lo * hi)
        if bsap.phi_satisfied(z, U_V(mid), L_mu, budget.error):
            hi = mid
        else:
            lo = mid
    return hi


def run(trials: int = 10, target: float = 0.05) -> dict:
    cat = catalog(clustered=True)  # homogeneous blocks: the failure regime
    ex = Executor(cat)
    # AVG over a clustered column: within-block correlation is extreme
    plan = L.Aggregate(child=L.Scan("lineitem"),
                       aggs=(L.AggSpec("sum", Col("l_shipdate"), "s"),))
    truth = ex.execute(plan).scalar("s")
    spec = ErrorSpec(error=target, confidence=0.95)

    t0 = time.perf_counter()
    naive_errs, bsap_errs = [], []
    theta_naive_hist = []
    for s in range(trials):
        theta = _naive_block_plan(ex, plan, "lineitem", 0.02, spec, seed=11 * s)
        if theta is None:
            continue
        theta_naive_hist.append(theta)
        fplan = L.rewrite_scans(plan, {"lineitem": L.SampleClause("block", theta, 7 * s)})
        est = ex.execute(fplan).scalar("s")
        naive_errs.append(abs(est - truth) / abs(truth))

    # BSAP on identical data/queries
    from repro.core import PilotDB

    db = PilotDB(ex, large_table_rows=100_000)
    q = Query(child=L.Scan("lineitem"),
              aggs=(CompositeAgg("s", "sum", Col("l_shipdate")),))
    exact = db.exact(q)
    for s in range(trials):
        ans = db.query(q, spec, seed=31 * s)
        if ans.report.fallback is None:
            bsap_errs.append(abs(ans.scalar("s") - exact.scalar("s"))
                             / abs(exact.scalar("s")))
    wall = time.perf_counter() - t0

    payload = {
        "target": target,
        "naive_max_err": max(naive_errs) if naive_errs else None,
        "naive_mean_err": float(np.mean(naive_errs)) if naive_errs else None,
        "naive_violation_ratio": (max(naive_errs) / target) if naive_errs else None,
        "naive_thetas": theta_naive_hist,
        "bsap_max_err": max(bsap_errs) if bsap_errs else None,
        "bsap_runs": len(bsap_errs),
    }
    save_results("bench_naive_clt", payload)
    print(csv_row("naive_clt_fig16_17", wall * 1e6 / max(trials, 1),
                  f"naive_max/target={payload['naive_violation_ratio']:.1f}x;"
                  f"bsap_max/target="
                  f"{(payload['bsap_max_err'] or 0) / target:.2f}x"))
    return payload


if __name__ == "__main__":
    run()
