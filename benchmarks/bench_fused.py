"""Fused single-launch TAQA benchmark: one device program vs two stages.

Measures the headline of the fused path (``PilotDB.run_fused`` /
``physical.compile_fused``): pilot scan -> BSAP rate solve -> final sampled
aggregation as ONE device dispatch with zero host syncs between the stages,
against the two-stage oracle (``PilotDB.query``: pilot launch, host round
trip for the f64 rate solve, final launch).

Bit-identity is asserted BEFORE any timing — the fused program must deliver
``np.array_equal`` values and an identical error report for every seed, and
exactly one ``device_dispatches`` increment per query (the oracle takes >=
2).  A violation raises, which ``benchmarks.run --only fused`` turns into a
nonzero exit — this is the CI smoke gate for the single-launch contract.

A second section drives the same contract through the session: a
constant-varied herd under ``SessionConfig(fused_taqa=True)`` (each
singleton pilot subgroup routes through the fused program) vs the default
two-stage drain (whose pilots ride the stacked batched-pilot dispatch).

Emits ``BENCH_fused.json`` at the repo root for trajectory tracking.

  PYTHONPATH=src python -m benchmarks.run --only fused
  BENCH_ROWS=200000 PYTHONPATH=src python -m benchmarks.bench_fused
"""

from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from benchmarks.common import SCALE_ROWS, catalog, csv_row, save_results
from repro.api import Session, SessionConfig
from repro.core import CompositeAgg, ErrorSpec, PilotDB, Query
from repro.engine import logical as L
from repro.engine.executor import Executor
from repro.engine.expr import And, Col

BENCH_FUSED_PATH = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_fused.json")

N_SEEDS = int(os.environ.get("BENCH_FUSED_SEEDS", 4))
REPS = int(os.environ.get("BENCH_FUSED_REPS", 5))  # median-of, warm caches

# ERROR 10% keeps the sampled plan feasible for EVERY pilot draw down to
# the CI smoke scale (BENCH_ROWS=200000, seeds 0..7 checked); a tighter
# target there solves some seeds to "no feasible plan cheaper than exact",
# which routes the answer through the exact fallback (2 launches) and the
# single-launch assertion would not be measuring the fused compose at all.
SPEC = ErrorSpec(error=0.10, confidence=0.95)
HERD_SQL = ("SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
            "WHERE l_shipdate BETWEEN 100 AND {hi} "
            "AND l_discount BETWEEN 0.02 AND 0.08 AND l_quantity < 24 "
            "ERROR 10% CONFIDENCE 95%")
HERD_N = 4


def _q6() -> Query:
    pred = And(Col("l_shipdate").between(100, 1500),
               And(Col("l_discount").between(0.02, 0.08),
                   Col("l_quantity") < 24))
    return Query(child=L.Filter(L.Scan("lineitem"), pred),
                 aggs=(CompositeAgg("revenue", "sum",
                                    Col("l_extendedprice") * Col("l_discount")),))


def _measure_query(tables) -> dict:
    """Per-seed PilotDB-level pairs: identity gate first, then warm wall."""
    seeds, two_wall, fused_wall = [], [], []
    for seed in range(N_SEEDS):
        ex_two, ex_fused = Executor(tables), Executor(tables)
        db_two = PilotDB(ex_two, large_table_rows=100_000)
        db_fused = PilotDB(ex_fused, large_table_rows=100_000)

        # ---- identity gate (warms both executors' compile caches) --------
        ans_two = db_two.query(_q6(), SPEC, seed=seed)
        launches_two = ex_two.device_dispatches
        ans_fused = db_fused.run_fused(_q6(), SPEC, seed=seed)
        launches_fused = ex_fused.device_dispatches
        assert ans_fused is not None, "fused path did not engage"
        assert launches_fused == 1, (
            f"fused must be ONE launch, saw {launches_fused} (seed {seed})")
        assert launches_two >= 2, launches_two
        assert np.array_equal(ans_two.values, ans_fused.values), \
            f"fused answer is not bit-identical to two-stage (seed {seed})"
        rt, rf = ans_two.report, ans_fused.report
        assert rt.fallback == rf.fallback and rt.theta_pilot == rf.theta_pilot
        assert dict(rt.plan.rates) == dict(rf.plan.rates)

        # ---- warm wall (executables cached; every call re-dispatches) ----
        tw, fw = [], []
        for _ in range(REPS):
            t0 = time.perf_counter()
            db_two.query(_q6(), SPEC, seed=seed)
            tw.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            db_fused.run_fused(_q6(), SPEC, seed=seed)
            fw.append(time.perf_counter() - t0)
        two_wall.append(float(np.median(tw)))
        fused_wall.append(float(np.median(fw)))
        seeds.append({
            "seed": seed,
            "launches_two_stage": launches_two,
            "launches_fused": launches_fused,
            # host round trips the statistics cross between stages: each
            # extra launch implies one (pilot -> host solve -> final)
            "host_syncs_between_stages_fused": launches_fused - 1,
            "two_stage_s": two_wall[-1],
            "fused_s": fused_wall[-1],
            "bit_identical": True,
        })
    two_s, fused_s = float(np.median(two_wall)), float(np.median(fused_wall))
    return {"n_seeds": N_SEEDS, "reps": REPS,
            "two_stage_s": two_s, "fused_s": fused_s,
            "fused_speedup": two_s / fused_s if fused_s else float("nan"),
            "launches_fused_per_query": 1,
            "host_syncs_between_stages_fused": 0,
            "per_seed": seeds}


def _run_session(tables, fused: bool) -> dict:
    cfg = SessionConfig(async_workers=0, result_cache_size=0,
                        large_table_rows=100_000, fused_taqa=fused)
    session = Session(tables, seed=17, config=cfg)
    sqls = [HERD_SQL.format(hi=1500 + 40 * i) for i in range(HERD_N)]
    for s in sqls:  # warm compile caches
        session.submit(s)
    session.drain()
    d0 = session.executor.device_dispatches
    walls = []
    for _ in range(REPS):
        handles = [session.submit(s) for s in sqls]
        t0 = time.perf_counter()
        session.drain()
        walls.append(time.perf_counter() - t0)
    assert all(h.status == "done" for h in handles)
    info = session.compile_cache_info()
    out = {
        "wall_s": float(np.median(walls)),
        "queries": HERD_N,
        "launches_per_drain": (session.executor.device_dispatches - d0) // REPS,
        "fused_engaged": info.fused_hits + info.fused_misses,
        "values": [np.asarray(h.result().values) for h in handles],
    }
    session.close()
    return out


def run() -> dict:
    tables = {k: v for k, v in catalog().items() if k != "skewed"}
    doc = {"bench": "fused", "rows": SCALE_ROWS,
           "query": _measure_query(tables)}

    base = _run_session(tables, fused=False)
    fused = _run_session(tables, fused=True)
    for a, b in zip(base.pop("values"), fused.pop("values")):
        assert np.array_equal(a, b), \
            "fused_taqa=True session herd is not bit-identical to default"
    assert fused["fused_engaged"] >= HERD_N, fused
    assert base["fused_engaged"] == 0, base
    doc["herd_two_stage"] = base
    doc["herd_fused"] = fused
    doc["herd_fused_speedup"] = (base["wall_s"] / fused["wall_s"]
                                 if fused["wall_s"] else float("nan"))
    doc["bit_identical"] = True

    with open(BENCH_FUSED_PATH, "w") as f:
        json.dump(doc, f, indent=1, default=float)
    print(f"# wrote {os.path.normpath(BENCH_FUSED_PATH)}", file=sys.stderr)
    save_results("fused", doc)

    q = doc["query"]
    print(csv_row("fused_query", q["fused_s"] * 1e6,
                  f"launches=1;speedup={q['fused_speedup']:.2f}x"))
    print(csv_row("fused_herd", fused["wall_s"] / HERD_N * 1e6,
                  f"n={HERD_N};launches_per_drain={fused['launches_per_drain']};"
                  f"speedup={doc['herd_fused_speedup']:.2f}x"))
    return doc


if __name__ == "__main__":
    run()
