"""Staged sample-catalog benchmark (repro.engine.staged).

Workload: a hot-table constant-varied dashboard herd — the case the result
cache cannot serve (every constant is a distinct answer) and the staged
ladder is built for.  Two measurements, staging on vs off:

* ``warm_dispatch`` — the tentpole number, isolated: N warmed
  constant-varied sampled finals dispatched against pre-staged rung arrays
  (memoized sub-draw, no per-query host RNG, gather from the small staged
  slabs) vs the per-query fresh path (host block draw + gather from the
  full table arrays).  Both executors pin the SAME staging seed — the
  "off" executor's ladder has one rung at 1e-9, so every query misses to a
  fresh draw of the identical realization — and bit-identity is asserted
  before timing.
* ``drain_wall`` — the serving view: the same herd pushed through the
  session scheduler (pilots + planning + finals), `staged_rates=` on vs
  off (None), plus a pinned-seed fresh reference that must be bit-identical
  to the staged run.

Emits the machine-readable ``BENCH_staged.json`` at the repo root.

  PYTHONPATH=src python -m benchmarks.run --only staged
  BENCH_ROWS=200000 PYTHONPATH=src python -m benchmarks.bench_staged
"""

from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from benchmarks.common import SCALE_ROWS, catalog, csv_row, save_results
from repro.api import Session, SessionConfig
from repro.engine import logical as L
from repro.engine.executor import Executor
from repro.engine.expr import And, Col

BENCH_STAGED_PATH = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_staged.json")

N_CONSTANTS = int(os.environ.get("BENCH_STAGED_N", 8))
REPS = int(os.environ.get("BENCH_STAGED_REPS", 11))
RATES = (0.01, 0.04, 0.16)
# Served by the 1% rung.  The staged win is per-query overhead (host block
# draw + sample-array device transfer), so it is largest in the small-rate
# regime where pilots and planner-chosen finals actually live; at large
# rates the (bit-identical, hence invariant) aggregation compute dominates
# both paths and the ratio tends to 1.
FINAL_RATE = 0.001
NEVER = (1e-9,)           # same pinned seed, every query misses to fresh

HERD_SQL = ("SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
            "WHERE l_quantity < {cap} ERROR 6% CONFIDENCE 95%")


def _final(i: int):
    pred = And(Col("l_shipdate").between(100, 1500),
               Col("l_quantity") < 18 + i)
    plan = L.Aggregate(
        child=L.Filter(L.Scan("lineitem"), pred),
        aggs=(L.AggSpec("sum",
                        Col("l_extendedprice") * Col("l_discount"), "rev"),
              L.AggSpec("count", None, "cnt")))
    return L.rewrite_scans(
        plan, {"lineitem": L.SampleClause("block", FINAL_RATE, seed=i)})


def _measure_warm_dispatch(tables) -> dict:
    """The headline: warmed constant-varied sampled finals, staged rung
    arrays vs per-query fresh draw + full-table gather (bit-identical)."""
    hot = Executor(dict(tables))
    hot.register_staged("lineitem", RATES, seed=0)
    ref = Executor(dict(tables))
    ref.register_staged("lineitem", NEVER, seed=0)

    plans = [_final(i) for i in range(N_CONSTANTS)]
    ref_out = [ref.execute(p) for p in plans]           # warm + reference
    for out, expect in zip((hot.execute(p) for p in plans), ref_out):
        assert np.array_equal(np.asarray(out.values),
                              np.asarray(expect.values)), \
            "staged answers must be bit-identical to fresh draws"
    fresh_t, staged_t = [], []
    for _ in range(REPS):
        t0 = time.perf_counter()
        for p in plans:
            ref.execute(p)
        fresh_t.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        for p in plans:
            hot.execute(p)
        staged_t.append(time.perf_counter() - t0)
    # min-of-reps (timeit's statistic, applied to both paths alike): the
    # least-interference estimate on a noisy shared-CPU host
    fresh_s, staged_s = float(np.min(fresh_t)), float(np.min(staged_t))
    return {"n_finals": N_CONSTANTS, "final_rate": FINAL_RATE,
            "fresh_s": fresh_s, "staged_s": staged_s,
            "dispatch_speedup": fresh_s / staged_s if staged_s
            else float("nan"),
            "staged_hits": hot.staged.hits, "fresh_misses": ref.staged.misses,
            "bit_identical": True}


def _drain_config(tables, staged_rates) -> dict:
    cfg = SessionConfig(result_cache_size=0, large_table_rows=100_000)
    session = Session(seed=17, config=cfg)
    session.register_table("lineitem", tables["lineitem"],
                           staged_rates=staged_rates)
    sqls = [HERD_SQL.format(cap=18 + i) for i in range(N_CONSTANTS)]
    for s in sqls:                       # warm jit caches + sub-draw memos
        session.sql(s)
    walls = []
    for _ in range(REPS):
        handles = [session.submit(s) for s in sqls]
        t0 = time.perf_counter()
        session.drain()
        walls.append(time.perf_counter() - t0)
    out = {
        "wall_s": float(np.median(walls)),
        "queries": len(handles),
        "failed": sum(h.status != "done" for h in handles),
        "staged_hits": session.executor.staged.hits,
        "staged_misses": session.executor.staged.misses,
        "values": [np.asarray(h.result().values) for h in handles],
    }
    session.close()
    return out


def _measure_drain_wall(tables) -> dict:
    on = _drain_config(tables, list(RATES))
    off = _drain_config(tables, None)               # today's behavior
    pinned_ref = _drain_config(tables, list(NEVER))  # fresh, same realization
    identical = all(np.array_equal(a, b)
                    for a, b in zip(on.pop("values"), pinned_ref["values"]))
    off.pop("values"), pinned_ref.pop("values")
    assert on["staged_hits"] > 0 and off["staged_hits"] == 0
    return {"herd_n": N_CONSTANTS,
            "staging_on": on, "staging_off": off,
            "pinned_fresh": pinned_ref,
            "bit_identical_vs_pinned_fresh": identical,
            "wall_speedup_vs_off": off["wall_s"] / on["wall_s"]
            if on["wall_s"] else float("nan")}


def run() -> dict:
    tables = {k: v for k, v in catalog().items() if k != "skewed"}
    doc = {"bench": "staged", "rows": SCALE_ROWS,
           "staged_rates": list(RATES), "cpu_count": os.cpu_count(),
           "warm_dispatch": _measure_warm_dispatch(tables),
           "drain_wall": _measure_drain_wall(tables)}

    with open(BENCH_STAGED_PATH, "w") as f:
        json.dump(doc, f, indent=1, default=float)
    print(f"# wrote {os.path.normpath(BENCH_STAGED_PATH)}", file=sys.stderr)
    save_results("staged", doc)

    wd = doc["warm_dispatch"]
    print(csv_row("staged_warm_dispatch", wd["staged_s"] / wd["n_finals"] * 1e6,
                  f"n={wd['n_finals']};rate={wd['final_rate']};"
                  f"dispatch_speedup={wd['dispatch_speedup']:.2f}x"))
    dw = doc["drain_wall"]
    print(csv_row("staged_drain_wall",
                  dw["staging_on"]["wall_s"] / dw["herd_n"] * 1e6,
                  f"n={dw['herd_n']};"
                  f"wall_speedup={dw['wall_speedup_vs_off']:.2f}x"))
    assert wd["bit_identical"], "staged dispatch must be bit-identical"
    assert dw["bit_identical_vs_pinned_fresh"], \
        "staged drains must be bit-identical to pinned-seed fresh drains"
    assert wd["dispatch_speedup"] > 1.0, \
        "staged warm dispatch must beat the fresh gather"
    assert all(c["failed"] == 0 for c in
               (dw["staging_on"], dw["staging_off"], dw["pinned_fresh"]))
    return doc


if __name__ == "__main__":
    run()
