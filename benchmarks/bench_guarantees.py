"""Fig. 6/7: a-priori error guarantees across the query suite.

For each query × target error, run PilotDB several times and record the
achieved relative errors.  The paper's claim: achieved <= target in every
run, conservatively (~half the target on average).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (csv_row, geomean, make_db, query_suite,
                               rel_errors, save_results)
from repro.core import ErrorSpec


def run(trials: int = 5, targets=(0.02, 0.05, 0.10)) -> dict:
    db = make_db()
    out = {}
    t0 = time.perf_counter()
    for bq in query_suite():
        exact = db.exact(bq.query)
        per_target = {}
        for e in targets:
            spec = ErrorSpec(error=e, confidence=0.95)
            achieved, fallbacks = [], 0
            for s in range(trials):
                ans = db.query(bq.query, spec, seed=1000 * s + hash(bq.name) % 997)
                if ans.report.fallback is not None:
                    fallbacks += 1
                    continue
                errs = rel_errors(ans, exact)
                if len(errs):
                    achieved.append(float(errs.max()))
            per_target[str(e)] = {
                "max": max(achieved) if achieved else None,
                "mean": float(np.mean(achieved)) if achieved else None,
                "violations": sum(a > e for a in achieved),
                "sampled_runs": len(achieved),
                "fallbacks": fallbacks,
            }
        out[bq.name] = per_target
    wall = time.perf_counter() - t0

    total_v = sum(t["violations"] for q in out.values() for t in q.values())
    total_runs = sum(t["sampled_runs"] for q in out.values() for t in q.values())
    ratios = [t["max"] / float(e) for q in out.values()
              for e, t in q.items() if t["max"] is not None]
    payload = {"per_query": out, "total_violations": total_v,
               "total_sampled_runs": total_runs,
               "mean_max_to_target": float(np.mean(ratios)) if ratios else None}
    save_results("bench_guarantees", payload)
    print(csv_row("guarantees_fig6_7", wall * 1e6 / max(total_runs, 1),
                  f"violations={total_v}/{total_runs};"
                  f"max_over_target_mean={payload['mean_max_to_target']:.2f}"))
    return payload


if __name__ == "__main__":
    run()
