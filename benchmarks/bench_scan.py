"""Fig. 4: system efficiency of sampling methods that don't modify the DBMS.

AVG query over lineitem at rates 0.01%..10%: block sampling touches only
sampled slabs (gather), row Bernoulli streams everything (mask).  We report
wall time and bytes moved; at small rates block sampling wins by orders of
magnitude — the motivation for BSAP.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import catalog, csv_row, save_results
from repro.engine import logical as L
from repro.engine.executor import EmptySampleError, Executor
from repro.engine.expr import Col


def run(rates=(0.0001, 0.001, 0.01, 0.1)) -> dict:
    ex = Executor(catalog())
    plan = L.Aggregate(child=L.Scan("lineitem"),
                       aggs=(L.AggSpec("avg", Col("l_extendedprice"), "a"),))
    # warmup + full-scan baseline
    full = ex.execute(L.strip_samples(plan))
    t0 = time.perf_counter()
    full = ex.execute(L.strip_samples(plan))
    t_full = time.perf_counter() - t0

    rows = {}
    for rate in rates:
        res = {}
        for method in ("block", "row"):
            # At tiny rates a Bernoulli draw can come back empty — the
            # executor surfaces that as EmptySampleError (a real DBMS would
            # return no rows); scan a few seeds for a non-empty draw and
            # record the rate as empty if none exists at this scale.
            timing = None
            for seed in range(3, 9):
                try:
                    ex.execute(L.rewrite_scans(
                        plan, {"lineitem": L.SampleClause(method, rate, seed)}))  # warm
                    t0 = time.perf_counter()
                    r = ex.execute(L.rewrite_scans(
                        plan, {"lineitem": L.SampleClause(method, rate, seed + 100)}))
                    timing = {"time_s": time.perf_counter() - t0,
                              "scanned_bytes": r.scanned_bytes}
                    break
                except EmptySampleError:
                    continue
            res[method] = timing or {"time_s": float("nan"), "scanned_bytes": 0,
                                     "empty_sample": True}
        res["speedup_block_vs_row"] = res["row"]["time_s"] / max(res["block"]["time_s"], 1e-9)
        res["bytes_ratio_row_vs_block"] = (res["row"]["scanned_bytes"]
                                           / max(res["block"]["scanned_bytes"], 1))
        rows[str(rate)] = res

    payload = {"full_scan_s": t_full, "rates": rows}
    save_results("bench_scan", payload)
    small = rows[str(rates[0])]
    big = rows[str(rates[-1])]
    # bytes ratio is the scale-free Fig.4 quantity; CPU wall time has an
    # eager-dispatch floor (~10 ms) that masks gains at tiny rates — the
    # jit'd kernel-path numbers are in bench_kernels.
    print(csv_row("scan_fig4", t_full * 1e6,
                  f"bytes_ratio@{rates[0]}={small['bytes_ratio_row_vs_block']:.0f}x;"
                  f"wall@{rates[-1]}={big['speedup_block_vs_row']:.1f}x"))
    return payload


if __name__ == "__main__":
    run()
