"""Tables 4/5 + Lemma 4.1 + Fig. 13/14/15: ablations & sensitivity.

* PilotDB-O (Table 4): oracle that skips stage 1 — the final query runs with
  a pre-known plan (we reuse the plan TAQA found, re-executed alone).  The
  gap PilotDB vs PilotDB-O is the pilot/planning overhead; 2nd-stage-only
  latency isolates plan quality.
* PilotDB-R (Table 5): covered in bench_quickr (row-level final), summarized
  here from the same machinery.
* Lemma 4.1: statistical-efficiency ratio on shuffled vs clustered layouts.
* Fig. 13: latency decomposition.  Fig. 14/15: θ_p and (δ1, δ2) sensitivity.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import (catalog, csv_row, geomean, make_db,
                               query_suite, save_results)
from repro.core import ErrorSpec, bsap
from repro.engine import logical as L
from repro.engine.executor import Executor


def run() -> dict:
    db = make_db()
    spec = ErrorSpec(error=0.05, confidence=0.95)
    t_all = time.perf_counter()

    # ---- Table 4: PilotDB vs PilotDB-O --------------------------------------
    overall_slow, stage2_slow, decomp = [], [], []
    for bq in query_suite():
        ans = db.query(bq.query, spec, seed=13)
        if ans.report.fallback is not None or ans.report.plan is None:
            continue
        r = ans.report
        total = r.pilot_time_s + r.plan_time_s + r.final_time_s
        # oracle: re-execute only the final (planned) query
        samples = {t: L.SampleClause("block", rate, 991)
                   for t, rate in r.plan.rates.items() if rate < 1.0}
        plan_engine, _ = db._engine_plan(bq.query)
        t0 = time.perf_counter()
        db.ex.execute(L.rewrite_scans(plan_engine, samples))
        t_oracle = time.perf_counter() - t0
        overall_slow.append(total / max(t_oracle, 1e-9))
        stage2_slow.append(r.final_time_s / max(t_oracle, 1e-9))
        decomp.append({"query": bq.name,
                       "pilot_frac": r.pilot_time_s / total,
                       "plan_frac": r.plan_time_s / total,
                       "final_frac": r.final_time_s / total})

    # ---- Lemma 4.1 -----------------------------------------------------------
    li = catalog(clustered=False)["lineitem"]
    li_c = catalog(clustered=True)["lineitem"]
    col = np.asarray(li.columns["l_shipdate"])[: li.num_rows].astype(float)
    col_c = np.asarray(li_c.columns["l_shipdate"])[: li_c.num_rows].astype(float)
    eff_shuffled = bsap.efficiency_ratio(col, li.block_rows)
    eff_clustered = bsap.efficiency_ratio(col_c, li_c.block_rows)

    # ---- Fig. 14: theta_p sensitivity (Q6 family) ----------------------------
    q6 = query_suite()[0]
    theta_sweep = {}
    for tp in (0.001, 0.005, 0.02, 0.05):
        s2 = dataclasses.replace(spec, theta_pilot=tp)
        a = db.query(q6.query, s2, seed=17)
        frac = (a.report.pilot_scanned_bytes + a.report.final_scanned_bytes) \
            / max(a.report.exact_scanned_bytes, 1)
        theta_sweep[str(tp)] = {"bytes_speedup": 1.0 / max(frac, 1e-9),
                                "fallback": a.report.fallback}

    # ---- Fig. 15: (delta1, delta2) allocation --------------------------------
    delta_sweep = {}
    p_c = spec.confidence
    budget_total = (1 - p_c) * 2 / 3  # keep p' = p + d1 + d2 < 1 as default
    for frac1 in (0.1, 0.5, 0.9):
        d1 = budget_total * frac1
        d2 = budget_total - d1
        from repro.core.allocation import allocate

        try:
            b = allocate(p_c, 1, spec.error, delta_split=(d1, d2))
            uv_scale = bsap.z_for(b.p_prime)
            delta_sweep[f"{frac1:.1f}"] = {"z": uv_scale, "d1": d1, "d2": d2}
        except ValueError as e:
            delta_sweep[f"{frac1:.1f}"] = {"error": str(e)}
    wall = time.perf_counter() - t_all

    payload = {
        "table4_overall_slowdown_gm": geomean(overall_slow),
        "table4_stage2_slowdown_gm": geomean(stage2_slow),
        "fig13_latency_decomposition": decomp,
        "lemma41_efficiency_shuffled": eff_shuffled,
        "lemma41_efficiency_clustered": eff_clustered,
        "fig14_theta_sweep": theta_sweep,
        "fig15_delta_sweep": delta_sweep,
    }
    save_results("bench_ablation", payload)
    print(csv_row("ablation_tab4_5_fig13_15", wall * 1e6,
                  f"overall_vs_oracle={payload['table4_overall_slowdown_gm']:.2f}x;"
                  f"stage2_vs_oracle={payload['table4_stage2_slowdown_gm']:.2f}x;"
                  f"eff_ratio_shuffled={eff_shuffled:.2f};"
                  f"clustered={eff_clustered:.1f}"))
    return payload


if __name__ == "__main__":
    run()
