"""Benchmark trajectory gate: fail CI when a key scalar regresses > 25%.

The ``BENCH_*.json`` files the benchmarks drop at the repo root are a
longitudinal record of what the engine can do — dispatch speedups, cache
behaviour, streaming latency, tracing/telemetry overhead.  Each one already
asserts its own *correctness* contract internally (bit-identity, audit
bounds); what nothing guarded until now is the *trajectory*: a refactor
that keeps every answer bitwise identical but quietly halves the batched
dispatch speedup sails through the whole suite.

This module closes that gap.  ``benchmarks/baselines/`` holds committed
copies of the BENCH files from a known-good run; ``python -m
benchmarks.trajectory`` compares the fresh repo-root files against them on
a curated metric list and exits nonzero when any metric moved more than
``--threshold`` (default 25%) in its bad direction.  Improvements never
fail, and metrics are curated for stability: raw wall-clock seconds are
deliberately absent (CI hardware varies run to run); the gate watches
*ratios* the benchmarks compute between two configurations measured on the
same machine in the same process (speedups, overheads), plus exact counts
(compile misses) that must never drift at all.

Usage::

    PYTHONPATH=src python -m benchmarks.trajectory            # gate
    PYTHONPATH=src python -m benchmarks.trajectory --update   # re-baseline

``--update`` copies the current repo-root BENCH files over the committed
baselines — run it after an intentional performance change and commit the
result, which makes the accepted trade-off reviewable in the diff.

Semantics per metric kind:

* ``higher`` (speedups): regression when ``new < base * (1 - threshold)``.
* ``lower`` (overheads): regression when ``new > base + threshold`` —
  compared *additively* because these are small ratios that legitimately
  hover around zero (a -1% baseline overhead would make any multiplicative
  comparison degenerate).
* ``exact`` (counts): any change at all fails; these encode structural
  invariants (a constant sweep costs exactly 2 compilations), not timings.

Missing fresh files are skipped with a note (the gate only judges what the
current CI run produced); missing *baselines* fail loudly — an unbaselined
metric is an unguarded metric.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from typing import List, Optional, Tuple

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE_DIR = os.path.join(REPO_ROOT, "benchmarks", "baselines")

# (file, json-path, kind) — kind in {"higher", "lower", "exact"}.
# Curated for cross-run stability: configuration-vs-configuration ratios
# measured within one process, and exact structural counts.  No raw seconds.
METRICS: List[Tuple[str, str, str]] = [
    ("BENCH_runtime.json", "final_dispatch/dispatch_speedup", "higher"),
    ("BENCH_runtime.json", "full/result_hits", "exact"),
    ("BENCH_compiled.json", "q6_pair/steady_speedup", "higher"),
    ("BENCH_compiled.json", "constant_sweep/compile_misses", "exact"),
    ("BENCH_dist.json", "pilot_fanout_speedup", "higher"),
    ("BENCH_staged.json", "warm_dispatch/dispatch_speedup", "higher"),
    ("BENCH_stream.json", "first_frame_speedup", "higher"),
    ("BENCH_fused.json", "query/launches_fused_per_query", "exact"),
    ("BENCH_obs.json", "tracing_overhead", "lower"),
    ("BENCH_obs.json", "audit/violations", "exact"),
    ("BENCH_obs.json", "telemetry/overhead", "lower"),
    ("BENCH_obs.json", "telemetry/flight_recorder/dropped", "exact"),
]

DEFAULT_THRESHOLD = 0.25


def _lookup(doc: dict, path: str) -> Optional[float]:
    node: object = doc
    for part in path.split("/"):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    if isinstance(node, bool) or not isinstance(node, (int, float)):
        return None
    return float(node)


def _load(path: str) -> Optional[dict]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def update_baselines() -> int:
    os.makedirs(BASELINE_DIR, exist_ok=True)
    copied = 0
    for fname in sorted({f for f, _, _ in METRICS}):
        src = os.path.join(REPO_ROOT, fname)
        if not os.path.exists(src):
            print(f"trajectory: skip {fname} (no fresh file at repo root)")
            continue
        shutil.copyfile(src, os.path.join(BASELINE_DIR, fname))
        print(f"trajectory: baselined {fname}")
        copied += 1
    if not copied:
        print("trajectory: nothing to baseline — run the benchmarks first",
              file=sys.stderr)
        return 1
    return 0


def check(threshold: float = DEFAULT_THRESHOLD) -> int:
    failures: List[str] = []
    fresh_cache: dict = {}
    base_cache: dict = {}
    for fname, path, kind in METRICS:
        if fname not in fresh_cache:
            fresh_cache[fname] = _load(os.path.join(REPO_ROOT, fname))
        fresh_doc = fresh_cache[fname]
        if fresh_doc is None:
            print(f"trajectory: skip {fname}:{path} (fresh file absent)")
            continue
        if fname not in base_cache:
            base_cache[fname] = _load(os.path.join(BASELINE_DIR, fname))
        base_doc = base_cache[fname]
        if base_doc is None:
            failures.append(f"{fname}: no committed baseline — run "
                            f"`python -m benchmarks.trajectory --update` "
                            f"and commit benchmarks/baselines/")
            continue
        new = _lookup(fresh_doc, path)
        base = _lookup(base_doc, path)
        if new is None or base is None:
            failures.append(f"{fname}:{path} missing "
                            f"(fresh={new}, baseline={base})")
            continue
        if kind == "exact":
            ok = new == base
            verdict = "ok" if ok else "REGRESSED (exact metric changed)"
        elif kind == "higher":
            ok = new >= base * (1.0 - threshold)
            verdict = "ok" if ok else \
                f"REGRESSED (> {threshold:.0%} below baseline)"
        else:  # lower: additive — overhead baselines hover around zero
            ok = new <= base + threshold
            verdict = "ok" if ok else \
                f"REGRESSED (> {threshold:+.0%} above baseline)"
        line = (f"{fname}:{path}  baseline={base:.6g}  "
                f"now={new:.6g}  {verdict}")
        print("trajectory:", line)
        if not ok:
            failures.append(line)
    if failures:
        print(f"\ntrajectory: {len(failures)} metric(s) regressed:",
              file=sys.stderr)
        for f in failures:
            print("  -", f, file=sys.stderr)
        return 1
    print("trajectory: all tracked metrics within budget")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        description="Gate BENCH_*.json scalars against committed baselines")
    parser.add_argument("--update", action="store_true",
                        help="copy fresh BENCH files over the baselines "
                             "instead of checking")
    parser.add_argument("--threshold", type=float,
                        default=DEFAULT_THRESHOLD,
                        help="fractional regression budget (default 0.25)")
    args = parser.parse_args(argv)
    if args.update:
        return update_baselines()
    return check(args.threshold)


if __name__ == "__main__":
    sys.exit(main())
