"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; detailed JSON lands in
benchmarks/results/.  The ``compiled`` bench additionally emits the
machine-readable ``BENCH_compiled.json`` at the repo root (eager vs compiled
latency, compile-cache hit rate, scanned bytes) for trajectory tracking.
BENCH_ROWS env var scales the data (default 2M rows).

  PYTHONPATH=src python -m benchmarks.run [--only <name>]
"""

import argparse
import json
import os
import sys
import time

BENCH_COMPILED_PATH = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_compiled.json")


def _emit_bench_compiled(payload: dict) -> None:
    """Flatten the compiled-vs-eager payload into the root JSON artifact."""
    from benchmarks.common import SCALE_ROWS  # the size the data was built at
    doc = {"bench": "compiled", "rows": SCALE_ROWS}
    for name, entry in payload.items():
        if name == "constant_sweep":
            doc[name] = dict(entry)  # already flat; misses must stay <= 2
            continue
        doc[name] = {
            "eager_steady_s": entry["eager"]["steady_state_s"],
            "compiled_steady_s": entry["compiled"]["steady_state_s"],
            "compiled_first_call_s": entry["compiled"]["first_call_s"],
            "steady_speedup": entry["steady_speedup"],
            "cache_hit_rate": entry["cache"]["hit_rate"],
            "cache_hits": entry["cache"]["hits"],
            "cache_misses": entry["cache"]["misses"],
            "pilot_scanned_bytes": entry["scanned_bytes"]["pilot"],
            "final_scanned_bytes": entry["scanned_bytes"]["final"],
            "scanned_bytes_equal": entry["scanned_bytes_equal"],
        }
    with open(BENCH_COMPILED_PATH, "w") as f:
        json.dump(doc, f, indent=1, default=float)
    print(f"# wrote {os.path.normpath(BENCH_COMPILED_PATH)}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single bench: guarantees|naive_clt|scan|"
                         "speedup|quickr|ablation|kernels|compiled|runtime|"
                         "dist|staged|stream|obs|fused")
    args = ap.parse_args()

    from benchmarks import (bench_ablation, bench_compiled, bench_dist,
                            bench_fused, bench_guarantees, bench_kernels,
                            bench_naive_clt, bench_obs, bench_quickr,
                            bench_runtime, bench_scan, bench_speedup,
                            bench_staged, bench_stream)

    benches = {
        "scan": bench_scan.run,              # Fig. 4
        "guarantees": bench_guarantees.run,  # Fig. 6/7
        "speedup": bench_speedup.run,        # Fig. 8/9/10
        "quickr": bench_quickr.run,          # Fig. 11/12 + Table 5
        "ablation": bench_ablation.run,      # Tables 4/5, Lemma 4.1, Fig. 13-15
        "naive_clt": bench_naive_clt.run,    # Fig. 16/17 (Appendix A.1)
        "kernels": bench_kernels.run,        # kernel-layer system model
        "compiled": bench_compiled.run,      # eager vs compiled physical layer
        "runtime": bench_runtime.run,        # serving herd: async/share/cache
        "dist": bench_dist.run,              # shard-parallel execution
        "staged": bench_staged.run,          # pre-staged sample-catalog ladders
        "stream": bench_stream.run,          # progressive frames: TTFF vs final
        "obs": bench_obs.run,                # tracing overhead + audit honesty
        "fused": bench_fused.run,            # single-launch TAQA vs two-stage
    }
    todo = [args.only] if args.only else list(benches)
    print("name,us_per_call,derived")
    t0 = time.time()
    failed = []
    for name in todo:
        try:
            payload = benches[name]()
            if name == "compiled" and payload:
                _emit_bench_compiled(payload)
        except Exception as e:  # keep the harness going; failures are visible
            print(f"{name},nan,FAILED:{type(e).__name__}:{e}")
            failed.append(name)
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)
    if args.only and failed:
        # single-bench invocations are CI smoke gates: their internal
        # assertions (compile-miss bounds, bit-identity) must fail the step
        sys.exit(1)


if __name__ == "__main__":
    main()
