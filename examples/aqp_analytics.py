"""Grouped + join analytics with guarantees (the paper's harder cases).

    PYTHONPATH=src python examples/aqp_analytics.py

Demonstrates: Group-By queries (per-group guarantees via Boole allocation),
composite aggregates (AVG via the corrected division rule), and a PK-FK join
whose pilot collects Lemma-4.8 block-pair statistics.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import CompositeAgg, ErrorSpec, PilotDB, Query
from repro.engine import logical as L
from repro.engine.datagen import tpch_catalog
from repro.engine.executor import Executor
from repro.engine.expr import Col


def show(db, name, q, spec, seed=7):
    exact = db.exact(q)
    ans = db.query(q, spec, seed=seed)
    r = ans.report
    errs = []
    for i in range(len(ans.names)):
        for g in range(ans.values.shape[1]):
            t = exact.values[i, g]
            if exact.group_present[g] and np.isfinite(t) and abs(t) > 1e-9:
                errs.append(abs(ans.values[i, g] - t) / abs(t))
    frac = (r.pilot_scanned_bytes + r.final_scanned_bytes) / r.exact_scanned_bytes
    print(f"[{name}] max err {max(errs):.3%} (target {spec.error:.0%}), "
          f"scanned {frac:.1%}, plan={r.plan.rates if r.plan else r.fallback}")


def main():
    cat = tpch_catalog(scale_rows=2_000_000, block_rows=32, seed=0)
    db = PilotDB(Executor(cat), large_table_rows=100_000)
    spec = ErrorSpec(error=0.05, confidence=0.95)

    show(db, "grouped Q1", Query(
        child=L.Scan("lineitem"),
        aggs=(CompositeAgg("qty", "sum", Col("l_quantity")),
              CompositeAgg("avg_price", "avg", Col("l_extendedprice")),
              CompositeAgg("orders", "count")),
        group_by="l_returnflag", max_groups=3), spec)

    show(db, "join     ", Query(
        child=L.Filter(L.Join(L.Scan("lineitem"), L.Scan("orders"),
                              "l_orderkey", "o_orderkey"),
                       Col("o_orderdate") < 1200),
        aggs=(CompositeAgg("rev", "sum", Col("l_extendedprice")),)), spec)

    show(db, "ratio Q14", Query(
        child=L.Filter(L.Scan("lineitem"), Col("l_shipdate").between(400, 2200)),
        aggs=(CompositeAgg("promo_share", "ratio",
                           Col("l_extendedprice") * Col("l_discount") * Col("l_linestatus"),
                           expr2=Col("l_extendedprice") * Col("l_discount")),)), spec)


if __name__ == "__main__":
    main()
