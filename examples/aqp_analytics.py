"""Grouped + join analytics with guarantees, through the Session front door.

    PYTHONPATH=src python examples/aqp_analytics.py

Demonstrates the three client surfaces over one session:
  * plain SQL with `ERROR e% CONFIDENCE p%` (grouped, join, ratio queries),
  * the fluent builder (`session.table(...).where(...).agg(...)`),
  * the concurrent scheduler: a herd of structurally identical queries
    drains as one signature group, compiling once and running warm.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.api import Session, avg_, count_, sum_
from repro.engine.datagen import tpch_catalog
from repro.engine.expr import Col


def show(name, approx, exact, error_target):
    errs = []
    a, e = approx.result(), exact.result()
    for i in range(len(a.names)):
        for g in range(a.values.shape[1]):
            t = e.values[i, g]
            if e.group_present[g] and np.isfinite(t) and abs(t) > 1e-9:
                errs.append(abs(a.values[i, g] - t) / abs(t))
    r = approx.report
    frac = (r.pilot_scanned_bytes + r.final_scanned_bytes) / r.exact_scanned_bytes
    print(f"[{name}] max err {max(errs):.3%} (target {error_target:.0%}), "
          f"scanned {frac:.1%}, plan={r.plan.rates if r.plan else r.fallback}")


def main():
    rows = int(os.environ.get("EXAMPLE_ROWS", 2_000_000))
    catalog = tpch_catalog(scale_rows=rows, block_rows=32, seed=0)
    session = Session(catalog, seed=7)

    # -- SQL front door ------------------------------------------------------
    grouped = ("SELECT SUM(l_quantity) AS qty, AVG(l_extendedprice) AS avg_price, "
               "COUNT(*) AS orders FROM lineitem GROUP BY l_returnflag "
               "ERROR 5% CONFIDENCE 95%")
    show("grouped Q1", session.sql(grouped),
         session.sql(grouped.split(" ERROR")[0]), 0.05)

    join = ("SELECT SUM(l_extendedprice) AS rev FROM lineitem "
            "JOIN orders ON l_orderkey = o_orderkey WHERE o_orderdate < 1200 "
            "ERROR 5% CONFIDENCE 95%")
    show("join     ", session.sql(join), session.sql(join.split(" ERROR")[0]), 0.05)

    ratio = ("SELECT SUM(l_extendedprice * l_discount * l_linestatus) / "
             "SUM(l_extendedprice * l_discount) AS promo_share FROM lineitem "
             "WHERE l_shipdate BETWEEN 400 AND 2200 ERROR 5% CONFIDENCE 95%")
    show("ratio Q14", session.sql(ratio), session.sql(ratio.split(" ERROR")[0]), 0.05)

    # -- fluent builder (lowers to the identical internal plan) --------------
    builder = (session.table("lineitem")
               .where(Col("l_shipdate") < 2400)
               .group_by("l_returnflag")
               .agg(sum_(Col("l_quantity")).as_("qty"),
                    avg_(Col("l_extendedprice")).as_("avg_price"),
                    count_().as_("orders"))
               .error(0.05, 0.95))
    approx = builder.run()
    exact = (session.table("lineitem")
             .where(Col("l_shipdate") < 2400)
             .group_by("l_returnflag")
             .agg(sum_(Col("l_quantity")).as_("qty"),
                  avg_(Col("l_extendedprice")).as_("avg_price"),
                  count_().as_("orders"))
             .run())
    show("builder  ", approx, exact, 0.05)

    # -- concurrent runtime: one pilot + cached answers for a herd -----------
    herd_sql = ("SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
                "WHERE l_quantity < 24 ERROR 8% CONFIDENCE 95%")
    warm = session.sql(herd_sql)  # warms compile caches AND the result cache
    handles = [session.submit(herd_sql) for _ in range(16)]
    session.drain()
    stats = session.scheduler.last_drain
    print(f"[runtime] {stats.n_queries} identical queries in "
          f"{stats.n_groups} group(s): {stats.pilots_run} pilot stage(s), "
          f"{stats.compile_misses} new compilations, "
          f"{stats.result_hits} answers from the result cache, "
          f"{stats.wall_time_s*1e3:.0f} ms total")
    assert all(h.status == "done" for h in handles)
    # cached answers are the warm query's original guaranteed answer
    assert all(h.scalar("rev") == warm.scalar("rev") for h in handles)


if __name__ == "__main__":
    main()
