"""Train a small LM end-to-end with the full runtime stack:

AQP-planned data mixture -> sharded AdamW + microbatching -> checkpoints
(+ resume) -> guaranteed-error approximate eval.

    PYTHONPATH=src python examples/train_tiny.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main


def main():
    with tempfile.TemporaryDirectory() as ck:
        train_main(["--arch", "internlm2-1.8b", "--reduced", "--steps", "40",
                    "--batch", "8", "--seq", "64", "--ckpt-dir", ck,
                    "--ckpt-every", "20", "--aqp-mixture", "--approx-eval"])
        print("-- simulating restart from checkpoint --")
        train_main(["--arch", "internlm2-1.8b", "--reduced", "--steps", "45",
                    "--batch", "8", "--seq", "64", "--ckpt-dir", ck,
                    "--resume"])


if __name__ == "__main__":
    main()
