"""Quickstart: approximate a SQL query with an a-priori error guarantee.

    PYTHONPATH=src python examples/quickstart.py

Builds a TPC-H-like catalog (EXAMPLE_ROWS rows, default 2M), opens a
:class:`repro.api.Session` — the middleware front door — and answers plain
SQL extended with the paper's `ERROR e% CONFIDENCE p%` clause (§2.4) via
PilotDB's two-stage TAQA algorithm with BSAP block-sampling statistics.
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import Session
from repro.engine.datagen import tpch_catalog

SQL = """
SELECT SUM(l_extendedprice * l_discount) AS revenue FROM lineitem
WHERE l_shipdate BETWEEN 100 AND 1500 AND l_discount BETWEEN 0.02 AND 0.08
ERROR 5% CONFIDENCE 95%
"""


def main():
    rows = int(os.environ.get("EXAMPLE_ROWS", 2_000_000))
    print(f"building {rows:,}-row catalog ...")
    catalog = tpch_catalog(scale_rows=rows, block_rows=32, seed=0)
    session = Session(catalog, seed=42)

    t0 = time.perf_counter()
    exact = session.sql(SQL.split("ERROR")[0])  # same query, no ERROR clause
    t_exact = time.perf_counter() - t0

    t0 = time.perf_counter()
    approx = session.sql(SQL)
    t_aqp = time.perf_counter() - t0

    r = approx.report
    err = abs(approx.scalar("revenue") - exact.scalar("revenue")) \
        / exact.scalar("revenue")
    scanned = r.pilot_scanned_bytes + r.final_scanned_bytes
    print(f"exact  : {exact.scalar('revenue'):.6g}   "
          f"({t_exact*1e3:.0f} ms, full scan)")
    print(f"approx : {approx.scalar('revenue'):.6g}   ({t_aqp*1e3:.0f} ms)")
    print(f"achieved error {err:.3%}  (guaranteed <= 5.0% w.p. 95%)")
    print(f"sampling plan  {r.plan.rates if r.plan else r.fallback}")
    print(f"scanned {scanned/r.exact_scanned_bytes:.1%} of the data "
          f"({r.exact_scanned_bytes/scanned:.0f}x fewer bytes)")
    assert approx.status == "done", approx.error
    assert err <= 0.05 or r.fallback is not None  # guarantee held (or exact)


if __name__ == "__main__":
    main()
