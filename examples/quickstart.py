"""Quickstart: approximate a query with an a-priori error guarantee.

    PYTHONPATH=src python examples/quickstart.py

Builds a 2M-row TPC-H-like catalog, then answers
  SELECT SUM(l_extendedprice * l_discount) FROM lineitem
  WHERE l_shipdate BETWEEN 100 AND 1500 AND l_discount BETWEEN 0.02 AND 0.08
  ERROR 5% CONFIDENCE 95%
via PilotDB's two-stage TAQA algorithm with BSAP block-sampling statistics.
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import CompositeAgg, ErrorSpec, PilotDB, Query
from repro.engine import logical as L
from repro.engine.datagen import tpch_catalog
from repro.engine.executor import Executor
from repro.engine.expr import And, Col


def main():
    print("building 2M-row catalog ...")
    cat = tpch_catalog(scale_rows=2_000_000, block_rows=32, seed=0)
    db = PilotDB(Executor(cat), large_table_rows=100_000)

    pred = And(Col("l_shipdate").between(100, 1500),
               Col("l_discount").between(0.02, 0.08))
    q = Query(child=L.Filter(L.Scan("lineitem"), pred),
              aggs=(CompositeAgg("revenue", "sum",
                                 Col("l_extendedprice") * Col("l_discount")),))
    spec = ErrorSpec(error=0.05, confidence=0.95)

    t0 = time.perf_counter()
    exact = db.exact(q)
    t_exact = time.perf_counter() - t0

    t0 = time.perf_counter()
    ans = db.query(q, spec, seed=42)
    t_aqp = time.perf_counter() - t0

    r = ans.report
    err = abs(ans.scalar("revenue") - exact.scalar("revenue")) / exact.scalar("revenue")
    scanned = r.pilot_scanned_bytes + r.final_scanned_bytes
    print(f"exact  : {exact.scalar('revenue'):.6g}   ({t_exact*1e3:.0f} ms, full scan)")
    print(f"approx : {ans.scalar('revenue'):.6g}   ({t_aqp*1e3:.0f} ms)")
    print(f"achieved error {err:.3%}  (guaranteed <= 5.0% w.p. 95%)")
    print(f"sampling plan  {r.plan.rates if r.plan else r.fallback}")
    print(f"scanned {scanned/r.exact_scanned_bytes:.1%} of the data "
          f"({r.exact_scanned_bytes/scanned:.0f}x fewer bytes)")


if __name__ == "__main__":
    main()
