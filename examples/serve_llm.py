"""End-to-end driver (paper kind = serving): batched LLM requests.

    PYTHONPATH=src python examples/serve_llm.py

Serves a reduced rwkv6 model (O(1)-state decode) with slot-based continuous
batching, then a GQA transformer — same engine, same compiled graph per arch.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve_main


def main():
    print("== rwkv6 (attention-free, O(1) state) ==")
    serve_main(["--arch", "rwkv6-7b", "--reduced", "--requests", "6",
                "--slots", "3", "--max-new", "12"])
    print("== internlm2 (GQA attention, KV cache) ==")
    serve_main(["--arch", "internlm2-1.8b", "--reduced", "--requests", "6",
                "--slots", "3", "--max-new", "12"])


if __name__ == "__main__":
    main()
