"""The paper's technique as a training-framework feature: guaranteed-error
approximate evaluation (see src/repro/aqpeval/).

    PYTHONPATH=src python examples/approx_eval.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.aqpeval import GuaranteedEvaluator
from repro.configs import get_config
from repro.models import build_model


def main():
    cfg = get_config("internlm2-1.8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_blocks, bsz, seq = 96, 2, 32
    rng = np.random.default_rng(1)
    shards = rng.integers(0, cfg.vocab_size, (n_blocks, bsz, seq + 1))

    @jax.jit
    def shard_loss(tokens):
        logits, _ = model.forward(params, {"tokens": tokens[:, :-1]})
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        return -jnp.take_along_axis(lp, tokens[:, 1:, None], axis=-1).sum()

    calls = {"n": 0}

    def block_metric(ids):
        calls["n"] += len(ids)
        sums = np.array([float(shard_loss(jnp.asarray(shards[i]))) for i in ids])
        return sums, np.full(len(ids), bsz * seq, float)

    ev = GuaranteedEvaluator(n_blocks, block_metric, seed=3)
    res = ev.evaluate(error=0.05, confidence=0.9, pilot_blocks=16)
    s, c = block_metric(np.arange(n_blocks))
    truth = s.sum() / c.sum()
    print(f"approx eval loss : {res.estimate:.4f}  (<=5% error w.p. 90%)")
    print(f"exact eval loss  : {truth:.4f}  (achieved {abs(res.estimate-truth)/truth:.2%})")
    print(f"model calls      : {res.pilot_blocks + res.final_blocks}/{res.total_blocks} "
          f"shards ({res.blocks_saved_frac:.0%} of eval compute saved)")


if __name__ == "__main__":
    main()
