"""Per-architecture smoke tests (deliverable f): reduced configs, one
forward/train step on CPU, shape + no-NaN asserts, and serving consistency
(prefill+decode == teacher-forced forward)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config, list_architectures
from repro.models import build_model
from repro.models.model import padded_vocab

RNG = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=16, with_labels=False):
    key = jax.random.PRNGKey(7)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (b, cfg.enc_seq, cfg.d_model),
                                            jnp.float32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (b, cfg.num_patches, cfg.d_model), jnp.float32)
    if with_labels:
        s_out = s + (cfg.num_patches if cfg.family == "vlm" else 0)
        batch["labels"] = jax.random.randint(key, (b, s_out), 0, cfg.vocab_size)
    return batch


def test_registry_contains_all_ten():
    assert len(ARCHITECTURES) == 10
    assert set(list_architectures()) == set(ARCHITECTURES)
    with pytest.raises(KeyError):
        get_config("not-a-model")


@pytest.mark.parametrize("arch", sorted(ARCHITECTURES))
def test_full_config_matches_assignment(arch):
    cfg = ARCHITECTURES[arch]
    spec = {
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "rwkv6-7b": (32, 4096, 0, 0, 14336, 65536),
    }[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab_size) == spec
    if arch == "granite-moe-1b-a400m":
        assert (cfg.num_experts, cfg.top_k) == (32, 8)
    if arch == "olmoe-1b-7b":
        assert (cfg.num_experts, cfg.top_k) == (64, 8)
    if arch == "hymba-1.5b":
        assert cfg.ssm_state == 16 and cfg.sub_quadratic
    if arch == "rwkv6-7b":
        assert cfg.attn_free and cfg.sub_quadratic


@pytest.mark.parametrize("arch", sorted(ARCHITECTURES))
def test_smoke_forward_step(arch):
    cfg = ARCHITECTURES[arch].reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    batch = make_batch(cfg)
    logits, aux = model.forward(params, batch)
    extra = cfg.num_patches if cfg.family == "vlm" else 0
    assert logits.shape == (2, 16 + extra, padded_vocab(cfg))
    arr = np.asarray(logits, np.float32)
    assert np.isfinite(arr).all(), f"{arch} produced non-finite logits"
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", sorted(ARCHITECTURES))
def test_smoke_train_gradient_step(arch):
    """One SGD step decreases nothing NaN-ish and produces finite grads."""
    cfg = ARCHITECTURES[arch].reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    batch = make_batch(cfg, with_labels=True)

    def loss_fn(p):
        logits, aux = model.forward(p, batch)
        labels = batch["labels"]
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1).mean()
        return nll + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in leaves)
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves)


@pytest.mark.parametrize("arch", sorted(ARCHITECTURES))
def test_decode_matches_teacher_forcing(arch):
    # generous MoE capacity so routing is dropless in both paths
    cfg = ARCHITECTURES[arch].reduced(capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(RNG)
    b, s, split = 1, 12, 6
    batch = make_batch(cfg, b=b, s=s)
    toks = batch["tokens"]
    full, _ = model.forward(params, batch)
    off = cfg.num_patches if cfg.family == "vlm" else 0

    pre = dict(batch)
    pre["tokens"] = toks[:, :split]
    lg, cache = model.prefill(params, pre, cache_len=32)
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(full[:, off + split - 1], np.float32),
                               rtol=2e-2, atol=2e-2)
    for t in range(split, s):
        lg, cache = model.decode_step(params, cache, toks[:, t])
        np.testing.assert_allclose(
            np.asarray(lg, np.float32),
            np.asarray(full[:, off + t], np.float32), rtol=3e-2, atol=3e-2)


def test_sliding_window_ring_buffer_long_decode():
    """hymba: decoding far past the window keeps shapes/values sane."""
    cfg = ARCHITECTURES["hymba-1.5b"].reduced(sliding_window=8)
    model = build_model(cfg)
    params = model.init(RNG)
    batch = make_batch(cfg, b=1, s=4)
    _, cache = model.prefill(params, batch, cache_len=64)
    assert cache["k"].shape[3] == 8  # ring bounded by the window
    for t in range(20):  # well past the window
        lg, cache = model.decode_step(params, cache, jnp.zeros((1,), jnp.int32))
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    assert int(cache["pos"][0]) == 24


def test_rwkv_state_is_constant_size():
    cfg = ARCHITECTURES["rwkv6-7b"].reduced()
    model = build_model(cfg)
    spec = model.cache_spec(batch=1, cache_len=1 << 19)  # 500k context
    assert "k" not in spec  # attention-free: no KV cache at all
    state_bytes = np.prod(spec["ssm"].shape) * 4
    assert state_bytes < 1 << 20  # O(1), independent of the 500k length


def test_vocab_padding_multiple_of_128():
    for cfg in ARCHITECTURES.values():
        assert padded_vocab(cfg) % 128 == 0
        assert padded_vocab(cfg) >= cfg.vocab_size
