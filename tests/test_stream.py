"""Progressive answer streaming (repro.stream): monotone frame contract.

The hard contract under test everywhere: intermediate frames are ADVISORY
and flagged as such, and the terminal FinalFrame is BITWISE identical to the
non-streaming ``handle.answer`` for the same query on an equal-seed session
— for every configuration (solo, shared-pilot herd, batched finals, cached
re-issues, staged ladders, every shard count) — while ``stream=False`` (the
default) is exactly today's behavior.
"""

import dataclasses as dc
import math

import numpy as np
import pytest

from repro.api import (ErrorFrame, ExactFrame, FinalFrame, PilotFrame,
                       Session, SessionConfig)
from repro.core.taqa import advisory_estimate
from repro.engine.datagen import tpch_catalog
from repro.serve.sql_gateway import SqlGateway
from repro.stream import FrameBuffer, Frame

HERD_SQL = ("SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
            "WHERE l_quantity < 24 ERROR 8% CONFIDENCE 95%")
# post-aggregation clauses (HAVING / ORDER BY / LIMIT) go before the spec
GROUPED_TEMPLATE = ("SELECT SUM(l_quantity) AS q, COUNT(*) AS n FROM "
                    "lineitem WHERE l_quantity < 30 GROUP BY l_returnflag "
                    "MAXGROUPS 3{suffix} ERROR 10% CONFIDENCE 90%")

SERIAL_CFG = SessionConfig(async_workers=0, share_pilots=False,
                           result_cache_size=0)
NOCACHE_CFG = SessionConfig(async_workers=4, result_cache_size=0)


@pytest.fixture(scope="module")
def catalog():
    return tpch_catalog(scale_rows=200_000, block_rows=32, seed=0)


def _assert_bitwise(answer_a, answer_b):
    assert np.array_equal(answer_a.values, answer_b.values)
    assert np.array_equal(answer_a.group_present, answer_b.group_present)
    assert list(answer_a.names) == list(answer_b.names)


# ---------------------------------------------------------------------------
# FrameBuffer mechanics
# ---------------------------------------------------------------------------

def test_frame_buffer_orders_and_closes():
    buf = FrameBuffer(7)
    buf.push(Frame(query_id=7))
    f2 = buf.push(ErrorFrame(query_id=7, error="x"))
    assert [f.seq for f in buf.frames()] == [0, 1]
    assert buf.closed and f2.terminal
    # post-terminal pushes are no-ops: the stream already ended
    buf.push(Frame(query_id=7))
    assert len(buf.frames()) == 2
    # iterating a finished stream terminates without blocking
    assert [f.seq for f in buf.stream()] == [0, 1]


def test_frames_carry_monotone_emitted_at(catalog):
    """Satellite contract: every frame is stamped with ``emitted_at`` —
    seconds since the query's submission (the buffer's t0 is the handle's
    ``t_submit``) — non-negative and monotone in seq, so TTFF is simply the
    first frame's stamp, with no cross-frame arithmetic."""
    s = Session(catalog, seed=3, config=SERIAL_CFG)
    h = s.sql(HERD_SQL, stream=True)
    frames = list(h.stream())
    assert len(frames) == 2
    stamps = [f.emitted_at for f in frames]
    assert all(t >= 0.0 for t in stamps)
    assert stamps == sorted(stamps)  # monotone in seq
    # emitted_at is the t_emit clock rebased to the handle's submit epoch
    for f in frames:
        assert f.emitted_at == f.t_emit - h.t_submit
    # a standalone buffer (no explicit t0) self-anchors at construction
    buf = FrameBuffer(9)
    f = buf.push(Frame(query_id=9))
    assert f.emitted_at >= 0.0


def test_frame_buffer_callback_replays_backlog():
    buf = FrameBuffer(1)
    early = Frame(query_id=1)
    buf.push(early)
    seen = []
    buf.add_callback(seen.append)
    assert seen == [early]  # late subscription replays, in order
    late = ErrorFrame(query_id=1, error="e")
    buf.push(late)
    assert seen == [early, late]


def test_frame_buffer_stream_timeout():
    buf = FrameBuffer(2)
    with pytest.raises(TimeoutError):
        next(buf.stream(timeout=0.01))


# ---------------------------------------------------------------------------
# Solo path: frame shape, advisory flags, bitwise final
# ---------------------------------------------------------------------------

def test_solo_stream_pilot_then_bitwise_final(catalog):
    plain = Session(catalog, seed=3, config=SERIAL_CFG).sql(HERD_SQL)
    assert plain.fallback is None

    s = Session(catalog, seed=3, config=SERIAL_CFG)
    h = s.sql(HERD_SQL, stream=True)
    frames = list(h.stream())
    assert [type(f) for f in frames] == [PilotFrame, FinalFrame]
    pf, ff = frames
    assert pf.advisory and not pf.terminal
    assert ff.terminal and not ff.advisory
    assert [f.seq for f in frames] == [0, 1]
    assert pf.t_emit < ff.t_emit
    # the terminal frame IS the delivered answer object — bitwise identity
    # with the equal-seed non-streaming session follows
    assert ff.answer is h.answer
    _assert_bitwise(ff.answer, plain.answer)
    # the advisory estimate is in the right ballpark of the guaranteed one
    # (pilot CI is provisional, but a wildly-off point estimate means the
    # Hájek math broke)
    rel = abs(pf.scalar("rev") - ff.scalar("rev")) / abs(ff.scalar("rev"))
    assert rel < 0.5
    assert math.isfinite(pf.half_width("rev")) and pf.half_width("rev") > 0
    assert pf.n_pilot_blocks == h.report.n_pilot_blocks
    assert pf.confidence == 0.95


def test_stream_false_is_nonstreaming_default(catalog):
    s = Session(catalog, seed=3, config=SERIAL_CFG)
    h = s.sql(HERD_SQL)
    assert not h.streaming and h.frames() == []
    # enabling after the fact synthesizes a complete single-frame stream
    frames = list(h.stream())
    assert len(frames) == 1 and frames[0].terminal
    assert frames[0].answer is h.answer


def test_advisory_estimate_matches_hand_computed_t_interval(catalog):
    """PilotEstimate's SUM channel is the Hájek total with a two-sided
    t-interval on the pilot block sums — checked against a hand
    computation from the same PilotOutcome."""
    from repro.stats import student_t_ppf
    s = Session(catalog, seed=3, config=SERIAL_CFG)
    hq = s.prepare(HERD_SQL)
    outcome = s.db.run_pilot(hq.query, hq.spec, s._pilot_seed_for(hq))
    est = advisory_estimate(hq.query, outcome, hq.spec.confidence)
    bs = np.asarray(outcome.pilot.block_sums, dtype=np.float64)
    n_p, N = bs.shape[0], float(outcome.pilot.n_total_blocks)
    idx = outcome.comp_channels[0][0]
    want_val = N * bs[:, 0, idx].mean()
    t_q = student_t_ppf(1.0 - 0.025, n_p - 1)
    want_hw = N * t_q / np.sqrt(n_p) * bs[:, 0, idx].std(ddof=1)
    assert est.scalar("rev") == pytest.approx(want_val, rel=1e-12)
    assert est.half_width("rev") == pytest.approx(want_hw, rel=1e-12)
    assert est.n_pilot_blocks == outcome.pilot.n_sampled_blocks


def test_error_frame_on_captured_failure(catalog):
    s = Session(catalog, seed=3, config=SERIAL_CFG)
    h = s.submit("SELECT COUNT(*) AS n FROM not_a_table GROUP BY g",
                 stream=True)
    s.drain()
    assert h.status == "failed"
    frames = list(h.stream())
    assert len(frames) == 1 and isinstance(frames[0], ErrorFrame)
    assert frames[0].terminal and frames[0].error == h.error


# ---------------------------------------------------------------------------
# Herd / shared pilot / batched finals
# ---------------------------------------------------------------------------

def test_herd_stream_shared_pilot_fanout_before_stage2(catalog):
    """Every herd member streams the shared pilot's advisory frame — and
    ALL pilot frames are emitted before ANY final frame (stage-2 dispatch
    starts only after the group's pilot fan-out re-joins)."""
    solo = Session(catalog, seed=11, config=SERIAL_CFG).sql(HERD_SQL)
    rt = Session(catalog, seed=11, config=NOCACHE_CFG)
    handles = [rt.submit(HERD_SQL, stream=True) for _ in range(5)]
    p0 = rt.executor.pilots_run
    rt.drain()
    assert rt.executor.pilots_run - p0 == 1  # streaming kept pilot sharing
    pilot_emits, final_emits = [], []
    for h in handles:
        frames = h.frames()
        assert [type(f) for f in frames] == [PilotFrame, FinalFrame]
        assert frames[0].shared  # fanned out from a shared pilot stage
        pilot_emits.append(frames[0].t_emit)
        final_emits.append(frames[1].t_emit)
        _assert_bitwise(frames[1].answer, solo.answer)
    assert max(pilot_emits) < min(final_emits)
    # one herd pilot stage => every member's advisory values are identical
    vals = {h.frames()[0].scalar("rev") for h in handles}
    assert len(vals) == 1
    stats = rt.scheduler.last_drain
    assert stats.frames_emitted == 10
    assert 0 < stats.time_to_first_frame_s < stats.time_to_final_s
    rt.close()


def test_batched_finals_stream_bitwise(catalog):
    """A constant-varied herd (batched finals, one pilot per constant)
    streams per-member FinalFrames bitwise identical to solo runs."""
    template = ("SELECT SUM(l_extendedprice) AS rev FROM lineitem "
                "WHERE l_quantity < {} ERROR 10% CONFIDENCE 90%")
    cuts = [18, 24, 30, 36]
    serial = Session(catalog, seed=9, config=SERIAL_CFG)
    want = {c: serial.sql(template.format(c)).answer for c in cuts}

    rt = Session(catalog, seed=9, config=NOCACHE_CFG)
    handles = {c: rt.submit(template.format(c), stream=True) for c in cuts}
    rt.drain()
    for c, h in handles.items():
        assert h.status == "done"
        ff = h.frames()[-1]
        assert ff.terminal
        _assert_bitwise(ff.answer, want[c])
    rt.close()


def test_mixed_streaming_and_plain_members_bitwise(catalog):
    """stream=True members riding a drain with stream=False peers change
    nothing for either: both match the serial solo answer bitwise."""
    solo = Session(catalog, seed=11, config=SERIAL_CFG).sql(HERD_SQL)
    rt = Session(catalog, seed=11, config=NOCACHE_CFG)
    hs = rt.submit(HERD_SQL, stream=True)
    hp = rt.submit(HERD_SQL)
    rt.drain()
    assert not hp.streaming and hp.frames() == []
    _assert_bitwise(hs.answer, solo.answer)
    _assert_bitwise(hp.answer, solo.answer)
    rt.close()


def test_on_frame_callback_and_late_subscription(catalog):
    s = Session(catalog, seed=3, config=SERIAL_CFG)
    live = []
    h = s.prepare(HERD_SQL, stream=True)
    h.on_frame(live.append)
    s.scheduler.submit(h)
    s.drain()
    assert [type(f) for f in live] == [PilotFrame, FinalFrame]
    # a late subscriber replays the full backlog in order
    replay = []
    h.on_frame(replay.append)
    assert [f.seq for f in replay] == [f.seq for f in live]


# ---------------------------------------------------------------------------
# Cached re-issues
# ---------------------------------------------------------------------------

def test_cached_stream_replays_pilot_summary(catalog):
    s = Session(catalog, seed=13)
    first = s.sql(HERD_SQL, stream=True)
    assert not first.cached
    again = s.sql(HERD_SQL, stream=True)
    assert again.cached
    frames = again.frames()
    assert [type(f) for f in frames] == [PilotFrame, FinalFrame]
    assert frames[0].from_cache  # replayed from the CachedAnswer record
    assert frames[1].cached
    # the replayed summary is the one the original pilot produced
    assert frames[0].scalar("rev") == first.frames()[0].scalar("rev")
    _assert_bitwise(frames[1].answer, first.frames()[1].answer)
    s.close()


def test_cached_entry_without_pilot_streams_single_frame(catalog):
    """Exact entries record no pilot summary: a streaming cache hit then
    emits only its terminal (Exact) frame."""
    s = Session(catalog, seed=13)
    sql = "SELECT COUNT(*) AS n FROM lineitem"  # no spec: requested exact
    first = s.sql(sql)
    assert first.fallback is not None
    again = s.sql(sql, stream=True)
    assert again.cached
    frames = again.frames()
    assert len(frames) == 1 and isinstance(frames[0], ExactFrame)
    s.close()


def test_result_cache_bytes_account_for_pilot_summary(catalog):
    """CachedAnswer.nbytes() charges the recorded pilot summary, keeping
    result_cache_bytes honest."""
    from repro.runtime import CachedAnswer
    s = Session(catalog, seed=13)
    h = s.sql(HERD_SQL, stream=True)
    est = h.frames()[0]
    base = CachedAnswer.from_answer(h.answer)
    key = s._cache_key(h)
    entry = s.result_cache.get(key)
    assert entry.pilot is not None
    assert entry.nbytes() == base.nbytes() + entry.pilot.nbytes()
    assert entry.pilot.nbytes() < 4096  # compact: summaries, not matrices
    # and the byte meter reflects what the entries report
    assert s.result_cache_info().bytes_used >= entry.nbytes()
    s.close()


# ---------------------------------------------------------------------------
# HAVING + ORDER BY/LIMIT interaction matrix (streamed vs plain, cached, dist)
# ---------------------------------------------------------------------------

_SUFFIXES = [
    "",
    " HAVING q >= 100",
    " ORDER BY q DESC LIMIT 2",
    " HAVING q >= 100 ORDER BY q ASC LIMIT 1",
]


@pytest.mark.parametrize("suffix", _SUFFIXES)
def test_having_limit_matrix_streamed_bitwise(catalog, suffix):
    sql = GROUPED_TEMPLATE.format(suffix=suffix)
    plain = Session(catalog, seed=21, config=SERIAL_CFG).sql(sql)
    s = Session(catalog, seed=21, config=SERIAL_CFG)
    h = s.sql(sql, stream=True)
    ff = h.frames()[-1]
    assert ff.terminal and ff.answer is h.answer
    # the frame carries the POST-HAVING/LIMIT delivered answer
    _assert_bitwise(ff.answer, plain.answer)


@pytest.mark.parametrize("suffix", _SUFFIXES)
def test_having_limit_matrix_cached_stream_bitwise(catalog, suffix):
    s = Session(catalog, seed=22)
    sql = GROUPED_TEMPLATE.format(suffix=suffix)
    first = s.sql(sql)
    again = s.sql(sql, stream=True)
    assert again.cached
    _assert_bitwise(again.frames()[-1].answer, first.answer)
    s.close()


@pytest.mark.parametrize("shards", [1, 2, 4])
def test_shard_counts_stream_bitwise(catalog, shards):
    """Streamed finals across every shard count match the monolithic
    serial answer bitwise, HAVING/LIMIT included."""
    sql = GROUPED_TEMPLATE.format(
        suffix=" HAVING q >= 100 ORDER BY q DESC LIMIT 2")
    mono = Session(catalog, seed=31, config=SERIAL_CFG).sql(sql)
    s = Session(seed=31, config=SERIAL_CFG)
    for name, tab in catalog.items():
        if name == "lineitem":
            s.register_table(name, tab, shards=shards)
        else:
            s.register_table(name, tab)
    h = s.sql(sql, stream=True)
    frames = h.frames()
    assert frames[-1].terminal
    if mono.fallback is None:
        assert isinstance(frames[0], PilotFrame)  # dist pilots stream too
    _assert_bitwise(frames[-1].answer, mono.answer)


def test_staged_stream_bitwise(catalog):
    """Streamed finals served from a staged ladder match the never-serving
    ladder reference bitwise (same pinned staging realization)."""
    def _run(rates, stream):
        s = Session(seed=41, config=SERIAL_CFG)
        for name, tab in catalog.items():
            s.register_table(name, tab,
                             staged_rates=rates if name == "lineitem"
                             else None)
        h = s.sql(HERD_SQL, stream=stream)
        hits = s.executor.staged_info()["hits"]
        return h, hits

    ref, _ = _run([1e-9], stream=False)     # ladder that never serves
    hot, hits = _run(True, stream=True)     # default ladder, streamed
    assert hits > 0  # the streamed run genuinely served staged rungs
    frames = hot.frames()
    assert isinstance(frames[0], PilotFrame) and frames[-1].terminal
    _assert_bitwise(frames[-1].answer, ref.answer)


# ---------------------------------------------------------------------------
# Gateway streaming
# ---------------------------------------------------------------------------

def test_gateway_submit_streaming_delivers_frames(catalog):
    session = Session(catalog, seed=5)
    gw = SqlGateway(session)
    t1 = gw.submit_streaming("alice", HERD_SQL)
    t2 = gw.submit("bob", HERD_SQL)  # plain ticket on the same drain
    results = gw.run()
    assert results[t1].status == "done" and results[t2].status == "done"
    frames = gw.frames_for("alice")
    assert [type(f) for f in frames] == [PilotFrame, FinalFrame]
    assert frames[1].answer is results[t1].answer
    assert gw.frames_for("alice") == []   # delivered once
    assert gw.frames_for("bob") == []     # plain tickets push no frames
    assert gw.stats.streams == 1
    assert gw.stats.frames_pushed == 2
    _assert_bitwise(results[t1].answer, results[t2].answer)
    session.close()


def test_gateway_streaming_parse_failure_is_terminal_frame(catalog):
    session = Session(catalog, seed=5)
    gw = SqlGateway(session)
    gw.submit_streaming("eve", "SELEKT 1")
    frames = gw.frames_for("eve")
    assert len(frames) == 1 and isinstance(frames[0], ErrorFrame)
    assert gw.stats.rejected == 1
    session.close()


def test_gateway_frame_queue_bounded_drops_oldest_advisory(catalog):
    session = Session(catalog, seed=6)
    gw = SqlGateway(session, max_frames_per_client=2)
    q1 = "SELECT SUM(l_quantity) AS q FROM lineitem ERROR 10% CONFIDENCE 90%"
    q2 = ("SELECT SUM(l_extendedprice) AS r FROM lineitem "
          "ERROR 10% CONFIDENCE 90%")
    gw.submit_streaming("c", q1)
    gw.submit_streaming("c", q2)
    gw.run()
    frames = gw.frames_for("c")
    # 4 frames were emitted into a 2-bounded queue: advisory frames gave
    # way, every terminal frame survived
    assert gw.stats.frames_dropped >= 1
    terminals = [f for f in frames if f.terminal]
    assert len(terminals) == 2
    session.close()


def test_gateway_stats_payload_staged_schema_pinned(catalog):
    """Satellite contract: payload['staged'] is ALWAYS present with the
    full key schema, zeroed when nothing is staged."""
    session = Session(catalog, seed=5)
    payload = SqlGateway(session).stats_payload()
    assert set(payload["staged"]) >= {"hits", "misses", "evictions",
                                      "resident_bytes", "max_bytes",
                                      "tables"}
    assert payload["staged"]["hits"] == 0
    assert payload["staged"]["tables"] == {}
    assert payload["gateway"]["streams"] == 0
    session.close()
