"""Sampling-equivalence rules (Props. 4.4-4.6) — property-based.

The physical rules hold *pathwise*: conditioned on the kept-block set, the
pre- and post-sampled pipelines produce identical surviving multisets.  Since
block sampling draws the kept set with the same distribution in both orders,
pathwise equality over the shared coupling implies Definition 4.2 equivalence
and hence Prop. 4.3 (identical aggregate distributions).  Hypothesis sweeps
tables, predicates, and kept sets; one test also verifies Prop. 4.3's
consequence numerically by exhaustive enumeration over all 2^N kept sets.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this container")
from hypothesis import given, settings, strategies as st

from repro.core import equivalence as EQ
from repro.engine import ops
from repro.engine.expr import Col
from repro.engine.table import BlockTable


def _table(rows, br, seed, name="t", key_mod=None):
    rng = np.random.default_rng(seed)
    cols = {
        "k": (np.arange(rows) % (key_mod or max(rows // 2, 1))).astype(np.int32),
        "x": rng.normal(5.0, 2.0, rows).astype(np.float32),
        "g": rng.integers(0, 3, rows).astype(np.int32),
    }
    return BlockTable.from_numpy(name, cols, br)


def _rows_equal(a, b):
    assert a["cols"] == b["cols"]
    np.testing.assert_allclose(a["rows"], b["rows"], rtol=1e-5, atol=1e-5)


keep_strategy = st.builds(
    lambda n, bits: np.array([i for i in range(n) if (bits >> i) & 1], dtype=np.int32),
    st.just(6), st.integers(min_value=0, max_value=63),
)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), thresh=st.floats(2.0, 8.0),
       bits=st.integers(1, 63))
def test_selection_commutes(seed, thresh, bits):
    t = _table(48, 8, seed)  # 6 blocks
    keep = np.array([i for i in range(6) if (bits >> i) & 1], dtype=np.int32)
    pred = Col("x") > thresh
    a = EQ.sample_then_filter(t, keep, pred)
    b = EQ.filter_then_sample(t, keep, pred)
    _rows_equal(EQ.surviving_rows(a), EQ.surviving_rows(b))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), bits=st.integers(1, 63))
def test_join_commutes(seed, bits):
    rng = np.random.default_rng(seed)
    left = _table(48, 8, seed, "l", key_mod=12)
    right = BlockTable.from_numpy(
        "r", {"pk": np.arange(12, dtype=np.int32),
              "w": rng.normal(size=12).astype(np.float32)}, 4)
    keep = np.array([i for i in range(6) if (bits >> i) & 1], dtype=np.int32)
    a = EQ.sample_then_join(left, keep, right, "k", "pk")
    b = EQ.join_then_sample(left, keep, right, "k", "pk")
    _rows_equal(EQ.surviving_rows(a), EQ.surviving_rows(b))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), bits1=st.integers(0, 15), bits2=st.integers(0, 15))
def test_union_commutes(seed, bits1, bits2):
    t1 = _table(32, 8, seed, "a")
    t2 = _table(32, 8, seed + 1, "b")
    k1 = np.array([i for i in range(4) if (bits1 >> i) & 1], dtype=np.int32)
    k2 = np.array([i for i in range(4) if (bits2 >> i) & 1], dtype=np.int32)
    if len(k1) + len(k2) == 0:
        return
    a = EQ.sample_then_union([t1, t2], [k1, k2])
    b = EQ.union_then_sample([t1, t2], [k1, k2])
    _rows_equal(EQ.surviving_rows(a), EQ.surviving_rows(b))


def test_prop_4_3_aggregate_distribution_exhaustive():
    """Prop. 4.3 consequence: SUM over pre- vs post-sampled pipelines has the
    identical distribution — verified exactly by enumerating all kept sets."""
    t = _table(40, 8, seed=9)  # 5 blocks
    pred = Col("x") > 5.0
    dist_a, dist_b = {}, {}
    for bits in range(1 << 5):
        keep = np.array([i for i in range(5) if (bits >> i) & 1], dtype=np.int32)
        if len(keep) == 0:
            continue
        sa = EQ.sample_then_filter(t, keep, pred)
        sb = EQ.filter_then_sample(t, keep, pred)
        for table, dist in ((sa, dist_a), (sb, dist_b)):
            d = table.to_numpy()
            v = round(float(d["x"].sum()), 3)
            dist[v] = dist.get(v, 0) + 1  # uniform over kept sets
    assert dist_a == dist_b


def test_normalize_accepts_scan_level_samples():
    from repro.engine import logical as L

    plan = L.Aggregate(
        child=L.Scan("t", L.SampleClause("block", 0.1)),
        aggs=(L.AggSpec("sum", Col("x"), "s"),))
    assert EQ.normalize(plan) is plan
