"""Concurrent query runtime: async execution, shared pilots, result cache.

The load-bearing invariant everywhere: answers are a pure function of
(session seed, query content) — never of worker count, pilot sharing,
caching, or submission order.  Every test that turns a runtime feature on
checks bit-identity against a session with it off.
"""

import dataclasses as dc

import numpy as np
import pytest

from repro.api import BackpressureError, Session, SessionConfig
from repro.core.taqa import PilotDB
from repro.engine.datagen import make_lineitem, tpch_catalog
from repro.runtime import ResultCache
from repro.runtime.shared_pilot import subgroup_by_pilot

HERD_SQL = ("SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
            "WHERE l_quantity < 24 ERROR 8% CONFIDENCE 95%")

# the synchronous-cooperative baseline: no pool, no sharing, no cache
SERIAL_CFG = SessionConfig(async_workers=0, share_pilots=False,
                           result_cache_size=0)
# runtime on, cache off; workers pinned (async_workers=None auto-sizes to 0
# on <= 2-core hosts, and these tests exercise real async mechanics)
NOCACHE_CFG = SessionConfig(async_workers=4, result_cache_size=0)


@pytest.fixture(scope="module")
def catalog():
    return tpch_catalog(scale_rows=200_000, block_rows=32, seed=0)


# ---------------------------------------------------------------------------
# Acceptance: one pilot, <= 1 compilation, bit-identical to solo
# ---------------------------------------------------------------------------

def test_herd_one_pilot_one_compile_bit_identical(catalog):
    solo = Session(catalog, seed=11, config=SERIAL_CFG).sql(HERD_SQL)
    assert solo.status == "done" and solo.fallback is None

    rt = Session(catalog, seed=11, config=NOCACHE_CFG)
    warm = rt.sql(HERD_SQL)  # pays the pilot + both compilations once
    assert np.array_equal(warm.result().values, solo.result().values)
    handles = [rt.submit(HERD_SQL) for _ in range(5)]
    p0 = rt.executor.pilots_run
    m0 = rt.compile_cache_info().misses
    done = rt.drain()
    # N structurally identical queries: exactly ONE pilot stage and at most
    # one new physical compilation (a sample-size bucket boundary), asserted
    # via the executor's counters.
    assert rt.executor.pilots_run - p0 == 1
    assert rt.compile_cache_info().misses - m0 <= 1
    assert rt.scheduler.last_drain.pilots_run == 1
    # every runtime answer is bit-identical to the solo equal-seed run
    for h in done:
        assert h.status == "done"
        assert np.array_equal(h.result().values, solo.result().values)
    rt.close()


def test_shared_pilot_fans_out_to_member_specs(catalog):
    """Same structure, different ErrorSpecs: one pilot, per-member plans,
    each bit-identical to its own solo run."""
    base = ("SELECT SUM(l_extendedprice) AS rev FROM lineitem "
            "WHERE l_shipdate < 2000 ")
    sql_a, sql_b = base + "ERROR 8% CONFIDENCE 95%", base + "ERROR 4% CONFIDENCE 95%"
    serial = Session(catalog, seed=4, config=SERIAL_CFG)
    solo_a, solo_b = serial.sql(sql_a), serial.sql(sql_b)

    rt = Session(catalog, seed=4, config=NOCACHE_CFG)
    rt.sql(sql_a)  # warm
    ha = [rt.submit(sql_a) for _ in range(2)]
    hb = [rt.submit(sql_b) for _ in range(2)]
    p0 = rt.executor.pilots_run
    rt.drain()
    assert rt.executor.pilots_run - p0 == 1  # specs share the pilot stage
    for h in ha:
        assert np.array_equal(h.result().values, solo_a.result().values)
    for h in hb:
        assert np.array_equal(h.result().values, solo_b.result().values)
    # the tighter spec buys a higher sampling rate, from the same pilot
    rate = lambda h: list(h.report.plan.rates.values())[0]
    assert rate(hb[0]) > rate(ha[0])
    assert hb[1].report.pilot_shared and not ha[0].report.pilot_shared
    rt.close()


def test_share_pilots_off_is_bit_identical_but_pays_n_pilots(catalog):
    rt_off = Session(catalog, seed=11, config=dc.replace(
        NOCACHE_CFG, share_pilots=False))
    handles = [rt_off.submit(HERD_SQL) for _ in range(3)]
    p0 = rt_off.executor.pilots_run
    rt_off.drain()
    assert rt_off.executor.pilots_run - p0 == 3  # one pilot per member
    solo = Session(catalog, seed=11, config=SERIAL_CFG).sql(HERD_SQL)
    for h in handles:
        assert np.array_equal(h.result().values, solo.result().values)
    rt_off.close()


# ---------------------------------------------------------------------------
# Async execution: worker pool, poll/wait, ordering
# ---------------------------------------------------------------------------

def test_async_drain_matches_serial_across_groups(catalog):
    sqls = [
        "SELECT SUM(l_quantity) AS q FROM lineitem ERROR 10% CONFIDENCE 90%",
        "SELECT COUNT(*) AS n FROM lineitem WHERE l_shipdate < 2000 "
        "ERROR 10% CONFIDENCE 90%",
        "SELECT AVG(l_extendedprice) AS p FROM lineitem "
        "WHERE l_discount BETWEEN 0.02 AND 0.08 ERROR 10% CONFIDENCE 90%",
        "SELECT SUM(l_quantity) AS q FROM lineitem",
    ]
    serial = Session(catalog, seed=2, config=SERIAL_CFG)
    expected = [serial.sql(s) for s in sqls]
    conc = Session(catalog, seed=2, config=dc.replace(NOCACHE_CFG,
                                                      async_workers=4))
    handles = [conc.submit(s) for s in sqls for _ in range(2)]
    done = conc.drain()
    assert len(done) == 8 and all(h.status == "done" for h in done)
    for h in handles:
        ref = expected[sqls.index(h.sql)]
        assert np.array_equal(h.result().values, ref.result().values)
    conc.close()


def test_drain_async_poll_wait(catalog):
    session = Session(catalog, seed=6, config=NOCACHE_CFG)
    h = session.submit(HERD_SQL)
    assert h.poll() == "pending"
    dispatched = session.drain_async()  # returns without blocking
    assert [x.query_id for x in dispatched] == [h.query_id]
    assert session.scheduler.pending_count == 0
    assert h.wait(timeout=120), "query did not finish in time"
    assert h.poll() == "done" and h.scalar("rev") > 0
    assert session.runtime.wait_idle(timeout=120)
    assert session.runtime.in_flight == 0
    session.close()


def test_submission_fair_order_under_interleaved_submissions(catalog):
    """Interleaved submissions across three signatures drain in earliest-
    arrival group order with submission order inside each group — also under
    the concurrent runtime, which must not let completion order leak into
    the returned batch."""
    session = Session(catalog, seed=1, config=NOCACHE_CFG)
    sql_a = "SELECT SUM(l_quantity) AS qty FROM lineitem ERROR 10% CONFIDENCE 90%"
    sql_b = ("SELECT COUNT(*) AS n FROM lineitem WHERE l_shipdate < 2000 "
             "ERROR 10% CONFIDENCE 90%")
    sql_c = "SELECT COUNT(*) AS n FROM orders"
    order = [session.submit(s) for s in
             (sql_a, sql_b, sql_c, sql_a, sql_b, sql_a)]
    done = session.drain()
    stats = session.scheduler.last_drain
    assert stats.n_groups == 3 and sorted(stats.group_sizes) == [1, 2, 3]
    ids = [h.query_id for h in done]
    assert ids == [order[0].query_id, order[3].query_id, order[5].query_id,
                   order[1].query_id, order[4].query_id, order[2].query_id]
    # a second interleaved wave starts fresh: B arrives first this time
    wave2 = [session.submit(s) for s in (sql_b, sql_a, sql_b)]
    ids2 = [h.query_id for h in session.drain()]
    assert ids2 == [wave2[0].query_id, wave2[2].query_id, wave2[1].query_id]
    session.close()


def test_drain_stats_report_resolved_pool_widths(catalog):
    """DrainStats carries the pool widths the drain ACTUALLY ran on (the
    auto-sized runtime values), never the raw config knob — async_workers=0
    or None must not surface as a meaningless 0 in reports."""
    for cfg in (SessionConfig(async_workers=3, pilot_workers=2,
                              result_cache_size=0),
                SessionConfig(async_workers=None, result_cache_size=0)):
        session = Session(catalog, seed=2, config=cfg)
        session.submit("SELECT COUNT(*) AS n FROM orders")
        session.drain()
        stats = session.scheduler.last_drain
        assert stats.workers == session.runtime.workers \
            == cfg.resolve_workers()
        assert stats.pilot_workers == session.runtime.pilot_workers \
            == cfg.resolve_pilot_workers()
        session.close()


def test_drain_stats_reset_per_drain(catalog):
    """Satellite contract (pinned by DrainStats' docstring): every field is
    PER DRAIN — ``last_drain`` is replaced wholesale each call, counters
    never carry over; cumulative totals live in ``scheduler.total_drained``
    and the session metrics registry."""
    session = Session(catalog, seed=5, config=NOCACHE_CFG)
    session.submit(HERD_SQL)
    session.submit(HERD_SQL)
    session.drain()
    first = session.scheduler.last_drain
    assert first.n_queries == 2 and first.pilots_run == 1
    session.submit(HERD_SQL)
    session.drain()
    second = session.scheduler.last_drain
    assert second is not first            # replaced wholesale, not mutated
    assert second.n_queries == 1          # this drain's batch only
    assert second.pilots_run == 1         # NOT 2: no carry-over from drain 1
    assert first.n_queries == 2           # the first snapshot is untouched
    # cumulative totals accumulate elsewhere
    assert session.scheduler.total_drained == 3
    assert session.metrics.counter("pilotdb_drains_total").value == 2
    assert session.metrics.counter(
        "pilotdb_drained_queries_total").value == 3
    # an empty drain still reports a fresh zeroed snapshot
    session.drain()
    assert session.scheduler.last_drain.n_queries == 0
    session.close()


# ---------------------------------------------------------------------------
# Failure capture under the runtime
# ---------------------------------------------------------------------------

def test_member_failure_mid_group_captured_alone(catalog, monkeypatch):
    """One member's stage 2 raising mid-group fails that handle only."""
    base = ("SELECT SUM(l_extendedprice) AS rev FROM lineitem "
            "WHERE l_shipdate < 2000 ")
    sqls = [base + f"ERROR {e}% CONFIDENCE 95%" for e in (8, 7, 6)]
    session = Session(catalog, seed=5, config=NOCACHE_CFG)
    real = PilotDB.prepare_final

    def flaky(self, q, spec, outcome, seed, shared=False):
        if abs(spec.error - 0.07) < 1e-12:  # the middle member only
            raise RuntimeError("worker exploded mid-group")
        return real(self, q, spec, outcome, seed, shared=shared)

    monkeypatch.setattr(PilotDB, "prepare_final", flaky)
    handles = [session.submit(s) for s in sqls]
    done = session.drain()
    assert len(done) == 3
    assert handles[0].status == "done"
    assert handles[2].status == "done"
    assert handles[1].status == "failed"
    assert "worker exploded mid-group" in handles[1].error
    session.close()


def test_pilot_failure_fails_every_member(catalog, monkeypatch):
    session = Session(catalog, seed=5, config=NOCACHE_CFG)

    def doomed(self, q, spec, pilot_seed):
        raise RuntimeError("pilot scan died")

    monkeypatch.setattr(PilotDB, "run_pilot", doomed)
    handles = [session.submit(HERD_SQL) for _ in range(3)]
    session.drain()
    for h in handles:  # each member solo would have raised identically
        assert h.status == "failed" and "pilot scan died" in h.error
    session.close()


def test_worker_pool_captures_group_machinery_crash(catalog, monkeypatch):
    """A bug in the group runner itself must fail the handles, not lose
    them or kill the pool."""
    session = Session(catalog, seed=3, config=NOCACHE_CFG)

    def crash(self, group):
        raise RuntimeError("group machinery bug")

    monkeypatch.setattr(Session, "_execute_group", crash)
    h = session.submit("SELECT COUNT(*) AS n FROM lineitem")
    done = session.drain()
    assert done == [h]
    assert h.status == "failed" and "runtime worker error" in h.error
    monkeypatch.undo()
    # the pool survives: the next drain runs normally
    h2 = session.submit("SELECT COUNT(*) AS n FROM lineitem")
    session.drain()
    assert h2.status == "done"
    session.close()


# ---------------------------------------------------------------------------
# Result cache
# ---------------------------------------------------------------------------

def test_repeated_dashboard_answers_from_cache_with_original_report(catalog):
    session = Session(catalog, seed=12)
    first = session.sql(HERD_SQL)
    assert not first.cached
    q0 = session.executor.queries_run
    again = session.sql(HERD_SQL)
    assert again.cached
    assert session.executor.queries_run == q0  # no execution at all
    # the cache stores a compact record (values + report + packed bitmap),
    # not the ApproxAnswer graph: the rebuilt answer shares the original
    # values and the ORIGINAL a-priori error report
    assert again.answer is not first.answer
    assert again.answer.values is first.answer.values
    assert again.report is first.report
    assert np.array_equal(again.answer.group_present,
                          first.answer.group_present)
    info = session.result_cache_info()
    assert info.hits >= 1 and info.size >= 1
    assert info.bytes_used > 0
    session.close()


def test_register_table_invalidates_only_that_tables_entries(catalog):
    session = Session(dict(catalog), seed=8)
    line_sql = "SELECT SUM(l_quantity) AS q FROM lineitem"
    orders_sql = "SELECT COUNT(*) AS n FROM orders"
    join_sql = ("SELECT SUM(l_extendedprice) AS rev FROM lineitem "
                "JOIN orders ON l_orderkey = o_orderkey "
                "WHERE o_orderdate < 1200")
    v1 = session.sql(line_sql).scalar("q")
    session.sql(orders_sql)
    session.sql(join_sql)
    # replace lineitem with different data: its entries (including the join,
    # which merely scans it) must go; the orders entry must survive
    session.register_table(
        "lineitem", make_lineitem(200_000, 32, num_orders=50_000, seed=99))
    h_orders = session.sql(orders_sql)
    assert h_orders.cached
    h_line = session.sql(line_sql)
    assert not h_line.cached
    assert h_line.scalar("q") != v1  # computed against the new data
    h_join = session.sql(join_sql)
    assert not h_join.cached
    session.close()


def test_register_table_mid_flight_fails_handle_and_skips_cache(
        catalog, monkeypatch):
    """A query in flight across a register_table() replacement may be torn
    (old-data pilot scaling a new-data final): the handle must fail with a
    retryable error, and nothing may enter the result cache."""
    session = Session(dict(catalog), seed=14)
    sql = "SELECT SUM(l_quantity) AS q FROM lineitem"
    new_table = make_lineitem(200_000, 32, num_orders=50_000, seed=77)
    real_exact = PilotDB.exact

    def swapping_exact(self, q):
        ans = real_exact(self, q)
        session.register_table("lineitem", new_table)  # mid-flight swap
        return ans

    monkeypatch.setattr(PilotDB, "exact", swapping_exact)
    h = session.sql(sql)
    monkeypatch.undo()
    assert h.status == "failed"
    assert "replaced while the query was in flight" in h.error
    # the resubmission executes cleanly against the new data, uncached
    h2 = session.sql(sql)
    assert h2.status == "done" and not h2.cached
    session.close()


def test_resubmit_during_async_execution_not_double_queued(catalog, monkeypatch):
    """A retried submit() while a worker holds the handle must not re-queue
    (and so double-execute) it."""
    import threading
    session = Session(catalog, seed=15, config=NOCACHE_CFG)
    started, release = threading.Event(), threading.Event()
    real = Session._execute_group

    def gated(self, group):
        started.set()
        release.wait(timeout=60)
        return real(self, group)

    monkeypatch.setattr(Session, "_execute_group", gated)
    h = session.submit("SELECT COUNT(*) AS n FROM lineitem")
    session.drain_async()
    assert started.wait(timeout=60)
    session.scheduler.submit(h)  # retry while in flight: must be a no-op
    assert session.scheduler.pending_count == 0
    q0 = session.executor.queries_run
    release.set()
    assert h.wait(timeout=120) and h.status == "done"
    session.runtime.wait_idle(timeout=120)
    assert session.executor.queries_run - q0 == 1  # executed exactly once
    session.close()


def test_result_cache_lru_eviction():
    cache = ResultCache(capacity=2)
    cache.put("a", 1, ("t",))
    cache.put("b", 2, ("t",))
    assert cache.get("a") == 1       # refreshes "a" to most-recent
    cache.put("c", 3, ("u",))        # evicts "b", the LRU entry
    assert cache.get("b") is None
    assert cache.get("a") == 1 and cache.get("c") == 3
    info = cache.info()
    assert info.evictions == 1 and info.size == 2
    assert cache.invalidate_table("t") == 1  # only "a" scans t
    assert cache.get("a") is None and cache.get("c") == 3


def test_result_cache_byte_budget_evicts_lru_first():
    from repro.core.taqa import ApproxAnswer, TaqaReport
    from repro.runtime import CachedAnswer

    def entry(n_groups):
        ans = ApproxAnswer(names=["a"], values=np.zeros((1, n_groups)),
                           group_present=np.ones(n_groups, bool),
                           report=TaqaReport())
        return CachedAnswer.from_answer(ans)

    small = entry(8)
    # budget fits two small entries but not three
    cache = ResultCache(capacity=100, max_bytes=2 * small.nbytes() + 10)
    cache.put("a", entry(8), ("t",))
    cache.put("b", entry(8), ("t",))
    assert cache.get("a") is not None     # refresh: "b" becomes LRU
    cache.put("c", entry(8), ("t",))      # over budget: evicts "b"
    assert cache.get("b") is None
    assert cache.get("a") is not None and cache.get("c") is not None
    info = cache.info()
    assert info.evictions == 1 and info.bytes_used <= info.max_bytes
    # an entry larger than the whole budget is never admitted
    cache.put("huge", entry(100_000), ("t",))
    assert cache.get("huge") is None


def test_cached_answer_packs_group_present_bitmap():
    from repro.core.taqa import ApproxAnswer, TaqaReport
    from repro.runtime import CachedAnswer
    present = np.array([True, False, True] * 30 + [False])
    ans = ApproxAnswer(names=["x"], values=np.arange(91.0).reshape(1, 91),
                       group_present=present, report=TaqaReport())
    compact = CachedAnswer.from_answer(ans)
    assert compact.present_bits.nbytes == (91 + 7) // 8  # 8 groups per byte
    rebuilt = compact.to_answer()
    assert np.array_equal(rebuilt.group_present, present)
    assert rebuilt.values is compact.values
    assert rebuilt.report is ans.report


def test_session_result_cache_byte_budget(catalog):
    session = Session(catalog, seed=3, config=SessionConfig(
        result_cache_size=64, result_cache_bytes=2_000))
    sqls = [f"SELECT COUNT(*) AS n FROM lineitem WHERE l_shipdate < {c}"
            for c in (500, 1000, 1500, 2000)]
    for s in sqls:
        session.sql(s)
    info = session.result_cache_info()
    assert info.max_bytes == 2_000
    assert info.bytes_used <= 2_000
    assert info.size < len(sqls)  # the budget, not capacity, bounded it
    session.close()


def test_result_cache_session_capacity_and_exact_queries(catalog):
    session = Session(catalog, seed=2, config=SessionConfig(
        result_cache_size=2))
    sqls = ["SELECT COUNT(*) AS n FROM orders",
            "SELECT SUM(l_quantity) AS q FROM lineitem",
            "SELECT COUNT(*) AS n FROM lineitem"]
    for s in sqls:
        assert not session.sql(s).cached  # exact-mode answers cache too
    assert session.sql(sqls[2]).cached    # still resident
    assert not session.sql(sqls[0]).cached  # evicted by capacity 2
    assert session.result_cache_info().evictions >= 1
    session.close()


def test_equal_seed_sessions_replay_in_any_order(catalog):
    """Content-derived seeds: replay is submission-order-independent."""
    sql_a = "SELECT SUM(l_quantity) AS q FROM lineitem ERROR 10% CONFIDENCE 90%"
    sql_b = ("SELECT COUNT(*) AS n FROM lineitem WHERE l_shipdate < 2000 "
             "ERROR 10% CONFIDENCE 90%")
    s1 = Session(catalog, seed=33)
    a1, b1 = s1.sql(sql_a), s1.sql(sql_b)
    s2 = Session(catalog, seed=33)
    b2, a2 = s2.sql(sql_b), s2.sql(sql_a)  # reversed order
    assert np.array_equal(a1.result().values, a2.result().values)
    assert np.array_equal(b1.result().values, b2.result().values)
    s1.close(), s2.close()


# ---------------------------------------------------------------------------
# Subgrouping / backpressure units
# ---------------------------------------------------------------------------

def test_subgroup_by_pilot_splits_exact_and_pilot_params(catalog):
    session = Session(catalog, seed=0, config=NOCACHE_CFG)
    base = "SELECT SUM(l_quantity) AS q FROM lineitem "
    h1 = session.prepare(base + "ERROR 8% CONFIDENCE 95%")
    h2 = session.prepare(base + "ERROR 5% CONFIDENCE 90%")  # same pilot params
    h3 = session.prepare(base)                              # exact: no pilot
    subs = subgroup_by_pilot([h1, h2, h3])
    assert [len(s) for s in subs] == [2, 1]
    assert subs[0] == [h1, h2]
    session.close()


def test_runtime_in_flight_tracks_dispatch(catalog):
    session = Session(catalog, seed=0, config=NOCACHE_CFG)
    assert session.runtime.in_flight == 0
    handles = [session.submit(HERD_SQL) for _ in range(2)]
    session.drain_async()
    assert session.runtime.wait_idle(timeout=120)
    assert session.runtime.in_flight == 0
    assert all(h.status == "done" for h in handles)
    session.close()


def test_backpressure_error_is_exported():
    assert issubclass(BackpressureError, RuntimeError)


# ---------------------------------------------------------------------------
# Streaming-latency registry histograms (continuous telemetry satellite)
# ---------------------------------------------------------------------------

def test_drain_streaming_latency_lands_in_registry_histograms(catalog):
    """A drain with streaming handles observes DrainStats'
    time_to_first_frame_s / time_to_final_s into the session registry; a
    drain with no streaming handles observes neither (zeros would poison
    the quantiles)."""
    session = Session(catalog, seed=11, config=NOCACHE_CFG)
    session.submit(HERD_SQL)  # plain handle: no frames
    session.drain()
    ttff = session.metrics.histogram("pilotdb_time_to_first_frame_seconds")
    ttf = session.metrics.histogram("pilotdb_time_to_final_seconds")
    assert ttff.count == 0 and ttf.count == 0

    session.submit(HERD_SQL, stream=True)
    session.drain()
    stats = session.scheduler.last_drain
    assert stats.frames_emitted > 0
    assert ttff.count == 1 and ttf.count == 1
    assert ttff.max == pytest.approx(stats.time_to_first_frame_s)
    assert ttf.max == pytest.approx(stats.time_to_final_s)
    session.close()
