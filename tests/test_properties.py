"""Cross-cutting property tests on system invariants (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this container")
from hypothesis import given, settings, strategies as st

from repro.models.layers import mea_attention
from repro.models.linear_attn import gla_chunked_xla
from repro.models.moe import moe_ffn, moe_ffn_dense
from repro.kernels.flash_attn.ref import attention_ref


# -- attention invariants -------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), sq=st.sampled_from([7, 16, 33]),
       skv=st.sampled_from([16, 40]), window=st.sampled_from([0, 8]))
def test_mea_attention_matches_dense_reference(seed, sq, skv, window):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (1, 4, sq, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (1, 2, skv, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (1, 2, skv, 16)).astype(np.float32))
    out = mea_attention(q, k, v, causal=True, window=window,
                        q_chunk=8, kv_chunk=8)
    # dense reference with the same mask semantics
    kr = jnp.repeat(k, 2, axis=1)
    vr = jnp.repeat(v, 2, axis=1)
    qpos = np.arange(sq)[:, None]
    kpos = np.arange(skv)[None, :]
    mask = kpos <= qpos
    if window:
        mask = mask & (kpos > qpos - window)
    sc = np.einsum("bhqd,bhkd->bhqk", np.asarray(q), np.asarray(kr)) / 4.0
    sc = np.where(mask, sc, -1e30)
    p = np.exp(sc - sc.max(-1, keepdims=True))
    p /= np.maximum(p.sum(-1, keepdims=True), 1e-30)
    ref = np.einsum("bhqk,bhkd->bhqd", p, np.asarray(vr))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-3, atol=2e-3)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 50))
def test_attention_is_permutation_equivariant_over_batch(seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (3, 2, 12, 8)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (3, 2, 12, 8)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (3, 2, 12, 8)).astype(np.float32))
    perm = rng.permutation(3)
    out = np.asarray(mea_attention(q, k, v, causal=True))
    out_p = np.asarray(mea_attention(q[perm], k[perm], v[perm], causal=True))
    np.testing.assert_allclose(out[perm], out_p, rtol=1e-5, atol=1e-5)


# -- GLA invariants ---------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 50), T=st.sampled_from([40, 64]))
def test_gla_impls_agree_and_state_composes(seed, T):
    """subblock == dif, and running two halves with state threading equals
    one full pass (the decode/train consistency invariant)."""
    rng = np.random.default_rng(seed)
    mk = lambda d: jnp.asarray(rng.normal(0, 1, (1, 2, T, d)).astype(np.float32))
    q, k, v = mk(8), mk(8), mk(12)
    g = jnp.asarray(-rng.uniform(0.01, 0.5, (1, 2, T, 8)).astype(np.float32))
    o1, s1 = gla_chunked_xla(q, k, v, g, impl="dif")
    o2, s2 = gla_chunked_xla(q, k, v, g, impl="subblock")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=3e-3, atol=3e-3)
    half = T // 2
    oa, sa = gla_chunked_xla(q[:, :, :half], k[:, :, :half], v[:, :, :half],
                             g[:, :, :half], impl="dif")
    ob, sb = gla_chunked_xla(q[:, :, half:], k[:, :, half:], v[:, :, half:],
                             g[:, :, half:], impl="dif", initial_state=sa)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([oa, ob], axis=2)),
                               np.asarray(o1), rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(np.asarray(sb), np.asarray(s1), rtol=3e-3, atol=3e-3)


# -- MoE invariants ---------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_moe_dense_equals_dispatch_when_dropless(seed):
    rng = np.random.default_rng(seed)
    T, D, E, F, K = 16, 8, 4, 16, 2
    x = jnp.asarray(rng.normal(0, 1, (T, D)).astype(np.float32))
    rw = jnp.asarray(rng.normal(0, 0.3, (D, E)).astype(np.float32))
    w1 = jnp.asarray(rng.normal(0, 0.1, (E, D, F)).astype(np.float32))
    w3 = jnp.asarray(rng.normal(0, 0.1, (E, D, F)).astype(np.float32))
    w2 = jnp.asarray(rng.normal(0, 0.1, (E, F, D)).astype(np.float32))
    y_dispatch, _ = moe_ffn(x, rw, w1, w3, w2, top_k=K, capacity_factor=100.0)
    y_dense = moe_ffn_dense(x, rw, w1, w3, w2, top_k=K)
    np.testing.assert_allclose(np.asarray(y_dispatch), np.asarray(y_dense),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_moe_token_permutation_equivariance(seed):
    """Routing is per token: permuting tokens permutes outputs (dropless)."""
    rng = np.random.default_rng(seed)
    T, D, E, F, K = 12, 8, 4, 16, 2
    x = jnp.asarray(rng.normal(0, 1, (T, D)).astype(np.float32))
    rw = jnp.asarray(rng.normal(0, 0.3, (D, E)).astype(np.float32))
    w1 = jnp.asarray(rng.normal(0, 0.1, (E, D, F)).astype(np.float32))
    w3 = jnp.asarray(rng.normal(0, 0.1, (E, D, F)).astype(np.float32))
    w2 = jnp.asarray(rng.normal(0, 0.1, (E, F, D)).astype(np.float32))
    perm = rng.permutation(T)
    y = np.asarray(moe_ffn_dense(x, rw, w1, w3, w2, top_k=K))
    y_p = np.asarray(moe_ffn_dense(x[perm], rw, w1, w3, w2, top_k=K))
    np.testing.assert_allclose(y[perm], y_p, rtol=1e-4, atol=1e-4)


# -- sqrt-remat invariant ----------------------------------------------------------

def test_sqrt_remat_preserves_forward_and_gradients():
    import dataclasses

    from repro.configs import ARCHITECTURES
    from repro.models import build_model

    cfg = ARCHITECTURES["internlm2-1.8b"].reduced(num_layers=4)
    cfg_g = dataclasses.replace(cfg, remat_groups=2)
    m1, m2 = build_model(cfg), build_model(cfg_g)
    params = m1.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                          cfg.vocab_size)}
    l1, _ = m1.forward(params, batch)
    l2, _ = m2.forward(params, batch)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), rtol=1e-5, atol=1e-5)

    def loss(m):
        def f(p):
            lg, _ = m.forward(p, batch)
            return jnp.mean(lg.astype(jnp.float32) ** 2)
        return f

    g1 = jax.grad(loss(m1))(params)
    g2 = jax.grad(loss(m2))(params)
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)).max()), g1, g2)
    assert max(jax.tree.leaves(diffs)) < 1e-4


# -- serving invariant ---------------------------------------------------------------

def test_decode_batch_independence():
    """Per-slot positions: one sequence's depth must not affect another's
    output (the continuous-batching correctness property)."""
    from repro.configs import ARCHITECTURES
    from repro.models import build_model

    cfg = ARCHITECTURES["internlm2-1.8b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # batch of 2: slot 0 at depth 5, slot 1 at depth 0
    cache = model.init_cache(2, 16)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 6), 0, cfg.vocab_size)
    for t in range(5):
        _, cache = model.decode_step(params, cache, toks[:, t])
    cache = dict(cache)
    cache["pos"] = cache["pos"].at[1].set(0)  # slot 1 restarts
    lg, _ = model.decode_step(params, cache, toks[:, 5])
    # reference: fresh single-slot decode of slot 1's token at pos 0
    cache1 = model.init_cache(1, 16)
    lg_ref, _ = model.decode_step(params, cache1, toks[1:, 5])
    np.testing.assert_allclose(np.asarray(lg[1:], np.float32),
                               np.asarray(lg_ref, np.float32),
                               rtol=2e-2, atol=2e-2)
