"""Compiled physical layer vs legacy eager path vs Pallas kernels.

Parametrized property tests (hypothesis is unavailable in the CPU container)
asserting the three lowerings of the same logical plan agree bit-for-bit-ish
(atol) on grouped sums/counts and per-block pilot statistics across group
counts, block sizes, and filter selectivities including 0% and 100% — plus
the compile-cache and empty-sample contracts of the physical layer.
"""

import numpy as np
import pytest

from repro.core import CompositeAgg, ErrorSpec, PilotDB, Query
from repro.engine import logical as L
from repro.engine.datagen import tpch_catalog
from repro.engine.executor import EmptySampleError, Executor
from repro.engine.expr import And, Col
from repro.engine.physical import ScanRuntime, plan_signature

BR = 64


@pytest.fixture(scope="module")
def catalog():
    return tpch_catalog(6_000, BR, seed=0)  # 94 lineitem blocks: tiny kernels


@pytest.fixture(scope="module")
def executors(catalog):
    return {
        "compiled": Executor(catalog),
        "pallas": Executor(catalog, kernel_mode="pallas"),
        "eager": Executor(catalog, use_compiled=False),
    }


# Selectivity knobs: l_shipdate is uniform on [0, 2526).
SELECTIVITY_PREDS = {
    "0%": Col("l_shipdate") < -1,
    "50%": Col("l_shipdate") < 1263,
    "100%": Col("l_shipdate") < 99_999,
}

Q6_PRED = And(Col("l_shipdate").between(100, 1500),
              And(Col("l_discount").between(0.02, 0.08), Col("l_quantity") < 24))


def _plan(pred=None, group_by=None, max_groups=1):
    child = L.Scan("lineitem") if pred is None else L.Filter(L.Scan("lineitem"), pred)
    return L.Aggregate(
        child=child,
        aggs=(L.AggSpec("sum", Col("l_extendedprice") * Col("l_discount"), "rev"),
              L.AggSpec("count", None, "cnt"),
              L.AggSpec("avg", Col("l_quantity"), "avg_qty")),
        group_by=group_by, max_groups=max_groups)


# -- compiled vs eager: full queries ------------------------------------------

@pytest.mark.parametrize("sel", list(SELECTIVITY_PREDS))
@pytest.mark.parametrize("groups", [None, ("l_returnflag", 3)])
def test_compiled_matches_eager_exact(executors, sel, groups):
    gb, mg = groups if groups else (None, 1)
    plan = _plan(SELECTIVITY_PREDS[sel], group_by=gb, max_groups=mg)
    rc = executors["compiled"].execute(plan)
    re = executors["eager"].execute(plan)
    np.testing.assert_allclose(rc.values, re.values, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(rc.group_counts, re.group_counts)
    assert rc.scanned_bytes == re.scanned_bytes


@pytest.mark.parametrize("rate", [0.1, 0.5])
@pytest.mark.parametrize("method", ["block", "row"])
def test_compiled_matches_eager_sampled(executors, rate, method):
    plan = L.rewrite_scans(_plan(SELECTIVITY_PREDS["50%"]),
                           {"lineitem": L.SampleClause(method, rate, seed=9)})
    rc = executors["compiled"].execute(plan)
    re = executors["eager"].execute(plan)
    np.testing.assert_allclose(rc.values, re.values, rtol=1e-4, atol=1e-4)
    assert rc.scanned_bytes == re.scanned_bytes
    # identical host-side TABLESAMPLE draw
    ic, ie = rc.sample_infos["lineitem"], re.sample_infos["lineitem"]
    assert ic.n_sampled_blocks == ie.n_sampled_blocks
    assert ic.n_sampled_rows == ie.n_sampled_rows


@pytest.mark.parametrize("block_rows", [32, 200])
def test_compiled_matches_eager_across_block_sizes(block_rows):
    cat = tpch_catalog(4_000, block_rows, seed=2)
    rc = Executor(cat).execute(_plan(SELECTIVITY_PREDS["50%"]))
    re = Executor(cat, use_compiled=False).execute(_plan(SELECTIVITY_PREDS["50%"]))
    np.testing.assert_allclose(rc.values, re.values, rtol=1e-5, atol=1e-5)


# -- compiled vs eager: pilot statistics --------------------------------------

@pytest.mark.parametrize("sel", list(SELECTIVITY_PREDS))
@pytest.mark.parametrize("groups", [None, ("l_returnflag", 3)])
def test_pilot_compiled_matches_eager(executors, sel, groups):
    gb, mg = groups if groups else (None, 1)
    plan = _plan(SELECTIVITY_PREDS[sel], group_by=gb, max_groups=mg)
    sc = executors["compiled"].execute_pilot(plan, "lineitem", 0.2, seed=3)
    se = executors["eager"].execute_pilot(plan, "lineitem", 0.2, seed=3)
    assert sc.block_sums.shape == se.block_sums.shape
    np.testing.assert_allclose(sc.block_sums, se.block_sums, rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(sc.group_present, se.group_present)
    assert sc.scanned_bytes == se.scanned_bytes


def test_pilot_pair_sums_compiled_matches_eager(executors):
    plan = L.Aggregate(
        child=L.Join(L.Scan("lineitem"), L.Scan("orders"), "l_orderkey", "o_orderkey"),
        aggs=(L.AggSpec("sum", Col("l_extendedprice"), "s"),))
    sc = executors["compiled"].execute_pilot(plan, "lineitem", 0.3, seed=5,
                                             pair_tables=("orders",))
    se = executors["eager"].execute_pilot(plan, "lineitem", 0.3, seed=5,
                                          pair_tables=("orders",))
    assert set(sc.pair_sums) == {"orders"} == set(se.pair_sums)
    np.testing.assert_allclose(sc.pair_sums["orders"], se.pair_sums["orders"],
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(sc.block_sums, se.block_sums, rtol=1e-4, atol=1e-3)


# -- Pallas kernel routes vs the XLA twin -------------------------------------

def test_pallas_filtered_route_matches_xla(executors):
    plan = L.Aggregate(child=L.Filter(L.Scan("lineitem"), Q6_PRED),
                       aggs=(L.AggSpec("sum", Col("l_extendedprice") * Col("l_discount"), "rev"),
                             L.AggSpec("count", None, "cnt")))
    sp = executors["pallas"].execute_pilot(plan, "lineitem", 0.3, seed=3)
    sx = executors["compiled"].execute_pilot(plan, "lineitem", 0.3, seed=3)
    np.testing.assert_allclose(sp.block_sums, sx.block_sums, rtol=1e-4, atol=1e-4)
    routes = {c.route for c in executors["pallas"].physical._cache.values()}
    assert "pallas_filtered" in routes


def test_pallas_block_route_matches_xla(executors):
    plan = L.Aggregate(child=L.Scan("lineitem"),
                       aggs=(L.AggSpec("sum", Col("l_quantity"), "s"),
                             L.AggSpec("count", None, "c")))
    sp = executors["pallas"].execute_pilot(plan, "lineitem", 0.3, seed=4)
    sx = executors["compiled"].execute_pilot(plan, "lineitem", 0.3, seed=4)
    np.testing.assert_allclose(sp.block_sums, sx.block_sums, rtol=1e-4, atol=1e-4)
    fp = L.rewrite_scans(plan, {"lineitem": L.SampleClause("block", 0.4, 11)})
    rp = executors["pallas"].execute(fp)
    rx = executors["compiled"].execute(fp)
    np.testing.assert_allclose(rp.values, rx.values, rtol=1e-4, atol=1e-4)
    routes = {c.route for c in executors["pallas"].physical._cache.values()}
    assert "pallas_block" in routes


# -- compile cache -------------------------------------------------------------

def test_compile_cache_hits_on_repeated_plan(catalog):
    ex = Executor(catalog)
    plan = _plan(SELECTIVITY_PREDS["50%"])
    sampled = L.rewrite_scans(plan, {"lineitem": L.SampleClause("block", 0.3, 1)})
    ex.execute(sampled)
    info0 = ex.compile_cache_info()
    assert info0.misses >= 1 and info0.hits == 0
    # structurally identical query: different seed and nearby rate land in
    # the same bucketed signature — the serve-layer concurrent-users case
    ex.execute(L.rewrite_scans(plan, {"lineitem": L.SampleClause("block", 0.31, 2)}))
    info1 = ex.compile_cache_info()
    assert info1.hits == info0.hits + 1
    assert info1.misses == info0.misses
    # pilots cache across attempts/seeds too
    ex.execute_pilot(plan, "lineitem", 0.2, seed=0)
    ex.execute_pilot(plan, "lineitem", 0.2, seed=99)
    info2 = ex.compile_cache_info()
    assert info2.hits == info1.hits + 1


def test_plan_signature_strips_rates_seeds_and_constants():
    p1 = L.rewrite_scans(_plan(), {"lineitem": L.SampleClause("block", 0.1, 0)})
    p2 = L.rewrite_scans(_plan(), {"lineitem": L.SampleClause("block", 0.7, 42)})
    rt = {"lineitem": ScanRuntime("block", 10, 64, np.zeros(64, np.int32))}
    assert plan_signature(p1, rt) == plan_signature(p2, rt)
    # predicate constants are hoisted out of the key too: they enter
    # executables as the runtime params operand, so constant variants of one
    # shape share one compilation
    assert plan_signature(_plan(SELECTIVITY_PREDS["50%"]), rt) == \
        plan_signature(_plan(SELECTIVITY_PREDS["100%"]), rt)
    # ...while structural differences (Filter present vs absent) still key apart
    assert plan_signature(_plan(SELECTIVITY_PREDS["50%"]), rt) != \
        plan_signature(_plan(), rt)
    # the hoisted constants come back position-aligned with the template
    from repro.engine.physical import plan_constants
    assert plan_constants(_plan(SELECTIVITY_PREDS["50%"])).tolist() != \
        plan_constants(_plan(SELECTIVITY_PREDS["100%"])).tolist()


# -- empty-sample surfacing ----------------------------------------------------

def test_empty_sample_raises_both_paths(catalog):
    plan = L.rewrite_scans(_plan(), {"lineitem": L.SampleClause("block", 1e-9, 0)})
    for ex in (Executor(catalog), Executor(catalog, use_compiled=False)):
        with pytest.raises(EmptySampleError):
            ex.execute(plan)


def test_taqa_falls_back_exact_on_empty_final_sample(catalog, monkeypatch):
    db = PilotDB(Executor(catalog), large_table_rows=1_000)
    q = Query(child=L.Scan("lineitem"),
              aggs=(CompositeAgg("s", "sum", Col("l_quantity")),))
    real_execute = db.ex.execute

    def sabotage(plan):
        scans = plan.scans()
        if any(s.sample is not None and s.sample.method == "block" for s in scans):
            raise EmptySampleError("lineitem", "block", 0.01)
        return real_execute(plan)

    monkeypatch.setattr(db.ex, "execute", sabotage)
    ans = db.query(q, ErrorSpec(error=0.10, confidence=0.9), seed=0)
    assert ans.report.fallback is not None
    assert "final sample empty" in ans.report.fallback
    exact = db.exact(q)
    np.testing.assert_allclose(ans.values, exact.values)
