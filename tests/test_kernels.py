"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_agg import block_agg
from repro.kernels.filtered_agg import filtered_agg
from repro.kernels.flash_attn import flash_attention
from repro.kernels.gla_chunk import gla_chunked


# -- block_agg ----------------------------------------------------------------

@pytest.mark.parametrize("block_rows", [64, 128, 200])
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_block_agg_matches_ref(block_rows, dtype):
    rng = np.random.default_rng(0)
    n_blocks = 40
    if dtype == np.int32:
        col = rng.integers(0, 100, n_blocks * block_rows).astype(dtype)
    else:
        col = rng.normal(10, 3, n_blocks * block_rows).astype(dtype)
    valid = (rng.random(n_blocks * block_rows) < 0.7).astype(np.float32)
    ids = rng.choice(n_blocks, size=7, replace=False).astype(np.int32)
    a = np.asarray(block_agg(jnp.asarray(col), jnp.asarray(valid), block_rows, ids))
    b = np.asarray(block_agg(jnp.asarray(col), jnp.asarray(valid), block_rows, ids,
                             use_ref=True))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_block_agg_agrees_with_host_numpy():
    rng = np.random.default_rng(1)
    block_rows, n_blocks = 64, 20
    col = rng.normal(0, 1, n_blocks * block_rows).astype(np.float32)
    valid = np.ones(n_blocks * block_rows, np.float32)
    ids = np.array([2, 9], np.int32)
    out = np.asarray(block_agg(jnp.asarray(col), jnp.asarray(valid), block_rows, ids))
    for j, b in enumerate(ids):
        seg = col[b * block_rows:(b + 1) * block_rows]
        assert out[j, 0] == pytest.approx(block_rows)
        assert out[j, 1] == pytest.approx(seg.sum(), rel=1e-4)
        assert out[j, 2] == pytest.approx((seg ** 2).sum(), rel=1e-4)
        assert out[j, 3] == pytest.approx(seg.min(), rel=1e-5)
        assert out[j, 4] == pytest.approx(seg.max(), rel=1e-5)


def test_block_agg_empty_block_sentinel():
    """A sampled block with zero valid rows reports count=0, sum=sumsq=0 and
    min=max=NaN (the documented sentinel), in kernel and oracle alike."""
    rng = np.random.default_rng(11)
    br, nb = 64, 8
    col = rng.normal(5, 2, nb * br).astype(np.float32)
    valid = np.ones(nb * br, np.float32)
    valid[2 * br:3 * br] = 0.0  # block 2 entirely invalid
    ids = np.array([1, 2, 5], np.int32)
    for use_ref in (False, True):
        out = np.asarray(block_agg(jnp.asarray(col), jnp.asarray(valid), br, ids,
                                   use_ref=use_ref))
        assert out[1, 0] == 0.0 and out[1, 1] == 0.0 and out[1, 2] == 0.0
        assert np.isnan(out[1, 3]) and np.isnan(out[1, 4])
        # non-empty blocks keep real extrema
        assert np.isfinite(out[0, 3:5]).all() and np.isfinite(out[2, 3:5]).all()
        assert out[0, 0] == br


def test_block_agg_single_block_and_all_blocks():
    rng = np.random.default_rng(2)
    col = jnp.asarray(rng.normal(size=6 * 128).astype(np.float32))
    valid = jnp.ones(6 * 128, jnp.float32)
    for ids in (np.array([0]), np.arange(6)):
        a = np.asarray(block_agg(col, valid, 128, ids))
        b = np.asarray(block_agg(col, valid, 128, ids, use_ref=True))
        np.testing.assert_allclose(a, b, rtol=1e-5)


# -- filtered_agg --------------------------------------------------------------

@pytest.mark.parametrize("block_rows", [64, 128])
def test_filtered_agg_matches_ref(block_rows):
    rng = np.random.default_rng(3)
    n_blocks = 30
    mk = lambda: jnp.asarray(rng.normal(1, 1, n_blocks * block_rows).astype(np.float32))
    x, y, f1, f2, f3 = mk(), mk(), mk(), mk(), mk()
    valid = jnp.asarray((rng.random(n_blocks * block_rows) < 0.85).astype(np.float32))
    ids = rng.choice(n_blocks, size=9, replace=False).astype(np.int32)
    bounds = (-0.5, 1.2, 0.0, 2.5, 1.0)
    a = np.asarray(filtered_agg(x, y, f1, f2, f3, valid, block_rows, ids, bounds))
    b = np.asarray(filtered_agg(x, y, f1, f2, f3, valid, block_rows, ids, bounds,
                                use_ref=True))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_filtered_agg_empty_predicate():
    rng = np.random.default_rng(4)
    n, br = 10, 64
    mk = lambda: jnp.asarray(rng.normal(size=n * br).astype(np.float32))
    x, y, f1, f2, f3 = mk(), mk(), mk(), mk(), mk()
    valid = jnp.ones(n * br, jnp.float32)
    out = np.asarray(filtered_agg(x, y, f1, f2, f3, valid, br, np.arange(3),
                                  (5.0, 6.0, 5.0, 6.0, -100.0)))
    np.testing.assert_allclose(out, 0.0, atol=1e-6)


# -- flash attention -------------------------------------------------------------

@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("seq,d", [(64, 32), (96, 64), (128, 128)])
def test_flash_attention_matches_ref(causal, seq, d):
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(0, 1, (1, 2, seq, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (1, 2, seq, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (1, 2, seq, d)).astype(np.float32))
    a = np.asarray(flash_attention(q, k, v, causal=causal, bq=32, bk=32))
    b = np.asarray(flash_attention(q, k, v, causal=causal, use_ref=True))
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


def test_flash_attention_gqa_and_ragged_seq():
    rng = np.random.default_rng(6)
    q = jnp.asarray(rng.normal(0, 1, (2, 8, 50, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (2, 2, 70, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (2, 2, 70, 32)).astype(np.float32))
    a = np.asarray(flash_attention(q, k, v, causal=False, bq=32, bk=32))
    b = np.asarray(flash_attention(q, k, v, causal=False, use_ref=True))
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(0, 1, (1, 2, 64, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(0, 1, (1, 2, 64, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(0, 1, (1, 2, 64, 64))).astype(jnp.bfloat16)
    a = np.asarray(flash_attention(q, k, v, causal=True, bq=32, bk=32),
                   dtype=np.float32)
    b = np.asarray(flash_attention(q, k, v, causal=True, use_ref=True),
                   dtype=np.float32)
    np.testing.assert_allclose(a, b, rtol=3e-2, atol=3e-2)


# -- gla_chunk -------------------------------------------------------------------

@pytest.mark.parametrize("T,chunk", [(64, 32), (96, 32), (80, 32), (128, 64)])
@pytest.mark.parametrize("dk,dv", [(16, 32), (64, 64)])
def test_gla_chunked_matches_recurrence(T, chunk, dk, dv):
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.normal(0, 1, (1, 2, T, dk)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (1, 2, T, dk)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (1, 2, T, dv)).astype(np.float32))
    g = jnp.asarray(-rng.uniform(0.001, 0.2, (1, 2, T, dk)).astype(np.float32))
    o1, s1 = gla_chunked(q, k, v, g, chunk=chunk)
    o2, s2 = gla_chunked(q, k, v, g, use_ref=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=3e-3, atol=3e-3)


def test_gla_strong_decay_forgets_prefix():
    """With very strong decay, outputs reduce to (almost) diag-only attention."""
    rng = np.random.default_rng(9)
    T, dk, dv = 64, 8, 8
    q = jnp.asarray(rng.normal(0, 1, (1, 1, T, dk)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (1, 1, T, dk)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (1, 1, T, dv)).astype(np.float32))
    g = jnp.full((1, 1, T, dk), -8.0, jnp.float32)
    o, _ = gla_chunked(q, k, v, g, chunk=32)
    exp = np.einsum("bhtd,bhtd->bht", np.asarray(q), np.asarray(k))[..., None] * np.asarray(v)
    np.testing.assert_allclose(np.asarray(o), exp, rtol=2e-2, atol=2e-2)


def test_gla_zero_decay_is_cumulative_linear_attention():
    rng = np.random.default_rng(10)
    T, d = 32, 8
    q = jnp.asarray(rng.normal(0, 1, (1, 1, T, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (1, 1, T, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (1, 1, T, d)).astype(np.float32))
    g = jnp.zeros((1, 1, T, d), jnp.float32)
    o, s = gla_chunked(q, k, v, g, chunk=16)
    qn, kn, vn = (np.asarray(a)[0, 0] for a in (q, k, v))
    attn = np.tril(qn @ kn.T)
    np.testing.assert_allclose(np.asarray(o)[0, 0], attn @ vn, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s)[0, 0], kn.T @ vn, rtol=2e-3, atol=2e-3)
